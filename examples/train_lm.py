"""End-to-end training driver example: train a ~100M-parameter
MiniCPM-family model on the synthetic Markov corpus for a few hundred
steps with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(A smaller default profile runs in ~a minute on CPU; pass --profile
100m for the real thing.)
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.train.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    get_schedule,
)

PROFILES = {
    # ~100M params: d=768, 12 layers (MiniCPM recipe incl. WSD schedule)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 head_dim=64, d_ff=2048, vocab_size=32_000),
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                 head_dim=32, d_ff=256, vocab_size=2_048),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--profile", default="tiny", choices=PROFILES)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="results/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(get_config("minicpm-2b")), **PROFILES[args.profile]
    )
    model = build_model(cfg)
    print(f"model: {cfg.name} ({args.profile}) ~"
          f"{sum(int(np.prod(i.shape)) for i in jax.tree.leaves(model.init(jax.random.PRNGKey(0))))/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=get_schedule("wsd", 6e-4, args.steps))  # MiniCPM WSD
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq_len, args.batch))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, opt_cfg)
    start = 0
    if mgr.latest_step() is not None:  # auto-resume after preemption
        start, state = mgr.restore({"params": params, "opt": opt._asdict()})
        params, opt = state["params"], AdamWState(**state["opt"])
        print(f"resumed from step {start}")

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        p2, o2, m = adamw_update(grads, opt, params, opt_cfg)
        return p2, o2, loss, m["lr"]

    t0 = time.time()
    for i in range(start, args.steps):
        params, opt, loss, lr = step(params, opt, data.batch(i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  lr {float(lr):.2e}  "
                  f"({(time.time()-t0):.1f}s)")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt._asdict()})
    mgr.save(args.steps, {"params": params, "opt": opt._asdict()})
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
