"""Private inference served at the edge: several clients' MLP queries
multiplexed through the CMPC serving engine (shard_map Phase-2 over
host devices), with per-request SLOs and continuous batching.

Each linear layer's weights stay private to the model owner: one
:class:`~repro.serve.ServingEngine` per layer holds the encoded weight
operand, clients submit activation rows with simulated arrival times,
and the engine folds concurrent requests into in-flight protocol
replays.  The nonlinearity (ReLU) runs in the clear at each client
between layers — the classic interactive-MPC split — so a client's
layer-2 request arrives exactly when its layer-1 response completes.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/private_inference.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core.constructions import PlanConfig  # noqa: E402
from repro.core.gf import Field  # noqa: E402
from repro.runtime.pool import ShiftedExponential, sample_trace  # noqa: E402
from repro.serve import ServingEngine  # noqa: E402

N_CLIENTS = 6
POOL = 20
SLO = 25.0


def make_engine(w, traces, mesh, field):
    """One serving engine per private layer operand."""
    return ServingEngine(
        w,
        traces,
        PlanConfig("age", s=2, t=2, z=2),
        field=field,
        mesh=mesh,
        slo=SLO,
        validate=True,  # every decode checked against the field oracle
        seed=0,
    )


def main():
    field = Field()
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    rng = np.random.default_rng(7)

    # a tiny 2-layer MLP; weights private to the model owner, activations
    # private to each querying client
    w1 = rng.normal(size=(16, 32)) * 0.5
    w2 = rng.normal(size=(32, 8)) * 0.5
    xs = [rng.normal(size=(4, 16)) for _ in range(N_CLIENTS)]  # [rows, k]
    arrivals = np.cumsum(rng.exponential(0.4, N_CLIENTS))

    # one replayable trace per protocol launch: heterogeneous edge pool
    traces = [
        sample_trace(POOL, ShiftedExponential(0.1, 0.5), seed=i, net_scale=0.3)
        for i in range(8)
    ]

    eng1 = make_engine(w1, traces, mesh, field)
    reqs1 = [eng1.submit(x, float(t)) for x, t in zip(xs, arrivals)]
    eng1.run()

    # ReLU in the clear at each client; the layer-2 request arrives the
    # moment the client holds its layer-1 response.
    eng2 = make_engine(w2, traces, mesh, field)
    reqs2 = [
        eng2.submit(np.maximum(r.y, 0.0), r.completion) for r in reqs1
    ]
    rep2 = eng2.run()

    # one workload-level relative error, as the single-batch original:
    # worst absolute deviation over every client, against the workload's
    # output magnitude
    refs = [np.maximum(x @ w1, 0.0) @ w2 for x in xs]
    abs_err = max(np.abs(r2.y - ref).max() for r2, ref in zip(reqs2, refs))
    worst = abs_err / (max(np.abs(ref).max() for ref in refs) + 1e-9)

    e2e = [r2.completion - r1.arrival for r1, r2 in zip(reqs1, reqs2)]
    s1, s2 = eng1.report().summary(), rep2.summary()
    print(f"devices as workers: {len(jax.devices())}")
    print(
        f"{N_CLIENTS} clients through a private 2-layer MLP: "
        f"{s1['replays']} + {s2['replays']} protocol replays "
        f"(continuous batching folded concurrent clients)"
    )
    print(
        f"layer latency p95: {s1['p95_latency']:.2f}s / "
        f"{s2['p95_latency']:.2f}s, end-to-end worst {max(e2e):.2f}s, "
        f"deadline misses {s1['deadline_misses'] + s2['deadline_misses']}"
    )
    print(
        f"relative error vs cleartext: {worst:.4f} "
        "(16-bit fixed point; use secure_matmul_crt for ~2e-3)"
    )
    assert all(r.y is not None for r in reqs2), "a request was shed"
    assert worst < 0.15

if __name__ == "__main__":
    main()
