"""Private inference at the edge: an MLP whose linear layers run under
AGE-CMPC across simulated edge workers (shard_map over host devices),
with straggler dropout in both protocol phases.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/private_inference.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import protocol as proto  # noqa: E402
from repro.core.constructions import PlanConfig  # noqa: E402
from repro.core.distributed import run_phase2_sharded  # noqa: E402
from repro.core.gf import Field  # noqa: E402
from repro.core.planner import BlockShapes, get_plan_for  # noqa: E402


def secure_layer_distributed(x, w, mesh, field, z=2, drop_worker=None):
    """One y = x @ W layer under CMPC with workers sharded on the mesh."""
    s = t = 2
    k, batch = x.shape[0], x.shape[1]
    config = PlanConfig("age", s=s, t=t, z=z, n_spare=3)
    plan = get_plan_for(
        config, BlockShapes(k=k, ma=batch, mb=w.shape[1], s=s, t=t)
    )
    from repro.core.layers import choose_scales

    scale = choose_scales(k, float(np.abs(x).max()), float(np.abs(w).max()), field.p)
    aq = field.encode(x, scale)
    bq = field.encode(w, scale)
    rng = np.random.default_rng(0)
    fa = proto.share_a(plan, aq, rng)
    fb = proto.share_b(plan, bq, rng)
    noise = field.random(rng, (plan.n_workers, z) + plan.shapes.blk_y)
    i_evals = run_phase2_sharded(plan, fa, fb, noise, mesh, mode="psum_scatter")
    # Phase 3: master decodes from any t^2 + z workers; drop a straggler
    ids = [i for i in range(plan.n_total) if i != drop_worker][: plan.decode_threshold]
    yq = proto.reconstruct(plan, i_evals, worker_ids=ids)
    return field.decode(yq, scale * scale)


def main():
    field = Field()
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    rng = np.random.default_rng(7)

    # a tiny 2-layer MLP; weights private to the model owner, activations
    # private to the querying client
    w1 = rng.normal(size=(16, 32)) * 0.5
    w2 = rng.normal(size=(32, 8)) * 0.5
    x = rng.normal(size=(16, 4))  # [features, batch] -> "A"

    h = secure_layer_distributed(x, w1, mesh, field, drop_worker=1)
    h = np.maximum(h, 0.0)  # ReLU in the clear at the client
    y = secure_layer_distributed(h.T, w2, mesh, field, drop_worker=0)

    ref = np.maximum(x.T @ w1, 0.0) @ w2
    err = np.abs(y - ref).max() / (np.abs(ref).max() + 1e-9)
    print(f"devices as workers: {len(jax.devices())}")
    print(f"private 2-layer MLP inference, straggler dropped each layer")
    print(f"relative error vs cleartext: {err:.4f} "
          "(16-bit fixed point; use secure_matmul_crt for ~2e-3)")
    assert err < 0.15


if __name__ == "__main__":
    main()
