"""Quickstart: privacy-preserving matrix multiplication with AGE-CMPC.

Two sources hold private matrices A and B; N edge workers compute
Y = A^T B without any z-subset of them (or the master) learning the
inputs.  Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import closed_form as cf
from repro.core import constructions as C
from repro.core.constructions import PlanConfig
from repro.core.gf import Field
from repro.core.layers import secure_matmul, secure_matmul_batched
from repro.core.planner import BlockShapes, get_plan_for, plan_cache_info
from repro.core import protocol


def main():
    s, t, z = 2, 2, 2  # partitions + collusion tolerance (paper Example 1)

    print("=== worker counts (s=2, t=2, z=2) ===")
    print(f"AGE-CMPC      : {cf.n_age_exact(s, t, z)[0]} workers (lambda* = {cf.n_age_exact(s, t, z)[1]})")
    print(f"PolyDot-CMPC  : {C.polydot_cmpc(s, t, z).n_workers}")
    print(f"Entangled-CMPC: {cf.n_entangled(s, t, z)}")
    print(f"SSMM          : {cf.n_ssmm(s, t, z)}")
    print(f"GCSA-NA       : {cf.n_gcsa_na(s, t, z)}")

    # --- exact field computation --------------------------------------
    # PlanConfig is the declarative entry point: name the construction
    # and its parameters, and get_plan_for builds (and caches) the plan.
    field = Field()
    rng = np.random.default_rng(0)
    m = 64
    a = field.random(rng, (m, m))
    b = field.random(rng, (m, m))
    config = PlanConfig("age", s=s, t=t, z=z, n_spare=2)
    plan = get_plan_for(config, BlockShapes(k=m, ma=m, mb=m, s=s, t=t))
    y, trace = protocol.run(plan, a, b)
    assert np.array_equal(y, field.matmul(a.T, b))
    pred = cf.predict(config, m)
    print(f"\nGF(p) protocol [{config.label()}]: N={plan.n_workers} "
          f"(+{config.n_spare} spares), exact result verified; "
          f"{trace.total:,} field elements moved "
          f"(closed form: {pred.comm:,} across all phases)")

    # --- batched device-resident engine -------------------------------
    batch = 8
    ab = field.random(rng, (batch, m, m))
    bb = field.random(rng, (batch, m, m))
    yb, traceb = protocol.run_batched(plan, ab, bb)
    for i in range(batch):
        assert np.array_equal(yb[i], field.matmul(ab[i].T, bb[i]))
    print(f"batched protocol: {batch} products in one jitted pipeline, "
          f"exact; {traceb.total:,} field elements moved")

    # --- real-valued wrapper ------------------------------------------
    x = rng.normal(size=(32, 16))
    w = rng.normal(size=(32, 8))
    res = secure_matmul(x, w, s=s, t=t, z=z)
    err = np.abs(res.y - x.T @ w).max()
    print(f"real-valued secure_matmul: max |err| = {err:.4f} (fixed-point)")

    # --- batched real-valued wrapper (one weight, many activations) ---
    xs = rng.normal(size=(batch, 32, 16))
    resb = secure_matmul_batched(xs, w, s=s, t=t, z=z)
    errb = max(np.abs(resb.y[i] - xs[i].T @ w).max() for i in range(batch))
    ci = plan_cache_info()
    print(f"batched secure_matmul: max |err| = {errb:.4f}; "
          f"plan cache: {ci['hits']} hits / {ci['misses']} misses")


if __name__ == "__main__":
    main()
