"""Batched serving example: prefill a batch of prompts, then decode with
temperature sampling against the KV cache — the serve-path used by the
decode_32k / long_500k dry-run cells, at toy scale.

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import build_model


def sample(logits, vocab, rng, temperature=0.8):
    """Temperature sampling, vectorized over the batch: one inverse-CDF
    draw per row instead of a per-row ``rng.choice`` loop."""
    logits = np.asarray(logits[:, -1, :vocab], np.float32) / temperature
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    cum = probs.cumsum(-1)
    u = rng.random((probs.shape[0], 1)) * cum[:, -1:]
    return np.minimum((cum < u).sum(-1), vocab - 1).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("pick a decoder-family arch for this example")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    b = args.batch
    max_len = args.prompt_len + args.gen_len
    prompts = rng.integers(0, cfg.vocab_size, (b, args.prompt_len)).astype(np.int32)
    cache = model.init_cache(b, max_len)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = rng.normal(size=(b, 4, cfg.d_model)).astype(np.float32)
        # patches occupy cache slots before the text
        cache = model.init_cache(b, max_len + 4)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    t_prefill = time.time() - t0

    offset = 4 if cfg.family == "vlm" else 0
    tok = sample(logits, cfg.vocab_size, rng)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen_len - 1):
        pos = np.full((b, 1), offset + args.prompt_len + i, np.int32)
        logits, cache = decode(params, tok[:, None], cache, pos)
        tok = sample(logits, cfg.vocab_size, rng)
        generated.append(tok)
    dt = time.time() - t0
    gen = np.stack(generated, axis=1)
    print(f"arch={args.arch} family={cfg.family}")
    print(f"prefill {args.prompt_len} toks x{b}: {t_prefill*1e3:.1f} ms")
    print(f"decode  {args.gen_len} steps x{b}: {dt*1e3:.1f} ms "
          f"({dt/args.gen_len*1e3:.2f} ms/step)")
    print("sampled token ids (seq 0):", gen[0][:16], "...")


if __name__ == "__main__":
    main()
