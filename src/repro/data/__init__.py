"""Deterministic synthetic data pipeline."""
