"""Deterministic synthetic data pipeline.

Step-indexed PRNG makes every batch a pure function of (seed, step,
shard), so training is bit-reproducible across restarts and elastic
re-shardings: after restoring a checkpoint at step k the pipeline
resumes from batch k with no state to save.  Host sharding follows the
(process_index, process_count) contract so multi-host launches read
disjoint shards.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Markov-chain synthetic text: learnable structure so loss can fall
    order_bias: float = 0.8


class SyntheticLM:
    """Zipfian tokens with a first-order Markov structure (so a model
    trained on it has signal to fit — loss decreases measurably)."""

    def __init__(self, cfg: DataConfig, process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        if cfg.global_batch % process_count:
            raise ValueError("global_batch must divide process_count")
        self.local_batch = cfg.global_batch // process_count
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self._zipf = 1.0 / np.arange(1, v + 1)
        self._zipf /= self._zipf.sum()
        self._perm = base.permutation(v)  # next-token mapping

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.cfg.seed, step, self.process_index, 0xD47A)
        )
        b, t, v = self.local_batch, self.cfg.seq_len, self.cfg.vocab_size
        toks = np.empty((b, t), np.int32)
        toks[:, 0] = rng.choice(v, size=b, p=self._zipf)
        flips = rng.random((b, t)) < self.cfg.order_bias
        rand = rng.choice(v, size=(b, t), p=self._zipf)
        for i in range(1, t):
            toks[:, i] = np.where(flips[:, i], self._perm[toks[:, i - 1]], rand[:, i])
        labels = np.concatenate([toks[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
