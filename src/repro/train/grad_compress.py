"""Gradient compression for cross-pod all-reduce.

int8 block-quantised gradients with error feedback: each step the
residual between the true gradient and its quantised transport is
carried locally and added back before the next quantisation, so the
compression bias telescopes away (convergence-preserving at 4x fewer
bytes on the slow pod-interconnect links).

Usage inside a train step (see launch/train.py):

    g_q, new_err = compress_with_feedback(grads, err)
    g_sync = psum(decompress(g_q)) / axis_size      # 1 byte/elem on wire
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: Any  # int8 tree
    scale: Any  # f32 per-block scales


def _blockify(x: jnp.ndarray) -> jnp.ndarray:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK)


def compress(tree) -> Compressed:
    def one(x):
        b = _blockify(x.astype(jnp.float32))
        scale = jnp.max(jnp.abs(b), axis=1, keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
        return q, scale

    qs = jax.tree.map(lambda x: one(x)[0], tree)
    ss = jax.tree.map(lambda x: one(x)[1], tree)
    return Compressed(q=qs, scale=ss)


def decompress(comp: Compressed, like) -> Any:
    def one(q, s, ref):
        flat = (q.astype(jnp.float32) * s).reshape(-1)[: ref.size]
        return flat.reshape(ref.shape)

    return jax.tree.map(one, comp.q, comp.scale, like)


def init_error(params) -> Any:
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)


def compress_with_feedback(grads, error) -> Tuple[Compressed, Any]:
    """Quantise (grads + carried error); return compressed + new error."""
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, error)
    comp = compress(corrected)
    recon = decompress(comp, corrected)
    new_error = jax.tree.map(lambda c, r: c - r, corrected, recon)
    return comp, new_error
