"""Training substrate: optimizer, schedules, gradient compression."""
