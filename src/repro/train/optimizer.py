"""Optimizers and LR schedules (pure-JAX, no external deps).

AdamW with decoupled weight decay, global-norm clipping, and the
schedules the assigned recipes call for: cosine (default) and WSD
(warmup-stable-decay, the MiniCPM schedule).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # leaves whose path matches any of these substrings skip weight decay
    no_decay: tuple = ("norm", "bias", "b_", "ln_", "a_log", "dt_bias", "d_skip")


def _decay_mask(params, no_decay) -> Any:
    def leaf(path, x):
        joined = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        ).lower()
        return not any(s in joined for s in no_decay)

    return jax.tree_util.tree_map_with_path(leaf, params)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr(step)
    mask = _decay_mask(params, cfg.no_decay)

    def upd(p, m, v, decay):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if decay else 0.0
        return (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu, mask)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), metrics


# ----------------------------------------------------------------------
# schedules
# ----------------------------------------------------------------------
def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def wsd_schedule(peak: float, warmup: int, stable: int, decay: int, floor: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM): linear warmup, long flat stage,
    short exponential-ish decay to ``floor * peak``."""

    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        in_decay = step - (warmup + stable)
        frac = jnp.clip(in_decay / max(decay, 1), 0.0, 1.0)
        dec = peak * jnp.exp(jnp.log(floor) * frac)
        out = jnp.where(step < warmup, warm, peak)
        return jnp.where(in_decay > 0, dec, out)

    return lr


def get_schedule(name: str, peak: float, total: int, warmup: Optional[int] = None):
    warmup = warmup if warmup is not None else max(total // 50, 10)
    if name == "cosine":
        return cosine_schedule(peak, warmup, total)
    if name == "wsd":
        decay = max(total // 10, 10)
        return wsd_schedule(peak, warmup, total - warmup - decay, decay)
    raise KeyError(name)
