"""Fault-tolerant checkpointing.

* atomic: writes to ``<dir>/tmp.<step>`` then ``os.replace`` into place,
  so a preemption mid-write never corrupts the latest checkpoint,
* self-describing: flat ``{path: array}`` npz + a JSON manifest with
  step / config fingerprint,
* keep-last-k garbage collection,
* topology-agnostic restore: arrays are saved unsharded (host gather)
  and re-placed with ``jax.device_put`` under the *current* mesh's
  shardings, so a run checkpointed on mesh (16,16) restores onto (2,16,16)
  or a differently-sized elastic mesh unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    def leaf(path, ref):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {ref.shape}")
        return arr.astype(ref.dtype)

    return jax.tree_util.tree_map_with_path(leaf, template)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], meta: Optional[dict] = None):
        tmp = os.path.join(self.directory, f"tmp.{step}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "keys": sorted(flat), **(meta or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"))

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def restore(
        self,
        template: Dict[str, Any],
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[int, Dict[str, Any]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            flat = {k: npz[k] for k in npz.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings
            )
        return step, state
