"""Fault-tolerant checkpointing."""
