"""Adaptive construction auto-planner: scheme selection as a feedback loop.

The static benchmark question "which construction is fastest on this
pool?" becomes a control problem once the pool itself drifts — links
degrade mid-stream, workers join and leave between replays.  This
module closes the loop:

1. every replay's :class:`~repro.runtime.metrics.RunMetrics` is
   projected onto the master-observable :class:`ObservedRun` record,
2. a sliding window of records is fitted into a
   :class:`~repro.runtime.metrics.PoolEstimate` (shifted-exponential
   straggler tails per protocol leg, dropout/crash/corruption rates),
3. candidate :class:`~repro.core.constructions.PlanConfig`\\ s are
   scored by the estimate's order-statistic completion model — the
   closed-form prior — blended with the candidate's own observed
   completion percentiles,
4. the winner is re-fitted to the current pool (``fit_to_pool`` spare
   re-accounting) and executed; the plan cache's replan fast path makes
   a spares-only refit nearly free.

Scoring starts from :data:`~repro.runtime.metrics.DEFAULT_ESTIMATE`
(unit-scale exponentials), under which candidates rank purely by how
deep into the pool's order-statistic tail they reach — small Phase-2
sets and small decode thresholds win.  Observations then reshape both
the fitted tails (re-ranking every candidate, even never-run ones) and
the per-candidate blend.  An exploration pass gives each candidate
whose prior is within ``explore_ratio`` of the best a single trial
before the planner settles, so the blend has real data to work with;
clearly dominated candidates are never executed.

``run_adaptive_over_pool`` drives the loop replay-by-replay over a
trace sequence or an :class:`~repro.runtime.pool.ElasticPool`;
``run_pipeline_over_pool(..., planner=...)`` makes the same decisions
at replay boundaries *inside* the pipeline, switching constructions
mid-stream.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core.closed_form import predict
from ..core.constructions import PlanConfig
from ..core.planner import BlockShapes, CMPCPlan, get_plan_for
from ..obs.metrics import REGISTRY
from ..obs.tracer import TRACER
from .metrics import (
    DEFAULT_ESTIMATE,
    ObservedRun,
    PoolEstimate,
    RunMetrics,
    estimate_pool,
    observed_run,
    order_stat_mean,
)
from .pool import ElasticPool, WorkerTrace
from .scheduler import BatchEdgeRun, run_batch_over_pool


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """One replay's planning outcome."""

    replay: int
    config: PlanConfig  # resolved and pool-fitted (n_spare accounted)
    pool_size: int
    predicted: float  # blended completion score of the winner
    reason: str  # "prior" | "explore" | "observed" | "forced"
    switched: bool  # construction differs from the previous replay
    respared: bool  # only the spare count changed
    # Trace id of this decision's ``autoplan.decide`` event (0 when the
    # tracer was disabled).  The runtimes echo it as ``decision_id`` on
    # the replay span this decision produced, so a trace links every
    # replay back to the reasoning that picked its construction.
    obs_id: int = 0


def _replay_seed(seed: int, k: int) -> int:
    """Deterministic, decorrelated per-replay integer seed."""
    return int(np.random.default_rng([seed, k]).integers(2**31 - 1))


class AutoPlanner:
    """Feedback-driven construction selection across replays.

    ``candidates`` are the PlanConfigs the planner may choose between
    (their ``n_spare`` is ignored — spares are re-fitted to each
    pool).  ``window`` bounds the estimator's memory so a degrading
    pool re-ranks candidates instead of averaging the past away;
    ``explore_ratio`` bounds how bad a prior score may be (relative to
    the best) and still earn an exploratory trial.

    ``cost_m``: when set (the problem's matrix dimension), each
    candidate's compute leg is weighted by its Corollary-10 per-worker
    work relative to the first candidate
    (:func:`~repro.core.closed_form.predict` /
    ``CostPrediction.compute_factor``) — the closed-form cost model
    folded into the prior.  Use it with runtimes that scale
    ``compute_delay`` the same way (``compute_scale``), where a trace's
    delay is time per unit work; observed set times are normalized by
    the same factor before entering the order-stat fit, so runs of
    *different* constructions still train one pool estimate.

    ``decode_mode``: the runtime's corruption-handling strategy the
    planner prices and tunes.  ``"detect"`` prices the decode wait one
    confirming witness deeper once corruption is observed; ``"correct"``
    prices the Berlekamp-Welch wait ``thr + 2e`` with the error budget
    ``e`` fitted from the observed corruption rate
    (:meth:`error_budget`); ``"auto"`` prices whichever is cheaper per
    candidate.
    """

    def __init__(
        self,
        candidates: Sequence[PlanConfig],
        window: int = 12,
        explore_ratio: float = 2.0,
        cost_m: Optional[int] = None,
        decode_mode: str = "detect",
    ):
        if not candidates:
            raise ValueError("need at least one candidate PlanConfig")
        if decode_mode not in ("detect", "correct", "auto"):
            raise ValueError(
                f"decode_mode must be 'detect', 'correct', or 'auto', "
                f"got {decode_mode!r}"
            )
        self.decode_mode = decode_mode
        seen: Dict[str, PlanConfig] = {}
        for c in candidates:
            seen.setdefault(c.resolved().label(), c.resolved())
        self.candidates = tuple(seen.values())
        self.window = int(window)
        self.explore_ratio = float(explore_ratio)
        self.cost_m = cost_m
        self._wf: Dict[str, float] = {}
        if cost_m is not None:
            ref = predict(self.candidates[0], cost_m)
            self._wf = {
                c.label(): predict(c, cost_m).compute_factor(ref)
                for c in self.candidates
            }
        self._runs: deque = deque(maxlen=self.window)
        # Observed completions are conditioned on the pool size they ran
        # on — a median from a 40-worker pool says nothing about the
        # same construction on 16 workers — so the per-candidate windows
        # are keyed by (label, pool size).  A pool resize therefore
        # hands ranking back to the fitted model (plus one exploration
        # pass at the new size) instead of trusting stale medians.
        self._obs: Dict[tuple, deque] = {}
        self.decisions: List[PlanDecision] = []

    # -- state ---------------------------------------------------------
    @property
    def n_switches(self) -> int:
        """Construction switches (method/s/t/z/lam) across decisions."""
        return sum(d.switched for d in self.decisions)

    @property
    def n_respares(self) -> int:
        """Spares-only refits (same construction, resized pool)."""
        return sum(d.respared for d in self.decisions)

    def estimate(self) -> PoolEstimate:
        """Current fitted pool estimate (windowed observations)."""
        return estimate_pool(self._runs)

    def work_factor(self, config: PlanConfig) -> float:
        """Per-worker compute weight of a candidate (1.0 unless the
        planner was built with ``cost_m``)."""
        return self._wf.get(config.resolved().label(), 1.0)

    def _obs_for(self, config: PlanConfig, pool_size: int) -> deque:
        key = (config.resolved().label(), int(pool_size))
        return self._obs.setdefault(key, deque(maxlen=self.window))

    # -- corruption tuning ---------------------------------------------
    def verify_extras_for(self, est: Optional[PoolEstimate] = None) -> int:
        """Confirming witnesses the planner would demand in ``"detect"``
        mode: one as soon as any corruption has been observed."""
        est = est or self.estimate()
        return 1 if est.corrupt_rate > 0 else 0

    def error_budget(
        self, config: PlanConfig, pool_size: int,
        est: Optional[PoolEstimate] = None,
    ) -> int:
        """Error budget ``e`` the planner would provision for a
        ``"correct"``-mode replay of ``config`` on ``pool_size``:
        the expected corrupt responder count under the fitted
        corruption rate, capped at what the pool can afford
        (``(pool_size - thr) // 2``)."""
        est = est or self.estimate()
        if est.corrupt_rate <= 0:
            return 0
        n_live = int(np.floor(pool_size * (1.0 - est.dropout_rate)))
        n_recv = int(np.floor(n_live * (1.0 - est.crash_rate)))
        cap = (pool_size - config.decode_threshold) // 2
        want = int(np.ceil(est.corrupt_rate * n_recv))
        return max(0, min(want, cap))

    # -- scoring -------------------------------------------------------
    def _threshold(
        self, config: PlanConfig, est: PoolEstimate, pool_size: int
    ) -> int:
        # Price of the decode wait under the planner's decode mode.
        # "detect": corruption observed -> the master withholds
        # acceptance for a confirming witness, one responder deeper
        # into the tail.  "correct": the BW decode waits for
        # thr + 2e responders at the fitted budget.  "auto": whichever
        # wait is shallower (the runtime resolves the same way).
        thr = config.decode_threshold
        detect = thr + self.verify_extras_for(est)
        if self.decode_mode == "detect":
            return detect
        correct = thr + 2 * self.error_budget(config, pool_size, est)
        if self.decode_mode == "correct":
            return correct
        return min(detect, correct)

    def _model(
        self, config: PlanConfig, pool_size: int, est: PoolEstimate
    ) -> float:
        """Order-stat completion model, compute leg weighted by the
        candidate's closed-form work factor (the fitted ready leg is in
        reference work units — see ``observe``)."""
        n_live = int(np.floor(pool_size * (1.0 - est.dropout_rate)))
        if config.n_workers > n_live:
            return float("inf")
        t_set = self.work_factor(config) * order_stat_mean(
            config.n_workers, n_live, est.ready_shift, est.ready_scale
        )
        n_recv = int(np.floor(n_live * (1.0 - est.crash_rate)))
        thr = self._threshold(config, est, pool_size)
        if thr > n_recv:
            return float("inf")
        return t_set + order_stat_mean(
            thr, n_recv, est.resp_shift, est.resp_scale
        )

    def score(
        self, config: PlanConfig, pool_size: int, est: Optional[PoolEstimate] = None
    ) -> float:
        """Blended expected completion of ``config`` on ``pool_size``.

        The closed-form prior is the order-statistic model under the
        fitted estimate; each windowed observation of this exact
        construction *on this pool size* pulls the score toward the
        observed median with weight n/(n+1).  Infeasible configs score
        ``inf``.
        """
        est = est or self.estimate()
        model = self._model(config, pool_size, est)
        if not np.isfinite(model):
            return float("inf")
        obs = self._obs_for(config, pool_size)
        if not obs:
            return model
        p50 = float(np.percentile(list(obs), 50))
        return (model + len(obs) * p50) / (1 + len(obs))

    # -- the loop ------------------------------------------------------
    def decide(self, pool_size: int) -> PlanDecision:
        """Pick the construction for the next replay on ``pool_size``."""
        est = self.estimate()
        prior = {
            c.label(): self._model(c, pool_size, est) for c in self.candidates
        }
        feasible = [c for c in self.candidates if np.isfinite(prior[c.label()])]
        if not feasible:
            raise ValueError(
                f"no candidate construction fits a pool of {pool_size} "
                f"workers (candidates need "
                f"{[c.n_workers for c in self.candidates]})"
            )
        best_prior = min(prior[c.label()] for c in feasible)
        unexplored = [
            c
            for c in feasible
            if not self._obs_for(c, pool_size)
            and prior[c.label()] <= self.explore_ratio * best_prior
        ]
        if unexplored:
            pick = min(unexplored, key=lambda c: prior[c.label()])
            reason = "explore"
        else:
            pick = min(feasible, key=lambda c: self.score(c, pool_size, est))
            reason = "observed" if self._obs_for(pick, pool_size) else "prior"

        prev = self.decisions[-1] if self.decisions else None
        switched = False
        respared = False
        if prev is not None:
            prev_base = prev.config.replace(n_spare=0)
            if prev_base.label() != pick.label():
                switched = True
                if not np.isfinite(self._model(prev_base, pool_size, est)):
                    reason = "forced"  # the old construction no longer fits
            elif prev.pool_size != pool_size:
                respared = True
        decision = PlanDecision(
            replay=len(self.decisions),
            config=pick.fit_to_pool(pool_size),
            pool_size=pool_size,
            predicted=self.score(pick, pool_size, est),
            reason=reason,
            switched=switched,
            respared=respared,
        )
        REGISTRY.counter("autoplan.decisions").inc()
        REGISTRY.counter(f"autoplan.reason.{reason}").inc()
        if TRACER.enabled:
            eid = TRACER.event(
                "autoplan.decide",
                replay=decision.replay,
                config=decision.config.label(),
                n_spare=decision.config.n_spare,
                pool=pool_size,
                predicted=float(decision.predicted),
                reason=reason,
                switched=switched,
                respared=respared,
            )
            decision = dataclasses.replace(decision, obs_id=eid)
        self.decisions.append(decision)
        return decision

    def observe(
        self, config: PlanConfig, metrics: RunMetrics, start: float = 0.0
    ) -> ObservedRun:
        """Feed one replay's outcome back into the estimator.

        The set time enters the shared order-stat fit normalized by the
        construction's work factor, so runs of heavy- and light-work
        candidates train one estimate in reference work units.
        """
        rec = observed_run(metrics, start)
        wf = self.work_factor(config)
        if wf != 1.0 and wf > 0:
            rec = dataclasses.replace(rec, set_time=rec.set_time / wf)
        self._runs.append(rec)
        if any(config.resolved().label() == c.label() for c in self.candidates):
            self._obs_for(config, rec.n_pool).append(rec.completion)
        return rec

    def summary(self) -> dict:
        """JSON-friendly account of every decision (benchmark output)."""
        est = self.estimate()
        return {
            "candidates": [c.label() for c in self.candidates],
            "replays": [
                {
                    "replay": d.replay,
                    "config": d.config.label(),
                    "n_spare": d.config.n_spare,
                    "pool": d.pool_size,
                    "predicted": d.predicted,
                    "reason": d.reason,
                    "switched": d.switched,
                }
                for d in self.decisions
            ],
            "switches": self.n_switches,
            "respares": self.n_respares,
            "decode_mode": self.decode_mode,
            "estimate": {
                "ready_shift": est.ready_shift,
                "ready_scale": est.ready_scale,
                "resp_shift": est.resp_shift,
                "resp_scale": est.resp_scale,
                "dropout_rate": est.dropout_rate,
                "crash_rate": est.crash_rate,
                "corrupt_rate": est.corrupt_rate,
                "n_runs": est.n_runs,
            },
        }


def plan_for_decision(
    decision: PlanDecision,
    k: int,
    ma: int,
    mb: int,
    field=None,
    seed: int = 0,
) -> CMPCPlan:
    """Materialize a decision into a (cached) plan for global operand
    dims ``Y[ma, mb] = A[k, ma]^T B[k, mb]`` — the block shapes follow
    the chosen construction's (s, t)."""
    cfg = decision.config
    shapes = BlockShapes(k=k, ma=ma, mb=mb, s=cfg.s, t=cfg.t)
    return get_plan_for(cfg, shapes, field=field, seed=seed)


@dataclasses.dataclass
class AdaptiveRun:
    """Result of an auto-planned replay sequence."""

    y: np.ndarray  # [K, batch, ma, mb]
    replay_metrics: List[RunMetrics]
    decisions: List[PlanDecision]
    planner: AutoPlanner


def run_adaptive_over_pool(
    planner: AutoPlanner,
    a: np.ndarray,
    b: np.ndarray,
    traces: Union[Sequence[WorkerTrace], ElasticPool],
    seed: int = 0,
    verify_extras="auto",
    master_decode_cost: float = 0.0,
    field=None,
    plan_seed: int = 0,
    compute_scale="auto",
    decode_mode: str = "detect",
) -> AdaptiveRun:
    """Replay-by-replay feedback loop over a (possibly elastic) pool.

    a: [K, batch, k, ma], b: [K, batch, k, mb] ([K, k, m] promotes to
    batch 1) — *global* operand dims, so every candidate construction
    computes the same products regardless of its block split.
    ``traces`` is one :class:`WorkerTrace` per replay or an
    :class:`ElasticPool`; pool sizes may differ between replays, which
    is exactly what the planner's ``fit_to_pool`` spare re-accounting
    (and the plan cache's replan fast path) absorb.  Each replay runs
    the batched engine (:func:`run_batch_over_pool`) under the
    construction the planner picked from everything observed so far.

    ``compute_scale``: ``"auto"`` scales each replay's worker compute
    by the chosen construction's work factor (1.0 for planners without
    ``cost_m``); a float forces one scale for every replay.

    ``decode_mode``: the corruption-handling strategy, *tuned per
    replay* by the planner: the error budget for ``"correct"``/
    ``"auto"`` comes from :meth:`AutoPlanner.error_budget` (the fitted
    corruption rate), and once corruption has been observed the planner
    forces at least one confirming witness in ``"detect"`` mode even
    when ``verify_extras="auto"`` would resolve lower.  Until the
    planner has observations, both fall back to the trace's configured
    fault model.
    """
    traces = list(traces)
    if not traces:
        raise ValueError("need at least one trace/replay")
    a = np.asarray(a)
    b = np.asarray(b)
    if a.ndim == 3:
        a = a[:, None]
    if b.ndim == 3:
        b = b[:, None]
    if a.ndim != 4 or b.ndim != 4:
        raise ValueError(
            f"expected [K, batch, k, m] operand stacks, got {a.shape} {b.shape}"
        )
    if a.shape[0] != len(traces) or b.shape[0] != len(traces):
        raise ValueError(
            f"{len(traces)} traces but operand stacks of depth "
            f"{a.shape[0]} / {b.shape[0]}"
        )
    gk, ma = int(a.shape[2]), int(a.shape[3])
    mb = int(b.shape[3])

    ys = []
    replay_metrics: List[RunMetrics] = []
    for idx, trace in enumerate(traces):
        decision = planner.decide(trace.n)
        plan = plan_for_decision(
            decision, gk, ma, mb, field=field, seed=plan_seed
        )
        scale = (
            planner.work_factor(decision.config)
            if compute_scale == "auto"
            else float(compute_scale)
        )
        # Planner-tuned corruption handling: once the estimator has
        # seen corruption, its fitted rate sets the error budget
        # (correct) and forces a confirming witness (detect); with no
        # observations yet, "auto" falls back to the trace's configured
        # fault model inside the runtime.
        e_k = planner.error_budget(decision.config, trace.n)
        extras_k = verify_extras
        if verify_extras == "auto" and planner.verify_extras_for() > 0:
            extras_k = planner.verify_extras_for()
        run: BatchEdgeRun = run_batch_over_pool(
            plan,
            a[idx],
            b[idx],
            trace,
            seed=_replay_seed(seed, idx),
            verify_extras=extras_k,
            master_decode_cost=master_decode_cost,
            compute_scale=scale,
            decode_mode=decode_mode,
            error_budget=e_k if e_k > 0 else "auto",
            # Links this replay's trace records to the decision that
            # picked its construction (decision_id -> autoplan.decide).
            obs_attrs={
                "replay": idx,
                "decision_id": decision.obs_id,
                "config": decision.config.label(),
            },
        )
        planner.observe(decision.config, run.metrics)
        ys.append(run.y)
        replay_metrics.append(run.metrics)
    return AdaptiveRun(
        y=np.stack(ys),
        replay_metrics=replay_metrics,
        decisions=list(planner.decisions[-len(traces):]),
        planner=planner,
    )
