"""Event-driven scheduler: the three-phase protocol over a worker pool.

The static plan machinery already answers "which subsets can serve each
phase" (``phase2_matrix`` / ``decode_matrix`` for arbitrary ids); this
module decides *which subset actually does*, by replaying a
``WorkerTrace`` through a priority-queue event loop:

1. shares go out at t=0 and reach worker n at ``share_delay[n]``;
   worker n finishes H(alpha_n) ``compute_delay[n]`` later (dropouts
   never do),
2. the moment the fastest ``n_workers`` workers have finished, the
   Phase-2 set is fixed — exactly the paper's straggler mitigation:
   spares keep primaries from gating the exchange — and every live
   worker receives its summed I(alpha_n) one D2D delay later,
3. responses stream back to the master; decode triggers as soon as the
   fastest ``decode_threshold`` responders are in (the per-subset
   decode matrix comes from the plan's subset cache, so recurring
   fastest-subsets cost one Gauss-Jordan total).

Corrupted responses: the master cannot see corruption directly, so when
``verify_extras > 0`` it withholds acceptance until a decode is
*confirmed* by that many responders outside the decode subset (the
interpolated I(x) must reproduce their evaluations).  A corrupt
response is garbage, so it can neither be confirmed as part of a subset
nor falsely confirm a clean one; mismatching responders are reported as
detected-corrupt.  ``verify_extras="auto"`` enables one confirmation
exactly when the trace can contain corruption.

The numeric path stays on the device-resident protocol ops
(``share_a/b``, ``worker_multiply``, ``degree_reduce``); the event loop
only decides subsets and timestamps.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional, Tuple

import numpy as np

from ..core import protocol as proto
from ..core.planner import CMPCPlan
from .metrics import RunMetrics
from .pool import WorkerTrace


class DecodeFailure(RuntimeError):
    """The pool could not complete the protocol (too many faults)."""


@dataclasses.dataclass
class EdgeRun:
    """Result of one execution over the pool."""

    y: np.ndarray
    metrics: RunMetrics


# Bound on per-event decode-subset search when hunting for a confirmable
# subset among corrupt responses; the search resumes at the next arrival.
# Half the budget goes to the deterministic colex front (fastest-first),
# half to seeded random subsets that keep heavy corruption from starving
# the front (see _candidate_subsets).
_MAX_SUBSET_TRIES = 128


def run_over_pool(
    plan: CMPCPlan,
    a: np.ndarray,
    b: np.ndarray,
    trace: WorkerTrace,
    seed: int = 0,
    verify_extras="auto",
    master_decode_cost: float = 0.0,
) -> EdgeRun:
    """Execute Y = A^T B over the simulated pool described by ``trace``.

    Returns the decoded product and the run's :class:`RunMetrics`.
    Raises :class:`DecodeFailure` when the surviving pool cannot serve
    Phase 2 (fewer than ``n_workers`` live workers) or the master never
    accumulates an acceptable responder subset.
    """
    n_total = plan.n_total
    if trace.n != n_total:
        raise ValueError(
            f"trace covers {trace.n} workers, plan provisions {n_total} "
            f"({plan.n_workers} + {plan.n_spare} spare)"
        )
    if verify_extras == "auto":
        verify_extras = 1 if bool(trace.corrupt.any()) else 0
    thr = plan.decode_threshold
    p = plan.field.p
    rng = np.random.default_rng(seed)

    alive = ~trace.dropout
    if int(alive.sum()) < plan.n_workers:
        raise DecodeFailure(
            f"{int(trace.dropout.sum())} dropouts leave "
            f"{int(alive.sum())} live workers < n_workers={plan.n_workers}"
        )

    # Data plane, Phase 1: sources evaluate and ship shares.
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    h = proto.worker_multiply(plan, fa, fb)

    share_at = trace.share_delay
    phase1_last = float(share_at[alive].max())

    # Event loop.  Heap entries: (time, seq, kind, worker).
    events: list = []
    seq = itertools.count()
    for w in np.flatnonzero(alive):
        heapq.heappush(
            events,
            (float(share_at[w] + trace.compute_delay[w]), next(seq), "compute", int(w)),
        )

    computed: list = []  # worker ids in compute-completion order
    phase2_ids: Optional[np.ndarray] = None
    phase2_set_time = float("nan")
    i_all: Optional[np.ndarray] = None
    vander_check: Optional[np.ndarray] = None
    arrived: list = []  # (time, worker) in response-arrival order
    first_response = float("nan")
    decode_cache: dict = {}  # subset id-tuple -> coeffs, across arrivals

    while events:
        t_now, _, kind, w = heapq.heappop(events)

        if kind == "compute":
            computed.append(w)
            if len(computed) != plan.n_workers:
                continue
            # Fastest n_workers fix the Phase-2 set; the mixing matrix
            # interpolates over exactly this subset (sorted for a
            # canonical subset-cache key).
            phase2_ids = np.sort(np.array(computed))
            phase2_set_time = t_now
            # np.array (not asarray): device outputs are read-only views
            # and corrupt rows are overwritten below.
            i_all = np.array(
                proto.degree_reduce(plan, h, rng, worker_ids=phase2_ids)
            )
            # Corrupt workers respond with garbage of the right shape.
            for c in np.flatnonzero(trace.corrupt & alive):
                i_all[c] = rng.integers(0, p, size=i_all[c].shape, dtype=np.int64)
            vander_check = plan.field.vandermonde(plan.alphas, range(thr))
            # Live, non-crashed workers respond one exchange + uplink
            # delay after the set is announced.
            for r in np.flatnonzero(alive & ~trace.crash_after_phase2):
                heapq.heappush(
                    events,
                    (
                        float(t_now + trace.d2d_delay[r] + trace.uplink_delay[r]),
                        next(seq),
                        "response",
                        int(r),
                    ),
                )
            continue

        # kind == "response"
        if not arrived:
            first_response = t_now
        arrived.append((t_now, w))
        if len(arrived) < thr + verify_extras:
            continue
        accepted = _try_decode(
            plan, i_all, arrived, verify_extras, vander_check, rng, decode_cache
        )
        if accepted is None:
            continue
        coeffs, responder_ids, confirmed_by, rejected = accepted
        y = proto.assemble_y(plan, coeffs)
        completion = t_now + master_decode_cost
        # crash-after-phase-2 workers fully serve the exchange (they
        # only skip the Phase-3 report), so they count as receivers
        n_recv = int(alive.sum())
        sh = plan.shapes
        t = plan.scheme.t
        blk_y = (sh.ma // t) * (sh.mb // t)
        comm = proto.Trace(
            phase1_source_to_worker=n_total
            * (sh.blk_a[0] * sh.blk_a[1] + sh.blk_b[0] * sh.blk_b[1]),
            phase2_worker_to_worker=plan.n_workers * (n_recv - 1) * blk_y,
            phase3_worker_to_master=len(arrived) * blk_y,
            elem_bytes=plan.field.elem_bytes,
        )
        metrics = RunMetrics(
            completion_time=float(completion),
            phase1_last_share=phase1_last,
            phase2_set_time=phase2_set_time,
            first_response=float(first_response),
            n_provisioned=n_total,
            n_dropped=int(trace.dropout.sum()),
            n_crashed=int((trace.crash_after_phase2 & alive).sum()),
            phase2_ids=phase2_ids,
            responder_ids=responder_ids,
            confirmed_by=confirmed_by,
            rejected_ids=rejected,
            trace=comm,
        )
        return EdgeRun(y=y, metrics=metrics)

    raise DecodeFailure(
        f"events exhausted before an acceptable decode: {len(arrived)} "
        f"responses arrived, need {thr} + {verify_extras} confirmations "
        f"(threshold {thr}); dropouts={int(trace.dropout.sum())}, "
        f"crashed={int((trace.crash_after_phase2 & alive).sum())}, "
        f"corrupt={int((trace.corrupt & alive).sum())}"
    )


def _candidate_subsets(k: int, thr: int, rng: np.random.Generator):
    """Arrival-position subsets, fastest-first, with a randomized tail.

    The deterministic front is *colex* order — every subset of the
    fastest ``m`` arrivals is enumerated before any subset touching
    arrival ``m+1`` — so the first candidate is the fastest ``thr``
    and a capped search always spends its budget on the fastest
    responders (plain lex order front-loads subsets *containing* the
    earliest arrivals, which livelocks when one of those is corrupt).
    After half the budget the generator switches to seeded random
    subsets: with ``c`` corrupt responders among ``k`` a uniform draw
    is clean with probability C(k-c, thr)/C(k, thr), so a few dozen
    draws find a clean subset even when the colex front is saturated
    with corrupt members.
    """
    n = 0
    for m in range(thr, k + 1):
        for head in itertools.combinations(range(m - 1), thr - 1):
            yield head + (m - 1,)
            n += 1
            if n >= _MAX_SUBSET_TRIES // 2:
                break
        else:
            continue
        break
    while n < _MAX_SUBSET_TRIES:
        yield tuple(np.sort(rng.choice(k, size=thr, replace=False)))
        n += 1


def _try_decode(
    plan: CMPCPlan,
    i_all: np.ndarray,
    arrived: list,
    verify_extras: int,
    vander_check: np.ndarray,
    rng: np.random.Generator,
    decode_cache: dict,
) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """Search arrival-ordered responder subsets for an acceptable decode.

    Returns (coeffs, responder_ids, confirmed_by, rejected_ids) or None
    if no subset of the responses so far can be accepted.  A subset is
    accepted when the interpolated I(x) reproduces the responses of at
    least ``verify_extras`` responders outside it (garbage responses
    can neither pass as subset members nor confirm a clean subset, so
    a corrupt witness only defers acceptance to the next arrival).
    A rejected subset must be re-*verified* at later arrivals (a new
    witness can confirm it) but never re-*decoded*: ``decode_cache``
    holds its coefficients across calls within one run.
    """
    thr = plan.decode_threshold
    ids_by_arrival = [w for _, w in arrived]
    flat = i_all.reshape(i_all.shape[0], -1)
    seen = set()
    for subset_pos in _candidate_subsets(len(ids_by_arrival), thr, rng):
        if subset_pos in seen:
            continue
        seen.add(subset_pos)
        subset = [ids_by_arrival[i] for i in subset_pos]
        ids = np.sort(np.array(subset))
        key = tuple(int(i) for i in ids)
        coeffs = decode_cache.get(key)
        if coeffs is None:
            w_dec = plan.decode_matrix_cached(ids)
            coeffs = plan.field.matmul(w_dec, flat[ids])
            decode_cache[key] = coeffs
        if verify_extras == 0:
            return coeffs, ids, np.array([], np.int64), np.array([], np.int64)
        others = np.array([j for j in ids_by_arrival if j not in subset])
        pred = plan.field.matmul(vander_check[others], coeffs)
        ok = np.all(pred == flat[others], axis=1)
        if int(ok.sum()) >= verify_extras:
            return coeffs, ids, others[ok], others[~ok]
    return None
