"""Event-driven scheduler: the three-phase protocol over a worker pool.

The static plan machinery already answers "which subsets can serve each
phase" (``phase2_matrix`` / ``decode_matrix`` for arbitrary ids); this
module decides *which subset actually does*, by replaying a
``WorkerTrace`` through a priority-queue event loop:

1. shares go out at t=0 and reach worker n at ``share_delay[n]``;
   worker n finishes H(alpha_n) ``compute_delay[n]`` later (dropouts
   never do),
2. the moment the fastest ``n_workers`` workers have finished, the
   Phase-2 set is fixed — exactly the paper's straggler mitigation:
   spares keep primaries from gating the exchange — and every live
   worker receives its summed I(alpha_n) one exchange leg later: the
   scalar D2D delay, or (link-resolved traces) the max over its
   incoming links from the sender set,
3. responses stream back to the master; decode triggers as soon as the
   fastest ``decode_threshold`` responders are in (the per-subset
   decode matrix comes from the plan's subset cache, so recurring
   fastest-subsets cost one Gauss-Jordan total).

Corrupted responses — two strategies, picked by ``decode_mode``:

* ``"detect"`` (confirm-and-retry): when ``verify_extras > 0`` the
  master withholds acceptance until a decode is *confirmed* by that
  many responders outside the decode subset (the interpolated I(x)
  must reproduce their evaluations).  A corrupt response is garbage,
  so it can neither be confirmed as part of a subset nor falsely
  confirm a clean one; mismatching responders are reported as
  detected-corrupt.  Under heavy corruption this degrades into the
  seeded-random subset hunt of ``_candidate_subsets``.
* ``"correct"`` (Berlekamp-Welch): the responses are a Reed-Solomon
  codeword, so with ``error_budget = e`` the master waits for the
  fastest ``thr + 2e`` responders and runs ONE error-correcting decode
  (``core.bw_decode``) that recovers I(x) *and* names the corrupt
  responders (``RunMetrics.corrected_workers``) — no subset search,
  no retry.  If more than ``e`` responders are corrupt, later arrivals
  widen the window (budget ``(k - thr) // 2`` at ``k`` responses)
  until the clean responders run out.
* ``"auto"``: ``"correct"`` when the resolved error budget is > 0,
  ``"detect"`` otherwise.
* ``"hybrid"``: detect until the *first rejection on the pool*, then
  escalate to BW correction for every later replay against it.  The
  escalation is cross-replay state, so it lives in a
  :class:`HybridState` the caller threads through its replay calls
  (the serving engine keeps one per pool/session); a bare call with no
  state behaves as a fresh pool — detect.

``verify_extras="auto"`` / ``error_budget="auto"`` resolve from the
trace's *configured* fault model (``WorkerTrace.fault_model`` — what
the master knows because it provisioned the pool), never from the
sampled ``trace.corrupt`` flags, which are ground truth the master
cannot see.  A hand-built corrupt trace with no fault model therefore
gets NO automatic protection — exactly the honest semantics.

Two replay entry points share ONE event loop (``_replay_events``):

* ``run_over_pool``        — per-product reference (numpy-rng share
                              path, dense Phase-2 simulation),
* ``run_batch_over_pool``  — a whole batch of products through one
                              trace: shares come from the jitted
                              batched engine, the batch folds into the
                              per-worker payload so the event loop,
                              Phase-2 subset selection, and the
                              decode-subset search are paid ONCE, and
                              with ``mesh`` the exchange is the real
                              ``shard_map`` collective of
                              ``core.distributed`` driven by the
                              scheduler's fastest-subset ``worker_ids``.

The numeric path stays on the device-resident protocol ops
(``share_a/b``, ``worker_multiply``, ``degree_reduce``,
``share_batched``, ``run_phase2_sharded``); the event loop only decides
subsets and timestamps — which is what makes the batch fold sound: the
timeline depends on the trace alone, and a corrupt worker is corrupt
for every product it serves.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import protocol as proto
from ..core.bw_decode import BWDecodeError, bw_decode_evals, bw_system_size
from ..core.distributed import run_phase2_sharded
from ..core.planner import CMPCPlan
from ..obs.metrics import REGISTRY
from ..obs.tracer import TRACER
from .metrics import RunMetrics
from .pool import WorkerTrace

_EMPTY_IDS = np.array([], np.int64)


class DecodeFailure(RuntimeError):
    """The pool could not complete the protocol (too many faults)."""


@dataclasses.dataclass
class EdgeRun:
    """Result of one execution over the pool."""

    y: np.ndarray
    metrics: RunMetrics


@dataclasses.dataclass
class BatchEdgeRun:
    """Result of one batched execution over the pool.

    One event-loop replay served every product: ``per_product`` metrics
    share the timeline and subsets, differing only in the (per-product)
    communication trace; ``metrics`` carries the whole-batch trace.
    The subset id arrays (``phase2_ids``, ``responder_ids``, ...) are
    shared views across entries and the aggregate — treat them as
    read-only.
    """

    y: np.ndarray  # [batch, ma, mb]
    metrics: RunMetrics  # aggregate (batch-level comm accounting)
    per_product: List[RunMetrics]


# Default bound on per-event decode-subset search when hunting for a
# confirmable subset among corrupt responses; the search resumes at the
# next arrival.  Half the budget goes to the deterministic colex front
# (fastest-first), half to seeded random subsets that keep heavy
# corruption from starving the front (see _candidate_subsets).  Callers
# override via ``max_subset_tries`` to trade search time for success
# rate deterministically under heavy corruption.
DEFAULT_SUBSET_TRIES = 128


@dataclasses.dataclass
class _Replay:
    """Everything the event loop decided for one trace replay."""

    coeffs: np.ndarray  # [thr, payload] interpolated I(x) coefficients
    phase2_ids: np.ndarray
    responder_ids: np.ndarray
    confirmed_by: np.ndarray
    rejected_ids: np.ndarray
    corrected_ids: np.ndarray  # BW-identified (and corrected) corrupt
    phase1_last: float
    phase2_set_time: float
    first_response: float
    completion: float
    n_arrived: int


def _emit_replay_obs(
    plan: CMPCPlan,
    res: _Replay,
    trace: WorkerTrace,
    alive: np.ndarray,
    share_at: np.ndarray,
    finish_at: np.ndarray,
    arrived: list,
    bw_log: list,
    attrs: dict,
) -> None:
    """Render one replay's event-loop timeline as simulated-clock trace
    records: per-worker ``("worker", w)`` lanes carry the share /
    compute / respond spans (the flame chart of workers x phases), the
    ``("replay", k)`` lane carries the whole-replay span, the Phase-2
    barrier, BW attempts, and decode acceptance.

    Every timestamp is read off the already-decided replay — nothing
    here draws randomness or reorders events, so enabling the tracer
    cannot perturb the (deterministic) replay it records.
    """
    ridx = int(attrs.get("replay", 0))
    rtrack = ("replay", ridx)
    t_start = float(attrs.get("t_start", 0.0))
    p2 = {int(i) for i in res.phase2_ids}
    comm = _comm_trace(
        plan, int(alive.sum()), res.n_arrived, int(attrs.get("batch", 1))
    )
    TRACER.sim_span(
        "replay", t_start, res.completion, track=rtrack,
        wire_bytes_total=comm.total_bytes,
        phase1_bytes=comm.phase1_bytes,
        phase2_bytes=comm.phase2_bytes,
        phase3_bytes=comm.phase3_bytes,
        **attrs,
    )
    for w in np.flatnonzero(alive):
        w = int(w)
        wtrack = ("worker", w)
        TRACER.sim_span(
            "phase1.share", t_start, float(share_at[w]), track=wtrack,
            replay=ridx, worker=w,
        )
        TRACER.sim_span(
            "phase2.compute", float(share_at[w]), float(finish_at[w]),
            track=wtrack, replay=ridx, worker=w, in_set=w in p2,
        )
    TRACER.sim_event(
        "phase2.barrier", res.phase2_set_time, track=rtrack,
        replay=ridx, n_set=int(res.phase2_ids.size),
    )
    for t_arr, w in arrived:
        TRACER.sim_span(
            "phase3.respond", res.phase2_set_time, float(t_arr),
            track=("worker", int(w)), replay=ridx, worker=int(w),
        )
    for t_a, e_eff, window, ok in bw_log:
        TRACER.sim_event(
            "phase3.bw_attempt", float(t_a), track=rtrack,
            replay=ridx, e_eff=int(e_eff), window=int(window), ok=bool(ok),
        )
    TRACER.sim_event(
        "phase3.decode", res.completion, track=rtrack,
        replay=ridx,
        n_arrived=res.n_arrived,
        n_responders=int(res.responder_ids.size),
        n_rejected=int(res.rejected_ids.size),
        n_corrected=int(res.corrected_ids.size),
    )


def _check_pool(plan: CMPCPlan, trace: WorkerTrace) -> np.ndarray:
    """Validate the trace against the plan; returns the alive mask."""
    if trace.n != plan.n_total:
        raise ValueError(
            f"trace covers {trace.n} workers, plan provisions {plan.n_total} "
            f"({plan.n_workers} + {plan.n_spare} spare)"
        )
    alive = ~trace.dropout
    if int(alive.sum()) < plan.n_workers:
        raise DecodeFailure(
            f"{int(trace.dropout.sum())} dropouts leave "
            f"{int(alive.sum())} live workers < n_workers={plan.n_workers}"
        )
    return alive


def _replay_events(
    plan: CMPCPlan,
    trace: WorkerTrace,
    alive: np.ndarray,
    compute_i_all: Callable[[np.ndarray], np.ndarray],
    verify_extras: int,
    rng: np.random.Generator,
    master_decode_cost: float,
    share_arrival: Optional[np.ndarray] = None,
    compute_finish: Optional[np.ndarray] = None,
    compute_scale: float = 1.0,
    decode_mode: str = "detect",
    error_budget: int = 0,
    max_subset_tries: int = DEFAULT_SUBSET_TRIES,
    obs_attrs: Optional[dict] = None,
) -> _Replay:
    """The shared event loop: timestamps, subsets, and the decode search.

    ``compute_i_all(phase2_ids)`` supplies the numeric Phase-2 result as
    an ``[n_total, ...]`` worker-stacked array (any trailing payload
    shape — the batched runtime folds its whole batch in there);
    corruption is injected here so every caller gets identical fault
    semantics.

    ``share_arrival`` / ``compute_finish`` override the trace-derived
    Phase-1 arrival and H(alpha_n) completion times with absolute
    timestamps — the hook the pipelined runtime uses to account for
    master-uplink serialization and per-worker compute occupancy
    across overlapping replays.  Defaults reproduce the standalone
    semantics: arrival at ``share_delay``, completion one
    ``compute_delay`` later.

    ``compute_scale`` multiplies every worker's compute delay — the
    hook for heterogeneous-work comparisons, where one trace's
    ``compute_delay`` is time per unit work and each construction's
    per-worker work (Corollary 10; ``CostPrediction.compute_factor``)
    sets the scale.  The default 1.0 keeps replays byte-identical to
    the legacy semantics.

    With a link-resolved trace (``trace.link_delay`` set), a receiver's
    exchange completes at the max over its *incoming* links from the
    Phase-2 sender set rather than one scalar D2D delay; a dead
    (infinite) incoming link starves the receiver, which then never
    responds in Phase 3.

    ``decode_mode`` must arrive resolved (``"detect"`` or
    ``"correct"``).  In ``"correct"`` mode ``verify_extras`` is ignored
    (the BW decode self-verifies against every clean responder in the
    window) and acceptance waits for ``thr + 2 * error_budget``
    responses; ``max_subset_tries`` bounds the ``"detect"`` subset
    search per arrival.

    ``obs_attrs`` annotates this replay's trace records when the
    process tracer is enabled — the pipelined/adaptive runtimes pass
    ``replay`` (lane index), ``t_start`` (absolute pipeline start), and
    the ``decision_id``/``config`` of the :class:`PlanDecision` that
    picked the construction, linking each decision to the replay it
    decided.
    """
    tracing = TRACER.enabled
    p = plan.field.p
    share_at = trace.share_delay if share_arrival is None else share_arrival
    phase1_last = float(share_at[alive].max())
    finish_at = (
        share_at + compute_scale * trace.compute_delay
        if compute_finish is None
        else compute_finish
    )

    # Heap entries: (time, seq, kind, worker).
    events: list = []
    seq = itertools.count()
    for w in np.flatnonzero(alive):
        heapq.heappush(
            events,
            (float(finish_at[w]), next(seq), "compute", int(w)),
        )

    computed: list = []  # worker ids in compute-completion order
    link_starved: list = []  # receivers with a dead incoming link
    phase2_ids: Optional[np.ndarray] = None
    phase2_set_time = float("nan")
    i_all: Optional[np.ndarray] = None
    vander_check: Optional[np.ndarray] = None
    arrived: list = []  # (time, worker) in response-arrival order
    first_response = float("nan")
    decode_cache: dict = {}  # subset id-tuple -> coeffs, across arrivals
    bw_attempts = 0  # correct-mode decode attempts, for the failure census
    bw_log: list = []  # (t, e_eff, window, ok) per attempt, when tracing

    def _finish(res: _Replay) -> _Replay:
        REGISTRY.counter("runtime.replays").inc()
        if tracing:
            _emit_replay_obs(
                plan, res, trace, alive, share_at, finish_at, arrived,
                bw_log, obs_attrs or {},
            )
        return res

    while events:
        t_now, _, kind, w = heapq.heappop(events)

        if kind == "compute":
            computed.append(w)
            if len(computed) != plan.n_workers:
                continue
            # Fastest n_workers fix the Phase-2 set; the mixing matrix
            # interpolates over exactly this subset (sorted for a
            # canonical subset-cache key).
            phase2_ids = np.sort(np.array(computed))
            phase2_set_time = t_now
            # np.array (not asarray): device outputs are read-only views
            # and corrupt rows are overwritten below.
            i_all = np.array(compute_i_all(phase2_ids))
            # Corrupt workers respond with garbage of the right shape
            # (garbage spans their whole payload — every product of a
            # batched replay sees the same worker corrupt).
            for c in np.flatnonzero(trace.corrupt & alive):
                i_all[c] = rng.integers(0, p, size=i_all[c].shape, dtype=np.int64)
            vander_check = plan.decode_check_matrix()
            # Live, non-crashed workers respond one exchange + uplink
            # delay after the set is announced.  With a link matrix the
            # exchange leg is the max over the receiver's incoming
            # links from the sender set (its own diagonal entry is 0);
            # a dead incoming link starves the receiver's I(alpha_r)
            # sum, so it never responds.  Exchange messages all go out
            # at the announcement, so a time-varying fabric resolves to
            # the matrix in effect *now*.
            link_now = trace.link_at(t_now)
            for r in np.flatnonzero(alive & ~trace.crash_after_phase2):
                if link_now is not None:
                    exchange = float(link_now[phase2_ids, r].max())
                    if not np.isfinite(exchange):
                        link_starved.append(int(r))
                        continue
                else:
                    exchange = float(trace.d2d_delay[r])
                heapq.heappush(
                    events,
                    (
                        float(t_now + exchange + trace.uplink_delay[r]),
                        next(seq),
                        "response",
                        int(r),
                    ),
                )
            continue

        # kind == "response"
        if not arrived:
            first_response = t_now
        arrived.append((t_now, w))
        if decode_mode == "correct":
            thr = plan.decode_threshold
            if len(arrived) < bw_system_size(thr, error_budget):
                continue
            # Fastest thr + 2e window at budget e; each further arrival
            # widens both the window and the budget ((k - thr) // 2), so
            # under-budgeted corruption degrades gracefully instead of
            # failing outright.
            e_eff = (len(arrived) - thr) // 2
            window = np.array(
                [wk for _, wk in arrived[: bw_system_size(thr, e_eff)]]
            )
            bw_attempts += 1
            REGISTRY.counter("runtime.bw_attempts").inc()
            try:
                coeffs, corrected = bw_decode_evals(
                    plan, i_all, window, e_eff, rng=rng
                )
            except BWDecodeError:
                if tracing:
                    bw_log.append((t_now, e_eff, len(window), False))
                continue  # > e_eff corrupt in the window: wait for more
            if tracing:
                bw_log.append((t_now, e_eff, len(window), True))
            responders = window[~np.isin(window, corrected)]
            return _finish(_Replay(
                coeffs=coeffs,
                phase2_ids=phase2_ids,
                responder_ids=np.sort(responders),
                confirmed_by=_EMPTY_IDS.copy(),
                rejected_ids=_EMPTY_IDS.copy(),
                corrected_ids=corrected,
                phase1_last=phase1_last,
                phase2_set_time=phase2_set_time,
                first_response=float(first_response),
                completion=float(t_now + master_decode_cost),
                n_arrived=len(arrived),
            ))
        if len(arrived) < plan.decode_threshold + verify_extras:
            continue
        accepted = _try_decode(
            plan, i_all, arrived, verify_extras, vander_check, rng,
            decode_cache, max_subset_tries,
        )
        if accepted is None:
            continue
        coeffs, responder_ids, confirmed_by, rejected = accepted
        return _finish(_Replay(
            coeffs=coeffs,
            phase2_ids=phase2_ids,
            responder_ids=responder_ids,
            confirmed_by=confirmed_by,
            rejected_ids=rejected,
            corrected_ids=_EMPTY_IDS.copy(),
            phase1_last=phase1_last,
            phase2_set_time=phase2_set_time,
            first_response=float(first_response),
            completion=float(t_now + master_decode_cost),
            n_arrived=len(arrived),
        ))

    REGISTRY.counter("runtime.decode_failures").inc()
    if decode_mode == "correct":
        raise DecodeFailure(
            f"events exhausted before a Berlekamp-Welch decode: "
            f"{len(arrived)} responses arrived, need "
            f"{plan.decode_threshold} + 2*{error_budget} "
            f"(threshold {plan.decode_threshold}, error budget "
            f"{error_budget}, {bw_attempts} BW attempts); "
            f"dropouts={int(trace.dropout.sum())}, "
            f"crashed={int((trace.crash_after_phase2 & alive).sum())}, "
            f"corrupt={int((trace.corrupt & alive).sum())}, "
            f"link_starved={len(link_starved)}"
        )
    raise DecodeFailure(
        f"events exhausted before an acceptable decode: {len(arrived)} "
        f"responses arrived, need {plan.decode_threshold} + {verify_extras} "
        f"confirmations (threshold {plan.decode_threshold}); "
        f"dropouts={int(trace.dropout.sum())}, "
        f"crashed={int((trace.crash_after_phase2 & alive).sum())}, "
        f"corrupt={int((trace.corrupt & alive).sum())}, "
        f"link_starved={len(link_starved)}"
    )


def _comm_trace(
    plan: CMPCPlan, n_recv: int, n_arrived: int, batch: int = 1
) -> proto.Trace:
    """Runtime communication accounting for one replay.

    Delegates to ``protocol.batch_trace`` (ONE home for the
    Corollary-12 formulas), overriding Phase 2's receivers with the
    *live* pool (crashed-after-phase-2 workers fully serve the
    exchange; dropouts receive nothing) and Phase 3 with the responses
    that actually arrived at acceptance.
    """
    return proto.batch_trace(
        plan, batch, n_receivers=n_recv, n_responses=n_arrived
    )


def _build_metrics(
    plan: CMPCPlan,
    trace: WorkerTrace,
    alive: np.ndarray,
    res: _Replay,
    batch: int = 1,
) -> RunMetrics:
    # crash-after-phase-2 workers fully serve the exchange (they only
    # skip the Phase-3 report), so they count as receivers
    n_recv = int(alive.sum())
    return RunMetrics(
        completion_time=res.completion,
        phase1_last_share=res.phase1_last,
        phase2_set_time=res.phase2_set_time,
        first_response=res.first_response,
        n_provisioned=plan.n_total,
        n_dropped=int(trace.dropout.sum()),
        n_crashed=int((trace.crash_after_phase2 & alive).sum()),
        phase2_ids=res.phase2_ids,
        responder_ids=res.responder_ids,
        confirmed_by=res.confirmed_by,
        rejected_ids=res.rejected_ids,
        corrected_workers=res.corrected_ids,
        trace=_comm_trace(plan, n_recv, res.n_arrived, batch),
        batch=batch,
    )


def _resolve_verify_extras(verify_extras, trace: WorkerTrace) -> int:
    """``"auto"`` -> 1 extra confirmation iff the pool was *provisioned*
    with a corrupting fault model.

    The master only ever sees what it configured (``trace.fault_model``),
    never the sampled ``trace.corrupt`` flags — those are ground truth.
    A hand-built corrupt trace with no fault model resolves to 0 extras
    and an unverified decode, exactly like a master that provisioned an
    honest pool.
    """
    if verify_extras == "auto":
        fm = trace.fault_model
        return 1 if fm is not None and fm.corrupt_frac > 0 else 0
    return int(verify_extras)


def _resolve_error_budget(error_budget, trace: WorkerTrace, plan: CMPCPlan) -> int:
    """``"auto"`` -> expected corrupt count under the *configured* fault
    model, capped at what the pool can afford ((n_total - thr) // 2);
    integers pass through (validated >= 0)."""
    if error_budget == "auto":
        fm = trace.fault_model
        if fm is None or fm.corrupt_frac <= 0:
            return 0
        cap = (plan.n_total - plan.decode_threshold) // 2
        want = int(np.ceil(fm.corrupt_frac * trace.n))
        return max(0, min(want, cap))
    e = int(error_budget)
    if e < 0:
        raise ValueError(f"error_budget must be >= 0, got {e}")
    return e


def _resolve_decode_mode(decode_mode: str, error_budget: int) -> str:
    """``"auto"`` -> ``"correct"`` iff the resolved error budget buys any
    protection; explicit modes pass through (validated).  ``"hybrid"``
    must already have been resolved against a :class:`HybridState`
    (``_resolve_hybrid``) before reaching here."""
    if decode_mode == "auto":
        return "correct" if error_budget > 0 else "detect"
    if decode_mode not in ("detect", "correct"):
        raise ValueError(
            f"decode_mode must be 'detect', 'correct', 'auto', or "
            f"'hybrid', got {decode_mode!r}"
        )
    return decode_mode


@dataclasses.dataclass
class HybridState:
    """Cross-replay escalation state for ``decode_mode="hybrid"``.

    Hybrid starts every pool in cheap detect mode (confirm-and-retry)
    and escalates to Berlekamp-Welch correction only after the first
    *evidence of corruption on this pool* — a rejected responder in a
    detect decode.  The evidence outlives any single replay, so the
    state is an explicit object the caller threads through consecutive
    replays against the same pool (the serving engine keeps one per
    session and resets it when the pool is reconfigured).  A call with
    no state gets a fresh one: a single replay can never escalate
    itself mid-flight, matching "escalate only *after* the first
    rejection".
    """

    escalated: bool = False
    rejections_seen: int = 0

    def note(self, metrics: RunMetrics) -> None:
        """Fold one finished replay's verdicts into the state."""
        n_bad = int(metrics.rejected_ids.size) + int(
            metrics.corrected_workers.size
        )
        if n_bad > 0:
            self.rejections_seen += n_bad
            self.escalated = True

    def reset(self) -> None:
        """Forget the pool (call after a reconfiguration)."""
        self.escalated = False
        self.rejections_seen = 0


def _resolve_hybrid(
    decode_mode: str,
    hybrid_state: Optional[HybridState],
    error_budget: int,
    plan: CMPCPlan,
) -> Tuple[str, int, Optional[HybridState]]:
    """Resolve ``"hybrid"`` against the pool's escalation state.

    Pre-escalation: plain detect with the caller's budget untouched.
    Post-escalation: BW correction with a budget of at least 1 (the
    auto-resolved budget is often 0 exactly when hybrid matters — the
    master provisioned an honest pool and was wrong), capped at what
    the pool can afford; a pool too small to fund any BW window stays
    in detect.  Non-hybrid modes pass through so the callers can
    resolve unconditionally.
    """
    if decode_mode != "hybrid":
        return decode_mode, error_budget, hybrid_state
    state = hybrid_state if hybrid_state is not None else HybridState()
    if not state.escalated:
        return "detect", error_budget, state
    cap = (plan.n_total - plan.decode_threshold) // 2
    budget = min(max(1, error_budget), cap)
    if budget <= 0:
        return "detect", error_budget, state
    return "correct", budget, state


def run_over_pool(
    plan: CMPCPlan,
    a: np.ndarray,
    b: np.ndarray,
    trace: WorkerTrace,
    seed: int = 0,
    verify_extras="auto",
    master_decode_cost: float = 0.0,
    compute_scale: float = 1.0,
    decode_mode: str = "detect",
    error_budget="auto",
    max_subset_tries: int = DEFAULT_SUBSET_TRIES,
    obs_attrs: Optional[dict] = None,
    hybrid_state: Optional[HybridState] = None,
) -> EdgeRun:
    """Execute Y = A^T B over the simulated pool described by ``trace``.

    ``decode_mode`` selects corruption handling (module docstring):
    ``"detect"`` confirm-and-retry (the default; ``verify_extras``
    confirmations, subset search bounded by ``max_subset_tries``),
    ``"correct"`` one Berlekamp-Welch decode over the fastest
    ``thr + 2 * error_budget`` responders, ``"auto"`` correct iff the
    resolved error budget is positive, ``"hybrid"`` detect until the
    first rejection recorded in ``hybrid_state`` then correct.
    ``error_budget="auto"`` resolves from the trace's configured fault
    model.

    Returns the decoded product and the run's :class:`RunMetrics`.
    Raises :class:`DecodeFailure` when the surviving pool cannot serve
    Phase 2 (fewer than ``n_workers`` live workers) or the master never
    accumulates an acceptable responder subset.
    """
    alive = _check_pool(plan, trace)
    verify_extras = _resolve_verify_extras(verify_extras, trace)
    error_budget = _resolve_error_budget(error_budget, trace, plan)
    decode_mode, error_budget, hybrid_state = _resolve_hybrid(
        decode_mode, hybrid_state, error_budget, plan
    )
    decode_mode = _resolve_decode_mode(decode_mode, error_budget)
    rng = np.random.default_rng(seed)

    # Data plane, Phase 1: sources evaluate and ship shares.
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    h = proto.worker_multiply(plan, fa, fb)

    def compute_i_all(phase2_ids: np.ndarray) -> np.ndarray:
        return proto.degree_reduce(plan, h, rng, worker_ids=phase2_ids)

    res = _replay_events(
        plan, trace, alive, compute_i_all, verify_extras, rng,
        master_decode_cost, compute_scale=compute_scale,
        decode_mode=decode_mode, error_budget=error_budget,
        max_subset_tries=max_subset_tries, obs_attrs=obs_attrs,
    )
    y = proto.assemble_y(plan, res.coeffs)
    metrics = _build_metrics(plan, trace, alive, res)
    if hybrid_state is not None:
        hybrid_state.note(metrics)
    return EdgeRun(y=y, metrics=metrics)


def _batched_compute_closure(
    plan: CMPCPlan,
    fa: jnp.ndarray,
    fb: jnp.ndarray,
    rng: np.random.Generator,
    batch: int,
    mesh,
    axis: str,
    mode: str,
    backend: str,
) -> Callable[[np.ndarray], np.ndarray]:
    """``compute_i_all`` for a batched replay (shared with the pipeline).

    Folds the whole batch into each worker's payload so one Phase-2
    pass serves every product; with ``mesh`` the exchange is the real
    ``shard_map`` collective driven by the scheduler's fastest subset.
    """
    bry, bcy = plan.shapes.blk_y

    def compute_i_all(phase2_ids: np.ndarray) -> np.ndarray:
        if mesh is not None:
            # Faithful distributed exchange: per-worker blinding draws,
            # whole batch on one collective, sender subset = the
            # scheduler's fastest n_workers.
            noise = plan.field.random(
                rng, (batch, plan.n_workers, plan.scheme.z, bry, bcy)
            )
            i_b = run_phase2_sharded(
                plan, fa, fb, noise, mesh,
                axis=axis, mode=mode, matmul_backend=backend,
                worker_ids=phase2_ids,
            )  # [batch, n_total, bry, bcy]
            return np.moveaxis(np.asarray(i_b), 1, 0).reshape(
                plan.n_total, batch * bry, bcy
            )
        # Dense simulation: fold the batch into the block rows so the
        # existing degree-reduction matmul serves every product at once.
        h = proto.worker_multiply(plan, fa, fb)  # [batch, n_total, bry, bcy]
        h_w = jnp.moveaxis(h, 0, 1).reshape(plan.n_total, batch * bry, bcy)
        return proto.degree_reduce(plan, h_w, rng, worker_ids=phase2_ids)

    return compute_i_all


def _unfold_batched_y(plan: CMPCPlan, coeffs: np.ndarray, batch: int) -> np.ndarray:
    """Per-product assembly: the interpolated coefficients carry the
    batch in their payload; unfold and lay out every Y at once (the
    batched mirror of ``assemble_y``)."""
    t = plan.scheme.t
    sh = plan.shapes
    bry, bcy = sh.blk_y
    blocks = coeffs.reshape(-1, batch, bry, bcy)[: t * t].reshape(
        t, t, batch, bry, bcy
    )  # [l, i, b, ., .]
    return blocks.transpose(2, 1, 3, 0, 4).reshape(batch, sh.ma, sh.mb)


def run_batch_over_pool(
    plan: CMPCPlan,
    a: np.ndarray,
    b: np.ndarray,
    trace: WorkerTrace,
    seed: int = 0,
    verify_extras="auto",
    master_decode_cost: float = 0.0,
    mesh=None,
    axis: str = "workers",
    mode: str = "all_to_all",
    backend: str = "auto",
    compute_scale: float = 1.0,
    decode_mode: str = "detect",
    error_budget="auto",
    max_subset_tries: int = DEFAULT_SUBSET_TRIES,
    obs_attrs: Optional[dict] = None,
    hybrid_state: Optional[HybridState] = None,
) -> BatchEdgeRun:
    """Replay a whole batch of products through ONE worker trace.

    a: [batch, k, ma], b: [batch, k, mb] (2D operands promote to batch
    1).  The event loop, Phase-2 fastest-subset barrier, and the
    decode-subset search run once for the whole batch: products fold
    into each worker's payload, which is sound because the timeline
    depends only on the trace, and a corrupt/crashed/dropped worker is
    faulty for every product it touches.  Shares and decode run on the
    batched device engine (``share_batched`` / jitted decode path).

    With ``mesh`` the Phase-2 exchange is the real ``shard_map``
    collective (``core.distributed.run_phase2_sharded``, ``mode`` one of
    ``all_to_all`` / ``psum`` / ``psum_scatter``) driven by the
    scheduler's fastest-subset ``worker_ids`` — the edge runtime and the
    distributed data plane composed end to end.  Without it, Phase 2 is
    the dense single-host simulation (``degree_reduce``).

    ``decode_mode`` / ``error_budget`` / ``max_subset_tries`` select the
    corruption-handling strategy exactly as in ``run_over_pool``; a
    Berlekamp-Welch decode (``"correct"``) corrects each corrupt
    worker's whole folded payload at once, so the whole batch rides one
    error-correcting decode.

    Returns :class:`BatchEdgeRun`; raises :class:`DecodeFailure` exactly
    like ``run_over_pool``.
    """
    alive = _check_pool(plan, trace)
    verify_extras = _resolve_verify_extras(verify_extras, trace)
    error_budget = _resolve_error_budget(error_budget, trace, plan)
    decode_mode, error_budget, hybrid_state = _resolve_hybrid(
        decode_mode, hybrid_state, error_budget, plan
    )
    decode_mode = _resolve_decode_mode(decode_mode, error_budget)
    rng = np.random.default_rng(seed)

    a_j, b_j = proto._prep_batched_operands(plan, a, b)
    batch = int(a_j.shape[0])
    fa, fb = proto.share_batched(
        plan, a_j, b_j, jax.random.PRNGKey(seed), backend=backend
    )
    compute_i_all = _batched_compute_closure(
        plan, fa, fb, rng, batch, mesh, axis, mode, backend
    )

    res = _replay_events(
        plan, trace, alive, compute_i_all, verify_extras, rng,
        master_decode_cost, compute_scale=compute_scale,
        decode_mode=decode_mode, error_budget=error_budget,
        max_subset_tries=max_subset_tries,
        obs_attrs={**(obs_attrs or {}), "batch": batch},
    )
    y = _unfold_batched_y(plan, res.coeffs, batch)

    aggregate = _build_metrics(plan, trace, alive, res, batch=batch)
    if hybrid_state is not None:
        hybrid_state.note(aggregate)
    # one replay served every product, so the per-product metrics are
    # identical by construction: build once, then give each entry its
    # own object (the subset id arrays stay shared read-only views)
    first = _build_metrics(plan, trace, alive, res, batch=1)
    per_product = [first] + [
        dataclasses.replace(first) for _ in range(batch - 1)
    ]
    return BatchEdgeRun(y=y, metrics=aggregate, per_product=per_product)


def _candidate_subsets(
    k: int, thr: int, rng: np.random.Generator,
    max_tries: int = DEFAULT_SUBSET_TRIES,
):
    """Arrival-position subsets, fastest-first, with a randomized tail.

    The deterministic front is *colex* order — every subset of the
    fastest ``m`` arrivals is enumerated before any subset touching
    arrival ``m+1`` — so the first candidate is the fastest ``thr``
    and a capped search always spends its budget on the fastest
    responders (plain lex order front-loads subsets *containing* the
    earliest arrivals, which livelocks when one of those is corrupt).
    After half the budget the generator switches to seeded random
    subsets: with ``c`` corrupt responders among ``k`` a uniform draw
    is clean with probability C(k-c, thr)/C(k, thr), so a few dozen
    draws find a clean subset even when the colex front is saturated
    with corrupt members.
    """
    n = 0
    for m in range(thr, k + 1):
        for head in itertools.combinations(range(m - 1), thr - 1):
            yield head + (m - 1,)
            n += 1
            if n >= max_tries // 2:
                break
        else:
            continue
        break
    while n < max_tries:
        yield tuple(np.sort(rng.choice(k, size=thr, replace=False)))
        n += 1


def _try_decode(
    plan: CMPCPlan,
    i_all: np.ndarray,
    arrived: list,
    verify_extras: int,
    vander_check: np.ndarray,
    rng: np.random.Generator,
    decode_cache: dict,
    max_subset_tries: int = DEFAULT_SUBSET_TRIES,
) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """Search arrival-ordered responder subsets for an acceptable decode.

    Returns (coeffs, responder_ids, confirmed_by, rejected_ids) or None
    if no subset of the responses so far can be accepted.  A subset is
    accepted when the interpolated I(x) reproduces the responses of at
    least ``verify_extras`` responders outside it (garbage responses
    can neither pass as subset members nor confirm a clean subset, so
    a corrupt witness only defers acceptance to the next arrival).
    A rejected subset must be re-*verified* at later arrivals (a new
    witness can confirm it) but never re-*decoded*: ``decode_cache``
    holds its coefficients across calls within one run.
    """
    thr = plan.decode_threshold
    ids_by_arrival = [w for _, w in arrived]
    flat = i_all.reshape(i_all.shape[0], -1)
    seen = set()
    # One wall span per decode search (not per subset candidate): the
    # host-side price of Phase 3 at this arrival.
    with TRACER.span(
        "protocol.phase3.subset_search", n_arrived=len(ids_by_arrival)
    ):
        for subset_pos in _candidate_subsets(
            len(ids_by_arrival), thr, rng, max_subset_tries
        ):
            if subset_pos in seen:
                continue
            seen.add(subset_pos)
            subset = [ids_by_arrival[i] for i in subset_pos]
            ids = np.sort(np.array(subset))
            key = tuple(int(i) for i in ids)
            coeffs = decode_cache.get(key)
            if coeffs is None:
                w_dec = plan.decode_matrix_cached(ids)
                coeffs = plan.field.matmul(w_dec, flat[ids])
                decode_cache[key] = coeffs
            if verify_extras == 0:
                return (
                    coeffs, ids, np.array([], np.int64), np.array([], np.int64)
                )
            others = np.array([j for j in ids_by_arrival if j not in subset])
            pred = plan.field.matmul(vander_check[others], coeffs)
            ok = np.all(pred == flat[others], axis=1)
            if int(ok.sum()) >= verify_extras:
                return coeffs, ids, others[ok], others[~ok]
    return None
