"""Edge worker-pool runtime: straggler-aware protocol execution.

The plan layer (``repro.core.planner``) already supports arbitrary
worker subsets — ``n_spare`` extra evaluation points, ``phase2_matrix``
and ``decode_matrix`` for any surviving set — but the core execution
paths assume every worker answers instantly.  This package turns that
static machinery into an execution engine for the paper's actual
setting: heterogeneous, flaky edge workers.

* ``pool``      — latency models (deterministic / shifted-exponential /
                   heavy-tail) and fault injection (stragglers,
                   dropouts, crash-after-phase-2, corrupted responses),
                   sampled into replayable per-worker traces,
* ``scheduler`` — the event loop: dispatch shares, pick the fastest
                   ``n_workers`` for Phase 2, decode from the fastest
                   ``decode_threshold`` responders (with consistency
                   verification against extra responders when corruption
                   is possible),
* ``metrics``   — per-run timeline, communication (bytes-level
                   ``Trace`` view), effective worker counts and
                   decode-subset statistics, plus aggregation across
                   runs.
"""
from .pool import (  # noqa: F401
    Deterministic,
    FaultSpec,
    HeavyTail,
    LatencyModel,
    ShiftedExponential,
    WorkerTrace,
    sample_trace,
)
from .scheduler import DecodeFailure, EdgeRun, run_over_pool  # noqa: F401
from .metrics import RunMetrics, summarize  # noqa: F401
