"""Edge worker-pool runtime: straggler-aware protocol execution.

The plan layer (``repro.core.planner``) already supports arbitrary
worker subsets — ``n_spare`` extra evaluation points, ``phase2_matrix``
and ``decode_matrix`` for any surviving set — but the core execution
paths assume every worker answers instantly.  This package turns that
static machinery into an execution engine for the paper's actual
setting: heterogeneous, flaky edge workers.

* ``pool``      — latency models (deterministic / shifted-exponential /
                   heavy-tail) and fault injection (stragglers,
                   dropouts, crash-after-phase-2, corrupted responses),
                   sampled into replayable per-worker traces,
* ``scheduler`` — the event loop: dispatch shares, pick the fastest
                   ``n_workers`` for Phase 2, decode from the fastest
                   ``decode_threshold`` responders (with consistency
                   verification against extra responders when corruption
                   is possible); ``run_batch_over_pool`` replays a whole
                   batch of products through one trace — event loop and
                   decode-subset search amortized across the batch — and
                   with a mesh drives the real ``shard_map`` Phase-2
                   exchange from the scheduler's fastest subset,
* ``metrics``   — per-run timeline, communication (bytes-level
                   ``Trace`` view), effective worker counts and
                   decode-subset statistics, plus aggregation across
                   runs.
"""
from .pool import (  # noqa: F401
    Deterministic,
    FaultSpec,
    HeavyTail,
    LatencyModel,
    ShiftedExponential,
    WorkerTrace,
    sample_trace,
)
from .scheduler import (  # noqa: F401
    BatchEdgeRun,
    DecodeFailure,
    EdgeRun,
    run_batch_over_pool,
    run_over_pool,
)
from .metrics import RunMetrics, summarize  # noqa: F401
