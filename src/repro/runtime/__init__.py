"""Edge worker-pool runtime: straggler-aware protocol execution.

The plan layer (``repro.core.planner``) already supports arbitrary
worker subsets — ``n_spare`` extra evaluation points, ``phase2_matrix``
and ``decode_matrix`` for any surviving set — but the core execution
paths assume every worker answers instantly.  This package turns that
static machinery into an execution engine for the paper's actual
setting: heterogeneous, flaky edge workers.

* ``pool``      — latency models (deterministic / shifted-exponential /
                   heavy-tail) and fault injection (stragglers,
                   dropouts, crash-after-phase-2, corrupted responses),
                   sampled into replayable per-worker traces,
* ``scheduler`` — the event loop: dispatch shares, pick the fastest
                   ``n_workers`` for Phase 2, decode from the fastest
                   ``decode_threshold`` responders (with consistency
                   verification against extra responders when corruption
                   is possible, or Byzantine error *correction* via
                   ``decode_mode="correct"`` — one Berlekamp-Welch decode
                   over the fastest ``thr + 2e`` responders that also
                   names the corrupt workers);
                   ``run_batch_over_pool`` replays a whole
                   batch of products through one trace — event loop and
                   decode-subset search amortized across the batch — and
                   with a mesh drives the real ``shard_map`` Phase-2
                   exchange from the scheduler's fastest subset,
* ``metrics``   — per-run timeline, communication (bytes-level
                   ``Trace`` view), effective worker counts and
                   decode-subset statistics, plus aggregation across
                   runs,
* ``pipeline``  — ``run_pipeline_over_pool`` keeps K batched replays
                   in flight at once with overlapping traces: master
                   links and worker compute are serial resources, so
                   replay k+1's Phase-1 transfers overlap replay k's
                   Phase-2 compute; aggregate ``PipelineMetrics``
                   report makespan, occupancy, and Phase-1 overlap.
                   The stateful core is ``PipelineSession``: replays
                   are *appended* one at a time against the live
                   occupancy (optionally floored by a request-arrival
                   ``not_before``), which is what lets the serving
                   tier (``repro.serve``) admit requests into an
                   in-flight pipeline instead of waiting for batch
                   boundaries.

Traces can be link-resolved: ``NetworkModel`` implementations
(``UniformLinks`` / ``AsymmetricLinks`` / ``ClusteredEdge``) sample a
per-``(sender, receiver)`` Phase-2 delay matrix plus master up/down
links, and the scheduler completes a receiver's exchange at the max
over its *incoming* links.

Scenario layer for the auto-planner (``autoplan``): a
``TimeVaryingLinks`` schedule degrades the Phase-2 fabric mid-replay
(the scheduler resolves the matrix in effect when the exchange goes
out), and an ``ElasticPool`` changes the worker membership between
replays.  ``AutoPlanner`` closes the loop — it fits the pool's
straggler tails and fault rates from observed runs (``estimate_pool``)
and picks the construction for each replay, either sequentially
(``run_adaptive_over_pool``) or mid-stream inside the pipeline
(``run_pipeline_over_pool(..., planner=...)``).
"""
from .pool import (  # noqa: F401
    AsymmetricLinks,
    ClusteredEdge,
    Deterministic,
    ElasticPool,
    FaultSpec,
    HeavyTail,
    LatencyModel,
    NetworkModel,
    ShiftedExponential,
    TimeVaryingLinks,
    UniformLinks,
    WorkerTrace,
    sample_trace,
)
from .scheduler import (  # noqa: F401
    DEFAULT_SUBSET_TRIES,
    BatchEdgeRun,
    DecodeFailure,
    EdgeRun,
    HybridState,
    run_batch_over_pool,
    run_over_pool,
)
from .metrics import (  # noqa: F401
    ObservedRun,
    PipelineMetrics,
    PoolEstimate,
    RunMetrics,
    estimate_pool,
    fit_order_stats,
    observed_run,
    order_stat_mean,
    summarize,
)
from .pipeline import (  # noqa: F401
    PipelineReplay,
    PipelineRun,
    PipelineSession,
    run_pipeline_over_pool,
)
from .autoplan import (  # noqa: F401
    AdaptiveRun,
    AutoPlanner,
    PlanDecision,
    plan_for_decision,
    run_adaptive_over_pool,
)
