"""Per-run runtime metrics and cross-run aggregation.

``RunMetrics`` records the timeline of one event-driven execution
(when each protocol phase unblocked), the communication trace (with the
bytes-level view from ``protocol.Trace``), which workers actually
served each phase, and what the master rejected as corrupt.  These are
the quantities behind the paper's edge claims: completion time under
stragglers, and how many provisioned workers were actually needed.

``summarize`` aggregates a list of runs into the latency distribution
(mean / p50 / p95 / max), mean effective worker count, decode-subset
statistics (how many distinct responder subsets the master decoded
from — the hit pattern of the planner's subset-matrix caches), and
total wire bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from ..core.protocol import Trace


@dataclasses.dataclass
class RunMetrics:
    """Timeline + accounting of one run over a worker pool."""

    completion_time: float  # master accepts the decode
    phase1_last_share: float  # last share delivered to a live worker
    phase2_set_time: float  # fastest n_workers finished H -> set fixed
    first_response: float  # first I(alpha_n) at the master
    n_provisioned: int
    n_dropped: int
    n_crashed: int
    phase2_ids: np.ndarray  # the fastest-subset Phase-2 senders
    responder_ids: np.ndarray  # accepted Phase-3 decode subset
    confirmed_by: np.ndarray  # extra responders that verified the decode
    rejected_ids: np.ndarray  # responders detected as corrupt
    trace: Trace  # communication (elements + bytes views)
    batch: int = 1  # products served by this replay (batched runtime)

    @property
    def effective_workers(self) -> int:
        """Distinct workers whose output the result depends on."""
        return int(
            np.union1d(np.union1d(self.phase2_ids, self.responder_ids),
                       self.confirmed_by).size
        )

    @property
    def decode_subset_key(self) -> Tuple[int, ...]:
        return tuple(int(i) for i in np.sort(self.responder_ids))


@dataclasses.dataclass
class PipelineMetrics:
    """Aggregate view of K pipelined batched replays.

    Per-replay timestamps are *absolute* on the shared pipeline clock
    (replay k's Phase-1 upload starts once the master's per-worker
    links free up from replay k-1).  ``occupancy`` is the mean number
    of in-flight replays over the makespan — sum of per-replay spans
    divided by the makespan; 1.0 means no overlap at all, values
    toward ``depth`` mean the pipeline is saturated.
    ``phase1_overlap`` totals the Phase-1 upload time that ran while
    an earlier replay was still in flight (the transfer/compute
    overlap the scalar runtime could not express).
    """

    depth: int  # replays in flight (K)
    batch: int  # products per replay
    products: int  # depth * batch
    makespan: float  # last replay accepted (absolute)
    completions: np.ndarray  # [K] absolute acceptance times
    starts: np.ndarray  # [K] first Phase-1 send of each replay
    occupancy: float  # mean in-flight replays = sum(span) / makespan
    phase1_overlap: float  # upload time overlapped with earlier replays
    trace: Trace  # aggregate communication across all replays

    @property
    def spans(self) -> np.ndarray:
        return self.completions - self.starts


def summarize(runs: List[RunMetrics]) -> Dict:
    """Aggregate a list of runs into distribution-level statistics."""
    if not runs:
        return {"runs": 0}
    times = np.array([r.completion_time for r in runs])
    subsets: Dict[Tuple[int, ...], int] = {}
    for r in runs:
        k = r.decode_subset_key
        subsets[k] = subsets.get(k, 0) + 1
    top = sorted(subsets.items(), key=lambda kv: -kv[1])[:3]
    return {
        "runs": len(runs),
        "products": int(sum(r.batch for r in runs)),
        "completion_mean": float(times.mean()),
        "completion_p50": float(np.percentile(times, 50)),
        "completion_p95": float(np.percentile(times, 95)),
        "completion_max": float(times.max()),
        "effective_workers_mean": float(
            np.mean([r.effective_workers for r in runs])
        ),
        "n_provisioned": runs[0].n_provisioned,
        "dropped_mean": float(np.mean([r.n_dropped for r in runs])),
        "rejected_total": int(sum(r.rejected_ids.size for r in runs)),
        "decode_subsets_distinct": len(subsets),
        "decode_subsets_top": [
            {"subset": list(k), "count": c} for k, c in top
        ],
        "wire_bytes_mean": float(np.mean([r.trace.total_bytes for r in runs])),
    }
