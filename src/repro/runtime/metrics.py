"""Per-run runtime metrics and cross-run aggregation.

``RunMetrics`` records the timeline of one event-driven execution
(when each protocol phase unblocked), the communication trace (with the
bytes-level view from ``protocol.Trace``), which workers actually
served each phase, and what the master rejected as corrupt.  These are
the quantities behind the paper's edge claims: completion time under
stragglers, and how many provisioned workers were actually needed.

``summarize`` aggregates a list of runs into the latency distribution
(mean / p50 / p95 / max), mean effective worker count, decode-subset
statistics (how many distinct responder subsets the master decoded
from — the hit pattern of the planner's subset-matrix caches), and
total wire bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..core.protocol import Trace


@dataclasses.dataclass
class RunMetrics:
    """Timeline + accounting of one run over a worker pool."""

    completion_time: float  # master accepts the decode
    phase1_last_share: float  # last share delivered to a live worker
    phase2_set_time: float  # fastest n_workers finished H -> set fixed
    first_response: float  # first I(alpha_n) at the master
    n_provisioned: int
    n_dropped: int
    n_crashed: int
    phase2_ids: np.ndarray  # the fastest-subset Phase-2 senders
    responder_ids: np.ndarray  # accepted Phase-3 decode subset
    confirmed_by: np.ndarray  # extra responders that verified the decode
    rejected_ids: np.ndarray  # responders detected as corrupt
    trace: Trace  # communication (elements + bytes views)
    batch: int = 1  # products served by this replay (batched runtime)
    # Berlekamp-Welch-identified corrupt responders whose errors the
    # decode corrected (decode_mode="correct"); empty under "detect".
    corrected_workers: np.ndarray = dataclasses.field(
        default_factory=lambda: np.array([], np.int64)
    )

    @property
    def effective_workers(self) -> int:
        """Distinct workers whose output the result depends on."""
        return int(
            np.union1d(np.union1d(self.phase2_ids, self.responder_ids),
                       self.confirmed_by).size
        )

    @property
    def observed_corrupt(self) -> int:
        """Responders caught misbehaving, either strategy: detected and
        discarded (``rejected_ids``) or BW-corrected
        (``corrected_workers``)."""
        return int(self.rejected_ids.size + self.corrected_workers.size)

    @property
    def decode_subset_key(self) -> Tuple[int, ...]:
        return tuple(int(i) for i in np.sort(self.responder_ids))


@dataclasses.dataclass
class PipelineMetrics:
    """Aggregate view of K pipelined batched replays.

    Per-replay timestamps are *absolute* on the shared pipeline clock
    (replay k's Phase-1 upload starts once the master's per-worker
    links free up from replay k-1).  ``occupancy`` is the mean number
    of in-flight replays over the makespan — sum of per-replay spans
    divided by the makespan; 1.0 means no overlap at all, values
    toward ``depth`` mean the pipeline is saturated.
    ``phase1_overlap`` totals the Phase-1 upload time that ran while
    an earlier replay was still in flight (the transfer/compute
    overlap the scalar runtime could not express).
    """

    depth: int  # replays in flight (K)
    batch: int  # products per replay
    products: int  # depth * batch
    makespan: float  # last replay accepted (absolute)
    completions: np.ndarray  # [K] absolute acceptance times
    starts: np.ndarray  # [K] first Phase-1 send of each replay
    occupancy: float  # mean in-flight replays = sum(span) / makespan
    phase1_overlap: float  # upload time overlapped with earlier replays
    trace: Trace  # aggregate communication across all replays

    def __post_init__(self):
        # Loud guards: an empty or time-inverted pipeline is a harness
        # bug, not a statistic — fail here instead of emitting NaN /
        # division-by-zero ratios downstream.
        if self.depth < 1:
            raise ValueError(
                f"pipeline needs at least one replay, got depth={self.depth}"
            )
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if not np.isfinite(self.makespan) or self.makespan < 0:
            raise ValueError(
                f"makespan must be finite and >= 0, got {self.makespan}"
            )

    @property
    def spans(self) -> np.ndarray:
        return self.completions - self.starts

    @property
    def overlap_ratio(self) -> float:
        """Phase-1 overlap as a fraction of the makespan.  A zero
        makespan (every leg instantaneous) has no overlap to attribute,
        so the ratio is a defined 0.0 — never a division error."""
        if self.makespan <= 0:
            return 0.0
        return float(self.phase1_overlap / self.makespan)


def summarize(runs: List[RunMetrics]) -> Dict:
    """Aggregate a list of runs into distribution-level statistics.

    An empty list is a defined outcome, not an error: callers summarize
    whatever subset of runs survived (e.g. all-failure fault sweeps),
    so ``summarize([])`` returns ``{"runs": 0}`` — no percentile or
    mean is ever taken over zero samples (regression-tested).
    """
    if not runs:
        return {"runs": 0}
    times = np.array([r.completion_time for r in runs])
    subsets: Dict[Tuple[int, ...], int] = {}
    for r in runs:
        k = r.decode_subset_key
        subsets[k] = subsets.get(k, 0) + 1
    top = sorted(subsets.items(), key=lambda kv: -kv[1])[:3]
    return {
        "runs": len(runs),
        "products": int(sum(r.batch for r in runs)),
        "completion_mean": float(times.mean()),
        "completion_p50": float(np.percentile(times, 50)),
        "completion_p95": float(np.percentile(times, 95)),
        "completion_max": float(times.max()),
        "effective_workers_mean": float(
            np.mean([r.effective_workers for r in runs])
        ),
        "n_provisioned": runs[0].n_provisioned,
        "dropped_mean": float(np.mean([r.n_dropped for r in runs])),
        "rejected_total": int(sum(r.rejected_ids.size for r in runs)),
        "corrected_total": int(sum(r.corrected_workers.size for r in runs)),
        "decode_subsets_distinct": len(subsets),
        "decode_subsets_top": [
            {"subset": list(k), "count": c} for k, c in top
        ],
        "wire_bytes_mean": float(np.mean([r.trace.total_bytes for r in runs])),
    }


# ----------------------------------------------------------------------
# estimators: what the master can infer about the pool from its runs
# ----------------------------------------------------------------------
#
# The event loop's two waits are order statistics of i.i.d. per-worker
# delays: the Phase-2 set fixes at the n_workers-th fastest
# share+compute completion, and the decode at the (threshold+extras)-th
# fastest exchange+uplink response.  Under the literature's
# shifted-exponential straggler model the k-th of n order statistic has
# mean ``shift + scale * (H_n - H_{n-k})`` (harmonic-number
# differences), so each observed run contributes one linear equation in
# (shift, scale) per wait — a handful of runs over different (k, n)
# pins both legs, and an auto-planner can extrapolate completion times
# to constructions it has never executed.


def harmonic(n: int) -> float:
    """H_n = sum_{i=1..n} 1/i (H_0 = 0)."""
    n = int(n)
    if n <= 0:
        return 0.0
    return float(np.sum(1.0 / np.arange(1, n + 1)))


def order_stat_mean(k: int, n: int, shift: float, scale: float) -> float:
    """Mean k-th of n order statistic of shift + Exp(scale) draws."""
    if k <= 0:
        return 0.0
    if k > n:
        return float("inf")
    return shift + scale * (harmonic(n) - harmonic(n - k))


def fit_order_stats(samples: Sequence[Tuple[float, int, int]]) -> Tuple[float, float]:
    """Least-squares (shift, scale) from (value, k, n) order-stat samples.

    Each sample says "the k-th of n i.i.d. delays was observed at
    ``value``", i.e. ``value ~= shift + scale * (H_n - H_{n-k})``.
    With fewer than two distinct harmonic gaps the system is
    underdetermined; attribute everything to ``scale`` (shift 0), which
    keeps extrapolation proportional — the conservative choice for
    ranking constructions by tail exposure.  ``scale`` is clamped >= 0.
    """
    pts = [
        (float(v), harmonic(n) - harmonic(n - k))
        for v, k, n in samples
        if 0 < k <= n
    ]
    if not pts:
        return 0.0, 0.0
    v = np.array([p[0] for p in pts])
    h = np.array([p[1] for p in pts])
    if np.ptp(h) < 1e-12 or len(pts) < 2:
        mean_h = float(h.mean())
        return 0.0, float(v.mean() / mean_h) if mean_h > 0 else 0.0
    a = np.stack([np.ones_like(h), h], axis=1)
    (shift, scale), *_ = np.linalg.lstsq(a, v, rcond=None)
    if scale < 0:  # pathological fit; fall back to proportional
        mean_h = float(h.mean())
        return 0.0, float(v.mean() / mean_h) if mean_h > 0 else 0.0
    return float(shift), float(scale)


@dataclasses.dataclass(frozen=True)
class ObservedRun:
    """Master-observable outcome of one replay — auto-planner food.

    All times are relative to the replay's own start (pass the absolute
    pipeline start to ``observed_run`` for pipelined replays).
    """

    n_pool: int  # provisioned workers
    n_workers: int  # Phase-2 set size (k of the ready order stat)
    n_ready_pool: int  # live workers racing for the set (its n)
    thr_arrived: int  # responses in hand at acceptance
    n_receivers: int  # live, non-crashed workers able to respond
    set_time: float  # Phase-2 set announcement
    response_delta: float  # completion - set_time (exchange+uplink leg)
    completion: float
    n_dropped: int
    n_rejected: int
    n_corrected: int = 0  # BW-corrected responders (decode_mode="correct")


def observed_run(m: RunMetrics, start: float = 0.0) -> ObservedRun:
    """Project a :class:`RunMetrics` onto what the master could observe."""
    n_live = m.n_provisioned - m.n_dropped
    return ObservedRun(
        n_pool=m.n_provisioned,
        n_workers=int(m.phase2_ids.size),
        n_ready_pool=n_live,
        thr_arrived=int(
            m.responder_ids.size + m.confirmed_by.size + m.rejected_ids.size
            + m.corrected_workers.size
        ),
        n_receivers=n_live - m.n_crashed,
        set_time=float(m.phase2_set_time - start),
        response_delta=float(m.completion_time - m.phase2_set_time),
        completion=float(m.completion_time - start),
        n_dropped=m.n_dropped,
        n_rejected=int(m.rejected_ids.size),
        n_corrected=int(m.corrected_workers.size),
    )


@dataclasses.dataclass(frozen=True)
class PoolEstimate:
    """Fitted pool behaviour: straggler tails and fault rates.

    ``ready_*`` parameterize the share+compute leg (Phase-1 delivery
    through H(alpha_n) completion), ``resp_*`` the exchange+uplink leg
    (Phase-2 announcement through a response landing at the master),
    both as shifted exponentials.  Rates are empirical frequencies.
    """

    ready_shift: float
    ready_scale: float
    resp_shift: float
    resp_scale: float
    dropout_rate: float
    crash_rate: float
    corrupt_rate: float
    n_runs: int

    def predict_completion(
        self, n_workers: int, threshold: int, pool_size: int
    ) -> float:
        """Expected completion of a construction on this pool.

        ``inf`` when the pool cannot field the Phase-2 set or the
        decode threshold after expected dropouts/crashes — the planner
        treats that as infeasible.
        """
        n_live = int(np.floor(pool_size * (1.0 - self.dropout_rate)))
        if n_workers > n_live:
            return float("inf")
        t_set = order_stat_mean(
            n_workers, n_live, self.ready_shift, self.ready_scale
        )
        n_recv = int(np.floor(n_live * (1.0 - self.crash_rate)))
        if threshold > n_recv:
            return float("inf")
        t_resp = order_stat_mean(
            threshold, n_recv, self.resp_shift, self.resp_scale
        )
        return t_set + t_resp


# Uninformed prior: unit-scale exponentials on both legs, no faults.
# Ranking candidates under it orders them purely by harmonic gaps —
# i.e. by how deep into the pool's tail each construction must reach.
DEFAULT_ESTIMATE = PoolEstimate(
    ready_shift=0.0,
    ready_scale=1.0,
    resp_shift=0.0,
    resp_scale=1.0,
    dropout_rate=0.0,
    crash_rate=0.0,
    corrupt_rate=0.0,
    n_runs=0,
)


def estimate_pool(runs: Sequence[ObservedRun]) -> PoolEstimate:
    """Fit a :class:`PoolEstimate` from observed replays.

    Runs may come from *different* constructions and pool sizes — that
    diversity is what makes the order-stat fits well-posed (each run
    contributes a different harmonic gap).  Falls back to
    :data:`DEFAULT_ESTIMATE` on an empty list.
    """
    runs = list(runs)
    if not runs:
        return DEFAULT_ESTIMATE
    ready_shift, ready_scale = fit_order_stats(
        [(r.set_time, r.n_workers, r.n_ready_pool) for r in runs]
    )
    resp_shift, resp_scale = fit_order_stats(
        [(r.response_delta, r.thr_arrived, r.n_receivers) for r in runs]
    )
    pool = sum(r.n_pool for r in runs)
    recv = sum(r.n_receivers for r in runs)
    return PoolEstimate(
        ready_shift=ready_shift,
        ready_scale=ready_scale,
        resp_shift=resp_shift,
        resp_scale=resp_scale,
        dropout_rate=sum(r.n_dropped for r in runs) / max(pool, 1),
        crash_rate=sum(r.n_ready_pool - r.n_receivers for r in runs)
        / max(sum(r.n_ready_pool for r in runs), 1),
        corrupt_rate=sum(r.n_rejected + r.n_corrected for r in runs)
        / max(recv, 1),
        n_runs=len(runs),
    )
