"""Simulated edge worker pool: latency models and fault injection.

A ``WorkerTrace`` is the *replayable* per-worker behaviour of one
protocol execution: message and compute delays plus fault flags, all
sampled up front from a seeded generator.  Sampling is separated from
scheduling so the same trace can be replayed against different schemes
— the scheme-comparison benchmark samples one trace at the largest
provisioned pool size and hands each scheme a prefix (``take``), so
PolyDot-CMPC and AGE-CMPC face byte-identical worker behaviour.

Latency models (per-worker, independent):

* ``Deterministic``        — constant; the all-fast baseline and the
                              unit-test fixture (schedule fully known),
* ``ShiftedExponential``   — shift + Exp(scale): the standard
                              straggler model of the coded-computation
                              literature,
* ``HeavyTail``            — shift + scale * Pareto(alpha): rare but
                              extreme stragglers (alpha <= 2 has
                              infinite variance).

Fault injection (``FaultSpec`` for Bernoulli sampling, or the explicit
``with_faults`` placement used when a test/benchmark needs exact
counts, e.g. "dropouts up to n_spare"):

* straggler          — compute slowed by ``straggler_slowdown``,
* dropout            — never computes or responds (lost share / dead),
* crash-after-phase-2 — serves the Phase-2 exchange, then crashes
                        before reporting I(alpha_n) to the master,
* corrupt            — responds on time with garbage (detected by the
                        scheduler via decode-consistency checks).

Fault flags are made disjoint with priority dropout > crash > corrupt
(a dropped worker cannot also crash later).

Per-link network models (``NetworkModel``): edge networks are defined
by heterogeneous *links*, not just heterogeneous workers, so a trace
can carry link-resolved delays instead of one scalar per worker:

* Phase 1 (master -> worker): the ``share_delay`` vector,
* Phase 2 (worker <-> worker): a ``link_delay[s, r]`` matrix — the
  delay of the exchange message from sender ``s`` to receiver ``r``
  (diagonal 0: a worker's own contribution crosses no link),
* Phase 3 (worker -> master): the ``uplink_delay`` vector.

``UniformLinks`` draws every link i.i.d., ``AsymmetricLinks`` scales
the master downlink / uplink / D2D fabrics independently (asymmetric
uplink is the defining property of last-mile edge connectivity), and
``ClusteredEdge`` partitions workers into clusters with fast
intra-cluster and slow inter-cluster links.  When ``link_delay`` is
``None`` the scheduler falls back to the scalar ``d2d_delay`` —
replays of existing traces are byte-identical — and ``take`` slices
the matrix ``[:n, :n]``, so link traces stay prefix-sliceable.
``with_dropped_links`` marks individual directed links dead
(infinite delay): a receiver missing an incoming Phase-2 link *from a
Phase-2 sender* can never finish its I(alpha_n) sum, so it goes
silent in Phase 3 while still serving as a Phase-2 *sender* —
strictly weaker than dropping the worker.  A dead link from a worker
outside the fastest-``n_workers`` sender set has no effect: receivers
only sum contributions from the senders.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


# ----------------------------------------------------------------------
# latency models
# ----------------------------------------------------------------------
class LatencyModel:
    """Per-worker delay distribution; ``sample`` returns seconds > 0."""

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Deterministic(LatencyModel):
    value: float = 1.0

    def sample(self, rng, n):
        rng.random(n)  # consume the stream so fault draws stay aligned
        return np.full(n, float(self.value))


@dataclasses.dataclass(frozen=True)
class ShiftedExponential(LatencyModel):
    shift: float = 1.0
    scale: float = 1.0

    def sample(self, rng, n):
        return self.shift + rng.exponential(self.scale, size=n)


@dataclasses.dataclass(frozen=True)
class HeavyTail(LatencyModel):
    shift: float = 1.0
    scale: float = 0.5
    alpha: float = 1.5

    def sample(self, rng, n):
        return self.shift + self.scale * rng.pareto(self.alpha, size=n)


# ----------------------------------------------------------------------
# per-link network models
# ----------------------------------------------------------------------
class NetworkModel:
    """Per-link delay sampler for one protocol execution.

    ``sample_links(rng, n)`` returns ``(share, link, uplink)``:

    * ``share[r]``    — master -> worker ``r`` Phase-1 delivery delay,
    * ``link[s, r]``  — worker ``s`` -> worker ``r`` Phase-2 exchange
                         delay (diagonal forced to 0),
    * ``uplink[r]``   — worker ``r`` -> master Phase-3 response delay.

    The draw order is fixed (share, then the row-major link matrix,
    then uplink), so a seeded trace is reproducible.
    """

    def sample_links(self, rng: np.random.Generator, n: int):
        raise NotImplementedError

    @staticmethod
    def _zero_diag(link: np.ndarray) -> np.ndarray:
        np.fill_diagonal(link, 0.0)
        return link


@dataclasses.dataclass(frozen=True)
class UniformLinks(NetworkModel):
    """Every link i.i.d. from one latency model, uniformly scaled.

    The link-resolved generalization of the legacy scalar sampling: a
    receiver's Phase-2 completion becomes the max over its incoming
    links instead of one draw.
    """

    model: LatencyModel = Deterministic(1.0)
    scale: float = 0.1

    def sample_links(self, rng, n):
        share = self.scale * self.model.sample(rng, n)
        link = self._zero_diag(
            self.scale * self.model.sample(rng, n * n).reshape(n, n)
        )
        uplink = self.scale * self.model.sample(rng, n)
        return share, link, uplink


@dataclasses.dataclass(frozen=True)
class AsymmetricLinks(NetworkModel):
    """Asymmetric master downlink / D2D fabric / master uplink.

    Last-mile edge connectivity is uplink-constrained: the Phase-3
    worker -> master responses ride the slow direction while Phase-1
    share delivery rides the fast one.  Each direction draws from the
    same latency model under its own scale.
    """

    model: LatencyModel = Deterministic(1.0)
    down_scale: float = 0.1  # master -> worker (Phase-1 shares)
    d2d_scale: float = 0.1  # worker <-> worker (Phase-2 exchange)
    up_scale: float = 0.5  # worker -> master (Phase-3 responses)

    def sample_links(self, rng, n):
        share = self.down_scale * self.model.sample(rng, n)
        link = self._zero_diag(
            self.d2d_scale * self.model.sample(rng, n * n).reshape(n, n)
        )
        uplink = self.up_scale * self.model.sample(rng, n)
        return share, link, uplink


@dataclasses.dataclass(frozen=True)
class ClusteredEdge(NetworkModel):
    """Workers in round-robin clusters; inter-cluster links are slow.

    Worker ``w`` belongs to cluster ``w % n_clusters``.  Intra-cluster
    Phase-2 links scale by ``intra_scale``, inter-cluster by
    ``inter_scale``; master links (Phase 1 / Phase 3) by
    ``master_scale``.  Models the paper's edge setting where devices
    hang off a few access points: D2D within an access point is cheap,
    crossing between them is not.
    """

    model: LatencyModel = Deterministic(1.0)
    n_clusters: int = 2
    intra_scale: float = 0.05
    inter_scale: float = 0.5
    master_scale: float = 0.1

    def sample_links(self, rng, n):
        share = self.master_scale * self.model.sample(rng, n)
        raw = self.model.sample(rng, n * n).reshape(n, n)
        cluster = np.arange(n) % self.n_clusters
        same = cluster[:, None] == cluster[None, :]
        link = self._zero_diag(
            np.where(same, self.intra_scale, self.inter_scale) * raw
        )
        uplink = self.master_scale * self.model.sample(rng, n)
        return share, link, uplink


# ----------------------------------------------------------------------
# fault injection
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Bernoulli fault probabilities, applied per worker."""

    straggler_frac: float = 0.0
    straggler_slowdown: float = 10.0
    dropout_frac: float = 0.0
    crash_after_phase2_frac: float = 0.0
    corrupt_frac: float = 0.0


NO_FAULTS = FaultSpec()


@dataclasses.dataclass(frozen=True)
class WorkerTrace:
    """Replayable behaviour of every provisioned worker.

    All arrays are length ``n`` (the pool size).  Delays are in
    arbitrary time units; the scheduler only compares and adds them.
    """

    share_delay: np.ndarray  # Phase-1 share delivery to worker n
    compute_delay: np.ndarray  # Phase-2a H(alpha_n) compute duration
    d2d_delay: np.ndarray  # Phase-2 exchange receive delay at worker n
    uplink_delay: np.ndarray  # Phase-3 response delay worker -> master
    dropout: np.ndarray  # bool
    crash_after_phase2: np.ndarray  # bool
    corrupt: np.ndarray  # bool
    # Optional [n, n] Phase-2 link matrix: link_delay[s, r] is the
    # sender-s -> receiver-r exchange delay (diagonal 0; np.inf = dead
    # link).  None = legacy scalar model: every incoming link of
    # receiver r costs d2d_delay[r].
    link_delay: Optional[np.ndarray] = None
    # Optional time-varying fabric: sorted (start_time, [n, n] matrix)
    # entries.  From ``start_time`` onward the entry's matrix replaces
    # ``link_delay`` for Phase-2 exchange legs *sent* at or after that
    # time; before the first entry ``link_delay`` applies.  Attached by
    # ``TimeVaryingLinks.apply`` (explicit matrices, no extra random
    # draws, so the pre-degradation replay is byte-identical).
    link_schedule: Optional[Tuple[Tuple[float, np.ndarray], ...]] = None
    # The *configured* fault model this trace was sampled under — what
    # the master legitimately knows about the pool (it provisioned it),
    # as opposed to the sampled fault flags above, which are ground
    # truth the master must never peek at.  ``verify_extras="auto"`` and
    # ``error_budget="auto"`` resolve from this; ``None`` means "no
    # fault model declared" (hand-built traces), which resolves to no
    # protection.
    fault_model: Optional[FaultSpec] = None

    @property
    def n(self) -> int:
        return int(self.share_delay.size)

    def __post_init__(self):
        for f in dataclasses.fields(self):
            if f.name in ("link_delay", "link_schedule", "fault_model"):
                continue
            arr = getattr(self, f.name)
            if arr.shape != (self.n,):
                raise ValueError(f"{f.name} must be a [{self.n}] vector")
        if self.link_delay is not None and self.link_delay.shape != (self.n, self.n):
            raise ValueError(
                f"link_delay must be a [{self.n}, {self.n}] matrix, "
                f"got {self.link_delay.shape}"
            )
        if self.link_schedule is not None:
            if self.link_delay is None:
                raise ValueError(
                    "link_schedule needs a base link_delay matrix "
                    "(materialize with with_links first)"
                )
            for start, mat in self.link_schedule:
                if mat.shape != (self.n, self.n):
                    raise ValueError(
                        f"link_schedule matrix at t={start} must be "
                        f"[{self.n}, {self.n}], got {mat.shape}"
                    )

    def link_at(self, t: float) -> Optional[np.ndarray]:
        """Phase-2 link matrix in effect for exchanges sent at time ``t``.

        ``None`` when the trace is scalar (no link matrix at all);
        otherwise the latest scheduled matrix whose start time is
        <= ``t``, falling back to ``link_delay`` before the first one.
        """
        mat = self.link_delay
        if self.link_schedule:
            for start, m in self.link_schedule:
                if t >= start:
                    mat = m
        return mat

    def _copy_fields(self) -> dict:
        out = {}
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            if arr is None:
                out[f.name] = None
            elif f.name == "link_schedule":
                out[f.name] = tuple((s, m.copy()) for s, m in arr)
            elif f.name == "fault_model":
                out[f.name] = arr  # frozen spec, shared by reference
            else:
                out[f.name] = arr.copy()
        return out

    def take(self, n: int) -> "WorkerTrace":
        """First-n-workers prefix (replay one trace across schemes).

        The link matrices slice ``[:n, :n]`` — a prefix pool keeps
        exactly the sub-fabric among its own workers.
        """
        if n > self.n:
            raise ValueError(f"trace holds {self.n} workers, need {n}")
        return self.select(np.arange(n))

    def select(self, ids: Sequence[int]) -> "WorkerTrace":
        """Arbitrary-membership sub-pool (elastic workers join/leave).

        Generalizes ``take``: the returned trace covers exactly the
        workers in ``ids`` (in the given order), with link matrices
        sliced to the sub-fabric among them, so a worker keeps
        byte-identical behaviour across every replay it attends.
        """
        idx = self._checked_ids("select ids", ids)
        out = {}
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            if arr is None:
                out[f.name] = None
            elif f.name == "link_delay":
                out[f.name] = arr[np.ix_(idx, idx)].copy()
            elif f.name == "link_schedule":
                out[f.name] = tuple(
                    (s, m[np.ix_(idx, idx)].copy()) for s, m in arr
                )
            elif f.name == "fault_model":
                out[f.name] = arr  # pool-level configuration, id-free
            else:
                out[f.name] = arr[idx].copy()
        return WorkerTrace(**out)

    def with_link_matrix(self, link: np.ndarray) -> "WorkerTrace":
        """Attach an explicit [n, n] Phase-2 link matrix.

        Validates the documented invariants beyond the shape check of
        ``__post_init__``: entries are non-negative and not NaN
        (``np.inf`` marks a dead link), and the diagonal is 0 — a
        worker's own contribution crosses no link, so a nonzero
        diagonal would silently add a phantom self-exchange delay.
        """
        link = np.asarray(link, float)
        if np.isnan(link).any() or (link < 0).any():
            raise ValueError("link_delay entries must be >= 0 (inf = dead)")
        if link.ndim == 2 and link.shape[0] == link.shape[1] and (
            np.diag(link) != 0.0
        ).any():
            raise ValueError("link_delay diagonal must be 0 (no self-link)")
        return dataclasses.replace(self, link_delay=link)

    def with_links(self) -> "WorkerTrace":
        """Materialize the scalar D2D model as an equivalent link matrix.

        Every incoming link of receiver ``r`` costs ``d2d_delay[r]``
        (receiver-constant columns, diagonal 0), so a replay is
        timeline-identical to the scalar trace — the starting point for
        link-level edits such as ``with_dropped_links``.
        """
        link = np.broadcast_to(self.d2d_delay[None, :], (self.n, self.n)).copy()
        np.fill_diagonal(link, 0.0)
        return dataclasses.replace(self, link_delay=link)

    def with_dropped_links(
        self, links: Sequence[Tuple[int, int]]
    ) -> "WorkerTrace":
        """Mark directed Phase-2 links (sender, receiver) as dead.

        A dead incoming link from a *Phase-2 sender* starves the
        receiver's I(alpha_n) sum, so the receiver never responds in
        Phase 3 — but unlike a dropped *worker* it still computes and
        serves as a Phase-2 sender itself.  A dead link whose sender
        ends up outside the fastest-``n_workers`` set is harmless
        (receivers only sum the senders' contributions), so experiments
        that need the starvation should check the sender landed in
        ``RunMetrics.phase2_ids``.  Materializes the link matrix if the
        trace is still scalar.
        """
        base = self if self.link_delay is not None else self.with_links()
        link = base.link_delay.copy()
        for s, r in links:
            s = int(s)
            r = int(r)
            if not (0 <= s < self.n and 0 <= r < self.n):
                raise ValueError(
                    f"link ({s}, {r}) out of range for a pool of {self.n}"
                )
            if s == r:
                raise ValueError(f"link ({s}, {r}) is a self-loop")
            link[s, r] = np.inf
        return dataclasses.replace(base, link_delay=link)

    def _checked_ids(self, name: str, ids: Sequence[int]) -> np.ndarray:
        """Validate explicit worker indices against the pool size.

        numpy fancy indexing would silently wrap negatives and raise an
        opaque IndexError past ``n``; fault placement demands exact
        worker identities, so reject out-of-range and duplicate ids with
        a pool-aware error instead.
        """
        arr = np.asarray(list(ids), dtype=np.int64)
        if arr.size == 0:
            return arr
        bad = arr[(arr < 0) | (arr >= self.n)]
        if bad.size:
            raise ValueError(
                f"{name} indices {bad.tolist()} out of range for a pool "
                f"of {self.n} workers (need 0 <= id < {self.n})"
            )
        if np.unique(arr).size != arr.size:
            raise ValueError(f"{name} contains duplicate worker indices: {arr.tolist()}")
        return arr

    def with_faults(
        self,
        dropout_ids: Sequence[int] = (),
        crash_ids: Sequence[int] = (),
        corrupt_ids: Sequence[int] = (),
        straggler_ids: Sequence[int] = (),
        straggler_slowdown: float = 10.0,
    ) -> "WorkerTrace":
        """Deterministic fault placement on explicit worker indices.

        Explicit placement is a *configuration* act, so the trace's
        ``fault_model`` is updated to admit at least the placed fraction
        of each fault class: the master learns "corruption is possible
        on this pool" (which it would know, having configured it), never
        *which* workers the flags landed on.
        """
        out = self._copy_fields()
        drop = self._checked_ids("dropout_ids", dropout_ids)
        crash = self._checked_ids("crash_ids", crash_ids)
        corr = self._checked_ids("corrupt_ids", corrupt_ids)
        out["dropout"][drop] = True
        out["crash_after_phase2"][crash] = True
        out["corrupt"][corr] = True
        sl = self._checked_ids("straggler_ids", straggler_ids)
        out["compute_delay"][sl] = out["compute_delay"][sl] * straggler_slowdown
        fm = out["fault_model"] or NO_FAULTS
        out["fault_model"] = dataclasses.replace(
            fm,
            dropout_frac=max(fm.dropout_frac, drop.size / self.n),
            crash_after_phase2_frac=max(
                fm.crash_after_phase2_frac, crash.size / self.n
            ),
            corrupt_frac=max(fm.corrupt_frac, corr.size / self.n),
        )
        return WorkerTrace(**out)._disjoint()

    def _disjoint(self) -> "WorkerTrace":
        crash = self.crash_after_phase2 & ~self.dropout
        corrupt = self.corrupt & ~self.dropout & ~crash
        return dataclasses.replace(self, crash_after_phase2=crash, corrupt=corrupt)


# ----------------------------------------------------------------------
# time-varying links and elastic pools (the auto-planner's scenarios)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TimeVaryingLinks:
    """Deterministic mid-replay Phase-2 link degradation schedule.

    ``schedule`` holds ``(start_time, factor)`` entries with strictly
    increasing non-negative start times: from ``start_time`` onward
    every Phase-2 link delay is the trace's base matrix scaled by
    ``factor`` (> 1 degrades, < 1 recovers; the 0 diagonal and dead
    ``inf`` links are preserved by scaling).  ``apply`` attaches the
    schedule to a trace as explicit matrices — no extra random draws —
    so the replay before the first start time is byte-identical to the
    base trace, and the scheduled trace prefix-slices (``take`` /
    ``select``) like any link-resolved trace.
    """

    schedule: Tuple[Tuple[float, float], ...]

    def __post_init__(self):
        starts = [float(s) for s, _ in self.schedule]
        if any(s < 0 for s in starts):
            raise ValueError("schedule start times must be >= 0")
        if any(b <= a for a, b in zip(starts, starts[1:])):
            raise ValueError("schedule start times must be strictly increasing")
        if any(float(f) <= 0 for _, f in self.schedule):
            raise ValueError("schedule factors must be > 0")

    def apply(self, trace: WorkerTrace) -> WorkerTrace:
        base = trace if trace.link_delay is not None else trace.with_links()
        entries = tuple(
            (float(s), base.link_delay * float(f)) for s, f in self.schedule
        )
        return dataclasses.replace(base, link_schedule=entries)


@dataclasses.dataclass(frozen=True)
class ElasticPool:
    """Per-replay worker membership over one master trace.

    ``master`` records the behaviour of every worker that ever appears;
    ``membership[k]`` lists the ids present for replay ``k``, so
    workers join and leave between replays while each attending
    worker's behaviour stays byte-identical (every replay trace is a
    ``select`` of the same master draw — an elastic replay equals a
    static run over the same members).  A shrinking pool is what forces
    an auto-planner to re-fit spares or switch constructions between
    replays.  Iterating yields the per-replay traces.
    """

    master: WorkerTrace
    membership: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        norm = tuple(tuple(int(i) for i in ids) for ids in self.membership)
        object.__setattr__(self, "membership", norm)
        for k, ids in enumerate(norm):
            self.master._checked_ids(f"membership[{k}]", ids)

    @property
    def depth(self) -> int:
        return len(self.membership)

    def sizes(self) -> Tuple[int, ...]:
        return tuple(len(ids) for ids in self.membership)

    def trace_for(self, k: int) -> WorkerTrace:
        return self.master.select(self.membership[k])

    def __len__(self) -> int:
        return self.depth

    def __iter__(self):
        return (self.trace_for(k) for k in range(self.depth))


def sample_trace(
    n: int,
    latency: Optional[LatencyModel] = None,
    faults: FaultSpec = NO_FAULTS,
    seed: int = 0,
    net_scale: float = 0.1,
    network: Optional[NetworkModel] = None,
) -> WorkerTrace:
    """Sample one replayable trace for a pool of ``n`` workers.

    ``latency`` drives the compute-time draw.  Without ``network``, the
    three network delays (share delivery, D2D exchange, uplink) are
    independent per-worker draws from the same model scaled by
    ``net_scale`` (edge links are fast relative to compute, but share
    the same tail shape).  With a ``network`` model the delays are
    link-resolved instead: ``share_delay`` / ``uplink_delay`` become
    the master links and the trace carries the full ``link_delay[s, r]``
    Phase-2 matrix (``net_scale`` is then unused; ``d2d_delay`` is kept
    as the per-receiver mean of its incoming links — a display summary
    the scheduler ignores once the matrix is present).

    Draw order is fixed, so two calls with the same seed, ``n``, and
    model arguments are identical — but traces of different ``n`` are
    *not* prefixes of each other; sample once at the largest pool size
    and ``take`` prefixes when several schemes must see identical
    worker (and link) behaviour.
    """
    latency = latency or Deterministic()
    rng = np.random.default_rng(seed)
    compute = latency.sample(rng, n)
    if network is None:
        share = net_scale * latency.sample(rng, n)
        d2d = net_scale * latency.sample(rng, n)
        uplink = net_scale * latency.sample(rng, n)
        link = None
    else:
        share, link, uplink = network.sample_links(rng, n)
        off_diag = link.sum(axis=0) / max(n - 1, 1)  # incoming mean, diag is 0
        d2d = off_diag
    straggler = rng.random(n) < faults.straggler_frac
    compute = np.where(straggler, compute * faults.straggler_slowdown, compute)
    trace = WorkerTrace(
        share_delay=share,
        compute_delay=compute,
        d2d_delay=d2d,
        uplink_delay=uplink,
        dropout=rng.random(n) < faults.dropout_frac,
        crash_after_phase2=rng.random(n) < faults.crash_after_phase2_frac,
        corrupt=rng.random(n) < faults.corrupt_frac,
        link_delay=link,
        fault_model=faults,
    )
    return trace._disjoint()
