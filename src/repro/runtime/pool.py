"""Simulated edge worker pool: latency models and fault injection.

A ``WorkerTrace`` is the *replayable* per-worker behaviour of one
protocol execution: message and compute delays plus fault flags, all
sampled up front from a seeded generator.  Sampling is separated from
scheduling so the same trace can be replayed against different schemes
— the scheme-comparison benchmark samples one trace at the largest
provisioned pool size and hands each scheme a prefix (``take``), so
PolyDot-CMPC and AGE-CMPC face byte-identical worker behaviour.

Latency models (per-worker, independent):

* ``Deterministic``        — constant; the all-fast baseline and the
                              unit-test fixture (schedule fully known),
* ``ShiftedExponential``   — shift + Exp(scale): the standard
                              straggler model of the coded-computation
                              literature,
* ``HeavyTail``            — shift + scale * Pareto(alpha): rare but
                              extreme stragglers (alpha <= 2 has
                              infinite variance).

Fault injection (``FaultSpec`` for Bernoulli sampling, or the explicit
``with_faults`` placement used when a test/benchmark needs exact
counts, e.g. "dropouts up to n_spare"):

* straggler          — compute slowed by ``straggler_slowdown``,
* dropout            — never computes or responds (lost share / dead),
* crash-after-phase-2 — serves the Phase-2 exchange, then crashes
                        before reporting I(alpha_n) to the master,
* corrupt            — responds on time with garbage (detected by the
                        scheduler via decode-consistency checks).

Fault flags are made disjoint with priority dropout > crash > corrupt
(a dropped worker cannot also crash later).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


# ----------------------------------------------------------------------
# latency models
# ----------------------------------------------------------------------
class LatencyModel:
    """Per-worker delay distribution; ``sample`` returns seconds > 0."""

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Deterministic(LatencyModel):
    value: float = 1.0

    def sample(self, rng, n):
        rng.random(n)  # consume the stream so fault draws stay aligned
        return np.full(n, float(self.value))


@dataclasses.dataclass(frozen=True)
class ShiftedExponential(LatencyModel):
    shift: float = 1.0
    scale: float = 1.0

    def sample(self, rng, n):
        return self.shift + rng.exponential(self.scale, size=n)


@dataclasses.dataclass(frozen=True)
class HeavyTail(LatencyModel):
    shift: float = 1.0
    scale: float = 0.5
    alpha: float = 1.5

    def sample(self, rng, n):
        return self.shift + self.scale * rng.pareto(self.alpha, size=n)


# ----------------------------------------------------------------------
# fault injection
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Bernoulli fault probabilities, applied per worker."""

    straggler_frac: float = 0.0
    straggler_slowdown: float = 10.0
    dropout_frac: float = 0.0
    crash_after_phase2_frac: float = 0.0
    corrupt_frac: float = 0.0


NO_FAULTS = FaultSpec()


@dataclasses.dataclass(frozen=True)
class WorkerTrace:
    """Replayable behaviour of every provisioned worker.

    All arrays are length ``n`` (the pool size).  Delays are in
    arbitrary time units; the scheduler only compares and adds them.
    """

    share_delay: np.ndarray  # Phase-1 share delivery to worker n
    compute_delay: np.ndarray  # Phase-2a H(alpha_n) compute duration
    d2d_delay: np.ndarray  # Phase-2 exchange receive delay at worker n
    uplink_delay: np.ndarray  # Phase-3 response delay worker -> master
    dropout: np.ndarray  # bool
    crash_after_phase2: np.ndarray  # bool
    corrupt: np.ndarray  # bool

    @property
    def n(self) -> int:
        return int(self.share_delay.size)

    def __post_init__(self):
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            if arr.shape != (self.n,):
                raise ValueError(f"{f.name} must be a [{self.n}] vector")

    def take(self, n: int) -> "WorkerTrace":
        """First-n-workers prefix (replay one trace across schemes)."""
        if n > self.n:
            raise ValueError(f"trace holds {self.n} workers, need {n}")
        return WorkerTrace(
            **{
                f.name: getattr(self, f.name)[:n].copy()
                for f in dataclasses.fields(self)
            }
        )

    def _checked_ids(self, name: str, ids: Sequence[int]) -> np.ndarray:
        """Validate explicit worker indices against the pool size.

        numpy fancy indexing would silently wrap negatives and raise an
        opaque IndexError past ``n``; fault placement demands exact
        worker identities, so reject out-of-range and duplicate ids with
        a pool-aware error instead.
        """
        arr = np.asarray(list(ids), dtype=np.int64)
        if arr.size == 0:
            return arr
        bad = arr[(arr < 0) | (arr >= self.n)]
        if bad.size:
            raise ValueError(
                f"{name} indices {bad.tolist()} out of range for a pool "
                f"of {self.n} workers (need 0 <= id < {self.n})"
            )
        if np.unique(arr).size != arr.size:
            raise ValueError(f"{name} contains duplicate worker indices: {arr.tolist()}")
        return arr

    def with_faults(
        self,
        dropout_ids: Sequence[int] = (),
        crash_ids: Sequence[int] = (),
        corrupt_ids: Sequence[int] = (),
        straggler_ids: Sequence[int] = (),
        straggler_slowdown: float = 10.0,
    ) -> "WorkerTrace":
        """Deterministic fault placement on explicit worker indices."""
        out = {f.name: getattr(self, f.name).copy() for f in dataclasses.fields(self)}
        out["dropout"][self._checked_ids("dropout_ids", dropout_ids)] = True
        out["crash_after_phase2"][self._checked_ids("crash_ids", crash_ids)] = True
        out["corrupt"][self._checked_ids("corrupt_ids", corrupt_ids)] = True
        sl = self._checked_ids("straggler_ids", straggler_ids)
        out["compute_delay"][sl] = out["compute_delay"][sl] * straggler_slowdown
        return WorkerTrace(**out)._disjoint()

    def _disjoint(self) -> "WorkerTrace":
        crash = self.crash_after_phase2 & ~self.dropout
        corrupt = self.corrupt & ~self.dropout & ~crash
        return dataclasses.replace(self, crash_after_phase2=crash, corrupt=corrupt)


def sample_trace(
    n: int,
    latency: Optional[LatencyModel] = None,
    faults: FaultSpec = NO_FAULTS,
    seed: int = 0,
    net_scale: float = 0.1,
) -> WorkerTrace:
    """Sample one replayable trace for a pool of ``n`` workers.

    ``latency`` drives the compute-time draw; the three network delays
    (share delivery, D2D exchange, uplink) are independent draws from
    the same model scaled by ``net_scale`` (edge links are fast relative
    to compute, but share the same tail shape).

    Draw order is fixed, so two calls with the same seed and ``n`` are
    identical — but traces of different ``n`` are *not* prefixes of each
    other; sample once at the largest pool size and ``take`` prefixes
    when several schemes must see identical worker behaviour.
    """
    latency = latency or Deterministic()
    rng = np.random.default_rng(seed)
    compute = latency.sample(rng, n)
    share = net_scale * latency.sample(rng, n)
    d2d = net_scale * latency.sample(rng, n)
    uplink = net_scale * latency.sample(rng, n)
    straggler = rng.random(n) < faults.straggler_frac
    compute = np.where(straggler, compute * faults.straggler_slowdown, compute)
    trace = WorkerTrace(
        share_delay=share,
        compute_delay=compute,
        d2d_delay=d2d,
        uplink_delay=uplink,
        dropout=rng.random(n) < faults.dropout_frac,
        crash_after_phase2=rng.random(n) < faults.crash_after_phase2_frac,
        corrupt=rng.random(n) < faults.corrupt_frac,
    )
    return trace._disjoint()
