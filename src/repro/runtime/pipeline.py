"""Pipelined batched replays: K protocol executions in flight at once.

``run_batch_over_pool`` amortizes the event loop across a *batch* of
products, but successive batches still run back-to-back: replay k+1's
Phase-1 upload waits for replay k's decode, even though the master's
links and the workers sit idle for most of that span.  This module
overlaps them — the ROADMAP's "pipelining many batched replays with
overlapping traces" item, and its Phase-1/Phase-2 overlap rule is the
"overlapping Phase-1 transfers with Phase-2 compute" item.

Pipeline timing model (two serial resources, everything else overlaps):

* **master -> worker link**: replay k's share to worker ``w`` starts
  the moment replay k-1's share to ``w`` has *arrived* (store-and-
  forward per link; links to different workers are independent), so
  ``arrive[k, w] = sum_{j <= k} share_delay_j(w)``,
* **worker compute**: worker ``w`` starts replay k's H(alpha_n) at
  ``max(arrive[k, w], finish[k-1, w])`` — one multiply at a time;
  dropped workers never compute, so they release the worker
  immediately.  A worker *abandons* replay k's compute the moment
  replay k's Phase-2 set is announced without it: its H(alpha_n) can
  no longer enter the exchange, so queueing it further would only
  starve replay k+1 (without cancellation a straggler's stale compute
  compounds across replays and pipelining can lose to back-to-back
  execution).

Phases 2 and 3 of each replay proceed independently through the shared
event loop (``scheduler._replay_events``) with these absolute times
injected: each in-flight replay fixes its own fastest-``n_workers``
Phase-2 set, runs its own (link-aware) exchange, and decodes from its
own fastest responder subset — the fastest-subset/decode-subset
machinery is reused per replay, per-replay traces may differ (that is
what "overlapping traces" means), and faults are per-(replay, worker).

The upshot: replay k+1's Phase-1 transfers overlap replay k's Phase-2
compute whenever ``share_delay`` < completion span, which is exactly
the edge regime (fast links, slow/heterogeneous compute).  Aggregate
accounting lands in :class:`~repro.runtime.metrics.PipelineMetrics`
(makespan, per-replay spans, pipeline occupancy, Phase-1 overlap, and
the summed communication ``Trace``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import numpy as np

from ..core import protocol as proto
from ..core.planner import CMPCPlan
from ..obs.metrics import REGISTRY
from .metrics import PipelineMetrics, RunMetrics
from .pool import WorkerTrace
from .scheduler import (
    DEFAULT_SUBSET_TRIES,
    _batched_compute_closure,
    _build_metrics,
    _check_pool,
    _replay_events,
    _resolve_decode_mode,
    _resolve_error_budget,
    _resolve_verify_extras,
    _unfold_batched_y,
)


@dataclasses.dataclass
class PipelineRun:
    """Result of K pipelined batched replays.

    ``y[k]`` is replay k's decoded batch; ``replay_metrics[k]`` its
    :class:`RunMetrics` on the absolute pipeline clock (batch-level
    aggregate accounting, like ``BatchEdgeRun.metrics``); ``metrics``
    the cross-replay :class:`PipelineMetrics`.
    """

    y: np.ndarray  # [K, batch, ma, mb]
    replay_metrics: List[RunMetrics]
    metrics: PipelineMetrics


def _prep_pipeline_operands(plan, a, b, depth: int):
    """Promote operands to [K, batch, k, m]; validate against the plan
    when one is fixed up front (auto-planned pipelines pick per-replay
    plans whose block splits differ, but the global dims still bind)."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.ndim == 3:  # [K, k, m] -> batch-1 replays
        a = a[:, None]
    if b.ndim == 3:
        b = b[:, None]
    if a.ndim != 4 or b.ndim != 4:
        raise ValueError(
            f"expected [K, batch, k, m] operand stacks, got {a.shape} {b.shape}"
        )
    if a.shape[0] != depth or b.shape[0] != depth:
        raise ValueError(
            f"{depth} traces but operand stacks of depth {a.shape[0]} / "
            f"{b.shape[0]}"
        )
    if a.shape[1] != b.shape[1]:
        raise ValueError(f"batch mismatch: {a.shape[1]} vs {b.shape[1]}")
    if a.shape[2] != b.shape[2]:
        raise ValueError(f"inner-dim mismatch: {a.shape[2]} vs {b.shape[2]}")
    if plan is not None:
        sh = plan.shapes
        if a.shape[2:] != (sh.k, sh.ma) or b.shape[2:] != (sh.k, sh.mb):
            raise ValueError(
                f"operands {a.shape[2:]}/{b.shape[2:]} disagree with plan "
                f"shapes ({sh.k}, {sh.ma})/({sh.k}, {sh.mb})"
            )
    return a, b


def run_pipeline_over_pool(
    plan: Optional[CMPCPlan],
    a: np.ndarray,
    b: np.ndarray,
    traces: Sequence[WorkerTrace],
    seed: int = 0,
    verify_extras="auto",
    master_decode_cost: float = 0.0,
    mesh=None,
    axis: str = "workers",
    mode: str = "all_to_all",
    backend: str = "auto",
    planner=None,
    plan_seed: int = 0,
    compute_scale="auto",
    decode_mode: str = "detect",
    error_budget="auto",
    max_subset_tries: int = DEFAULT_SUBSET_TRIES,
) -> PipelineRun:
    """Run K batched replays through the pool with overlapping traces.

    a: [K, batch, k, ma], b: [K, batch, k, mb] ([K, k, m] promotes to
    batch 1); ``traces`` holds one :class:`WorkerTrace` per replay
    (they may differ — each replay faces its own latency/fault/link
    draw).  Replay k+1's Phase-1 upload to each worker starts as soon
    as that master link is free, so transfers overlap earlier replays'
    Phase-2 compute; each replay then fixes its own Phase-2 subset and
    decode subset through the shared event loop.  Per-replay decode
    failures raise :class:`DecodeFailure` exactly like the standalone
    entry points.

    With ``planner`` (an :class:`~repro.runtime.autoplan.AutoPlanner`)
    the construction is chosen *per replay* at the pipeline's replay
    boundaries: the planner decides from everything observed so far,
    the chosen config is re-fitted to the (fixed-size) pool, and the
    replay's outcome feeds back before the next decision — mid-stream
    scheme/lambda/spare switching inside one pipeline.  ``plan`` may
    then be ``None``; pool size must be constant across traces (the
    pipeline's serialized master links and worker occupancy assume a
    stable worker set — elastic pools go through
    :func:`~repro.runtime.autoplan.run_adaptive_over_pool`).

    ``compute_scale``: per-unit-work compute scaling (see
    ``run_batch_over_pool``).  The default ``"auto"`` resolves to the
    planner's per-construction work factor when a planner is given
    (different constructions do different per-worker work on the same
    trace) and to 1.0 otherwise; pass a float to force one scale.

    ``decode_mode`` / ``error_budget`` / ``max_subset_tries``: the
    corruption-handling knobs of ``run_over_pool``, resolved *per
    replay* against each trace's configured fault model (replays in one
    pipeline may face differently-provisioned fault draws).

    Randomness: replay k draws from ``default_rng([seed, k])`` and the
    folded JAX key, so replays are independent but the whole pipeline
    is reproducible per seed.

    Returns :class:`PipelineRun` with per-replay results on one
    absolute clock plus the aggregate :class:`PipelineMetrics`.
    """
    depth = len(traces)
    if depth == 0:
        raise ValueError("need at least one trace/replay")
    if plan is None and planner is None:
        raise ValueError("need a plan or a planner")
    if planner is None:
        for k, trace in enumerate(traces):
            if trace.n != plan.n_total:
                raise ValueError(
                    f"trace {k} covers {trace.n} workers, plan provisions "
                    f"{plan.n_total}"
                )
    else:
        sizes = {trace.n for trace in traces}
        if len(sizes) != 1:
            raise ValueError(
                f"pipelined replays need one pool size, got {sorted(sizes)}"
            )
    a, b = _prep_pipeline_operands(plan, a, b, depth)
    batch = int(a.shape[1])
    key = jax.random.PRNGKey(seed)

    n = plan.n_total if plan is not None else traces[0].n
    upload_free = np.zeros(n)  # when the master's link to w frees up
    worker_free = np.zeros(n)  # when worker w's compute frees up

    ys = []
    replay_metrics: List[RunMetrics] = []
    starts = np.zeros(depth)
    completions = np.zeros(depth)
    phase1_lasts = np.zeros(depth)
    agg_trace = None

    for k, trace in enumerate(traces):
        if planner is None:
            decision = None
            plan_k = plan
        else:
            # Replay-boundary feedback: decide from everything observed
            # so far, re-fitting spares to the pool (same-construction
            # decisions hit the plan cache; spare refits take the
            # replan fast path).
            from .autoplan import plan_for_decision

            decision = planner.decide(trace.n)
            plan_k = plan_for_decision(
                decision,
                int(a.shape[2]),
                int(a.shape[3]),
                int(b.shape[3]),
                seed=plan_seed,
            )
        alive = _check_pool(plan_k, trace)
        extras_k = _resolve_verify_extras(verify_extras, trace)
        budget_k = _resolve_error_budget(error_budget, trace, plan_k)
        mode_k = _resolve_decode_mode(decode_mode, budget_k)
        rng = np.random.default_rng([seed, k])
        if compute_scale == "auto":
            scale_k = (
                planner.work_factor(decision.config) if planner is not None else 1.0
            )
        else:
            scale_k = float(compute_scale)

        # -- pipeline timing: serialize the master links and compute --
        starts[k] = float(upload_free.min())
        arrive = upload_free + trace.share_delay
        upload_free = arrive.copy()
        comp_start = np.maximum(arrive, worker_free)
        finish = np.where(
            trace.dropout, comp_start, comp_start + scale_k * trace.compute_delay
        )
        # worker_free is updated after the replay: non-set workers
        # abandon at the Phase-2 announcement (see below).

        # -- numeric path: same batched engine as run_batch_over_pool --
        a_j, b_j = proto._prep_batched_operands(plan_k, a[k], b[k])
        fa, fb = proto.share_batched(
            plan_k, a_j, b_j, jax.random.fold_in(key, k), backend=backend
        )
        compute_i_all = _batched_compute_closure(
            plan_k, fa, fb, rng, batch, mesh, axis, mode, backend
        )
        # Trace annotations: lane index + absolute start, plus the
        # deciding PlanDecision when a planner drives the pipeline
        # (decision_id links the replay span to its autoplan.decide
        # event).
        obs_k = {"replay": k, "t_start": float(starts[k]), "batch": batch}
        if decision is not None:
            obs_k["decision_id"] = decision.obs_id
            obs_k["config"] = decision.config.label()
        res = _replay_events(
            plan_k,
            trace,
            alive,
            compute_i_all,
            extras_k,
            rng,
            master_decode_cost,
            share_arrival=arrive,
            compute_finish=finish,
            decode_mode=mode_k,
            error_budget=budget_k,
            max_subset_tries=max_subset_tries,
            obs_attrs=obs_k,
        )
        # Straggler cancellation: a worker outside replay k's Phase-2
        # set abandons its (now useless) H-compute when the set is
        # announced, freeing it for replay k+1.  Set members finished
        # at or before the announcement, so they are unaffected.
        in_set = np.zeros(n, bool)
        in_set[res.phase2_ids] = True
        abandoned = ~in_set & ~trace.dropout
        worker_free = np.where(
            abandoned,
            np.minimum(finish, np.maximum(comp_start, res.phase2_set_time)),
            finish,
        )

        ys.append(_unfold_batched_y(plan_k, res.coeffs, batch))
        m = _build_metrics(plan_k, trace, alive, res, batch=batch)
        replay_metrics.append(m)
        if planner is not None:
            planner.observe(decision.config, m, start=starts[k])
        completions[k] = m.completion_time
        phase1_lasts[k] = m.phase1_last_share
        agg_trace = m.trace if agg_trace is None else agg_trace + m.trace

    makespan = float(completions.max())
    spans = completions - starts
    # Phase-1 upload time of replay k that ran while replay k-1 (or any
    # earlier one) was still in flight — the overlap the sequential
    # runtime forgoes entirely.
    prev_busy_until = np.concatenate(([0.0], np.maximum.accumulate(completions)[:-1]))
    phase1_overlap = float(
        np.maximum(0.0, np.minimum(phase1_lasts, prev_busy_until) - starts).sum()
    )
    metrics = PipelineMetrics(
        depth=depth,
        batch=batch,
        products=depth * batch,
        makespan=makespan,
        completions=completions,
        starts=starts,
        occupancy=float(spans.sum() / makespan) if makespan > 0 else 0.0,
        phase1_overlap=phase1_overlap,
        trace=agg_trace,
    )
    REGISTRY.counter("pipeline.runs").inc()
    REGISTRY.gauge("pipeline.occupancy").set(metrics.occupancy)
    REGISTRY.gauge("pipeline.makespan").set(metrics.makespan)
    REGISTRY.gauge("pipeline.overlap_ratio").set(metrics.overlap_ratio)
    return PipelineRun(
        y=np.stack(ys), replay_metrics=replay_metrics, metrics=metrics
    )
