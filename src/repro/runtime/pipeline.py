"""Pipelined batched replays: K protocol executions in flight at once.

``run_batch_over_pool`` amortizes the event loop across a *batch* of
products, but successive batches still run back-to-back: replay k+1's
Phase-1 upload waits for replay k's decode, even though the master's
links and the workers sit idle for most of that span.  This module
overlaps them — the ROADMAP's "pipelining many batched replays with
overlapping traces" item, and its Phase-1/Phase-2 overlap rule is the
"overlapping Phase-1 transfers with Phase-2 compute" item.

Pipeline timing model (two serial resources, everything else overlaps):

* **master -> worker link**: replay k's share to worker ``w`` starts
  the moment replay k-1's share to ``w`` has *arrived* (store-and-
  forward per link; links to different workers are independent), so
  ``arrive[k, w] = sum_{j <= k} share_delay_j(w)``,
* **worker compute**: worker ``w`` starts replay k's H(alpha_n) at
  ``max(arrive[k, w], finish[k-1, w])`` — one multiply at a time;
  dropped workers never compute, so they release the worker
  immediately.  A worker *abandons* replay k's compute the moment
  replay k's Phase-2 set is announced without it: its H(alpha_n) can
  no longer enter the exchange, so queueing it further would only
  starve replay k+1 (without cancellation a straggler's stale compute
  compounds across replays and pipelining can lose to back-to-back
  execution).

Phases 2 and 3 of each replay proceed independently through the shared
event loop (``scheduler._replay_events``) with these absolute times
injected: each in-flight replay fixes its own fastest-``n_workers``
Phase-2 set, runs its own (link-aware) exchange, and decodes from its
own fastest responder subset — the fastest-subset/decode-subset
machinery is reused per replay, per-replay traces may differ (that is
what "overlapping traces" means), and faults are per-(replay, worker).

The upshot: replay k+1's Phase-1 transfers overlap replay k's Phase-2
compute whenever ``share_delay`` < completion span, which is exactly
the edge regime (fast links, slow/heterogeneous compute).  Aggregate
accounting lands in :class:`~repro.runtime.metrics.PipelineMetrics`
(makespan, per-replay spans, pipeline occupancy, Phase-1 overlap, and
the summed communication ``Trace``).

Two entry points share one implementation:

* :class:`PipelineSession` — the stateful core: replays are *appended*
  one at a time against the live link/compute occupancy, so a caller
  (the serving engine) can decide replay k+1's contents *after* seeing
  replay k's outcome, inject request-arrival floors (``not_before``),
  and stop whenever its queue drains.  Nothing about the pipeline is
  fixed up front — not the depth, not the batch sizes, not even the
  construction (per-append planner decisions).
* :func:`run_pipeline_over_pool` — the fixed-K convenience wrapper:
  prepares a ``[K, batch, k, m]`` operand stack and appends each slice
  back-to-back.  Replays byte-identically to the pre-session
  implementation (same rng streams, same timestamps).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import numpy as np

from ..core import protocol as proto
from ..core.planner import CMPCPlan
from ..obs.metrics import REGISTRY
from .metrics import PipelineMetrics, RunMetrics
from .pool import WorkerTrace
from .scheduler import (
    DEFAULT_SUBSET_TRIES,
    HybridState,
    _batched_compute_closure,
    _build_metrics,
    _check_pool,
    _replay_events,
    _resolve_decode_mode,
    _resolve_error_budget,
    _resolve_hybrid,
    _resolve_verify_extras,
    _unfold_batched_y,
)


@dataclasses.dataclass
class PipelineRun:
    """Result of K pipelined batched replays.

    ``y[k]`` is replay k's decoded batch; ``replay_metrics[k]`` its
    :class:`RunMetrics` on the absolute pipeline clock (batch-level
    aggregate accounting, like ``BatchEdgeRun.metrics``); ``metrics``
    the cross-replay :class:`PipelineMetrics`.
    """

    y: np.ndarray  # [K, batch, ma, mb]
    replay_metrics: List[RunMetrics]
    metrics: PipelineMetrics


def _prep_pipeline_operands(plan, a, b, depth: int):
    """Promote operands to [K, batch, k, m]; validate against the plan
    when one is fixed up front (auto-planned pipelines pick per-replay
    plans whose block splits differ, but the global dims still bind)."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.ndim == 3:  # [K, k, m] -> batch-1 replays
        a = a[:, None]
    if b.ndim == 3:
        b = b[:, None]
    if a.ndim != 4 or b.ndim != 4:
        raise ValueError(
            f"expected [K, batch, k, m] operand stacks, got {a.shape} {b.shape}"
        )
    if a.shape[0] != depth or b.shape[0] != depth:
        raise ValueError(
            f"{depth} traces but operand stacks of depth {a.shape[0]} / "
            f"{b.shape[0]}"
        )
    if a.shape[1] != b.shape[1]:
        raise ValueError(f"batch mismatch: {a.shape[1]} vs {b.shape[1]}")
    if a.shape[2] != b.shape[2]:
        raise ValueError(f"inner-dim mismatch: {a.shape[2]} vs {b.shape[2]}")
    if plan is not None:
        sh = plan.shapes
        if a.shape[2:] != (sh.k, sh.ma) or b.shape[2:] != (sh.k, sh.mb):
            raise ValueError(
                f"operands {a.shape[2:]}/{b.shape[2:]} disagree with plan "
                f"shapes ({sh.k}, {sh.ma})/({sh.k}, {sh.mb})"
            )
    return a, b


@dataclasses.dataclass
class PipelineReplay:
    """One appended replay's outcome on the session's absolute clock."""

    index: int  # session replay index (rng lane)
    y: np.ndarray  # [batch, ma, mb]
    metrics: RunMetrics
    start: float  # first Phase-1 send (absolute)
    completion: float  # decode acceptance (absolute)
    batch: int
    decision: Optional[object] = None  # PlanDecision when planner-driven


class PipelineSession:
    """Stateful pipelined replays: append batched replays mid-flight.

    Holds the two serial resources of the pipeline timing model — the
    per-worker master-link occupancy (``upload_free``) and per-worker
    compute occupancy (``worker_free``) — across an *open-ended*
    sequence of :meth:`append` calls.  Each append replays one batch
    through the shared event loop at absolute times derived from the
    live occupancy, exactly as one iteration of the fixed-K pipeline;
    between appends the caller is free to look at results, consult a
    queue, or change the batch size — that is what lets the serving
    engine admit requests into an in-flight pipeline instead of
    waiting for a batch boundary.

    ``not_before`` on an append floors the replay's upload start (a
    request that arrives at t cannot be shared before t); with the
    default 0.0 and ``base_time=0.0`` the session replays
    byte-identically to the historical fixed-K loop — same rng streams
    (``default_rng([seed, k])`` / folded JAX key, ``k`` the session
    replay counter), same timestamps.

    ``base_time`` starts the clock late: a session rebuilt after a pool
    reconfiguration continues on the absolute clock of its predecessor.

    ``decode_mode="hybrid"`` holds one :class:`HybridState` for the
    whole session (pass ``hybrid_state`` to share or pre-escalate it):
    the first rejected responder on any append escalates every later
    append to Berlekamp-Welch correction.

    Pool size is fixed by the first append (the serialized occupancy
    vectors assume a stable worker set); a pool resize needs a new
    session — see the serving engine's reconfiguration barrier.
    """

    def __init__(
        self,
        plan: Optional[CMPCPlan] = None,
        *,
        seed: int = 0,
        verify_extras="auto",
        master_decode_cost: float = 0.0,
        mesh=None,
        axis: str = "workers",
        mode: str = "all_to_all",
        backend: str = "auto",
        planner=None,
        plan_seed: int = 0,
        compute_scale="auto",
        decode_mode: str = "detect",
        error_budget="auto",
        max_subset_tries: int = DEFAULT_SUBSET_TRIES,
        base_time: float = 0.0,
        hybrid_state: Optional[HybridState] = None,
    ):
        if plan is None and planner is None:
            raise ValueError("need a plan or a planner")
        self.plan = plan
        self.planner = planner
        self.seed = seed
        self.base_time = float(base_time)
        self._verify_extras = verify_extras
        self._master_decode_cost = master_decode_cost
        self._mesh = mesh
        self._axis = axis
        self._mode = mode
        self._backend = backend
        self._plan_seed = plan_seed
        self._compute_scale = compute_scale
        self._decode_mode = decode_mode
        self._error_budget = error_budget
        self._max_subset_tries = max_subset_tries
        if hybrid_state is None and decode_mode == "hybrid":
            hybrid_state = HybridState()
        self.hybrid_state = hybrid_state
        self._key = jax.random.PRNGKey(seed)

        self._n: Optional[int] = None  # pool size, fixed at first append
        self._upload_free: Optional[np.ndarray] = None
        self._worker_free: Optional[np.ndarray] = None
        self._replays: List[PipelineReplay] = []
        self._agg_trace = None

    # -- introspection the batcher schedules against --------------------

    @property
    def depth(self) -> int:
        """Replays appended so far."""
        return len(self._replays)

    def next_start(self) -> float:
        """Earliest absolute time the next append's Phase 1 can begin
        (the soonest any master link frees up) — the continuous
        batcher's launch clock."""
        if self._upload_free is None:
            return self.base_time
        return float(self._upload_free.min())

    def busy_until(self) -> float:
        """Latest decode acceptance so far (``base_time`` when empty) —
        the batch-boundary launch clock."""
        if not self._replays:
            return self.base_time
        return max(r.completion for r in self._replays)

    def ready_at(self, pipe_depth: int = 1) -> float:
        """Earliest launch time keeping at most ``pipe_depth`` replays
        in flight (and the master uplink free).

        ``pipe_depth=1`` is the batch-boundary discipline — wait for
        every in-flight replay to decode.  ``pipe_depth>=2`` is
        continuous batching: the next replay's Phase-1 upload launches
        while the tail replay is still in its Phase-2/Phase-3 window,
        so requests overlap the in-flight batch instead of waiting for
        the pool to drain.
        """
        if pipe_depth < 1:
            raise ValueError(f"pipe_depth must be >= 1, got {pipe_depth}")
        t = self.next_start()
        if len(self._replays) >= pipe_depth:
            comps = sorted(r.completion for r in self._replays)
            t = max(t, comps[len(comps) - pipe_depth])
        return t

    # -- the core: one replay against the live occupancy ----------------

    def append(
        self,
        a: np.ndarray,
        b: np.ndarray,
        trace: WorkerTrace,
        *,
        not_before: float = 0.0,
        obs_attrs: Optional[dict] = None,
    ) -> PipelineReplay:
        """Replay one batch (a: [batch, k, ma], b: [batch, k, mb]; 2D
        promotes to batch 1) against ``trace`` at the live occupancy.

        ``not_before`` floors the upload start on every master link —
        the serving engine passes the launch time its admission loop
        chose (>= the admitted requests' arrivals).  Raises
        :class:`~repro.runtime.scheduler.DecodeFailure` exactly like
        the standalone entry points; a failed append leaves the
        occupancy state untouched (the replay never ran).
        """
        k = len(self._replays)
        if self.planner is None:
            decision = None
            plan_k = self.plan
            if trace.n != plan_k.n_total:
                raise ValueError(
                    f"trace {k} covers {trace.n} workers, plan provisions "
                    f"{plan_k.n_total}"
                )
        else:
            # Replay-boundary feedback: decide from everything observed
            # so far, re-fitting spares to the pool (same-construction
            # decisions hit the plan cache; spare refits take the
            # replan fast path).
            from .autoplan import plan_for_decision

            a_dims = np.asarray(a)
            b_dims = np.asarray(b)
            decision = self.planner.decide(trace.n)
            plan_k = plan_for_decision(
                decision,
                int(a_dims.shape[-2]),
                int(a_dims.shape[-1]),
                int(b_dims.shape[-1]),
                seed=self._plan_seed,
            )
        if self._n is None:
            self._n = trace.n
            self._upload_free = np.full(self._n, self.base_time)
            self._worker_free = np.full(self._n, self.base_time)
        elif trace.n != self._n:
            raise ValueError(
                f"pipelined replays need one pool size, got "
                f"{sorted({self._n, trace.n})}"
            )
        alive = _check_pool(plan_k, trace)
        extras_k = _resolve_verify_extras(self._verify_extras, trace)
        budget_k = _resolve_error_budget(self._error_budget, trace, plan_k)
        mode_k, budget_k, _ = _resolve_hybrid(
            self._decode_mode, self.hybrid_state, budget_k, plan_k
        )
        mode_k = _resolve_decode_mode(mode_k, budget_k)
        rng = np.random.default_rng([self.seed, k])
        if self._compute_scale == "auto":
            scale_k = (
                self.planner.work_factor(decision.config)
                if self.planner is not None
                else 1.0
            )
        else:
            scale_k = float(self._compute_scale)

        # -- pipeline timing: serialize the master links and compute --
        upload_base = np.maximum(self._upload_free, float(not_before))
        start = float(upload_base.min())
        arrive = upload_base + trace.share_delay
        comp_start = np.maximum(arrive, self._worker_free)
        finish = np.where(
            trace.dropout, comp_start, comp_start + scale_k * trace.compute_delay
        )
        # upload_free/worker_free commit only after the replay succeeds
        # (a DecodeFailure must not half-advance the occupancy).

        # -- numeric path: same batched engine as run_batch_over_pool --
        a_j, b_j = proto._prep_batched_operands(plan_k, a, b)
        batch = int(a_j.shape[0])
        fa, fb = proto.share_batched(
            plan_k, a_j, b_j, jax.random.fold_in(self._key, k),
            backend=self._backend,
        )
        compute_i_all = _batched_compute_closure(
            plan_k, fa, fb, rng, batch, self._mesh, self._axis, self._mode,
            self._backend,
        )
        # Trace annotations: lane index + absolute start, plus the
        # deciding PlanDecision when a planner drives the pipeline
        # (decision_id links the replay span to its autoplan.decide
        # event).
        obs_k = {"replay": k, "t_start": start, "batch": batch}
        if decision is not None:
            obs_k["decision_id"] = decision.obs_id
            obs_k["config"] = decision.config.label()
        if obs_attrs:
            obs_k.update(obs_attrs)
        res = _replay_events(
            plan_k,
            trace,
            alive,
            compute_i_all,
            extras_k,
            rng,
            self._master_decode_cost,
            share_arrival=arrive,
            compute_finish=finish,
            decode_mode=mode_k,
            error_budget=budget_k,
            max_subset_tries=self._max_subset_tries,
            obs_attrs=obs_k,
        )
        self._upload_free = arrive.copy()
        # Straggler cancellation: a worker outside replay k's Phase-2
        # set abandons its (now useless) H-compute when the set is
        # announced, freeing it for replay k+1.  Set members finished
        # at or before the announcement, so they are unaffected.
        in_set = np.zeros(self._n, bool)
        in_set[res.phase2_ids] = True
        abandoned = ~in_set & ~trace.dropout
        self._worker_free = np.where(
            abandoned,
            np.minimum(finish, np.maximum(comp_start, res.phase2_set_time)),
            finish,
        )

        y = _unfold_batched_y(plan_k, res.coeffs, batch)
        m = _build_metrics(plan_k, trace, alive, res, batch=batch)
        if self.hybrid_state is not None:
            self.hybrid_state.note(m)
        if self.planner is not None:
            self.planner.observe(decision.config, m, start=start)
        self._agg_trace = (
            m.trace if self._agg_trace is None else self._agg_trace + m.trace
        )
        replay = PipelineReplay(
            index=k, y=y, metrics=m, start=start,
            completion=m.completion_time, batch=batch, decision=decision,
        )
        self._replays.append(replay)
        return replay

    # -- aggregation ----------------------------------------------------

    def result(self) -> PipelineRun:
        """Aggregate everything appended so far into a
        :class:`PipelineRun` (requires at least one replay; ``y`` is
        stacked only when every append used one batch size, else the
        per-replay ``replay_metrics``/session records are the API)."""
        if not self._replays:
            raise ValueError("need at least one trace/replay")
        depth = len(self._replays)
        starts = np.array([r.start for r in self._replays])
        completions = np.array([r.completion for r in self._replays])
        phase1_lasts = np.array(
            [r.metrics.phase1_last_share for r in self._replays]
        )
        batches = [r.batch for r in self._replays]
        makespan = float(completions.max())
        busy = makespan - self.base_time
        spans = completions - starts
        # Phase-1 upload time of replay k that ran while replay k-1 (or
        # any earlier one) was still in flight — the overlap the
        # sequential runtime forgoes entirely.
        prev_busy_until = np.concatenate(
            ([self.base_time], np.maximum.accumulate(completions)[:-1])
        )
        phase1_overlap = float(
            np.maximum(
                0.0, np.minimum(phase1_lasts, prev_busy_until) - starts
            ).sum()
        )
        metrics = PipelineMetrics(
            depth=depth,
            batch=max(batches),
            products=int(sum(batches)),
            makespan=makespan,
            completions=completions,
            starts=starts,
            occupancy=float(spans.sum() / busy) if busy > 0 else 0.0,
            phase1_overlap=phase1_overlap,
            trace=self._agg_trace,
        )
        REGISTRY.counter("pipeline.runs").inc()
        REGISTRY.gauge("pipeline.occupancy").set(metrics.occupancy)
        REGISTRY.gauge("pipeline.makespan").set(metrics.makespan)
        REGISTRY.gauge("pipeline.overlap_ratio").set(metrics.overlap_ratio)
        uniform = len(set(batches)) == 1
        y = (
            np.stack([r.y for r in self._replays])
            if uniform
            else np.concatenate([r.y for r in self._replays])
        )
        return PipelineRun(
            y=y,
            replay_metrics=[r.metrics for r in self._replays],
            metrics=metrics,
        )


def run_pipeline_over_pool(
    plan: Optional[CMPCPlan],
    a: np.ndarray,
    b: np.ndarray,
    traces: Sequence[WorkerTrace],
    seed: int = 0,
    verify_extras="auto",
    master_decode_cost: float = 0.0,
    mesh=None,
    axis: str = "workers",
    mode: str = "all_to_all",
    backend: str = "auto",
    planner=None,
    plan_seed: int = 0,
    compute_scale="auto",
    decode_mode: str = "detect",
    error_budget="auto",
    max_subset_tries: int = DEFAULT_SUBSET_TRIES,
    hybrid_state: Optional[HybridState] = None,
) -> PipelineRun:
    """Run K batched replays through the pool with overlapping traces.

    a: [K, batch, k, ma], b: [K, batch, k, mb] ([K, k, m] promotes to
    batch 1); ``traces`` holds one :class:`WorkerTrace` per replay
    (they may differ — each replay faces its own latency/fault/link
    draw).  Replay k+1's Phase-1 upload to each worker starts as soon
    as that master link is free, so transfers overlap earlier replays'
    Phase-2 compute; each replay then fixes its own Phase-2 subset and
    decode subset through the shared event loop.  Per-replay decode
    failures raise :class:`DecodeFailure` exactly like the standalone
    entry points.

    With ``planner`` (an :class:`~repro.runtime.autoplan.AutoPlanner`)
    the construction is chosen *per replay* at the pipeline's replay
    boundaries: the planner decides from everything observed so far,
    the chosen config is re-fitted to the (fixed-size) pool, and the
    replay's outcome feeds back before the next decision — mid-stream
    scheme/lambda/spare switching inside one pipeline.  ``plan`` may
    then be ``None``; pool size must be constant across traces (the
    pipeline's serialized master links and worker occupancy assume a
    stable worker set — elastic pools go through
    :func:`~repro.runtime.autoplan.run_adaptive_over_pool`).

    ``compute_scale``: per-unit-work compute scaling (see
    ``run_batch_over_pool``).  The default ``"auto"`` resolves to the
    planner's per-construction work factor when a planner is given
    (different constructions do different per-worker work on the same
    trace) and to 1.0 otherwise; pass a float to force one scale.

    ``decode_mode`` / ``error_budget`` / ``max_subset_tries``: the
    corruption-handling knobs of ``run_over_pool``, resolved *per
    replay* against each trace's configured fault model (replays in one
    pipeline may face differently-provisioned fault draws);
    ``decode_mode="hybrid"`` escalates to BW correction after the first
    rejected responder (``hybrid_state`` shares/pre-escalates the
    cross-replay state).

    Randomness: replay k draws from ``default_rng([seed, k])`` and the
    folded JAX key, so replays are independent but the whole pipeline
    is reproducible per seed.

    Returns :class:`PipelineRun` with per-replay results on one
    absolute clock plus the aggregate :class:`PipelineMetrics`.
    """
    depth = len(traces)
    if depth == 0:
        raise ValueError("need at least one trace/replay")
    if plan is None and planner is None:
        raise ValueError("need a plan or a planner")
    if planner is None:
        for k, trace in enumerate(traces):
            if trace.n != plan.n_total:
                raise ValueError(
                    f"trace {k} covers {trace.n} workers, plan provisions "
                    f"{plan.n_total}"
                )
    else:
        sizes = {trace.n for trace in traces}
        if len(sizes) != 1:
            raise ValueError(
                f"pipelined replays need one pool size, got {sorted(sizes)}"
            )
    a, b = _prep_pipeline_operands(plan, a, b, depth)
    session = PipelineSession(
        plan,
        seed=seed,
        verify_extras=verify_extras,
        master_decode_cost=master_decode_cost,
        mesh=mesh,
        axis=axis,
        mode=mode,
        backend=backend,
        planner=planner,
        plan_seed=plan_seed,
        compute_scale=compute_scale,
        decode_mode=decode_mode,
        error_budget=error_budget,
        max_subset_tries=max_subset_tries,
        hybrid_state=hybrid_state,
    )
    for k, trace in enumerate(traces):
        session.append(a[k], b[k], trace)
    return session.result()
