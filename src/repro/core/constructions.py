"""Executable CMPC share-polynomial constructions.

Implements the *algorithmic* form of the paper's constructions:

* ``polydot_cmpc``  — Algorithm 1: PolyDot coded terms (eq. 7-8) plus
  greedy secret powers satisfying C1-C3 (eq. 9).
* ``age_cmpc``      — Algorithm 2: AGE coded terms (eq. 25-26) with gap
  parameter ``lambda``, S_B = z consecutive powers past the largest
  important power (eq. 29), S_A greedy under C5 (eq. 28), and the
  adaptive ``lambda*`` search of Algorithm 3 / Theorem 8.
* ``entangled_cmpc`` — the [15] baseline (lambda = 0 coded terms with
  the secret-term layout implied by Theorem 1 of [15]); used for
  worker-count comparisons and protocol cross-checks.

The greedy selections are provably identical to the closed forms of
Theorems 1/7 (the theorems enumerate exactly the greedy-feasible sets);
tests cross-validate ``n_workers`` against ``closed_form`` over grids.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from . import closed_form as cf
from .powers import (
    CodedSupport,
    age_coded,
    diffset,
    greedy_powers,
    h_support,
    polydot_coded,
    secret_conditions_hold,
    sumset,
)


@dataclasses.dataclass(frozen=True)
class Scheme:
    """A fully-specified CMPC share construction."""

    method: str
    s: int
    t: int
    z: int
    lam: Optional[int]  # AGE gap parameter (None for PolyDot)
    coded: CodedSupport
    sa: Tuple[int, ...]  # secret powers of F_A
    sb: Tuple[int, ...]  # secret powers of F_B
    h_powers: Tuple[int, ...]  # support of H(x) (sorted)

    @property
    def n_workers(self) -> int:
        return len(self.h_powers)

    @property
    def fa_powers(self) -> List[int]:
        return sorted(set(self.coded.pa) | set(self.sa))

    @property
    def fb_powers(self) -> List[int]:
        return sorted(set(self.coded.pb) | set(self.sb))

    @property
    def decode_threshold(self) -> int:
        """Workers needed by the master in Phase 3: deg I(x) + 1 = t^2 + z."""
        return self.t * self.t + self.z

    def validate(self) -> None:
        if len(self.sa) != self.z or len(self.sb) != self.z:
            raise ValueError("secret supports must have exactly z powers")
        if not secret_conditions_hold(self.coded, list(self.sa), list(self.sb)):
            raise ValueError("secret powers collide with important powers")


def _build(method: str, s: int, t: int, z: int, lam, coded, sa, sb) -> Scheme:
    scheme = Scheme(
        method=method,
        s=s,
        t=t,
        z=z,
        lam=lam,
        coded=coded,
        sa=tuple(int(x) for x in sa),
        sb=tuple(int(x) for x in sb),
        h_powers=tuple(int(x) for x in h_support(coded, sa, sb)),
    )
    scheme.validate()
    return scheme


# ----------------------------------------------------------------------
# PolyDot-CMPC (Algorithm 1)
# ----------------------------------------------------------------------
def polydot_cmpc(s: int, t: int, z: int) -> Scheme:
    if s == 1 and t == 1:
        raise ValueError("s = t = 1 is plain BGW; PolyDot-CMPC excludes it")
    if z < 1:
        raise ValueError("z >= 1 colluding workers required")
    coded = polydot_coded(s, t)
    # Step 1 (C1): S_A avoids Imp - P(C_B).
    sa = greedy_powers(z, diffset(coded.imp, coded.pb))
    # Step 2 (C2 + C3): S_B avoids (Imp - S_A) and (Imp - P(C_A)).
    bad_b = np.union1d(diffset(coded.imp, sa), diffset(coded.imp, coded.pa))
    sb = greedy_powers(z, bad_b)
    return _build("polydot", s, t, z, None, coded, sa, sb)


# ----------------------------------------------------------------------
# AGE-CMPC (Algorithm 2 + the lambda* search of Algorithm 3)
# ----------------------------------------------------------------------
def age_cmpc_fixed(s: int, t: int, z: int, lam: int) -> Scheme:
    if z < 1:
        raise ValueError("z >= 1 colluding workers required")
    if not (0 <= lam <= z):
        raise ValueError("0 <= lambda <= z required (Appendix H)")
    coded = age_coded(s, t, lam)
    # Step 1: S_B = z consecutive powers from max important power + 1.
    start = max(coded.imp) + 1
    sb = list(range(start, start + z))
    # Step 2 (C5): S_A avoids Imp - P(C_B).  C4/C6 hold by construction.
    sa = greedy_powers(z, diffset(coded.imp, coded.pb))
    return _build("age", s, t, z, lam, coded, sa, sb)


def age_cmpc(
    s: int, t: int, z: int, lam: Optional[int] = None, exact_search: bool = True
) -> Scheme:
    """AGE-CMPC with the adaptive-gap selection.

    ``exact_search=True`` minimises the *exact* worker count over
    ``lambda in [0, z]`` (this can only improve on Theorem 8's closed
    form and matches it in our validation grids for ``0 < lambda``).
    The minimisation runs on ``closed_form.n_age_exact`` — indicator
    convolutions over the structured Theorem-7 supports, O(D^2) bitops
    per lambda — so only the *winning* gap's greedy ``Scheme`` is ever
    constructed (the structured supports provably equal the greedy
    Algorithm-2 output; tests cross-check the selected scheme against
    the exhaustive build-them-all search over the validation grid).
    ``exact_search=False`` picks ``lambda*`` by Theorem 8's formulas
    (paper-faithful).
    """
    if lam is not None:
        return age_cmpc_fixed(s, t, z, lam)
    if t == 1:
        return age_cmpc_fixed(s, t, z, min(z, 0))
    if exact_search:
        _, lam_star = cf.n_age_exact(s, t, z)
        return age_cmpc_fixed(s, t, z, lam_star)
    lam_star = min(range(0, z + 1), key=lambda g: cf.age_gamma(s, t, z, g))
    return age_cmpc_fixed(s, t, z, lam_star)


# ----------------------------------------------------------------------
# Entangled-CMPC baseline [15]
# ----------------------------------------------------------------------
# Entangled-CMPC, SSMM and GCSA-NA are *worker-count / overhead*
# baselines, exactly as in the paper (Lemmas 3-5, 9 compare against the
# published formulas of [15]-[17], not re-derived constructions).  Their
# N formulas live in ``closed_form``.  Note a small beyond-paper
# observation validated in tests: running Algorithm 2's greedy secret
# selection on the lambda = 0 (entangled) coded terms yields N *below*
# [15]'s N_Entangled in some cells (e.g. s=t=z=2: 18 vs 19), i.e. the
# adaptive-gap machinery already improves the entangled layout itself.
# ``age_cmpc_fixed(s, t, z, 0)`` is that executable variant.


# ----------------------------------------------------------------------
# construction registry
# ----------------------------------------------------------------------
# One entry per construction family, carrying *capabilities* (does it
# take a gap parameter? does it self-tune lambda?) and a cheap exact
# worker-count oracle so planners can rank candidates without building
# schemes.  ``build_scheme`` stays as the thin string entry point, now
# dispatching through the registry; harnesses that iterate methods or
# auto-plan should consume ``Construction`` records instead of
# hard-coding name lists.


@dataclasses.dataclass(frozen=True)
class Construction:
    """Registry record for one CMPC construction family.

    ``build(s, t, z, lam)`` returns the executable :class:`Scheme`;
    ``n_workers(s, t, z, lam)`` is the *exact* worker count of that
    scheme without constructing it (closed-form / support-convolution
    fast paths), the quantity auto-planners rank candidates by.
    """

    name: str
    build: Callable[[int, int, int, Optional[int]], Scheme]
    n_workers: Callable[[int, int, int, Optional[int]], int]
    supports_lam: bool  # accepts an explicit gap parameter
    adaptive_gap: bool  # self-tunes lambda* when lam is None
    description: str = ""
    aliases: Tuple[str, ...] = ()


_REGISTRY: Dict[str, Construction] = {}
_ALIASES: Dict[str, str] = {}


def register_construction(ctor: Construction) -> Construction:
    """Add a construction family to the registry (idempotent per name)."""
    key = ctor.name.lower()
    _REGISTRY[key] = ctor
    for alias in ctor.aliases:
        _ALIASES[alias.lower()] = key
    return ctor


def get_construction(method: str) -> Construction:
    key = method.lower()
    key = _ALIASES.get(key, key)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown CMPC method: {method} (known: {known_methods()})"
        ) from None


def known_methods() -> Tuple[str, ...]:
    """Canonical registered method names (one per family)."""
    return tuple(_REGISTRY)


def _n_polydot_exact(s: int, t: int, z: int, lam: Optional[int]) -> int:
    # Theorem 2 overcounts a few gapped s=1 cells; the (cached) greedy
    # construction is the exact oracle.
    return _cached_scheme("polydot", s, t, z, None).n_workers


def _n_age_exact(s: int, t: int, z: int, lam: Optional[int]) -> int:
    if lam is None:
        return cf.n_age_exact(s, t, z)[0]
    if t == 1:
        return 2 * s + 2 * z - 1
    return cf.n_age_exact_fixed(s, t, z, lam)


register_construction(Construction(
    name="polydot",
    build=lambda s, t, z, lam=None: polydot_cmpc(s, t, z),
    n_workers=_n_polydot_exact,
    supports_lam=False,
    adaptive_gap=False,
    description="PolyDot-CMPC (Algorithm 1, Theorem 2)",
    aliases=("polydot-cmpc",),
))
register_construction(Construction(
    name="age",
    build=lambda s, t, z, lam=None: age_cmpc(s, t, z, lam=lam),
    n_workers=_n_age_exact,
    supports_lam=True,
    adaptive_gap=True,
    description="AGE-CMPC with the exact adaptive-gap search (Algorithm 3)",
    aliases=("age-cmpc",),
))
register_construction(Construction(
    name="age-paper",
    build=lambda s, t, z, lam=None: age_cmpc(s, t, z, lam=lam, exact_search=False),
    n_workers=lambda s, t, z, lam=None: _n_age_exact(
        s, t, z, lam if lam is not None else cf.age_lambda_star(s, t, z)
    ),
    supports_lam=True,
    adaptive_gap=True,
    description="AGE-CMPC with Theorem 8's closed-form lambda* (paper-faithful)",
))
register_construction(Construction(
    name="entangled-greedy",
    build=lambda s, t, z, lam=None: age_cmpc_fixed(s, t, z, 0),
    n_workers=lambda s, t, z, lam=None: _n_age_exact(s, t, z, 0),
    supports_lam=False,
    adaptive_gap=False,
    description="lambda = 0 coded terms with Algorithm 2's greedy secrets "
    "(improves on [15]'s published N in some cells)",
))

# Back-compat iterable surface (now derived from the registry).
KNOWN_METHODS = known_methods()

# Schemes are pure functions of (method, s, t, z, lam) but the greedy
# builders cost combinatorial Python; planners re-resolve the same
# candidates every replay, so resolution is memoized process-wide.
_SCHEME_CACHE: Dict[Tuple, Scheme] = {}
_SCHEME_CACHE_MAX = 1024


def _cached_scheme(method: str, s: int, t: int, z: int, lam: Optional[int]) -> Scheme:
    key = (method, s, t, z, lam)
    sch = _SCHEME_CACHE.get(key)
    if sch is None:
        sch = get_construction(method).build(s, t, z, lam)
        _SCHEME_CACHE[key] = sch
        while len(_SCHEME_CACHE) > _SCHEME_CACHE_MAX:
            _SCHEME_CACHE.pop(next(iter(_SCHEME_CACHE)))
    return sch


def build_scheme(method: str, s: int, t: int, z: int, lam: Optional[int] = None) -> Scheme:
    """Resolve a method name to its (memoized) executable ``Scheme``."""
    ctor = get_construction(method)
    if lam is not None and not ctor.supports_lam:
        if lam != (0 if ctor.name == "entangled-greedy" else None):
            raise ValueError(f"construction {ctor.name!r} takes no gap parameter")
    return _cached_scheme(ctor.name, s, t, z, lam)


# ----------------------------------------------------------------------
# PlanConfig: the declarative selection surface
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Everything selectable about one protocol deployment.

    The single value object threaded from construction choice to
    runtime: which family (``method``), the partition/privacy point
    ``(s, t, z)``, the AGE gap (``lam``, ``None`` = adaptive), how many
    spare evaluation points to provision (``n_spare``), and how many
    decode confirmations the master demands (``verify_extras``,
    ``"auto"`` = one exactly when corruption is possible).  Hashable
    and immutable, so it keys plan caches and auto-planner score
    tables directly.
    """

    method: str = "age"
    s: int = 2
    t: int = 2
    z: int = 1
    lam: Optional[int] = None
    n_spare: int = 0
    verify_extras: Union[int, str] = "auto"

    def __post_init__(self):
        get_construction(self.method)  # fail fast on unknown families
        if self.z < 1:
            raise ValueError("z >= 1 colluding workers required")
        if self.n_spare < 0:
            raise ValueError("n_spare must be >= 0")
        if self.verify_extras != "auto" and int(self.verify_extras) < 0:
            raise ValueError('verify_extras must be >= 0 or "auto"')

    def scheme(self) -> Scheme:
        """The (memoized) executable construction this config selects."""
        return build_scheme(self.method, self.s, self.t, self.z, lam=self.lam)

    @property
    def n_workers(self) -> int:
        """Exact worker count, without building the scheme."""
        ctor = get_construction(self.method)
        return ctor.n_workers(self.s, self.t, self.z, self.lam)

    @property
    def n_total(self) -> int:
        return self.n_workers + self.n_spare

    @property
    def decode_threshold(self) -> int:
        return self.t * self.t + self.z

    def replace(self, **kw) -> "PlanConfig":
        return dataclasses.replace(self, **kw)

    def resolved(self) -> "PlanConfig":
        """Pin the adaptive gap to the lambda the scheme actually uses,
        so configs that resolve to the same construction compare equal
        (the canonical form plan caches key on)."""
        lam = self.scheme().lam
        return self if lam == self.lam else self.replace(lam=lam)

    def fit_to_pool(self, pool_size: int) -> "PlanConfig":
        """Re-account spares against a physical pool of ``pool_size``
        workers: ``n_spare = pool_size - n_workers``.  Raises when the
        pool cannot even seat the primary workers — the elastic-pool
        feasibility check planners run before proposing a config."""
        spare = pool_size - self.n_workers
        if spare < 0:
            raise ValueError(
                f"pool of {pool_size} cannot seat {self.method}"
                f"(s={self.s}, t={self.t}, z={self.z}): needs "
                f"{self.n_workers} workers"
            )
        return self.replace(n_spare=spare)

    def label(self) -> str:
        lam = "" if self.lam is None else f",lam={self.lam}"
        return f"{self.method}(s={self.s},t={self.t},z={self.z}{lam})"
