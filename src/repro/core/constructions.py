"""Executable CMPC share-polynomial constructions.

Implements the *algorithmic* form of the paper's constructions:

* ``polydot_cmpc``  — Algorithm 1: PolyDot coded terms (eq. 7-8) plus
  greedy secret powers satisfying C1-C3 (eq. 9).
* ``age_cmpc``      — Algorithm 2: AGE coded terms (eq. 25-26) with gap
  parameter ``lambda``, S_B = z consecutive powers past the largest
  important power (eq. 29), S_A greedy under C5 (eq. 28), and the
  adaptive ``lambda*`` search of Algorithm 3 / Theorem 8.
* ``entangled_cmpc`` — the [15] baseline (lambda = 0 coded terms with
  the secret-term layout implied by Theorem 1 of [15]); used for
  worker-count comparisons and protocol cross-checks.

The greedy selections are provably identical to the closed forms of
Theorems 1/7 (the theorems enumerate exactly the greedy-feasible sets);
tests cross-validate ``n_workers`` against ``closed_form`` over grids.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import closed_form as cf
from .powers import (
    CodedSupport,
    age_coded,
    diffset,
    greedy_powers,
    h_support,
    polydot_coded,
    secret_conditions_hold,
    sumset,
)


@dataclasses.dataclass(frozen=True)
class Scheme:
    """A fully-specified CMPC share construction."""

    method: str
    s: int
    t: int
    z: int
    lam: Optional[int]  # AGE gap parameter (None for PolyDot)
    coded: CodedSupport
    sa: Tuple[int, ...]  # secret powers of F_A
    sb: Tuple[int, ...]  # secret powers of F_B
    h_powers: Tuple[int, ...]  # support of H(x) (sorted)

    @property
    def n_workers(self) -> int:
        return len(self.h_powers)

    @property
    def fa_powers(self) -> List[int]:
        return sorted(set(self.coded.pa) | set(self.sa))

    @property
    def fb_powers(self) -> List[int]:
        return sorted(set(self.coded.pb) | set(self.sb))

    @property
    def decode_threshold(self) -> int:
        """Workers needed by the master in Phase 3: deg I(x) + 1 = t^2 + z."""
        return self.t * self.t + self.z

    def validate(self) -> None:
        if len(self.sa) != self.z or len(self.sb) != self.z:
            raise ValueError("secret supports must have exactly z powers")
        if not secret_conditions_hold(self.coded, list(self.sa), list(self.sb)):
            raise ValueError("secret powers collide with important powers")


def _build(method: str, s: int, t: int, z: int, lam, coded, sa, sb) -> Scheme:
    scheme = Scheme(
        method=method,
        s=s,
        t=t,
        z=z,
        lam=lam,
        coded=coded,
        sa=tuple(int(x) for x in sa),
        sb=tuple(int(x) for x in sb),
        h_powers=tuple(int(x) for x in h_support(coded, sa, sb)),
    )
    scheme.validate()
    return scheme


# ----------------------------------------------------------------------
# PolyDot-CMPC (Algorithm 1)
# ----------------------------------------------------------------------
def polydot_cmpc(s: int, t: int, z: int) -> Scheme:
    if s == 1 and t == 1:
        raise ValueError("s = t = 1 is plain BGW; PolyDot-CMPC excludes it")
    if z < 1:
        raise ValueError("z >= 1 colluding workers required")
    coded = polydot_coded(s, t)
    # Step 1 (C1): S_A avoids Imp - P(C_B).
    sa = greedy_powers(z, diffset(coded.imp, coded.pb))
    # Step 2 (C2 + C3): S_B avoids (Imp - S_A) and (Imp - P(C_A)).
    bad_b = np.union1d(diffset(coded.imp, sa), diffset(coded.imp, coded.pa))
    sb = greedy_powers(z, bad_b)
    return _build("polydot", s, t, z, None, coded, sa, sb)


# ----------------------------------------------------------------------
# AGE-CMPC (Algorithm 2 + the lambda* search of Algorithm 3)
# ----------------------------------------------------------------------
def age_cmpc_fixed(s: int, t: int, z: int, lam: int) -> Scheme:
    if z < 1:
        raise ValueError("z >= 1 colluding workers required")
    if not (0 <= lam <= z):
        raise ValueError("0 <= lambda <= z required (Appendix H)")
    coded = age_coded(s, t, lam)
    # Step 1: S_B = z consecutive powers from max important power + 1.
    start = max(coded.imp) + 1
    sb = list(range(start, start + z))
    # Step 2 (C5): S_A avoids Imp - P(C_B).  C4/C6 hold by construction.
    sa = greedy_powers(z, diffset(coded.imp, coded.pb))
    return _build("age", s, t, z, lam, coded, sa, sb)


def age_cmpc(
    s: int, t: int, z: int, lam: Optional[int] = None, exact_search: bool = True
) -> Scheme:
    """AGE-CMPC with the adaptive-gap selection.

    ``exact_search=True`` minimises the *exact* worker count over
    ``lambda in [0, z]`` (this can only improve on Theorem 8's closed
    form and matches it in our validation grids for ``0 < lambda``).
    ``exact_search=False`` picks ``lambda*`` by Theorem 8's formulas
    (paper-faithful).
    """
    if lam is not None:
        return age_cmpc_fixed(s, t, z, lam)
    if t == 1:
        return age_cmpc_fixed(s, t, z, min(z, 0))
    if exact_search:
        best = None
        for cand in range(0, z + 1):
            sch = age_cmpc_fixed(s, t, z, cand)
            if best is None or sch.n_workers < best.n_workers:
                best = sch
        return best
    lam_star = min(range(0, z + 1), key=lambda g: cf.age_gamma(s, t, z, g))
    return age_cmpc_fixed(s, t, z, lam_star)


# ----------------------------------------------------------------------
# Entangled-CMPC baseline [15]
# ----------------------------------------------------------------------
# Entangled-CMPC, SSMM and GCSA-NA are *worker-count / overhead*
# baselines, exactly as in the paper (Lemmas 3-5, 9 compare against the
# published formulas of [15]-[17], not re-derived constructions).  Their
# N formulas live in ``closed_form``.  Note a small beyond-paper
# observation validated in tests: running Algorithm 2's greedy secret
# selection on the lambda = 0 (entangled) coded terms yields N *below*
# [15]'s N_Entangled in some cells (e.g. s=t=z=2: 18 vs 19), i.e. the
# adaptive-gap machinery already improves the entangled layout itself.
# ``age_cmpc_fixed(s, t, z, 0)`` is that executable variant.


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
# Canonical method names (one per construction family) — the iterable
# surface for scheme-comparison harnesses like benchmarks/edge_runtime.
KNOWN_METHODS = ("polydot", "age", "age-paper", "entangled-greedy")


def build_scheme(method: str, s: int, t: int, z: int, lam: Optional[int] = None) -> Scheme:
    method = method.lower()
    if method in ("polydot", "polydot-cmpc"):
        return polydot_cmpc(s, t, z)
    if method in ("age", "age-cmpc"):
        return age_cmpc(s, t, z, lam=lam)
    if method in ("age-paper",):
        return age_cmpc(s, t, z, lam=lam, exact_search=False)
    if method in ("entangled-greedy",):
        return age_cmpc_fixed(s, t, z, 0)
    raise KeyError(f"unknown CMPC method: {method} (known: {KNOWN_METHODS})")
