"""CMPC execution planning.

A ``CMPCPlan`` freezes everything that is *data independent* about one
protocol instance: the share construction (``Scheme``), the field, the
evaluation points alpha_n, the Phase-2 mixing matrix (Lagrange-style
coefficients r_n^{(i,l)} folded with the receiver Vandermonde), the
Phase-3 decode matrix, and block-shape bookkeeping.  Plans are computed
on the host in exact int64 and shipped to devices as int32 constants.

Worker redundancy: ``n_spare`` extra evaluation points provide
straggler tolerance in Phase 2 — any ``n_workers`` of the
``n_workers + n_spare`` provisioned workers can serve Phase 2 (the
mixing matrix is recomputed per surviving subset via ``phase2_matrix``),
and any ``t^2 + z`` of those can serve Phase 3.

``get_plan`` is the cached entry point: one plan per
``(scheme, shapes, field, n_spare, seed)`` signature, shared
process-wide so repeated layer calls reuse the Vandermonde / mixing
constants instead of re-running Gauss-Jordan inversions
(``plan_cache_info`` / ``plan_cache_clear`` expose the counters).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from ..obs.tracer import TRACER
from .constructions import PlanConfig, Scheme
from .gf import Field


@dataclasses.dataclass(frozen=True)
class BlockShapes:
    """Partition bookkeeping for Y = A^T B.

    A: [k, ma]  (so A^T: [ma, k]), B: [k, mb], Y: [ma, mb].
    A^T is split into t x s blocks of [ma/t, k/s]; B into s x t blocks
    of [k/s, mb/t].
    """

    k: int
    ma: int
    mb: int
    s: int
    t: int

    def __post_init__(self):
        if self.k % self.s:
            raise ValueError(f"s={self.s} must divide inner dim k={self.k}")
        if self.ma % self.t or self.mb % self.t:
            raise ValueError(f"t={self.t} must divide output dims {self.ma}, {self.mb}")

    @property
    def blk_a(self) -> Tuple[int, int]:
        return (self.ma // self.t, self.k // self.s)

    @property
    def blk_b(self) -> Tuple[int, int]:
        return (self.k // self.s, self.mb // self.t)

    @property
    def blk_y(self) -> Tuple[int, int]:
        return (self.ma // self.t, self.mb // self.t)


@dataclasses.dataclass(frozen=True)
class CMPCPlan:
    scheme: Scheme
    field: Field
    shapes: BlockShapes
    n_spare: int
    alphas: np.ndarray  # [n_total] distinct nonzero points
    va: np.ndarray  # [n_total, |P(F_A)|] Vandermonde on F_A support
    vb: np.ndarray  # [n_total, |P(F_B)|]
    # Phase 2: mix[n, n'] = sum_{i,l} r_n^{(i,l)} alpha_{n'}^{i+t*l}
    # for the primary worker set (first n_workers alphas).
    mix: np.ndarray  # [n_workers, n_total]
    vnoise: np.ndarray  # [n_total, z] receiver Vandermonde on powers t^2+w
    decode_w: np.ndarray  # [t^2+z, t^2+z] inverse Vandermonde, first t^2+z workers
    important_idx: np.ndarray  # [t, t] -> index of u_{i,l} in h_powers

    @property
    def n_workers(self) -> int:
        return self.scheme.n_workers

    @property
    def n_total(self) -> int:
        return self.n_workers + self.n_spare

    @property
    def decode_threshold(self) -> int:
        return self.scheme.decode_threshold

    # ------------------------------------------------------------------
    def phase2_matrix(self, worker_ids: Sequence[int]) -> np.ndarray:
        """Recompute the Phase-2 mixing matrix for an arbitrary surviving
        subset of exactly ``n_workers`` workers (straggler mitigation)."""
        return _phase2_matrix(self.scheme, self.field, self.alphas, np.asarray(worker_ids))

    def decode_matrix(self, worker_ids: Sequence[int]) -> np.ndarray:
        """Inverse Vandermonde for Phase-3 reconstruction from any
        ``t^2 + z`` workers."""
        ids = np.asarray(worker_ids)
        if ids.size != self.decode_threshold:
            raise ValueError(
                f"need exactly {self.decode_threshold} workers, got {ids.size}"
            )
        v = self.field.vandermonde(self.alphas[ids], range(self.decode_threshold))
        return self.field.inv_matrix(v)

    # ------------------------------------------------------------------
    # per-subset matrix caches (straggler-aware runtime hot path)
    # ------------------------------------------------------------------
    # The edge runtime decodes from whatever responder subset happens to
    # be fastest, and under a stationary latency distribution the same
    # few subsets recur run after run.  Both subset matrices cost a
    # Gauss-Jordan inversion mod p in Python, so they get the same
    # treatment as ``get_plan``: a bounded insertion-ordered cache, here
    # per plan (keyed by the frozen id tuple) since the matrices are
    # meaningless across plans.  The primary prefix bypasses the cache
    # entirely — it is already stored on the plan.

    def phase2_matrix_cached(self, worker_ids: Sequence[int]) -> np.ndarray:
        ids = np.asarray(worker_ids)
        if ids.size == self.n_workers and np.array_equal(ids, np.arange(self.n_workers)):
            return self.mix
        return self._subset_cached("mix", ids, self.phase2_matrix)

    def decode_check_matrix(self) -> np.ndarray:
        """Vandermonde of *every* provisioned alpha on the decode powers
        0..t^2+z-1 — the master's consistency-check matrix (an accepted
        I(x) must reproduce the evaluations of extra responders).  Built
        once per plan and memoized like ``device_plan``: the edge
        runtime consults it on every run, and rebuilding it was a
        per-call host loop in the replay hot path."""
        v = self.__dict__.get("_decode_check_v")
        if v is None:
            _DECODE_CHECK_STATS["misses"] += 1
            v = self.field.vandermonde(self.alphas, range(self.decode_threshold))
            object.__setattr__(self, "_decode_check_v", v)
        else:
            _DECODE_CHECK_STATS["hits"] += 1
        return v

    def decode_matrix_cached(self, worker_ids: Sequence[int]) -> np.ndarray:
        ids = np.asarray(worker_ids)
        thr = self.decode_threshold
        if ids.size == thr and np.array_equal(ids, np.arange(thr)):
            return self.decode_w
        return self._subset_cached("dec", ids, self.decode_matrix)

    def bw_decode_matrices(self, worker_ids: Sequence[int], e: int) -> np.ndarray:
        """Vandermonde block behind the Berlekamp-Welch key system for a
        responder subset: ``V[i, j] = alphas[ids[i]] ** j`` on powers
        ``0..thr+e-1``.  Columns ``0..thr+e-1`` are the Q(x) block, its
        first ``e`` columns double as the low-order error-locator block,
        and column ``e`` carries the monic ``x^e`` term — one matrix
        serves the whole system.  Rows follow the given (arrival) order;
        cached per ``(subset order, e)`` alongside the decode/check
        caches, so the recurring fastest ``thr + 2e`` responders pay one
        power-table build total.
        """
        ids = np.asarray(worker_ids)
        e = int(e)
        if e < 0:
            raise ValueError("error budget e must be >= 0")
        width = self.decode_threshold + e

        def build(ids_arr: np.ndarray) -> np.ndarray:
            return self.field.vandermonde(self.alphas[ids_arr], range(width))

        return self._subset_cached(f"bw{e}", ids, build)

    def _subset_cached(self, kind: str, ids: np.ndarray, build) -> np.ndarray:
        cache = self.__dict__.get("_subset_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_subset_cache", cache)
        key = (kind, tuple(int(i) for i in ids))
        hit = cache.get(key)
        if hit is not None:
            _SUBSET_CACHE_STATS["hits"] += 1
            return hit
        _SUBSET_CACHE_STATS["misses"] += 1
        mat = build(ids)
        cache[key] = mat
        while len(cache) > _SUBSET_CACHE_MAX:
            cache.pop(next(iter(cache)))
        return mat


def _phase2_rows(
    scheme: Scheme, field: Field, alphas: np.ndarray, ids: np.ndarray
) -> np.ndarray:
    """r[(i,l), n]: interpolation rows extracting the important
    coefficients u_{i,l} from the sender subset ``ids`` — the expensive
    (Gauss-Jordan) sender-side half of the Phase-2 mixing matrix,
    independent of the receiver set."""
    if ids.size != scheme.n_workers:
        raise ValueError(
            f"phase 2 needs exactly {scheme.n_workers} workers, got {ids.size}"
        )
    t = scheme.t
    h_powers = list(scheme.h_powers)
    v_h = field.vandermonde(alphas[ids], h_powers)  # [N, |P(H)|]
    v_inv = field.inv_matrix(v_h)  # coeff = v_inv @ evals
    imp_map = scheme.coded.important_map()
    pos = {u: j for j, u in enumerate(h_powers)}
    r = np.zeros((t * t, ids.size), np.int64)
    for (i, l), u in imp_map.items():
        r[i + t * l] = v_inv[pos[u]]
    return r


def _mix_from_rows(
    scheme: Scheme, field: Field, r: np.ndarray, alphas: np.ndarray
) -> np.ndarray:
    """Fold sender rows with the receiver Vandermonde (cheap half)."""
    # receiver Vandermonde on G powers {i + t*l} = 0..t^2-1
    v_g = field.vandermonde(alphas, range(scheme.t * scheme.t))
    # mix[n, n'] = sum_g r[g, n] * v_g[n', g]
    return field.matmul(r.T, v_g.T)  # [N, n_receivers]


def _phase2_matrix(
    scheme: Scheme, field: Field, alphas: np.ndarray, ids: np.ndarray
) -> np.ndarray:
    """mix[n, n'] for senders ``ids`` (interpolating H's support from the
    evaluations at alphas[ids]) and all receivers."""
    r = _phase2_rows(scheme, field, alphas, ids)
    return _mix_from_rows(scheme, field, r, alphas)


# ----------------------------------------------------------------------
# plan cache
# ----------------------------------------------------------------------
# Plans are pure functions of (scheme, shapes, field, n_spare, seed) but
# cost Vandermonde inversions (Gauss-Jordan mod p in Python) to build.
# Layer code calls get_plan so repeated calls with the same protocol
# signature — every forward pass of a PrivateLinear, every step of a
# batched pipeline — reuse the mixing/decode constants.  The key tuple
# is exactly a resolved ``PlanConfig`` plus (shapes, p, seed); an
# auto-planner re-proposing a config between replays lands on the same
# entry, and a config differing ONLY in ``n_spare`` takes the
# ``_replan_n_spare`` fast path (no new Gauss-Jordan inversions).
_PLAN_CACHE: dict = {}
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0, "replans": 0}
# Sibling index for the re-plan fast path: same (scheme, shapes, field,
# seed), any n_spare -> the latest plan, whose sender-side constants a
# different spare count can reuse verbatim.
_PLAN_BY_SIG: dict = {}
# Per-plan subset-matrix caches (phase2_matrix_cached /
# decode_matrix_cached) share process-wide hit counters and a per-plan
# size bound; a runtime facing a pool of n_total workers sees at most
# C(n_total, threshold) distinct subsets but in practice a handful.
_SUBSET_CACHE_STATS = {"hits": 0, "misses": 0}
_SUBSET_CACHE_MAX = 512
# The per-plan decode_check_matrix memo (the master's consistency-check
# Vandermonde), counted process-wide like the other two cache spellings
# so obs.metrics can report all three behind one snapshot().
_DECODE_CHECK_STATS = {"hits": 0, "misses": 0}
# Plans pin O(n_total^2) host matrices (plus device constants once the
# batched engine touches them), and callers key on runtime batch sizes,
# so bound the cache: oldest-inserted entries are evicted first.
_PLAN_CACHE_MAX = 256


def _plan_sig(scheme: Scheme, shapes: BlockShapes, field: Field, seed: int):
    """Everything a plan depends on except the spare count."""
    return (
        scheme.method,
        scheme.s,
        scheme.t,
        scheme.z,
        scheme.lam,
        (shapes.k, shapes.ma, shapes.mb, shapes.s, shapes.t),
        field.p,
        seed,
    )


def _plan_key(scheme: Scheme, shapes: BlockShapes, field: Field, n_spare: int, seed: int):
    return _plan_sig(scheme, shapes, field, seed) + (n_spare,)


def get_plan(
    scheme: Scheme,
    shapes: BlockShapes,
    field: Optional[Field] = None,
    n_spare: int = 0,
    seed: int = 0,
) -> CMPCPlan:
    """Memoized ``make_plan``: one plan per (scheme, shapes, field,
    n_spare, seed) signature, shared across layers and batches.

    A miss whose signature matches a cached plan except for ``n_spare``
    re-plans from that sibling instead of building from scratch:
    evaluation points are prefix-consistent per seed, so the Phase-2
    sender interpolation and the decode inverse carry over unchanged
    and only receiver-side Vandermonde rows are grown or sliced.  An
    auto-planner resizing spares between replays (elastic pools) pays
    no Gauss-Jordan inversions for the switch.
    """
    field = field or Field()
    sig = _plan_sig(scheme, shapes, field, seed)
    key = sig + (n_spare,)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        sibling = _PLAN_BY_SIG.get(sig)
        if sibling is not None and sibling.n_spare != n_spare:
            outcome = "replan"
            _PLAN_CACHE_STATS["replans"] += 1
            plan = _replan_n_spare(sibling, n_spare, seed)
        else:
            outcome = "miss"
            _PLAN_CACHE_STATS["misses"] += 1
            plan = make_plan(scheme, shapes, field=field, n_spare=n_spare, seed=seed)
        _PLAN_CACHE[key] = plan
        _PLAN_BY_SIG[sig] = plan
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
            _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
    else:
        outcome = "hit"
        _PLAN_CACHE_STATS["hits"] += 1
    if TRACER.enabled:
        TRACER.event(
            "planner.get_plan",
            outcome=outcome,
            method=scheme.method,
            n_workers=scheme.n_workers,
            n_spare=n_spare,
        )
    return plan


def get_plan_for(
    config: PlanConfig,
    shapes: BlockShapes,
    field: Optional[Field] = None,
    seed: int = 0,
) -> CMPCPlan:
    """The ``PlanConfig`` entry point: resolve the construction through
    the registry and fetch the (cached) plan.  Configs that resolve to
    the same scheme — e.g. ``lam=None`` and its pinned ``lambda*`` —
    share one cache entry."""
    if shapes.s != config.s or shapes.t != config.t:
        raise ValueError("config and shapes disagree on (s, t)")
    return get_plan(
        config.scheme(), shapes, field=field, n_spare=config.n_spare, seed=seed
    )


def plan_cache_info() -> dict:
    """{'hits', 'misses', 'replans', 'size'} for the process-wide cache.
    ``replans`` counts misses served by the n_spare fast path (no new
    matrix inversions)."""
    return dict(_PLAN_CACHE_STATS, size=len(_PLAN_CACHE))


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    _PLAN_BY_SIG.clear()
    _ALPHA_CACHE.clear()
    _PLAN_CACHE_STATS.update(hits=0, misses=0, replans=0)


def subset_cache_info() -> dict:
    """Process-wide {'hits', 'misses'} of the per-plan subset caches."""
    return dict(_SUBSET_CACHE_STATS)


def subset_cache_clear() -> None:
    _SUBSET_CACHE_STATS.update(hits=0, misses=0)


def decode_check_cache_info() -> dict:
    """Process-wide {'hits', 'misses'} of the per-plan
    ``decode_check_matrix`` memo."""
    return dict(_DECODE_CHECK_STATS)


def decode_check_cache_clear() -> None:
    _DECODE_CHECK_STATS.update(hits=0, misses=0)


# Evaluation points are prefixes of ONE seeded permutation of the
# nonzero field elements, so plans differing only in pool size share
# alpha prefixes — the invariant behind the n_spare re-plan fast path.
# One permutation costs ~p int64s; bound the cache.
_ALPHA_CACHE: dict = {}
_ALPHA_CACHE_MAX = 16


def _alpha_prefix(field: Field, seed: int, n: int) -> np.ndarray:
    if n >= field.p:
        raise ValueError("field too small for worker count")
    key = (field.p, seed)
    perm = _ALPHA_CACHE.get(key)
    if perm is None:
        rng = np.random.default_rng(seed)
        perm = rng.permutation(field.p - 1).astype(np.int64) + 1
        _ALPHA_CACHE[key] = perm
        while len(_ALPHA_CACHE) > _ALPHA_CACHE_MAX:
            _ALPHA_CACHE.pop(next(iter(_ALPHA_CACHE)))
    return perm[:n].copy()


def make_plan(
    scheme: Scheme,
    shapes: BlockShapes,
    field: Optional[Field] = None,
    n_spare: int = 0,
    seed: int = 0,
) -> CMPCPlan:
    field = field or Field()
    if shapes.s != scheme.s or shapes.t != scheme.t:
        raise ValueError("scheme and shapes disagree on (s, t)")
    n = scheme.n_workers + n_spare
    # distinct nonzero evaluation points (seeded-permutation prefix)
    alphas = _alpha_prefix(field, seed, n)
    va = field.vandermonde(alphas, scheme.fa_powers)
    vb = field.vandermonde(alphas, scheme.fb_powers)
    r = _phase2_rows(scheme, field, alphas, np.arange(scheme.n_workers))
    mix = _mix_from_rows(scheme, field, r, alphas)
    tt = scheme.t * scheme.t
    vnoise = field.vandermonde(alphas, range(tt, tt + scheme.z))
    dec_ids = np.arange(scheme.decode_threshold)
    v_dec = field.vandermonde(alphas[dec_ids], range(scheme.decode_threshold))
    decode_w = field.inv_matrix(v_dec)
    imp = scheme.coded.important_map()
    pos = {u: j for j, u in enumerate(scheme.h_powers)}
    important_idx = np.zeros((scheme.t, scheme.t), np.int64)
    for (i, l), u in imp.items():
        important_idx[i, l] = pos[u]
    plan = CMPCPlan(
        scheme=scheme,
        field=field,
        shapes=shapes,
        n_spare=n_spare,
        alphas=alphas,
        va=va,
        vb=vb,
        mix=mix,
        vnoise=vnoise,
        decode_w=decode_w,
        important_idx=important_idx,
    )
    # stash the sender-side interpolation rows for the re-plan fast path
    object.__setattr__(plan, "_phase2_r", r)
    return plan


def _replan_n_spare(base: CMPCPlan, n_spare: int, seed: int) -> CMPCPlan:
    """Re-plan ``base`` for a different spare count without re-running
    any Gauss-Jordan inversion.

    Evaluation points are prefix-consistent per seed, so the primary
    workers (and hence the Phase-2 sender interpolation rows and the
    decode inverse) are untouched; only receiver-indexed rows of the
    Vandermonde constants grow or shrink.  Shrinking slices; growing
    evaluates Vandermonde rows for the new alphas and extends the mix
    with receiver columns folded from the stashed sender rows.
    """
    scheme, field = base.scheme, base.field
    n_new = scheme.n_workers + n_spare
    n_old = base.n_total
    r = base.__dict__.get("_phase2_r")
    if r is None:  # plan predates the stash (or crossed a process)
        r = _phase2_rows(scheme, field, base.alphas, np.arange(scheme.n_workers))
    if n_new <= n_old:
        alphas = base.alphas[:n_new].copy()
        va = base.va[:n_new].copy()
        vb = base.vb[:n_new].copy()
        vnoise = base.vnoise[:n_new].copy()
        mix = base.mix[:, :n_new].copy()
    else:
        alphas = _alpha_prefix(field, seed, n_new)
        if not np.array_equal(alphas[:n_old], base.alphas):
            raise ValueError(
                "re-plan sibling has mismatched evaluation points "
                "(plan not built from this seed's alpha permutation)"
            )
        new = alphas[n_old:]
        va = np.vstack([base.va, field.vandermonde(new, scheme.fa_powers)])
        vb = np.vstack([base.vb, field.vandermonde(new, scheme.fb_powers)])
        tt = scheme.t * scheme.t
        vnoise = np.vstack(
            [base.vnoise, field.vandermonde(new, range(tt, tt + scheme.z))]
        )
        mix = np.hstack([base.mix, _mix_from_rows(scheme, field, r, new)])
    plan = CMPCPlan(
        scheme=scheme,
        field=field,
        shapes=base.shapes,
        n_spare=n_spare,
        alphas=alphas,
        va=va,
        vb=vb,
        mix=mix,
        vnoise=vnoise,
        decode_w=base.decode_w,  # depends on the (unchanged) first thr alphas
        important_idx=base.important_idx,
    )
    object.__setattr__(plan, "_phase2_r", r)
    return plan
