"""Distributed CMPC: workers mapped onto a mesh axis via shard_map.

TPU-native adaptation of the paper's edge-worker topology (DESIGN.md
"hardware adaptation"):

* the N protocol workers become shards along a ``workers`` mesh axis
  (padded to a multiple of the axis size; pad workers send zero),
* Phase 2's pairwise exchange — worker n sends G_n(alpha_{n'}) to every
  n' (N(N-1) point-to-point messages on D2D links in the paper) — maps
  onto ONE collective:

    - ``all_to_all``     faithful transposition of the (sender,
                          receiver) axes; bytes on the wire match the
                          paper's zeta = N(N-1) m^2/t^2 accounting,
    - ``psum``           all-reduce of the receiver-indexed partial
                          sums; simple but replicates I(x) everywhere,
    - ``psum_scatter``   reduce-scatter: each device ends with exactly
                          its receivers' I(alpha) — the beyond-paper
                          optimization (see EXPERIMENTS.md §Perf): the
                          exchanged volume drops from O(N^2 m^2/t^2) to
                          O(N m^2/t^2) because the sum into I(x) is
                          *linear* and can be fused into the collective.

The exchange is batched: a whole batch of products rides one collective
by folding the batch axis into each worker's flattened block payload
(the exchange is elementwise over the payload, so the collective shape
is the only thing that grows).  ``protocol.run_batched_sharded`` and
the edge runtime's ``run_batch_over_pool`` enter through this path.

Integer safety: all lane values are < p < 2**16 and ``_mod_sum``
accumulates at most ``npad`` (the pool padded to the axis size) int32
partial values before reducing mod p, so the requirement is
``npad * p < 2**31`` — independent of ``n_workers``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map

from ..kernels.modmatmul.ops import mod_matmul
from .planner import CMPCPlan


def _pad_to_multiple(x: np.ndarray, mult: int, axis: int = 0) -> np.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


def run_phase2_sharded(
    plan: CMPCPlan,
    fa: jnp.ndarray,
    fb: jnp.ndarray,
    noise: np.ndarray,
    mesh: Mesh,
    axis: str = "workers",
    mode: str = "all_to_all",
    matmul_backend: str = "auto",
    return_compiled: bool = False,
    worker_ids: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Workers compute H and run the G-exchange on a device mesh.

    fa: [n_total, br, bk] shares, fb: [n_total, bk, bc]; noise:
    [n_workers, z, br, bc] per-worker blinding matrices R_w^{(n)}.
    Batched: fa [batch, n_total, br, bk], fb [batch, n_total, bk, bc],
    noise [batch, n_workers, z, br, bc] — the batch folds into each
    worker's flat payload, so the whole batch rides ONE collective.
    Returns I(alpha_n) for all (unpadded) provisioned workers:
    [n_total, br, bc], or [batch, n_total, br, bc] for batched inputs.

    ``worker_ids`` selects which ``n_workers`` of the provisioned pool
    serve as Phase-2 senders (straggler mitigation — e.g. the fastest
    subset picked by ``repro.runtime``); ``noise`` rows follow the same
    order.  Non-senders are receive-only (zero mix rows), matching the
    pad workers.  Default is the primary prefix; explicit subsets reuse
    the plan's cached subset mix matrices.

    ``matmul_backend`` threads through to the kernel layer
    (``auto``/``pallas``/``f32limb``): the per-shard worker multiply is
    a batched mod_matmul, so on TPU it lowers to one Pallas launch per
    shard with the local worker count on the batch grid axis.
    """
    p = plan.field.p
    d = mesh.shape[axis]
    n_total = plan.n_total
    # _mod_sum accumulates <= npad int32 values < p before reducing, so
    # the bound is npad * p (padded pool size; n_workers plays no role).
    npad = n_total + ((-n_total) % d)
    assert npad * p < (1 << 31), "int32 reduction bound: npad * p < 2**31"

    if worker_ids is None:
        ids = np.arange(plan.n_workers)
        mix = plan.mix
    else:
        ids = np.asarray(worker_ids)
        mix = plan.phase2_matrix_cached(ids)

    fa_np = np.asarray(fa)
    fb_np = np.asarray(fb)
    noise_np = np.asarray(noise)
    batched = fa_np.ndim == 4
    if not batched:
        fa_np = fa_np[None]
        fb_np = fb_np[None]
        noise_np = noise_np[None]
    batch = fa_np.shape[0]

    # Worker axis leads on the mesh; the batch joins the per-worker
    # payload.  Pad worker-stacked operands to the axis size; pad
    # workers are receive-only (zero mix rows / zero noise).
    fa_p = _pad_to_multiple(np.moveaxis(fa_np, 1, 0), d)  # [npad, batch, br, bk]
    fb_p = _pad_to_multiple(np.moveaxis(fb_np, 1, 0), d)
    assert fa_p.shape[0] == npad
    mix_rows = np.zeros((npad, npad), np.int64)
    mix_rows[ids, :n_total] = mix  # [senders, receivers]
    vnz = np.zeros((npad, plan.scheme.z), np.int64)
    vnz[:n_total] = plan.vnoise
    # noise rows follow ids order; layout [npad, z, batch, br, bc] so the
    # local reshape (nloc, z, payload) flattens batch into the payload.
    noise_w = np.moveaxis(noise_np, 0, 2)  # [n_workers, z, batch, br, bc]
    noise_p = np.zeros((npad,) + noise_w.shape[1:], np.int64)
    noise_p[ids] = noise_w

    mix_j = jnp.asarray(mix_rows.astype(np.int32))
    vn_j = jnp.asarray(vnz.astype(np.int32))
    noise_j = jnp.asarray(noise_p.astype(np.int32))
    fa_j = jnp.asarray(fa_p)
    fb_j = jnp.asarray(fb_p)

    br = fa_p.shape[2]
    bc = fb_p.shape[3]
    blk = batch * br * bc  # per-worker flat payload (whole batch)

    def local(fa_l, fb_l, mix_l, noise_l):
        # Phase 2a: every local worker multiplies its shares (the batch
        # is just another leading dim of the batched mod_matmul).
        h_l = mod_matmul(fa_l, fb_l, p=p, backend=matmul_backend)  # [nloc, batch, br, bc]
        nloc = h_l.shape[0]
        h_flat = h_l.reshape(nloc, blk)
        # Phase 2b: local workers' G evaluated at every receiver:
        # contrib[nl, r, :] = mix[nl, r] * H[nl] + sum_w R[nl, w] * vn[r, w]
        contrib = (
            mix_l[:, :, None].astype(jnp.uint32) * h_flat[:, None, :].astype(jnp.uint32)
        ) % jnp.uint32(p)
        # Per-worker blinding: noise_eval[nl, r] = sum_w R[nl, w] vn[r, w],
        # accumulated mod p each step (uint32-safe for any z).
        nz = noise_l.reshape(nloc, plan.scheme.z, blk)

        def nmix(acc, w):
            term = (
                vn_j[:, w][None, :, None].astype(jnp.uint32)
                * nz[:, w, :][:, None, :].astype(jnp.uint32)
            ) % jnp.uint32(p)
            return (acc + term) % jnp.uint32(p), None

        acc0 = jnp.zeros((nloc, vn_j.shape[0], blk), jnp.uint32)
        noise_eval, _ = jax.lax.scan(nmix, acc0, jnp.arange(plan.scheme.z))
        contrib = ((contrib + noise_eval) % jnp.uint32(p)).astype(jnp.int32)

        if mode == "all_to_all":
            # [nloc, npad, blk] -> exchange receiver chunks -> [npad, nloc_r, blk]
            exch = jax.lax.all_to_all(
                contrib, axis, split_axis=1, concat_axis=0, tiled=True
            )
            i_local = _mod_sum(exch, p)  # [nloc_r, blk]
        elif mode == "psum":
            part = _mod_sum(contrib, p)  # [npad, blk] local partial
            i_all = jax.lax.psum(part, axis) % p
            idx = jax.lax.axis_index(axis)
            nloc_r = npad // d
            i_local = jax.lax.dynamic_slice_in_dim(i_all, idx * nloc_r, nloc_r, 0)
        elif mode == "psum_scatter":
            part = _mod_sum(contrib, p)  # [npad, blk]
            i_local = jax.lax.psum_scatter(part, axis, scatter_dimension=0, tiled=True) % p
        else:
            raise ValueError(f"unknown mode {mode}")
        return i_local.astype(jnp.int32).reshape(-1, batch, br, bc)

    spec = P(axis)
    shard_fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=spec,
        check_vma=False,
    )
    jitted = jax.jit(shard_fn)
    if return_compiled:
        return jitted.lower(fa_j, fb_j, mix_j, noise_j).compile()
    i_evals = np.asarray(jitted(fa_j, fb_j, mix_j, noise_j))
    i_evals = np.moveaxis(i_evals[:n_total], 0, 1)  # [batch, n_total, br, bc]
    return i_evals if batched else i_evals[0]


def _mod_sum(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """Sum over axis 0 with int32 accumulation (safe: npad * p < 2**31)."""
    return (jnp.sum(x.astype(jnp.int32), axis=0) % p).astype(jnp.int32)
