"""Core library: the paper's contribution — coded MPC (AGE-CMPC,
PolyDot-CMPC) — as composable JAX modules.

Layers (bottom-up):

* ``gf``             — GF(p) arithmetic (host oracle + f32-limb device path)
* ``powers``         — polynomial power-set combinatorics (sumsets, C1-C6)
* ``constructions``  — executable Algorithm 1 / Algorithm 2 share builders
* ``closed_form``    — Theorems 2 & 8 + baseline worker counts / overheads
* ``planner``        — CMPCPlan: evaluation points, interpolation matrices
* ``protocol``       — the 3-phase protocol engine (jit-able, vmapped)
* ``bw_decode``      — Berlekamp-Welch error-correcting Phase-3 decode
* ``distributed``    — shard_map execution over a worker mesh axis
* ``layers``         — secure_matmul / PrivateLinear high-level API
"""
from .bw_decode import (  # noqa: F401
    BWDecodeError,
    bw_decode_evals,
    bw_interpolate,
    bw_system_size,
)
from .closed_form import (  # noqa: F401
    CostPrediction,
    age_gamma,
    age_lambda_star,
    communication_overhead,
    computation_overhead,
    n_age,
    n_entangled,
    n_gcsa_na,
    n_polydot,
    n_ssmm,
    n_workers,
    predict,
    storage_overhead,
)
from .constructions import (  # noqa: F401
    Construction,
    PlanConfig,
    Scheme,
    age_cmpc,
    age_cmpc_fixed,
    build_scheme,
    get_construction,
    known_methods,
    polydot_cmpc,
    register_construction,
)
from .gf import Field, P_DEFAULT, mod_matmul_f32  # noqa: F401
