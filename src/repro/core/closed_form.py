"""Closed-form worker counts and overhead formulas.

Transcriptions of:

* Theorem 2  — N_PolyDot-CMPC (psi_1..psi_6, region-wise)
* Theorem 8  — N_AGE-CMPC = min_lambda Gamma(lambda) (Upsilon_1..Upsilon_9)
* Theorem 1 of [15] — N_Entangled-CMPC (eq. 194)
* Theorem 1 of [16] — N_SSMM = (t+1)(ts+z) - 1
* Table 1 of [17]   — N_GCSA-NA = 2st^2 + 2z - 1 (one multiplication)
* Corollaries 10-12 — computation / storage / communication overheads

All functions take the paper's parameters: ``s`` row partitions, ``t``
column partitions, ``z`` colluding workers (and ``m`` for overheads).
The exact greedy constructions in ``constructions`` are the ground
truth; tests check these formulas against them over dense grids.
"""
from __future__ import annotations

import math
from fractions import Fraction


# ----------------------------------------------------------------------
# Theorem 2: PolyDot-CMPC
# ----------------------------------------------------------------------
def _polydot_p(s: int, t: int, z: int) -> int:
    """p = min{floor((z-1)/(theta'-ts)), t-1}; theta' - ts = ts - t.

    For s = 1 the denominator vanishes and p = t - 1 by definition
    (Lemma 33); for t = 1, min(..., 0) = 0 (Lemma 32).
    """
    denom = t * s - t
    if denom <= 0:
        return t - 1 if t > 1 else 0
    return min((z - 1) // denom, t - 1)


def n_polydot(s: int, t: int, z: int) -> int:
    """Theorem 2."""
    if s == 1 and t == 1:
        raise ValueError("s = t = 1 excluded (BGW)")
    if z < 1:
        raise ValueError("z >= 1")
    thetap = t * (2 * s - 1)
    p = _polydot_p(s, t, z)
    psi1 = (p + 2) * t * s + thetap * (t - 1) + 2 * z - 1
    if t == 1:
        return psi1  # = 2s + 2z - 1
    if s == 1:
        return psi1 if z > t else t * t + 2 * t + t * z - 1  # Lemma 33
    if z > t * s:
        return psi1
    if t * s - t < z <= t * s:
        return 2 * t * s + thetap * (t - 1) + 3 * z - 1  # psi2
    if t * s - 2 * t < z <= t * s - t:
        return 2 * t * s + thetap * (t - 1) + 2 * z - 1  # psi3
    vprime = max(Fraction(t * s - 2 * t - s + 2), Fraction(t * s - 2 * t + 1, 2))
    if z > vprime:
        return (t + 1) * t * s + (t - 1) * (z + t - 1) + 2 * z - 1  # psi4
    return thetap * t + z  # psi5


# ----------------------------------------------------------------------
# Theorem 8: AGE-CMPC
# ----------------------------------------------------------------------
def age_gamma(s: int, t: int, z: int, lam: int) -> int:
    """Gamma(lambda) of eq. (31).  Requires t != 1."""
    if not (0 <= lam <= z):
        raise ValueError("0 <= lambda <= z")
    theta = t * s + lam
    if lam == 0:
        if z > t * s - s:
            return 2 * s * t * t + 2 * z - 1  # Upsilon_1
        return s * t * t + 3 * s * t - 2 * s + t * (z - 1) + 1  # Upsilon_2
    if lam == z:
        return 2 * t * s + (t * s + z) * (t - 1) + 2 * z - 1  # Upsilon_3
    q = min((z - 1) // lam, t - 1)
    if z > t * s:
        return (q + 2) * t * s + theta * (t - 1) + 2 * z - 1  # Upsilon_4
    if t * s < lam + s - 1:
        return 3 * t * s + theta * (t - 1) + 2 * z - 1  # Upsilon_5
    if lam + s - 1 < z:  # (and z <= ts)
        if q * lam >= s:
            return 2 * t * s + theta * (t - 1) + (q + 2) * z - q - 1  # Upsilon_6
        return (  # Upsilon_7
            theta * (t + q + 1)
            + q * (z - 1)
            - 2 * lam
            + z
            + t * s
            + min(0, z + s * (1 - t) - lam * q - 1)
        )
    # z <= lam + s - 1 <= ts
    if q * lam >= s:
        return (  # Upsilon_8
            2 * t * s + theta * (t - 1) + 3 * z + (lam + s - 1) * q - lam - s - 1
        )
    return (  # Upsilon_9
        theta * (t + 1)
        + q * (s - 1)
        - 3 * lam
        + 3 * z
        - 1
        + min(0, t * s - z + 1 + lam * q - s)
    )


def n_age(s: int, t: int, z: int) -> int:
    """Theorem 8: min over lambda in [0, z]."""
    if z < 1:
        raise ValueError("z >= 1")
    if t == 1:
        return 2 * s + 2 * z - 1
    return min(age_gamma(s, t, z, lam) for lam in range(0, z + 1))


def age_lambda_star(s: int, t: int, z: int) -> int:
    if t == 1:
        return 0
    return min(range(0, z + 1), key=lambda g: age_gamma(s, t, z, g))


# ----------------------------------------------------------------------
# baselines
# ----------------------------------------------------------------------
def n_entangled(s: int, t: int, z: int) -> int:
    """Theorem 1 of [15] (eq. 194)."""
    if z > t * s - s:
        return 2 * s * t * t + 2 * z - 1
    return s * t * t + 3 * s * t - 2 * s + t * z - t + 1


def n_ssmm(s: int, t: int, z: int) -> int:
    """Theorem 1 of [16]."""
    return (t + 1) * (t * s + z) - 1


def n_gcsa_na(s: int, t: int, z: int) -> int:
    """[17], one matrix multiplication (batch size 1)."""
    return 2 * s * t * t + 2 * z - 1


# ----------------------------------------------------------------------
# Exact worker counts (fast structured supports + indicator convolution)
# ----------------------------------------------------------------------
# The appendix closed forms above are transcriptions of the paper's
# Theorems 2/8.  Tests show they match the exact greedy constructions in
# most regions but overcount by small amounts in a few (Upsilon_5/7/9
# cells and PolyDot s=1 with z <= t, where the H-support has gaps the
# formulas do not discount).  Since eq. (23) *defines*
# N = |P(H(x))|, the exact counts below are authoritative; the
# transcribed formulas are kept for region-validated comparison.

import numpy as np


def n_from_supports(fa, fb) -> int:
    """|P(F_A) + P(F_B)| via indicator convolution (exact, O(D^2) bitops)."""
    fa = np.asarray(sorted(set(map(int, fa))), np.int64)
    fb = np.asarray(sorted(set(map(int, fb))), np.int64)
    ia = np.zeros(int(fa.max()) + 1, np.float64)
    ib = np.zeros(int(fb.max()) + 1, np.float64)
    ia[fa] = 1.0
    ib[fb] = 1.0
    conv = np.convolve(ia, ib)
    return int(np.count_nonzero(conv > 0.5))


def age_supports(s: int, t: int, z: int, lam: int):
    """Structured P(F_A), P(F_B) for AGE-CMPC (Theorem 7 / eqs. 28-29).

    S_A fills the lambda-length gaps [ts + theta*l, ts + theta*l + lam)
    for l = 0..t-2 and then runs past ts + theta*(t-1); S_B is z
    consecutive powers after the largest important power.  Validated
    against the greedy Algorithm 2 in tests.
    """
    theta = t * s + lam
    ca = list(range(0, t * s))  # {j + s*i}
    cb = [(s - 1 - k) + theta * l for k in range(s) for l in range(t)]
    max_imp = (s - 1) + s * (t - 1) + theta * (t - 1)
    sb = list(range(max_imp + 1, max_imp + 1 + z))
    sa = []
    if t == 1:
        sa = list(range(s, s + z))
    else:
        for l in range(t - 1):
            if len(sa) >= z:
                break
            lo = t * s + theta * l
            take = min(lam, z - len(sa))
            sa.extend(range(lo, lo + take))
        if len(sa) < z:
            lo = t * s + theta * (t - 1)
            sa.extend(range(lo, lo + z - len(sa)))
    return sorted(set(ca) | set(sa)), sorted(set(cb) | set(sb))


def n_age_exact_fixed(s: int, t: int, z: int, lam: int) -> int:
    fa, fb = age_supports(s, t, z, lam)
    return n_from_supports(fa, fb)


def n_age_exact(s: int, t: int, z: int):
    """Exact N_AGE-CMPC = min_lambda |P(H)| with the Algorithm-2 layout.

    Returns (n, lambda*).
    """
    if t == 1:
        return 2 * s + 2 * z - 1, 0
    best, best_lam = None, 0
    for lam in range(0, z + 1):
        n = n_age_exact_fixed(s, t, z, lam)
        if best is None or n < best:
            best, best_lam = n, lam
    return best, best_lam


N_FORMULAS = {
    "age": n_age,
    "polydot": n_polydot,
    "entangled": n_entangled,
    "ssmm": n_ssmm,
    "gcsa-na": n_gcsa_na,
}


def n_workers(method: str, s: int, t: int, z: int) -> int:
    return N_FORMULAS[method.lower()](s, t, z)


# ----------------------------------------------------------------------
# Corollaries 10-12: per-worker overheads (scalar counts)
# ----------------------------------------------------------------------
def computation_overhead(m: int, s: int, t: int, z: int, n: int) -> int:
    """Corollary 10: scalar multiplications per worker (eq. 32)."""
    return m**3 // (s * t * t) + m * m + n * (t * t + z - 1) * (m * m // (t * t))


def storage_overhead(m: int, s: int, t: int, z: int, n: int) -> int:
    """Corollary 11: scalars stored per worker (eq. 33)."""
    return (2 * n + z + 1) * (m * m // (t * t)) + 2 * m * m // (s * t) + t * t


def communication_overhead(m: int, t: int, n: int) -> int:
    """Corollary 12: scalars exchanged among workers in Phase 2 (eq. 34)."""
    return n * (n - 1) * (m * m // (t * t))


# ----------------------------------------------------------------------
# unified cost model: the closed-form prior behind plan selection
# ----------------------------------------------------------------------
import dataclasses as _dataclasses


@_dataclasses.dataclass(frozen=True)
class CostPrediction:
    """Closed-form resource prediction for one ``PlanConfig`` at size m.

    ``n_workers`` is the *exact* worker count (registry fast paths, not
    the occasionally-overcounting transcribed formulas); the per-worker
    overheads are Corollaries 10-12 evaluated at that count.  This is
    the data-independent prior an auto-planner scores candidates with
    before it has seen a single measured run.
    """

    n_workers: int
    n_total: int  # provisioned = workers + spares
    decode_threshold: int
    compute: int  # scalar mults per worker (Corollary 10)
    storage: int  # scalars stored per worker (Corollary 11)
    comm: int  # scalars exchanged among workers, Phase 2 (Corollary 12)
    # Adversarial accounting: with up to ``n_errors`` Byzantine workers
    # a Berlekamp-Welch decode needs ``decode_threshold + 2e`` responses
    # (Reed-Solomon distance), so the construction must provision
    # ``N + 2e`` workers to keep its straggler margin.  ``n_errors=0``
    # reproduces the fault-free prediction exactly.
    n_errors: int = 0

    @property
    def n_adversarial(self) -> int:
        """Workers needed with ``n_errors`` Byzantine among them: N + 2e."""
        return self.n_workers + 2 * self.n_errors

    @property
    def decode_responses(self) -> int:
        """Responses a correcting decode waits for: ``thr + 2e``."""
        return self.decode_threshold + 2 * self.n_errors

    def compute_factor(self, reference: "CostPrediction") -> float:
        """Per-worker compute relative to another prediction — the
        scale heterogeneous-compute scenarios multiply worker compute
        delays by when replaying one pool under several constructions."""
        return self.compute / max(reference.compute, 1)


def predict(config, m: int, pool_size: int = None, e: int = 0) -> CostPrediction:
    """Unified cost-model entry: ``PlanConfig``-shaped config -> costs.

    ``config`` needs attributes ``method, s, t, z, lam, n_spare``
    (a :class:`~repro.core.constructions.PlanConfig`).  ``m`` is the
    square-matrix dimension of the Corollary 10-12 overheads.  With
    ``pool_size`` the spare count is re-accounted against that physical
    pool (``n_total = pool_size``) instead of ``config.n_spare`` —
    the elastic-pool form planners use.

    ``e`` is the Byzantine error budget: a correcting decode needs
    ``decode_threshold + 2e`` responses, so the adversarial worker
    count is ``N + 2e`` (``CostPrediction.n_adversarial``) — what the
    auto-planner prices error correction against confirm-and-retry
    with.  A pool too small to seat ``N + 2e`` raises, mirroring the
    fault-free seating check.
    """
    from .constructions import get_construction  # deferred: cycle-free

    ctor = get_construction(config.method)
    n = ctor.n_workers(config.s, config.t, config.z, config.lam)
    e = int(e)
    if e < 0:
        raise ValueError("error budget e must be >= 0")
    n_adv = n + 2 * e
    if pool_size is not None:
        if pool_size < n_adv:
            raise ValueError(
                f"pool of {pool_size} cannot seat {config.method} "
                f"(needs {n} workers + 2e = {n_adv} under e={e} errors)"
            )
        n_total = pool_size
    else:
        n_total = n_adv + config.n_spare
    s, t, z = config.s, config.t, config.z
    return CostPrediction(
        n_workers=n,
        n_total=n_total,
        decode_threshold=t * t + z,
        compute=computation_overhead(m, s, t, z, n),
        storage=storage_overhead(m, s, t, z, n),
        comm=communication_overhead(m, t, n),
        n_errors=e,
    )
