"""Finite-field GF(p) arithmetic for coded MPC.

Two execution paths:

* **Host path** (numpy ``int64``): exact reference arithmetic used for
  protocol planning (Vandermonde inverses, Lagrange coefficients) and as
  the test oracle.  ``p`` may be any prime < 2**31.

* **Device path** (jnp ``float32`` limbs): TPU-native modular matmul.
  The MXU is a floating-point systolic array, so instead of porting an
  integer GPU algorithm we decompose field elements ``a = a_hi*256 +
  a_lo`` into 8-bit limbs, accumulate limb products exactly in f32
  (products < 2**16; <=256 accumulands keeps partial sums < 2**24, the
  f32 exact-integer bound) and reduce mod p after every 256-deep chunk.
  This requires ``p < 2**16``; the default prime is 65521 (the largest
  16-bit prime).

The device path is also implemented as a Pallas TPU kernel in
``repro.kernels.modmatmul``; the jnp version here is the portable
fallback (identical math, usable inside shard_map/vmap everywhere).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Largest 16-bit prime: elements fit in two 8-bit limbs exactly, enabling
# exact f32 accumulation on the MXU with 256-deep inner chunks.
P_DEFAULT = 65521

# Inner-dimension chunk depth for exact f32 limb accumulation:
# 255*255*256 = 16_646_400 < 2**24.
CHUNK_K = 256

LIMB = 256  # limb base


@dataclasses.dataclass(frozen=True)
class Field:
    """A prime field GF(p)."""

    p: int = P_DEFAULT

    def __post_init__(self):
        if self.p < 3:
            raise ValueError("p must be an odd prime")

    # ------------------------------------------------------------------
    # host (numpy int64) reference arithmetic
    # ------------------------------------------------------------------
    def asarray(self, x) -> np.ndarray:
        return np.asarray(x, dtype=np.int64) % self.p

    def add(self, a, b):
        return (np.asarray(a, np.int64) + np.asarray(b, np.int64)) % self.p

    def sub(self, a, b):
        return (np.asarray(a, np.int64) - np.asarray(b, np.int64)) % self.p

    def mul(self, a, b):
        return (np.asarray(a, np.int64) * np.asarray(b, np.int64)) % self.p

    def matmul(self, a, b) -> np.ndarray:
        """Exact (mod p) matmul on the host; chunked to avoid int64 overflow."""
        a = self.asarray(a)
        b = self.asarray(b)
        k = a.shape[-1]
        # (p-1)^2 * chunk must stay < 2**63; p < 2**31 -> chunk >= 2 always ok.
        chunk = max(1, int((2**62) // (int(self.p - 1) ** 2)))
        out = np.zeros(a.shape[:-1] + b.shape[1:], dtype=np.int64)
        for s in range(0, k, chunk):
            out = (out + a[..., s : s + chunk] @ b[s : s + chunk]) % self.p
        return out

    def pow(self, a, e: int):
        a = int(a) % self.p
        return pow(a, int(e), self.p)

    def inv(self, a):
        a = int(a) % self.p
        if a == 0:
            raise ZeroDivisionError("0 has no inverse in GF(p)")
        return pow(a, self.p - 2, self.p)

    def neg(self, a):
        return (-np.asarray(a, np.int64)) % self.p

    def random(self, rng: np.random.Generator, shape) -> np.ndarray:
        return rng.integers(0, self.p, size=shape, dtype=np.int64)

    # ------------------------------------------------------------------
    # structured host helpers
    # ------------------------------------------------------------------
    def vandermonde(self, points, powers) -> np.ndarray:
        """V[n, j] = points[n] ** powers[j]  (mod p)."""
        points = np.asarray(points, np.int64) % self.p
        powers = list(int(u) for u in powers)
        cols = [np.array([self.pow(x, u) for x in points], np.int64) for u in powers]
        return np.stack(cols, axis=1)

    def solve(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Solve a @ x = b (mod p) by Gauss-Jordan elimination."""
        a = self.asarray(a).copy()
        b = self.asarray(b).copy()
        n = a.shape[0]
        if a.shape[1] != n:
            raise ValueError("square system required")
        if b.ndim == 1:
            b = b[:, None]
            squeeze = True
        else:
            squeeze = False
        for col in range(n):
            piv = None
            for r in range(col, n):
                if a[r, col] != 0:
                    piv = r
                    break
            if piv is None:
                raise ZeroDivisionError("singular matrix mod p")
            if piv != col:
                a[[col, piv]] = a[[piv, col]]
                b[[col, piv]] = b[[piv, col]]
            inv = self.inv(a[col, col])
            a[col] = (a[col] * inv) % self.p
            b[col] = (b[col] * inv) % self.p
            for r in range(n):
                if r != col and a[r, col] != 0:
                    f = a[r, col]
                    a[r] = (a[r] - f * a[col]) % self.p
                    b[r] = (b[r] - f * b[col]) % self.p
        x = b % self.p
        return x[:, 0] if squeeze else x

    def inv_matrix(self, a: np.ndarray) -> np.ndarray:
        return self.solve(a, np.eye(a.shape[0], dtype=np.int64))

    # ------------------------------------------------------------------
    # fixed-point quantisation (real <-> field)
    # ------------------------------------------------------------------
    def encode(self, x: np.ndarray, scale: int) -> np.ndarray:
        """Quantise reals into the field with a centered lift."""
        q = np.rint(np.asarray(x, np.float64) * scale).astype(np.int64)
        half = (self.p - 1) // 2
        if np.any(np.abs(q) > half):
            raise OverflowError("value out of field range at this scale")
        return q % self.p

    def decode(self, x: np.ndarray, scale: int) -> np.ndarray:
        """Centered lift back to signed reals."""
        x = self.asarray(x)
        half = (self.p - 1) // 2
        signed = np.where(x > half, x - self.p, x)
        return signed.astype(np.float64) / scale


# ----------------------------------------------------------------------
# jnp device path: exact f32 limb arithmetic (p < 2**16)
# ----------------------------------------------------------------------
def _check_limb_prime(p: int):
    if p >= 1 << 16:
        raise ValueError("f32 limb path requires p < 2**16")


def _mod_f32(x: jnp.ndarray, p: float) -> jnp.ndarray:
    """x mod p for exact-integer-valued f32 x with x < 2**24.

    f32 division rounds, so floor(x/p) can be off by one; both products
    q*p and the correction arithmetic stay exact (< 2**24), so a single
    conditional fix-up on each side restores exactness.
    """
    q = jnp.floor(x / p)
    r = x - q * p
    r = jnp.where(r < 0, r + p, r)
    return jnp.where(r >= p, r - p, r)


def _mulmod_const_f32(x: jnp.ndarray, c: int, p: int) -> jnp.ndarray:
    """x * c mod p for f32 x in [0, p), constant c in [0, p), p < 2**16.

    Decomposes x into 8-bit limbs so every product stays < 2**24 (f32
    exact-integer range) for *any* 16-bit prime.
    """
    pf = float(p)
    c_hi = float((c * LIMB) % p)  # (256*c mod p) < 2**16
    c_lo = float(c % p)
    x_hi = jnp.floor(x / LIMB)  # < 256
    x_lo = x - x_hi * LIMB  # < 256
    return _mod_f32(_mod_f32(x_hi * c_hi, pf) + _mod_f32(x_lo * c_lo, pf), pf)


def _limb_split(x: jnp.ndarray):
    hi = jnp.floor(x / LIMB)
    return hi, x - hi * LIMB


def _limb_dot(a_hi, a_lo, b_hi, b_lo, p: int) -> jnp.ndarray:
    """One <=256-deep limb-decomposed dot, reduced mod p (exact in f32).

    Each single dot accumulates <= 256 products of 8-bit limbs, staying
    below 2**24 (exact in f32); the two cross dots must be reduced
    *separately* before adding — their raw sum can reach ~2**25 and
    lose the low bit.
    """
    pf = float(p)
    f_hihi = int((LIMB * LIMB) % p)  # 2**16 mod p
    f_mid = int(LIMB % p)  # 2**8 mod p
    hh = _mod_f32(a_hi @ b_hi, pf)
    hl = _mod_f32(_mod_f32(a_hi @ b_lo, pf) + _mod_f32(a_lo @ b_hi, pf), pf)
    ll = _mod_f32(a_lo @ b_lo, pf)
    return _mod_f32(
        _mulmod_const_f32(hh, f_hihi, p) + _mulmod_const_f32(hl, f_mid, p) + ll, pf
    )


@partial(jax.jit, static_argnames=("p",))
def mod_matmul_f32(a: jnp.ndarray, b: jnp.ndarray, p: int = P_DEFAULT) -> jnp.ndarray:
    """Exact GF(p) matmul via 8-bit limb decomposition in f32.

    a: [..., M, K] int32 in [0, p);  b: [K, N] int32 in [0, p).
    Returns int32 [..., M, N] = a @ b mod p.

    Contractions of depth <= CHUNK_K take a no-padding single-dot fast
    path (any accumulation <= 256 deep is exact in f32); deeper ones are
    zero-padded to a CHUNK_K multiple and reduced once per chunk under a
    scan.  The protocol's per-worker block products are typically far
    shallower than CHUNK_K, where padding would waste ~CHUNK_K/K of the
    FLOPs.
    """
    _check_limb_prime(p)
    pf = float(p)
    k = a.shape[-1]

    if k <= CHUNK_K:
        a_hi, a_lo = _limb_split(a.astype(jnp.float32))
        b_hi, b_lo = _limb_split(b.astype(jnp.float32))
        return _limb_dot(a_hi, a_lo, b_hi, b_lo, p).astype(jnp.int32)

    pad = (-k) % CHUNK_K
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
        b = jnp.pad(b, [(0, pad), (0, 0)])
        k += pad
    nchunk = k // CHUNK_K

    a_hi, a_lo = _limb_split(a.astype(jnp.float32))
    b_hi, b_lo = _limb_split(b.astype(jnp.float32))

    out_shape = a.shape[:-1] + (b.shape[-1],)
    acc0 = jnp.zeros(out_shape, jnp.float32)

    # Re-chunk the contraction dim to the scan axis: [nchunk, ..., CHUNK_K].
    def chunked_lhs(x):
        x = x.reshape(x.shape[:-1] + (nchunk, CHUNK_K))
        return jnp.moveaxis(x, -2, 0)

    ah_c, al_c = chunked_lhs(a_hi), chunked_lhs(a_lo)
    bh_c = b_hi.reshape(nchunk, CHUNK_K, b.shape[-1])
    bl_c = b_lo.reshape(nchunk, CHUNK_K, b.shape[-1])

    def body(acc, xs):
        ah, al, bh, bl = xs
        # Each dot accumulates <=256 products of values < 2**16: exact in f32.
        chunkv = _limb_dot(ah, al, bh, bl, p)
        return _mod_f32(acc + chunkv, pf), None

    acc, _ = jax.lax.scan(body, acc0, (ah_c, al_c, bh_c, bl_c))
    return acc.astype(jnp.int32)


@partial(jax.jit, static_argnames=("p",))
def mod_mul(a: jnp.ndarray, b: jnp.ndarray, p: int = P_DEFAULT) -> jnp.ndarray:
    """Elementwise a*b mod p. Products of 16-bit values fit exactly in uint32."""
    _check_limb_prime(p)
    prod = a.astype(jnp.uint32) * b.astype(jnp.uint32)
    return (prod % jnp.uint32(p)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("p",))
def mod_add(a: jnp.ndarray, b: jnp.ndarray, p: int = P_DEFAULT) -> jnp.ndarray:
    s = a.astype(jnp.uint32) + b.astype(jnp.uint32)
    return (s % jnp.uint32(p)).astype(jnp.int32)


def random_field_device(key, shape, p: int = P_DEFAULT) -> jnp.ndarray:
    """Uniform GF(p) elements drawn on-device with the JAX PRNG.

    Device-resident counterpart of ``Field.random`` (numpy) — used by the
    batched protocol engine so secret/blinding terms never touch the
    host.  Returns int32 in [0, p); traceable under jit.
    """
    return jax.random.randint(key, shape, 0, p, dtype=jnp.int32)


def powers_matrix(points: np.ndarray, powers, p: int = P_DEFAULT) -> np.ndarray:
    """Host-side Vandermonde with arbitrary power support; int64 -> int32-safe."""
    f = Field(p)
    return f.vandermonde(points, powers).astype(np.int64)
