"""Finite-field GF(p) arithmetic for coded MPC.

Two execution paths:

* **Host path** (numpy ``int64``): exact reference arithmetic used for
  protocol planning (Vandermonde inverses, Lagrange coefficients) and as
  the test oracle.  ``p`` may be any prime < 2**31.

* **Device path** (jnp ``float32`` limbs): TPU-native modular matmul.
  The MXU is a floating-point systolic array, so instead of porting an
  integer GPU algorithm we decompose field elements ``a = a_hi*256 +
  a_lo`` into 8-bit limbs, accumulate limb products exactly in f32
  (products < 2**16; <=256 accumulands keeps partial sums < 2**24, the
  f32 exact-integer bound) and reduce mod p after every 256-deep chunk.
  This requires ``p < 2**16``; the default prime is 65521 (the largest
  16-bit prime).

The device path is also implemented as a Pallas TPU kernel in
``repro.kernels.modmatmul``; the jnp version here is the portable
fallback (identical math, usable inside shard_map/vmap everywhere).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Largest 16-bit prime: elements fit in two 8-bit limbs exactly, enabling
# exact f32 accumulation on the MXU with 256-deep inner chunks.
P_DEFAULT = 65521

# Inner-dimension chunk depth for exact f32 limb accumulation:
# 255*255*256 = 16_646_400 < 2**24.
CHUNK_K = 256

# Lazy-reduction depth bound for *pure-f32* pipelines (the Pallas
# kernel): the two cross-limb dots may be summed raw before a single
# reduction iff 2 * depth * 255**2 < 2**24, i.e. depth <= 129.  At
# depth <= 128 the final recombination may also fold the raw low-limb
# dot and the running accumulator into one reduction:
# 3*(p-1) + 128*255**2 = 8_519_760 < 2**24 for any p < 2**16.
LAZY_K = 128

LIMB = 256  # limb base

# Contraction-depth bound for the native-integer (uint32 accumulator)
# matmul path.  Raw per-chunk limb dots are summed across chunks in
# uint32 *without* intermediate reductions; the binding constraint is
# the summed cross-limb dot: each CHUNK_K-deep chunk contributes at
# most 2 * 256 * 255**2 = 33_292_800, and 129 chunks stay under 2**32
# (129 * 33_292_800 = 4_294_771_200) while 130 would wrap.  The
# same-depth hi/lo dots are a factor ~4 below their bound.
INT32_ACC_CHUNKS = 129
INT32_ACC_K = INT32_ACC_CHUNKS * CHUNK_K  # 33024


@dataclasses.dataclass(frozen=True)
class Field:
    """A prime field GF(p)."""

    p: int = P_DEFAULT

    def __post_init__(self):
        if self.p < 3:
            raise ValueError("p must be an odd prime")

    @property
    def elem_bytes(self) -> int:
        """Wire width of one field element (bytes-level Trace views)."""
        return (self.p.bit_length() + 7) // 8

    # ------------------------------------------------------------------
    # host (numpy int64) reference arithmetic
    # ------------------------------------------------------------------
    def asarray(self, x) -> np.ndarray:
        return np.asarray(x, dtype=np.int64) % self.p

    def add(self, a, b):
        return (np.asarray(a, np.int64) + np.asarray(b, np.int64)) % self.p

    def sub(self, a, b):
        return (np.asarray(a, np.int64) - np.asarray(b, np.int64)) % self.p

    def mul(self, a, b):
        return (np.asarray(a, np.int64) * np.asarray(b, np.int64)) % self.p

    def matmul(self, a, b) -> np.ndarray:
        """Exact (mod p) matmul on the host; chunked to avoid int64 overflow."""
        a = self.asarray(a)
        b = self.asarray(b)
        k = a.shape[-1]
        # (p-1)^2 * chunk must stay < 2**63; p < 2**31 -> chunk >= 2 always ok.
        chunk = max(1, int((2**62) // (int(self.p - 1) ** 2)))
        out = np.zeros(a.shape[:-1] + b.shape[1:], dtype=np.int64)
        for s in range(0, k, chunk):
            out = (out + a[..., s : s + chunk] @ b[s : s + chunk]) % self.p
        return out

    def pow(self, a, e: int):
        a = int(a) % self.p
        return pow(a, int(e), self.p)

    def inv(self, a):
        a = int(a) % self.p
        if a == 0:
            raise ZeroDivisionError("0 has no inverse in GF(p)")
        return pow(a, self.p - 2, self.p)

    def neg(self, a):
        return (-np.asarray(a, np.int64)) % self.p

    def random(self, rng: np.random.Generator, shape) -> np.ndarray:
        return rng.integers(0, self.p, size=shape, dtype=np.int64)

    # ------------------------------------------------------------------
    # structured host helpers
    # ------------------------------------------------------------------
    def _pow_table(self, base: np.ndarray, exps: np.ndarray) -> np.ndarray:
        """T[n, j] = base[n] ** exps[j] (mod p) by column-wise repeated
        squaring: one vectorized squaring pass per exponent bit instead
        of a scalar ``pow`` per element.  exps must be non-negative."""
        out = np.ones((base.size, exps.size), np.int64)
        sq = base % self.p
        e = exps.astype(np.int64).copy()
        while e.any():
            mask = (e & 1).astype(bool)
            if mask.any():
                # (p-1)**2 < 2**62 for p < 2**31: int64-exact.
                out[:, mask] = (out[:, mask] * sq[:, None]) % self.p
            e >>= 1
            sq = (sq * sq) % self.p
        return out

    def vandermonde(self, points, powers) -> np.ndarray:
        """V[n, j] = points[n] ** powers[j]  (mod p)."""
        points = np.atleast_1d(np.asarray(points, np.int64)) % self.p
        exps = np.asarray([int(u) for u in powers], np.int64)
        out = np.ones((points.size, exps.size), np.int64)
        if exps.size == 0:
            return out
        pos = exps >= 0
        if pos.any():
            out[:, pos] = self._pow_table(points, exps[pos])
        if (~pos).any():
            if np.any(points == 0):
                raise ZeroDivisionError("0 has no inverse in GF(p)")
            inv_pts = self._pow_table(points, np.array([self.p - 2]))[:, 0]
            out[:, ~pos] = self._pow_table(inv_pts, -exps[~pos])
        return out

    def solve(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Solve a @ x = b (mod p) by Gauss-Jordan elimination."""
        a = self.asarray(a).copy()
        b = self.asarray(b).copy()
        n = a.shape[0]
        if a.shape[1] != n:
            raise ValueError("square system required")
        if b.ndim == 1:
            b = b[:, None]
            squeeze = True
        else:
            squeeze = False
        for col in range(n):
            piv = None
            for r in range(col, n):
                if a[r, col] != 0:
                    piv = r
                    break
            if piv is None:
                raise ZeroDivisionError("singular matrix mod p")
            if piv != col:
                a[[col, piv]] = a[[piv, col]]
                b[[col, piv]] = b[[piv, col]]
            inv = self.inv(a[col, col])
            a[col] = (a[col] * inv) % self.p
            b[col] = (b[col] * inv) % self.p
            for r in range(n):
                if r != col and a[r, col] != 0:
                    f = a[r, col]
                    a[r] = (a[r] - f * a[col]) % self.p
                    b[r] = (b[r] - f * b[col]) % self.p
        x = b % self.p
        return x[:, 0] if squeeze else x

    def inv_matrix(self, a: np.ndarray) -> np.ndarray:
        return self.solve(a, np.eye(a.shape[0], dtype=np.int64))

    def solve_any(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """One solution of a @ x = b (mod p) for a general [m, n] system.

        Unlike :meth:`solve`, ``a`` may be rectangular or rank-deficient:
        Gauss-Jordan runs column by column, free variables are pinned to
        zero, and a zero row of the reduced ``a`` with a nonzero reduced
        ``b`` raises ``ValueError`` (inconsistent system).  This is what
        the Berlekamp-Welch decoder needs — its key system is
        deliberately overdetermined (``thr + 2e`` unknowns, more
        equations) and singular whenever fewer than ``e`` errors actually
        occurred, where *any* particular solution is a valid decode.
        """
        a = self.asarray(a).copy()
        b = self.asarray(b).copy()
        m, n = a.shape
        if b.ndim == 1:
            b = b[:, None]
            squeeze = True
        else:
            squeeze = False
        if b.shape[0] != m:
            raise ValueError(f"rhs has {b.shape[0]} rows, lhs has {m}")
        pivots = []
        row = 0
        for col in range(n):
            if row >= m:
                break
            piv = None
            for r in range(row, m):
                if a[r, col] != 0:
                    piv = r
                    break
            if piv is None:
                continue  # free column
            if piv != row:
                a[[row, piv]] = a[[piv, row]]
                b[[row, piv]] = b[[piv, row]]
            inv = self.inv(a[row, col])
            a[row] = (a[row] * inv) % self.p
            b[row] = (b[row] * inv) % self.p
            for r in range(m):
                if r != row and a[r, col] != 0:
                    f = a[r, col]
                    a[r] = (a[r] - f * a[row]) % self.p
                    b[r] = (b[r] - f * b[row]) % self.p
            pivots.append(col)
            row += 1
        if row < m and np.any(b[row:] != 0):
            raise ValueError("inconsistent linear system mod p")
        x = np.zeros((n, b.shape[1]), np.int64)
        if pivots:
            x[np.asarray(pivots)] = b[: len(pivots)]
        return x[:, 0] if squeeze else x

    def poly_divmod(
        self, num: np.ndarray, den: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Polynomial division mod p on ascending coefficient vectors.

        Returns (quotient, remainder) with ``num = quotient * den +
        remainder`` and ``deg(remainder) < deg(den)``.  ``den`` need not
        be monic (its leading coefficient is inverted once).
        """
        num = self.asarray(num).copy()
        den = self.asarray(den)
        d = int(den.size) - 1
        while d > 0 and den[d] == 0:
            d -= 1
        if den[d] == 0:
            raise ZeroDivisionError("division by the zero polynomial")
        lead_inv = self.inv(den[d])
        n = int(num.size) - 1
        if n < d:
            return np.zeros(1, np.int64), num
        quo = np.zeros(n - d + 1, np.int64)
        for k in range(n - d, -1, -1):
            c = (num[k + d] * lead_inv) % self.p
            if c:
                quo[k] = c
                num[k : k + d + 1] = (num[k : k + d + 1] - c * den[: d + 1]) % self.p
        rem = num[:d] if d > 0 else np.zeros(1, np.int64)
        return quo, rem

    def poly_eval(self, coeffs: np.ndarray, xs: np.ndarray) -> np.ndarray:
        """Evaluate an ascending-coefficient polynomial at points xs
        (Horner, vectorized over the points)."""
        coeffs = self.asarray(coeffs)
        xs = self.asarray(xs)
        out = np.zeros_like(xs)
        for c in coeffs[::-1]:
            out = (out * xs + c) % self.p
        return out

    # ------------------------------------------------------------------
    # fixed-point quantisation (real <-> field)
    # ------------------------------------------------------------------
    def encode(self, x: np.ndarray, scale: int) -> np.ndarray:
        """Quantise reals into the field with a centered lift."""
        q = np.rint(np.asarray(x, np.float64) * scale).astype(np.int64)
        half = (self.p - 1) // 2
        if np.any(np.abs(q) > half):
            raise OverflowError("value out of field range at this scale")
        return q % self.p

    def decode(self, x: np.ndarray, scale: int) -> np.ndarray:
        """Centered lift back to signed reals."""
        x = self.asarray(x)
        half = (self.p - 1) // 2
        signed = np.where(x > half, x - self.p, x)
        return signed.astype(np.float64) / scale


# ----------------------------------------------------------------------
# jnp device path: exact f32 limb arithmetic (p < 2**16)
# ----------------------------------------------------------------------
def _check_limb_prime(p: int):
    if p >= 1 << 16:
        raise ValueError("f32 limb path requires p < 2**16")


def _limb_split(x: jnp.ndarray):
    hi = jnp.floor(x / LIMB)
    return hi, x - hi * LIMB


def _limb_dot_u32(dot, a_hi, a_lo, b_hi, b_lo, p: int, acc=None) -> jnp.ndarray:
    """One <=256-deep limb-decomposed contraction, reduced mod p.

    The four limb dots run on the matrix unit in f32 (each accumulates
    <= 256 products of 8-bit limbs, staying below 2**24 — exact in f32);
    the f32 -> uint32 handoff is therefore exact, and all recombination
    happens lazily in uint32 where the headroom is 2**32 instead of
    2**24.  Per-dot reductions disappear entirely: the cross dots are
    summed raw (< 2**25), the low-limb dot and the running accumulator
    fold into the final reduction, and the recombination constants are
    applied with a *static* overflow check that pre-reduces only when
    bound * c could actually exceed uint32 range.

    ``dot`` is any f32 contraction of depth <= CHUNK_K (a closure over
    ``lax.dot_general`` dimension numbers, so the same code serves 2D,
    batched, and one-sided-constant operand layouts).  ``acc`` is an
    optional uint32 accumulator in [0, p).  Returns uint32 in [0, p).
    """
    pu = jnp.uint32(p)
    f_hihi = int((LIMB * LIMB) % p)  # 2**16 mod p
    f_mid = int(LIMB % p)  # 2**8 mod p
    hh = dot(a_hi, b_hi).astype(jnp.uint32)  # < 2**24
    mid = dot(a_hi, b_lo).astype(jnp.uint32) + dot(a_lo, b_hi).astype(jnp.uint32)
    ll = dot(a_lo, b_lo).astype(jnp.uint32)  # < 2**24

    def mulc(x, c, xmax):
        # x * c mod p for x <= xmax; pre-reduce x only when the raw
        # product could overflow uint32 (static check — c, xmax are
        # Python ints).
        if c == 0:
            return jnp.zeros_like(x)
        if xmax * c >= 1 << 32:
            x = x % pu
        return (x * jnp.uint32(c)) % pu

    tile = mulc(hh, f_hihi, (1 << 24) - 1) + mulc(mid, f_mid, (1 << 25) - 1) + ll
    # tile < 2*p + 2**24 < 2**25; adding acc (< p) stays far below 2**32.
    if acc is not None:
        tile = tile + acc
    return tile % pu


def _contract_dnums(a_ndim: int, b_ndim: int, n_batch: int):
    """dot_general dimension numbers for [..., M, K] @ [..., K, N].

    Returns (contract_dims, batch_dims, a_kaxis, b_kaxis, move_m) where
    ``move_m`` flags the 2D-LHS/batched-RHS layout whose raw output is
    [M, *batch, N] and needs the M axis moved back before returning.
    """
    if b_ndim == 2:
        # [..., M, K] @ [K, N] -> [..., M, N]
        return ((a_ndim - 1,), (0,)), ((), ()), a_ndim - 1, 0, False
    if a_ndim == 2:
        # [M, K] @ [*batch, K, N] -> [M, *batch, N]: the constant LHS is
        # contracted (and limb-split) ONCE instead of being broadcast
        # per batch element.
        return ((1,), (b_ndim - 2,)), ((), ()), 1, b_ndim - 2, True
    batch = tuple(range(n_batch))
    return (
        ((n_batch + 1,), (n_batch,)),
        (batch, batch),
        n_batch + 1,
        n_batch,
        False,
    )


@partial(jax.jit, static_argnames=("p",))
def mod_matmul_f32(a: jnp.ndarray, b: jnp.ndarray, p: int = P_DEFAULT) -> jnp.ndarray:
    """Exact GF(p) matmul via 8-bit limb decomposition in f32.

    a: [..., M, K] @ b: [..., K, N] (int32 in [0, p)) with numpy-style
    broadcasting over the leading batch dims; either side may be a 2D
    constant matrix, which is contracted via ``dot_general`` without
    materializing per-batch copies (and limb-split exactly once).
    Returns int32 [..., M, N] = a @ b mod p.

    Contractions of depth <= CHUNK_K take a no-padding single-dot fast
    path (any accumulation <= 256 deep is exact in f32); deeper ones are
    zero-padded to a CHUNK_K multiple and reduced once per chunk under a
    scan.  The protocol's per-worker block products are typically far
    shallower than CHUNK_K, where padding would waste ~CHUNK_K/K of the
    FLOPs.
    """
    _check_limb_prime(p)
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim < 2 or b.ndim < 2:
        raise ValueError(f"operands must be at least 2D, got {a.shape} {b.shape}")
    if a.ndim > 2 and b.ndim > 2:
        batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        a = jnp.broadcast_to(a, batch + a.shape[-2:])
        b = jnp.broadcast_to(b, batch + b.shape[-2:])
        n_batch = len(batch)
    else:
        n_batch = 0
    contract, batch_dims, ka, kb, move_m = _contract_dnums(a.ndim, b.ndim, n_batch)
    dnums = (contract, batch_dims)

    def dot(x, y):
        return jax.lax.dot_general(x, y, dnums, preferred_element_type=jnp.float32)

    def finish(out_u32):
        out = out_u32.astype(jnp.int32)
        return jnp.moveaxis(out, 0, -2) if move_m else out

    k = a.shape[ka]
    if k <= CHUNK_K:
        a_hi, a_lo = _limb_split(a.astype(jnp.float32))
        b_hi, b_lo = _limb_split(b.astype(jnp.float32))
        return finish(_limb_dot_u32(dot, a_hi, a_lo, b_hi, b_lo, p))

    pad = (-k) % CHUNK_K
    if pad:
        wa = [(0, 0)] * a.ndim
        wa[ka] = (0, pad)
        wb = [(0, 0)] * b.ndim
        wb[kb] = (0, pad)
        a = jnp.pad(a, wa)
        b = jnp.pad(b, wb)
        k += pad
    nchunk = k // CHUNK_K

    a_hi, a_lo = _limb_split(a.astype(jnp.float32))
    b_hi, b_lo = _limb_split(b.astype(jnp.float32))

    def chunked(x, axis):
        # Split the contraction axis into (nchunk, CHUNK_K) and move the
        # chunk count to the front as the scan axis; the CHUNK_K slice
        # stays at ``axis`` so the same dnums apply inside the scan.
        x = x.reshape(x.shape[:axis] + (nchunk, CHUNK_K) + x.shape[axis + 1 :])
        return jnp.moveaxis(x, axis, 0)

    xs = (
        chunked(a_hi, ka),
        chunked(a_lo, ka),
        chunked(b_hi, kb),
        chunked(b_lo, kb),
    )
    acc0 = jnp.zeros(jax.eval_shape(dot, a_hi, b_hi).shape, jnp.uint32)

    def body(acc, limbs):
        ah, al, bh, bl = limbs
        return _limb_dot_u32(dot, ah, al, bh, bl, p, acc=acc), None

    acc, _ = jax.lax.scan(body, acc0, xs)
    return finish(acc)


# ----------------------------------------------------------------------
# native-integer path: Barrett reduction in pure uint32
# ----------------------------------------------------------------------
def barrett_reduce_u32(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """x mod p for uint32 x (any value < 2**32), without 64-bit arithmetic.

    Barrett with mu = floor(2**32 / p): the quotient estimate
    q = floor(x * mu / 2**32) satisfies floor(x/p) - q in {0, 1}, so one
    conditional subtract finishes the reduction.  The 64-bit product
    x * mu is never formed — its high word is assembled from four 16-bit
    limb products, each of which fits uint32:

        x*mu = 2**32*xh*mh + 2**16*(xh*ml + xl*mh) + xl*ml
        q    = xh*mh + (u >> 16) + (v >> 16)       (exact; see below)

    with u = xh*ml + (xl*ml >> 16) and v = xl*mh + (u & 0xFFFF) — the
    carries of the middle column folded in 16 bits at a time.  Every op
    lowers to uint32 vector mul/shift/add, so the same code runs in jnp,
    inside Pallas kernel bodies, and on integer-capable accelerators.
    Requires 1 < p < 2**16 (so that q * p also stays in uint32).
    """
    if not 1 < p < (1 << 16):
        raise ValueError(f"barrett_reduce_u32 requires 1 < p < 2**16, got {p}")
    mu = (1 << 32) // p
    mh = jnp.uint32(mu >> 16)
    ml = jnp.uint32(mu & 0xFFFF)
    x = x.astype(jnp.uint32)
    xh = x >> jnp.uint32(16)
    xl = x & jnp.uint32(0xFFFF)
    t = xl * ml
    u = xh * ml + (t >> jnp.uint32(16))
    v = xl * mh + (u & jnp.uint32(0xFFFF))
    q = xh * mh + (u >> jnp.uint32(16)) + (v >> jnp.uint32(16))
    r = x - q * jnp.uint32(p)
    return jnp.where(r >= jnp.uint32(p), r - jnp.uint32(p), r)


def _barrett_recombine(hh, mid, ll, p: int) -> jnp.ndarray:
    """Recombine raw uint32 limb-dot accumulators into [0, p).

    hh/mid/ll are the hi*hi / cross / lo*lo contraction sums (uint32,
    any value — callers enforce the no-wrap depth bounds).  Each is
    Barrett-reduced before the 16-bit recombination constant is applied,
    so every intermediate stays below p * 2**16 < 2**32.
    """
    f_hihi = (1 << 16) % p
    f_mid = LIMB % p

    def mulc(x, c):
        if c == 0:
            return jnp.zeros_like(x)
        return barrett_reduce_u32(barrett_reduce_u32(x, p) * jnp.uint32(c), p)

    out = mulc(hh, f_hihi) + mulc(mid, f_mid) + barrett_reduce_u32(ll, p)
    return barrett_reduce_u32(out, p)  # sum of three residues < 3p


@partial(jax.jit, static_argnames=("p",))
def mod_matmul_int32(a: jnp.ndarray, b: jnp.ndarray, p: int = P_DEFAULT) -> jnp.ndarray:
    """Exact GF(p) matmul on the native-integer tier (uint32 + Barrett).

    Same operand contract as :func:`mod_matmul_f32` (batched / one-sided
    2D layouts, int32 in [0, p)).  The limb dots still run in f32 (on
    CPU/TPU the f32 GEMM is the fast contraction engine), but everything
    *between* chunks moves to uint32:

    * the contraction is split into CHUNK_K-deep chunks batched into ONE
      set of dots (the chunk axis rides ``vmap`` as a batch dimension —
      no ``scan``, no per-chunk reduction),
    * the raw per-chunk partial sums accumulate across chunks in uint32,
      where the headroom is 2**32 instead of f32's 2**24,
    * a single Barrett recombination at the end replaces the per-chunk
      ``%`` of the f32limb path.

    Deep contractions therefore pay O(1) reductions instead of O(K/256),
    which is where this path overtakes ``mod_matmul_f32`` (see
    ``BENCH_protocol.json`` / ``docs/kernel_design.md``).  The no-wrap
    bound is loud, not silent: padded depth beyond ``INT32_ACC_K``
    (= 33024) raises instead of wrapping the accumulator.
    """
    _check_limb_prime(p)
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim < 2 or b.ndim < 2:
        raise ValueError(f"operands must be at least 2D, got {a.shape} {b.shape}")
    if a.ndim > 2 and b.ndim > 2:
        batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        a = jnp.broadcast_to(a, batch + a.shape[-2:])
        b = jnp.broadcast_to(b, batch + b.shape[-2:])
        n_batch = len(batch)
    else:
        n_batch = 0
    contract, batch_dims, ka, kb, move_m = _contract_dnums(a.ndim, b.ndim, n_batch)
    dnums = (contract, batch_dims)

    def dot(x, y):
        return jax.lax.dot_general(x, y, dnums, preferred_element_type=jnp.float32)

    def finish(out_u32):
        out = out_u32.astype(jnp.int32)
        return jnp.moveaxis(out, 0, -2) if move_m else out

    k = a.shape[ka]
    kpad = -(-k // CHUNK_K) * CHUNK_K
    if kpad > INT32_ACC_K:
        raise ValueError(
            f"int32 backend: padded contraction depth {kpad} exceeds the "
            f"uint32 accumulator bound INT32_ACC_K={INT32_ACC_K} "
            f"({INT32_ACC_CHUNKS} raw chunks; deeper sums would wrap "
            f"silently) — split the contraction or use the f32limb backend"
        )
    if k <= CHUNK_K:
        a_hi, a_lo = _limb_split(a.astype(jnp.float32))
        b_hi, b_lo = _limb_split(b.astype(jnp.float32))
        hh = dot(a_hi, b_hi).astype(jnp.uint32)
        mid = dot(a_hi, b_lo).astype(jnp.uint32) + dot(a_lo, b_hi).astype(jnp.uint32)
        ll = dot(a_lo, b_lo).astype(jnp.uint32)
        return finish(_barrett_recombine(hh, mid, ll, p))

    pad = kpad - k
    if pad:
        wa = [(0, 0)] * a.ndim
        wa[ka] = (0, pad)
        wb = [(0, 0)] * b.ndim
        wb[kb] = (0, pad)
        a = jnp.pad(a, wa)
        b = jnp.pad(b, wb)
    nchunk = kpad // CHUNK_K

    a_hi, a_lo = _limb_split(a.astype(jnp.float32))
    b_hi, b_lo = _limb_split(b.astype(jnp.float32))

    def chunked(x, axis):
        # Split the contraction axis into (nchunk, CHUNK_K) with the
        # chunk count leading — the vmapped dot below turns it into one
        # extra *batch* dimension of a single dot_general (the original
        # dnums still apply to each CHUNK_K slice).
        x = x.reshape(x.shape[:axis] + (nchunk, CHUNK_K) + x.shape[axis + 1 :])
        return jnp.moveaxis(x, axis, 0)

    dot_chunks = jax.vmap(dot)
    hh = jnp.sum(dot_chunks(chunked(a_hi, ka), chunked(b_hi, kb)).astype(jnp.uint32), axis=0)
    mid = jnp.sum(
        dot_chunks(chunked(a_hi, ka), chunked(b_lo, kb)).astype(jnp.uint32)
        + dot_chunks(chunked(a_lo, ka), chunked(b_hi, kb)).astype(jnp.uint32),
        axis=0,
    )
    ll = jnp.sum(dot_chunks(chunked(a_lo, ka), chunked(b_lo, kb)).astype(jnp.uint32), axis=0)
    return finish(_barrett_recombine(hh, mid, ll, p))


# ----------------------------------------------------------------------
# counter-based PRNG: threefry2x32 usable inside Pallas kernel bodies
# ----------------------------------------------------------------------
_THREEFRY_ROT = (13, 15, 26, 6, 17, 29, 16, 24)
_THREEFRY_PARITY = 0x1BD11BDA


def _rotl32(x: jnp.ndarray, r: int) -> jnp.ndarray:
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, c0: jnp.ndarray, c1: jnp.ndarray):
    """Threefry-2x32, 20 rounds (the Random123 / JAX PRNG block cipher).

    Implemented from the spec in plain uint32 shifts/adds/xors so the
    SAME function body runs at the jnp level *and* inside Pallas kernel
    tiles — which is what makes fused in-kernel mask generation
    bit-identical to the materialized :func:`field_mask` path.  The
    5 x 4 round structure injects the extended key (k0, k1,
    k0^k1^parity) after every group of four rounds, per the Skein key
    schedule.  Returns the two output words.
    """
    k0 = jnp.uint32(k0)
    k1 = jnp.uint32(k1)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_THREEFRY_PARITY))
    x0 = c0.astype(jnp.uint32) + ks[0]
    x1 = c1.astype(jnp.uint32) + ks[1]
    for g in range(1, 6):
        rots = _THREEFRY_ROT[:4] if g % 2 else _THREEFRY_ROT[4:]
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl32(x1, r) ^ x0
        x0 = x0 + ks[g % 3]
        x1 = x1 + ks[(g + 1) % 3] + jnp.uint32(g)
    return x0, x1


@partial(jax.jit, static_argnames=("shape", "p"))
def field_mask(key: jnp.ndarray, shape: tuple, p: int = P_DEFAULT) -> jnp.ndarray:
    """Counter-based uniform GF(p) mask: the materialized reference of
    the fused in-kernel blinding stream.

    Element at row-major flat index i is
    ``threefry2x32(key, (i, 0))[0] mod p`` — a pure function of (key,
    position), so a Pallas tile can generate exactly its own slice from
    program ids without the array ever existing in memory, and this
    helper materializes the identical values for the portable backends
    and the bit-identity tests.  ``key`` is a (2,) uint32 word pair (a
    classic ``jax.random.PRNGKey`` works as-is).  The modulo-p bias
    (~p / 2**32) matches the repo-standard ``jax.random.randint`` draw.
    """
    _check_limb_prime(p)
    total = 1
    for d in shape:
        total *= int(d)
    if total >= 1 << 32:
        raise ValueError(
            f"field_mask counter space exhausted: prod{tuple(shape)} = "
            f"{total} >= 2**32 — counters would wrap and reuse mask values"
        )
    if total == 0:
        return jnp.zeros(shape, jnp.int32)
    key = jnp.asarray(key, jnp.uint32).reshape(-1)
    ctr = jax.lax.iota(jnp.uint32, total)
    x0, _ = threefry2x32(key[0], key[1], ctr, jnp.zeros_like(ctr))
    return barrett_reduce_u32(x0, p).astype(jnp.int32).reshape(shape)


def crt_combine(residues, primes) -> np.ndarray:
    """Chinese-Remainder combination of per-prime residue arrays.

    Garner's algorithm on the host: int64-exact for
    ``prod(primes) < 2**62`` (checked loudly).  Returns int64 in
    [0, prod(primes)).
    """
    primes = [int(q) for q in primes]
    if len(residues) != len(primes):
        raise ValueError("one residue array per prime required")
    prod = 1
    for q in primes:
        prod *= q
    if prod >= 1 << 62:
        raise ValueError(
            f"prod(primes) = {prod} >= 2**62: CRT combination would "
            f"overflow int64 — use fewer/smaller primes"
        )
    x = np.asarray(residues[0], np.int64) % primes[0]
    m = primes[0]
    for r, q in zip(residues[1:], primes[1:]):
        inv = pow(m % q, -1, q)  # raises if the moduli are not coprime
        diff = (np.asarray(r, np.int64) - x) % q
        x = x + (diff * inv % q) * m
        m *= q
    return x


@partial(jax.jit, static_argnames=("p",))
def mod_mul(a: jnp.ndarray, b: jnp.ndarray, p: int = P_DEFAULT) -> jnp.ndarray:
    """Elementwise a*b mod p. Products of 16-bit values fit exactly in uint32."""
    _check_limb_prime(p)
    prod = a.astype(jnp.uint32) * b.astype(jnp.uint32)
    return (prod % jnp.uint32(p)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("p",))
def mod_add(a: jnp.ndarray, b: jnp.ndarray, p: int = P_DEFAULT) -> jnp.ndarray:
    s = a.astype(jnp.uint32) + b.astype(jnp.uint32)
    return (s % jnp.uint32(p)).astype(jnp.int32)


def random_field_device(key, shape, p: int = P_DEFAULT) -> jnp.ndarray:
    """Uniform GF(p) elements drawn on-device with the JAX PRNG.

    Device-resident counterpart of ``Field.random`` (numpy) — used by the
    batched protocol engine so secret/blinding terms never touch the
    host.  Returns int32 in [0, p); traceable under jit.
    """
    return jax.random.randint(key, shape, 0, p, dtype=jnp.int32)


def powers_matrix(points: np.ndarray, powers, p: int = P_DEFAULT) -> np.ndarray:
    """Host-side Vandermonde with arbitrary power support; int64 -> int32-safe."""
    f = Field(p)
    return f.vandermonde(points, powers).astype(np.int64)
