"""High-level privacy-preserving compute API.

``secure_matmul`` runs one Y = A^T B under CMPC between two logical
sources, with fixed-point quantisation into GF(p) and centered-lift
decode.  ``PrivateLinear`` wraps a weight matrix as "source 2" so that
activations from "source 1" are multiplied without either worker (or
the master) learning the operands — the paper's edge-inference setting
with the transformer stack of this framework as the surrounding model.

Overflow discipline: an inner product of length k with operands bounded
by ``a_max``/``w_max`` needs  k * (a_max*scale_a) * (w_max*scale_w)
< (p-1)/2.  ``choose_scales`` picks the largest power-of-two scales
satisfying that bound; with p = 65521 this caps precision, so
``PrivateLinear`` also supports column-blocked accumulation (split the
inner dim, run multiple protocol instances, sum the decoded reals) —
precision then scales with the number of blocks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .constructions import Scheme, build_scheme
from .gf import Field
from .planner import BlockShapes, CMPCPlan, get_plan, make_plan
from . import protocol


def choose_scales(k: int, a_max: float, w_max: float, p: int) -> int:
    """Largest power-of-two scale S such that k*(a_max*S)*(w_max*S) fits."""
    half = (p - 1) // 2
    s = 1
    while k * (a_max * 2 * s) * (w_max * 2 * s) < half:
        s *= 2
    return s


@dataclasses.dataclass
class SecureMatmulResult:
    y: np.ndarray
    trace: protocol.Trace
    plan: CMPCPlan


def secure_matmul(
    a: np.ndarray,
    b: np.ndarray,
    method: str = "age",
    s: int = 2,
    t: int = 2,
    z: int = 1,
    field: Optional[Field] = None,
    scale: Optional[int] = None,
    n_spare: int = 0,
    seed: int = 0,
) -> SecureMatmulResult:
    """Privacy-preserving Y = A^T B over the reals.

    a: [k, ma] held by source 1;  b: [k, mb] held by source 2.
    """
    field = field or Field()
    k, ma = a.shape
    k2, mb = b.shape
    if k != k2:
        raise ValueError("inner dimensions disagree")
    if scale is None:
        scale = choose_scales(k, float(np.abs(a).max() + 1e-9), float(np.abs(b).max() + 1e-9), field.p)
    scheme = build_scheme(method, s, t, z)
    shapes = BlockShapes(k=k, ma=ma, mb=mb, s=s, t=t)
    plan = get_plan(scheme, shapes, field=field, n_spare=n_spare, seed=seed)
    aq = field.encode(a, scale)
    bq = field.encode(b, scale)
    yq, trace = protocol.run(plan, aq, bq, seed=seed + 1)
    y = field.decode(yq, scale * scale)
    return SecureMatmulResult(y=y, trace=trace, plan=plan)


def secure_matmul_batched(
    a: np.ndarray,
    b: np.ndarray,
    method: str = "age",
    s: int = 2,
    t: int = 2,
    z: int = 1,
    field: Optional[Field] = None,
    scale: Optional[int] = None,
    n_spare: int = 0,
    seed: int = 0,
    backend: str = "auto",
) -> SecureMatmulResult:
    """Privacy-preserving Y[i] = A[i]^T B[i] for a batch of products.

    a: [batch, k, ma];  b: [batch, k, mb] or [k, mb] (a single B — e.g.
    one weight matrix against a batch of activations — is broadcast).
    One plan (from the process-wide plan cache) serves every product;
    all three phases run device-resident via ``protocol.run_batched``,
    amortizing plan setup and jit compilation across the batch.
    """
    field = field or Field()
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if a.ndim != 3:
        raise ValueError(f"a must be [batch, k, ma], got {a.shape}")
    if b.ndim == 2:
        b = np.broadcast_to(b, (a.shape[0],) + b.shape)
    batch, k, ma = a.shape
    if b.shape[:2] != (batch, k):
        raise ValueError(f"batch/inner dims disagree: {a.shape} vs {b.shape}")
    mb = b.shape[2]
    if scale is None:
        scale = choose_scales(
            k, float(np.abs(a).max() + 1e-9), float(np.abs(b).max() + 1e-9), field.p
        )
    scheme = build_scheme(method, s, t, z)
    shapes = BlockShapes(k=k, ma=ma, mb=mb, s=s, t=t)
    plan = get_plan(scheme, shapes, field=field, n_spare=n_spare, seed=seed)
    aq = field.encode(a, scale)
    bq = field.encode(b, scale)
    yq, trace = protocol.run_batched(plan, aq, bq, seed=seed + 1, backend=backend)
    y = field.decode(yq, scale * scale)
    return SecureMatmulResult(y=y, trace=trace, plan=plan)


def secure_matmul_crt(
    a: np.ndarray,
    b: np.ndarray,
    method: str = "age",
    s: int = 2,
    t: int = 2,
    z: int = 1,
    primes: tuple = (65521, 65519),
    scale: Optional[int] = None,
    seed: int = 0,
) -> SecureMatmulResult:
    """CRT dual-prime CMPC (beyond-paper): run the protocol once per
    16-bit prime and combine residues with the Chinese Remainder
    Theorem.  The effective modulus P = p1*p2 ~ 2**32 gives fixed-point
    headroom the single 16-bit field cannot, at exactly 2x the worker
    compute (both instances still use the f32-limb TPU kernel).
    """
    k, ma = a.shape
    _, mb = b.shape
    pbig = int(np.prod([int(p) for p in primes]))
    if scale is None:
        half = (pbig - 1) // 2
        a_max = float(np.abs(a).max() + 1e-9)
        w_max = float(np.abs(b).max() + 1e-9)
        scale = 1
        while k * (a_max * 2 * scale) * (w_max * 2 * scale) < half:
            scale *= 2
    scheme = build_scheme(method, s, t, z)
    shapes = BlockShapes(k=k, ma=ma, mb=mb, s=s, t=t)

    aq_signed = np.rint(np.asarray(a, np.float64) * scale).astype(np.int64)
    bq_signed = np.rint(np.asarray(b, np.float64) * scale).astype(np.int64)
    residues = []
    plans = []
    trace = None
    for i, p in enumerate(primes):
        field = Field(int(p))
        plan = make_plan(scheme, shapes, field=field, seed=seed + 17 * i)
        yq, trace = protocol.run(plan, aq_signed % p, bq_signed % p, seed=seed + 31 * i)
        residues.append(np.asarray(yq, np.int64))
        plans.append(plan)
    # CRT combine (python ints to avoid overflow), then centered lift.
    p1, p2 = (int(p) for p in primes)
    inv_p1_mod_p2 = pow(p1, -1, p2)
    r1, r2 = residues
    combined = (r1 + ((r2 - r1) * inv_p1_mod_p2 % p2) * p1) % pbig
    half = pbig // 2
    signed = np.where(combined > half, combined - pbig, combined)
    y = signed.astype(np.float64) / (scale * scale)
    return SecureMatmulResult(y=y, trace=trace, plan=plans[0])


class PrivateLinear:
    """y = x @ W via CMPC, W private to the layer owner.

    The plan is built once per (k, out, s, t, z) signature and reused
    across calls; the inner dimension may be split into ``blocks``
    independent protocol instances for extra fixed-point headroom.
    """

    def __init__(
        self,
        w: np.ndarray,
        method: str = "age",
        s: int = 2,
        t: int = 2,
        z: int = 1,
        blocks: int = 1,
        field: Optional[Field] = None,
        seed: int = 0,
    ):
        self.w = np.asarray(w, np.float64)
        self.method, self.s, self.t, self.z = method, s, t, z
        self.blocks = blocks
        self.field = field or Field()
        self.seed = seed
        # the scheme depends only on ctor args: build it once, not per call
        self._scheme = build_scheme(method, s, t, z)
        k = self.w.shape[0]
        if k % blocks:
            raise ValueError("blocks must divide the inner dimension")

    def _plan(self, batch: int, kblk: int) -> CMPCPlan:
        # Delegates to the process-wide plan cache (planner.get_plan):
        # every PrivateLinear with the same protocol signature shares one
        # plan's Vandermonde/mixing constants.
        shapes = BlockShapes(k=kblk, ma=batch, mb=self.w.shape[1], s=self.s, t=self.t)
        return get_plan(self._scheme, shapes, field=self.field, seed=self.seed)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        """x: [batch, k] activations (source 1).  Returns [batch, out]."""
        x = np.asarray(x, np.float64)
        batch, k = x.shape
        kblk = k // self.blocks
        out = np.zeros((batch, self.w.shape[1]))
        for bi in range(self.blocks):
            sl = slice(bi * kblk, (bi + 1) * kblk)
            xa = x[:, sl].T  # [kblk, batch] == "A"
            wb = self.w[sl]  # [kblk, out]  == "B"
            scale = choose_scales(
                kblk,
                float(np.abs(xa).max() + 1e-9),
                float(np.abs(wb).max() + 1e-9),
                self.field.p,
            )
            plan = self._plan(batch, kblk)
            aq = self.field.encode(xa, scale)
            bq = self.field.encode(wb, scale)
            yq, _ = protocol.run(plan, aq, bq, seed=self.seed + bi)
            out += self.field.decode(yq, scale * scale)
        return out
