"""High-level privacy-preserving compute API.

``secure_matmul`` runs one Y = A^T B under CMPC between two logical
sources, with fixed-point quantisation into GF(p) and centered-lift
decode.  ``PrivateLinear`` wraps a weight matrix as "source 2" so that
activations from "source 1" are multiplied without either worker (or
the master) learning the operands — the paper's edge-inference setting
with the transformer stack of this framework as the surrounding model.

Overflow discipline: an inner product of length k with operands bounded
by ``a_max``/``w_max`` needs  k * (a_max*scale_a) * (w_max*scale_w)
< (p-1)/2.  ``choose_scales`` picks the largest power-of-two scales
satisfying that bound; with p = 65521 this caps precision, so
``PrivateLinear`` also supports column-blocked accumulation (split the
inner dim, run multiple protocol instances, sum the decoded reals) —
precision then scales with the number of blocks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .constructions import Scheme, build_scheme
from .gf import Field
from .planner import BlockShapes, CMPCPlan, get_plan
from . import protocol


def choose_scales(k: int, a_max: float, w_max: float, p: int) -> int:
    """Largest power-of-two scale S such that k*(a_max*S)*(w_max*S) fits."""
    half = (p - 1) // 2
    s = 1
    while k * (a_max * 2 * s) * (w_max * 2 * s) < half:
        s *= 2
    return s


@dataclasses.dataclass
class SecureMatmulResult:
    y: np.ndarray
    trace: protocol.Trace
    plan: CMPCPlan


class MatmulHandle:
    """One deferred Y = A^T B submission against an executor.

    ``submit`` returns immediately with a handle; the numeric result
    materializes when the owning executor flushes — either explicitly
    (the batcher decides the group is full) or implicitly on the first
    ``result()`` of a still-pending handle.  This is the composition
    point the serving tier batches through: many requests submit, one
    ``protocol.run_batched`` serves them all.
    """

    __slots__ = ("_executor", "_value")

    def __init__(self, executor: "InlineExecutor"):
        self._executor = executor
        self._value: Optional[SecureMatmulResult] = None

    def done(self) -> bool:
        return self._value is not None

    def result(self) -> SecureMatmulResult:
        """The decoded product (flushes the executor when pending)."""
        if self._value is None:
            self._executor.flush()
        assert self._value is not None, "flush did not resolve this handle"
        return self._value

    def _resolve(self, value: SecureMatmulResult) -> None:
        self._value = value


@dataclasses.dataclass
class _PendingMatmul:
    handle: MatmulHandle
    aq: np.ndarray  # [k, ma], field-encoded
    bq: np.ndarray  # [k, mb], field-encoded
    scale: int


class InlineExecutor:
    """Synchronous batching executor for secure matmuls.

    Submissions accumulate per *group* — products with identical
    ``(method, s, t, z, n_spare, k, ma, mb)`` signatures share one plan
    and can fold into one batched protocol execution — until
    :meth:`flush` runs one ``protocol.run_batched`` per group and
    resolves every handle.  Per-request fixed-point scales survive the
    fold: encoding happens at submit with the request's own scale, the
    field-level batch runs scale-oblivious, and each product decodes
    with its own ``scale**2``.

    This is the data-plane half of continuous batching (shares, device
    matmuls, decode); the *timing* half — when a batch launches against
    a simulated pool — lives in ``repro.serve`` which drives the same
    grouping through ``runtime.PipelineSession``.
    """

    def __init__(
        self,
        field: Optional[Field] = None,
        backend: str = "auto",
        seed: int = 0,
    ):
        self.field = field or Field()
        self.backend = backend
        self.seed = seed
        self._pending: dict = {}  # group signature -> [_PendingMatmul]
        self.flushes = 0
        self.submitted = 0

    def pending(self) -> int:
        return sum(len(g) for g in self._pending.values())

    def submit(
        self,
        a: np.ndarray,
        b: np.ndarray,
        method: str = "age",
        s: int = 2,
        t: int = 2,
        z: int = 1,
        scale: Optional[int] = None,
        n_spare: int = 0,
    ) -> MatmulHandle:
        """Queue one Y = A^T B (a: [k, ma], b: [k, mb]); returns its
        handle.  ``scale=None`` picks the per-request power-of-two
        fixed-point scale from this request's operand ranges."""
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        if a.ndim != 2 or b.ndim != 2 or a.shape[0] != b.shape[0]:
            raise ValueError(
                f"expected [k, ma] / [k, mb] operands, got {a.shape} {b.shape}"
            )
        k, ma = a.shape
        mb = b.shape[1]
        if scale is None:
            scale = choose_scales(
                k,
                float(np.abs(a).max() + 1e-9),
                float(np.abs(b).max() + 1e-9),
                self.field.p,
            )
        key = (method, s, t, z, n_spare, k, ma, mb)
        handle = MatmulHandle(self)
        self._pending.setdefault(key, []).append(
            _PendingMatmul(
                handle=handle,
                aq=self.field.encode(a, scale),
                bq=self.field.encode(b, scale),
                scale=int(scale),
            )
        )
        self.submitted += 1
        return handle

    def flush(self) -> int:
        """Run every pending group through ``protocol.run_batched`` and
        resolve its handles; returns the number of products served."""
        pending, self._pending = self._pending, {}
        served = 0
        for (method, s, t, z, n_spare, k, ma, mb), group in pending.items():
            scheme = build_scheme(method, s, t, z)
            shapes = BlockShapes(k=k, ma=ma, mb=mb, s=s, t=t)
            plan = get_plan(
                scheme, shapes, field=self.field, n_spare=n_spare,
                seed=self.seed,
            )
            aq = np.stack([g.aq for g in group])
            bq = np.stack([g.bq for g in group])
            yq, trace = protocol.run_batched(
                plan, aq, bq, seed=self.seed + 1 + self.flushes,
                backend=self.backend,
            )
            self.flushes += 1
            yq = np.asarray(yq)
            for i, g in enumerate(group):
                g.handle._resolve(
                    SecureMatmulResult(
                        y=self.field.decode(yq[i], g.scale * g.scale),
                        trace=trace,
                        plan=plan,
                    )
                )
            served += len(group)
        return served


def secure_matmul_submit(
    a: np.ndarray,
    b: np.ndarray,
    method: str = "age",
    s: int = 2,
    t: int = 2,
    z: int = 1,
    field: Optional[Field] = None,
    scale: Optional[int] = None,
    n_spare: int = 0,
    seed: int = 0,
    backend: str = "auto",
    executor: Optional[InlineExecutor] = None,
) -> MatmulHandle:
    """Async twin of :func:`secure_matmul`: queue the product on an
    executor and return a :class:`MatmulHandle`.

    With a shared ``executor`` many submissions (from different
    callers/layers/requests) fold into one batched protocol run at the
    next flush; without one, a private single-use executor makes
    ``handle.result()`` equivalent to ``secure_matmul_batched`` at
    batch 1.  When ``executor`` is given, its field/seed/backend govern
    and the corresponding arguments here must be left at their
    defaults.
    """
    if executor is None:
        executor = InlineExecutor(field=field, backend=backend, seed=seed)
    elif field is not None and field.p != executor.field.p:
        raise ValueError(
            f"executor field p={executor.field.p} != requested p={field.p}"
        )
    return executor.submit(
        a, b, method=method, s=s, t=t, z=z, scale=scale, n_spare=n_spare
    )


def secure_matmul(
    a: np.ndarray,
    b: np.ndarray,
    method: str = "age",
    s: int = 2,
    t: int = 2,
    z: int = 1,
    field: Optional[Field] = None,
    scale: Optional[int] = None,
    n_spare: int = 0,
    seed: int = 0,
) -> SecureMatmulResult:
    """Privacy-preserving Y = A^T B over the reals.

    a: [k, ma] held by source 1;  b: [k, mb] held by source 2.
    """
    field = field or Field()
    k, ma = a.shape
    k2, mb = b.shape
    if k != k2:
        raise ValueError("inner dimensions disagree")
    if scale is None:
        scale = choose_scales(k, float(np.abs(a).max() + 1e-9), float(np.abs(b).max() + 1e-9), field.p)
    scheme = build_scheme(method, s, t, z)
    shapes = BlockShapes(k=k, ma=ma, mb=mb, s=s, t=t)
    plan = get_plan(scheme, shapes, field=field, n_spare=n_spare, seed=seed)
    aq = field.encode(a, scale)
    bq = field.encode(b, scale)
    yq, trace = protocol.run(plan, aq, bq, seed=seed + 1)
    y = field.decode(yq, scale * scale)
    return SecureMatmulResult(y=y, trace=trace, plan=plan)


def secure_matmul_batched(
    a: np.ndarray,
    b: np.ndarray,
    method: str = "age",
    s: int = 2,
    t: int = 2,
    z: int = 1,
    field: Optional[Field] = None,
    scale: Optional[int] = None,
    n_spare: int = 0,
    seed: int = 0,
    backend: str = "auto",
) -> SecureMatmulResult:
    """Privacy-preserving Y[i] = A[i]^T B[i] for a batch of products.

    a: [batch, k, ma];  b: [batch, k, mb] or [k, mb] (a single B — e.g.
    one weight matrix against a batch of activations — is broadcast).
    One plan (from the process-wide plan cache) serves every product;
    all three phases run device-resident via ``protocol.run_batched``,
    amortizing plan setup and jit compilation across the batch.
    """
    field = field or Field()
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if a.ndim != 3:
        raise ValueError(f"a must be [batch, k, ma], got {a.shape}")
    if b.ndim == 2:
        b = np.broadcast_to(b, (a.shape[0],) + b.shape)
    batch, k, ma = a.shape
    if b.shape[:2] != (batch, k):
        raise ValueError(f"batch/inner dims disagree: {a.shape} vs {b.shape}")
    mb = b.shape[2]
    if scale is None:
        scale = choose_scales(
            k, float(np.abs(a).max() + 1e-9), float(np.abs(b).max() + 1e-9), field.p
        )
    scheme = build_scheme(method, s, t, z)
    shapes = BlockShapes(k=k, ma=ma, mb=mb, s=s, t=t)
    plan = get_plan(scheme, shapes, field=field, n_spare=n_spare, seed=seed)
    aq = field.encode(a, scale)
    bq = field.encode(b, scale)
    yq, trace = protocol.run_batched(plan, aq, bq, seed=seed + 1, backend=backend)
    y = field.decode(yq, scale * scale)
    return SecureMatmulResult(y=y, trace=trace, plan=plan)


def secure_matmul_crt(
    a: np.ndarray,
    b: np.ndarray,
    method: str = "age",
    s: int = 2,
    t: int = 2,
    z: int = 1,
    primes: tuple = (65521, 65519),
    scale: Optional[int] = None,
    seed: int = 0,
    n_spare: int = 0,
    backend: str = "auto",
    fused_masks: bool = False,
) -> SecureMatmulResult:
    """CRT multi-prime CMPC (beyond-paper): run the protocol once per
    16-bit prime and combine residues with the Chinese Remainder
    Theorem.  The effective modulus P = prod(primes) ~ 2**32 for the
    default pair gives fixed-point headroom a single 16-bit field
    cannot, at one extra protocol pass per extra prime.

    Routed through ``protocol.run_batched_crt``, so every residue pass
    is the batched device-resident pipeline: ``a``/``b`` may be 2D
    ([k, ma]/[k, mb], promoted to batch 1, returning a 2D ``y``) or
    batched 3D, ``backend`` selects the kernel tier per residue, and
    ``fused_masks`` generates secrets/blinding in-kernel.  Residue plans
    come from the process-wide plan cache (one per prime field).
    """
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    batched = a.ndim == 3
    if not batched:
        a = a[None]
        b = b[None]
    _, k, ma = a.shape
    mb = b.shape[-1]
    pbig = 1
    for p in primes:
        pbig *= int(p)
    if scale is None:
        half = (pbig - 1) // 2
        a_max = float(np.abs(a).max() + 1e-9)
        w_max = float(np.abs(b).max() + 1e-9)
        scale = 1
        while k * (a_max * 2 * scale) * (w_max * 2 * scale) < half:
            scale *= 2
    scheme = build_scheme(method, s, t, z)
    shapes = BlockShapes(k=k, ma=ma, mb=mb, s=s, t=t)
    plans = [
        get_plan(
            scheme, shapes, field=Field(int(p)), n_spare=n_spare,
            seed=seed + 17 * i,
        )
        for i, p in enumerate(primes)
    ]

    aq_signed = np.rint(a * scale).astype(np.int64)
    bq_signed = np.rint(b * scale).astype(np.int64)
    combined, trace = protocol.run_batched_crt(
        plans, aq_signed, bq_signed, seed=seed + 31,
        backend=backend, fused_masks=fused_masks,
    )
    # centered lift from [0, P) to (-P/2, P/2], then undo the scaling
    half = pbig // 2
    signed = np.where(combined > half, combined - pbig, combined)
    y = signed.astype(np.float64) / (scale * scale)
    if not batched:
        y = y[0]
    return SecureMatmulResult(y=y, trace=trace, plan=plans[0])


class LinearHandle:
    """Deferred ``PrivateLinear`` application: one part-handle per
    inner-dim block, summed at :meth:`result`."""

    __slots__ = ("_parts",)

    def __init__(self, parts):
        self._parts = list(parts)

    def done(self) -> bool:
        return all(h.done() for h in self._parts)

    def result(self) -> np.ndarray:
        """[batch, out] activations (flushes pending parts)."""
        out = self._parts[0].result().y
        for h in self._parts[1:]:
            out = out + h.result().y
        return out


class PrivateLinear:
    """y = x @ W via CMPC, W private to the layer owner.

    The plan is built once per (k, out, s, t, z) signature and reused
    across calls; the inner dimension may be split into ``blocks``
    independent protocol instances for extra fixed-point headroom.

    With an ``executor`` (:class:`InlineExecutor`) the layer becomes a
    submission source: :meth:`submit` queues its per-block products and
    returns a :class:`LinearHandle`, so many layers/requests sharing
    one executor fold into one batched protocol run per flush —
    ``__call__`` then submits + flushes (sync facade over the async
    path).  Without one, ``__call__`` keeps the historical per-block
    ``protocol.run`` path unchanged.
    """

    def __init__(
        self,
        w: np.ndarray,
        method: str = "age",
        s: int = 2,
        t: int = 2,
        z: int = 1,
        blocks: int = 1,
        field: Optional[Field] = None,
        seed: int = 0,
        executor: Optional[InlineExecutor] = None,
    ):
        self.w = np.asarray(w, np.float64)
        self.method, self.s, self.t, self.z = method, s, t, z
        self.blocks = blocks
        self.field = field or Field()
        self.seed = seed
        self.executor = executor
        if executor is not None and executor.field.p != self.field.p:
            raise ValueError(
                f"executor field p={executor.field.p} != layer p={self.field.p}"
            )
        # the scheme depends only on ctor args: build it once, not per call
        self._scheme = build_scheme(method, s, t, z)
        k = self.w.shape[0]
        if k % blocks:
            raise ValueError("blocks must divide the inner dimension")

    def submit(self, x: np.ndarray) -> LinearHandle:
        """Queue x @ W on the layer's executor (requires one); returns
        a :class:`LinearHandle` resolving to [batch, out]."""
        if self.executor is None:
            raise ValueError("PrivateLinear.submit needs an executor")
        x = np.asarray(x, np.float64)
        _, k = x.shape
        kblk = k // self.blocks
        parts = []
        for bi in range(self.blocks):
            sl = slice(bi * kblk, (bi + 1) * kblk)
            parts.append(
                self.executor.submit(
                    x[:, sl].T,  # [kblk, batch] == "A"
                    self.w[sl],  # [kblk, out]  == "B"
                    method=self.method, s=self.s, t=self.t, z=self.z,
                )
            )
        return LinearHandle(parts)

    def _plan(self, batch: int, kblk: int) -> CMPCPlan:
        # Delegates to the process-wide plan cache (planner.get_plan):
        # every PrivateLinear with the same protocol signature shares one
        # plan's Vandermonde/mixing constants.
        shapes = BlockShapes(k=kblk, ma=batch, mb=self.w.shape[1], s=self.s, t=self.t)
        return get_plan(self._scheme, shapes, field=self.field, seed=self.seed)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        """x: [batch, k] activations (source 1).  Returns [batch, out]."""
        if self.executor is not None:
            handle = self.submit(x)
            self.executor.flush()
            return handle.result()
        x = np.asarray(x, np.float64)
        batch, k = x.shape
        kblk = k // self.blocks
        out = np.zeros((batch, self.w.shape[1]))
        for bi in range(self.blocks):
            sl = slice(bi * kblk, (bi + 1) * kblk)
            xa = x[:, sl].T  # [kblk, batch] == "A"
            wb = self.w[sl]  # [kblk, out]  == "B"
            scale = choose_scales(
                kblk,
                float(np.abs(xa).max() + 1e-9),
                float(np.abs(wb).max() + 1e-9),
                self.field.p,
            )
            plan = self._plan(batch, kblk)
            aq = self.field.encode(xa, scale)
            bq = self.field.encode(wb, scale)
            yq, _ = protocol.run(plan, aq, bq, seed=self.seed + bi)
            out += self.field.decode(yq, scale * scale)
        return out
