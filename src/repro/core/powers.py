"""Power-set machinery for polynomial coded computation / coded MPC.

The paper's whole analysis lives in the combinatorics of *sets of
polynomial powers*:  a share polynomial ``F(x) = C(x) + S(x)`` has a
coded-term support ``P(C)`` and a secret-term support ``P(S)``; the
required number of workers equals ``|P(F_A) + P(F_B)|`` (Minkowski-sum
cardinality, eq. (23)); decodability requires the *important powers*
(the exponents that carry ``Y = A^T B`` blocks) to stay collision-free
from every *garbage* sumset (conditions C1-C3 / C4-C6).

Everything here is exact integer-set arithmetic (numpy-accelerated).
The greedy secret-power selection below is the algorithmic form of the
paper's Algorithm 1 (PolyDot-CMPC) and Algorithm 2 (AGE-CMPC); the
closed-form Theorems 2 and 8 are validated against it in the tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

BlockMap = Dict[Tuple[int, int], int]  # (block indices) -> polynomial power


# ----------------------------------------------------------------------
# sumset helpers
# ----------------------------------------------------------------------
def sumset(a, b) -> np.ndarray:
    """Sorted unique Minkowski sum A + B."""
    a = np.asarray(sorted(set(int(x) for x in a)), dtype=np.int64)
    b = np.asarray(sorted(set(int(x) for x in b)), dtype=np.int64)
    if a.size == 0 or b.size == 0:
        return np.zeros((0,), np.int64)
    return np.unique(a[:, None] + b[None, :])


def diffset(a, b) -> np.ndarray:
    """Sorted unique {x - y : x in A, y in B} intersected with naturals."""
    a = np.asarray(sorted(set(int(x) for x in a)), dtype=np.int64)
    b = np.asarray(sorted(set(int(x) for x in b)), dtype=np.int64)
    if a.size == 0 or b.size == 0:
        return np.zeros((0,), np.int64)
    d = np.unique(a[:, None] - b[None, :])
    return d[d >= 0]


def greedy_powers(z: int, forbidden: np.ndarray, start: int = 0) -> List[int]:
    """Pick the z smallest naturals >= start avoiding ``forbidden``.

    This is the generic greedy step of Algorithms 1 and 2: both pick
    secret powers "starting from the minimum possible element" subject
    to the non-collision conditions.
    """
    bad = set(int(x) for x in forbidden)
    out: List[int] = []
    x = start
    while len(out) < z:
        if x not in bad:
            out.append(x)
        x += 1
    return out


# ----------------------------------------------------------------------
# coded-term supports
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CodedSupport:
    """Support of the coded terms C_A, C_B and the important powers of
    C_A*C_B that carry the blocks of Y = A^T B."""

    s: int
    t: int
    # (i, j) -> power of A_{i,j} in C_A;  i in [t], j in [s]
    a_powers: Tuple[Tuple[int, int, int], ...]
    # (k, l) -> power of B_{k,l} in C_B;  k in [s], l in [t]
    b_powers: Tuple[Tuple[int, int, int], ...]
    # (i, l) -> important power carrying Y_{i,l}
    important: Tuple[Tuple[int, int, int], ...]

    @property
    def pa(self) -> List[int]:
        return [u for (_, _, u) in self.a_powers]

    @property
    def pb(self) -> List[int]:
        return [u for (_, _, u) in self.b_powers]

    @property
    def imp(self) -> List[int]:
        return [u for (_, _, u) in self.important]

    def a_power_map(self) -> BlockMap:
        return {(i, j): u for (i, j, u) in self.a_powers}

    def b_power_map(self) -> BlockMap:
        return {(k, l): u for (k, l, u) in self.b_powers}

    def important_map(self) -> BlockMap:
        return {(i, l): u for (i, l, u) in self.important}


def generalized_coded(s: int, t: int, alpha: int, beta: int, theta: int) -> CodedSupport:
    """Generalized polynomial-code family, eq. (24):

      C_A(x) = sum_{i,j} A_{i,j} x^{j*alpha + i*beta}
      C_B(x) = sum_{k,l} B_{k,l} x^{(s-1-k)*alpha + theta*l}

    PolyDot  = (alpha, beta, theta) = (t, 1, t(2s-1))   [note swapped roles below]
    Entangled/GPD = (1, s, ts)
    AGE      = (1, s, ts + lambda)
    """
    a_powers = tuple(
        (i, j, j * alpha + i * beta) for i in range(t) for j in range(s)
    )
    b_powers = tuple(
        (k, l, (s - 1 - k) * alpha + theta * l) for k in range(s) for l in range(t)
    )
    important = tuple(
        (i, l, (s - 1) * alpha + i * beta + theta * l) for i in range(t) for l in range(t)
    )
    return CodedSupport(s=s, t=t, a_powers=a_powers, b_powers=b_powers, important=important)


def polydot_coded(s: int, t: int) -> CodedSupport:
    """PolyDot codes [26], eqs. (7)-(8):

      P(C_A) = { i + t*j },  P(C_B) = { t(s-1-k) + theta'*l },
      theta' = t(2s-1); important powers { i + t(s-1) + t*l*(2s-1) }.
    """
    thetap = t * (2 * s - 1)
    a_powers = tuple((i, j, i + t * j) for i in range(t) for j in range(s))
    b_powers = tuple(
        (k, l, t * (s - 1 - k) + thetap * l) for k in range(s) for l in range(t)
    )
    important = tuple(
        (i, l, i + t * (s - 1) + thetap * l) for i in range(t) for l in range(t)
    )
    return CodedSupport(s=s, t=t, a_powers=a_powers, b_powers=b_powers, important=important)


def age_coded(s: int, t: int, lam: int) -> CodedSupport:
    """AGE codes: (alpha, beta, theta) = (1, s, ts + lambda), eq. (25)-(26)."""
    return generalized_coded(s, t, alpha=1, beta=s, theta=t * s + lam)


def entangled_coded(s: int, t: int) -> CodedSupport:
    """Entangled polynomial codes [22] == AGE with lambda = 0."""
    return age_coded(s, t, 0)


# ----------------------------------------------------------------------
# decodability checks (Theorem 6 invariants)
# ----------------------------------------------------------------------
def important_powers_distinct(c: CodedSupport) -> bool:
    imp = c.imp
    return len(set(imp)) == len(imp)


def coded_garbage_disjoint(c: CodedSupport) -> bool:
    """Important powers receive only j == k cross terms with matching (i, l)."""
    imp = set(c.imp)
    amap = c.a_power_map()
    bmap = c.b_power_map()
    impmap = {u: (i, l) for (i, l, u) in c.important}
    for (i, j), ua in amap.items():
        for (k, l), ub in bmap.items():
            u = ua + ub
            if u in imp:
                if j != k:
                    return False
                if impmap[u] != (i, l):
                    return False
    return True


def secret_conditions_hold(c: CodedSupport, sa: List[int], sb: List[int]) -> bool:
    """C1-C3 (PolyDot) / C4-C6 (AGE): no garbage sumset hits an important power."""
    imp = set(c.imp)
    for d in (sumset(sa, c.pb), sumset(sb, c.pa), sumset(sa, sb)):
        if imp & set(int(x) for x in d):
            return False
    return True


def h_support(c: CodedSupport, sa: List[int], sb: List[int]) -> np.ndarray:
    """Support of H(x) = (C_A + S_A)(C_B + S_B); |support| == N workers."""
    fa = sorted(set(c.pa) | set(sa))
    fb = sorted(set(c.pb) | set(sb))
    return sumset(fa, fb)
