"""The three-phase CMPC protocol engine.

Faithful execution of Algorithm 3 (AGE-CMPC) / Section IV-A
(PolyDot-CMPC) over GF(p):

Phase 1  sources evaluate F_A(alpha_n), F_B(alpha_n) and send one share
         pair to each worker,
Phase 2  every worker computes H(alpha_n) = F_A(alpha_n) F_B(alpha_n),
         forms G_n(x) (eq. 19) and exchanges evaluations; each worker
         sums the received values into I(alpha_n) (eq. 20),
Phase 3  the master reconstructs I(x) from any t^2 + z responses and
         reads Y = A^T B off the first t^2 coefficients (eq. 21).

This module operates on *stacked worker arrays* (leading axis = worker)
so the same code runs single-host (vmapped) or sharded over a mesh axis
via ``repro.core.distributed``.  All modular compute routes through the
``modmatmul`` kernel ops so the TPU path uses the Pallas kernel.

Three execution paths:

* ``run``          — per-product reference: host-side block stacking and
                     Phase-3 decode in numpy (the test oracle),
* ``run_batched_sharded`` — the batched pipeline with the *distributed*
                     Phase 2: the degree-reduction exchange is the
                     ``shard_map`` collective of ``core.distributed``
                     (``all_to_all`` / ``psum`` / ``psum_scatter``),
                     with Phases 1 and 3 on the same jitted kernels,
* ``run_batched``  — batched, fully-jitted, device-resident pipeline:
                     share evaluation, worker multiply, degree reduction
                     and decode execute inside one jitted computation
                     over a whole batch of products.  Block scatter /
                     gather and the decode assembly are index-based
                     ``jnp`` ops built once per plan (``DevicePlan``,
                     cached on the plan); secrets and blinding terms are
                     drawn on-device from the JAX PRNG.  Amortizes plan
                     setup, dispatch, and compilation across the batch —
                     see ``benchmarks/protocol_batch.py``.

A ``Trace`` records the byte movement of each phase, matching the
communication-overhead accounting of Corollary 12.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.modmatmul.ops import (
    mod_matmul,
    mod_matmul_masked,
    polyeval,
    polyeval_masked,
)
from ..obs.tracer import TRACER
from .gf import Field, crt_combine, random_field_device
from .planner import BlockShapes, CMPCPlan


@dataclasses.dataclass
class Trace:
    """Scalar-movement accounting, in field elements.

    Phase-1 counts cover every *provisioned* worker (primaries and
    spares alike — spares receive shares up front so they can step in),
    matching Corollary 12's accounting at N = n_total.  Phase-2 counts
    are spare-inclusive on the *receive* side for the same reason: each
    of the ``n_workers`` senders reaches the other ``n_total - 1``
    provisioned workers, because Phase 3 may decode from any of them.
    ``elem_bytes`` (the field's wire width, ``Field.elem_bytes``)
    converts the element counts into the bytes-level view used by the
    runtime metrics.
    """

    phase1_source_to_worker: int = 0
    phase2_worker_to_worker: int = 0
    phase3_worker_to_master: int = 0
    elem_bytes: int = 2  # width of one GF(p) element on the wire

    def __add__(self, other: "Trace") -> "Trace":
        """Phase-wise sum — aggregate accounting across replays (the
        pipelined runtime sums one Trace per in-flight replay)."""
        if not isinstance(other, Trace):
            return NotImplemented
        if self.elem_bytes != other.elem_bytes:
            raise ValueError(
                f"cannot sum traces with different wire widths "
                f"({self.elem_bytes} vs {other.elem_bytes} bytes)"
            )
        return Trace(
            phase1_source_to_worker=self.phase1_source_to_worker
            + other.phase1_source_to_worker,
            phase2_worker_to_worker=self.phase2_worker_to_worker
            + other.phase2_worker_to_worker,
            phase3_worker_to_master=self.phase3_worker_to_master
            + other.phase3_worker_to_master,
            elem_bytes=self.elem_bytes,
        )

    @property
    def total(self) -> int:
        return (
            self.phase1_source_to_worker
            + self.phase2_worker_to_worker
            + self.phase3_worker_to_master
        )

    @property
    def phase1_bytes(self) -> int:
        return self.phase1_source_to_worker * self.elem_bytes

    @property
    def phase2_bytes(self) -> int:
        return self.phase2_worker_to_worker * self.elem_bytes

    @property
    def phase3_bytes(self) -> int:
        return self.phase3_worker_to_master * self.elem_bytes

    @property
    def total_bytes(self) -> int:
        return self.total * self.elem_bytes


# ----------------------------------------------------------------------
# Phase 1 — sources share data with workers
# ----------------------------------------------------------------------
# The coefficient stacks are built directly in int32 with one reshape /
# transpose block scatter (the host mirror of ``_run_batched_jit``'s
# index-based scatter) and ONE bulk int32 PRNG draw for all z secret
# coefficients — replacing the per-block dict loop, the per-power int64
# draws, and the int64 -> int32 conversion pass over the whole stack
# that used to dominate the ``run()`` share path on CPU.


def _share_stack(
    blocks: np.ndarray,
    n_coeff: int,
    data_pos: np.ndarray,
    secret_pos: np.ndarray,
    p: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Scatter data blocks + fresh secrets into an int32 coeff stack."""
    stack = np.zeros((n_coeff,) + blocks.shape[1:], np.int32)
    stack[data_pos] = blocks
    stack[secret_pos] = rng.integers(
        0, p, size=(secret_pos.size,) + blocks.shape[1:], dtype=np.int32
    )
    return stack


def share_a(plan: CMPCPlan, a: np.ndarray, rng: np.random.Generator) -> jnp.ndarray:
    """Source 1: F_A(alpha_n) for every provisioned worker.

    Returns int32 [n_total, ma/t, k/s].
    """
    sh = plan.shapes
    s, t = plan.scheme.s, plan.scheme.t
    br, bc = sh.blk_a
    dp = device_plan(plan)  # constants uploaded once per plan, not per call
    with TRACER.span("protocol.phase1.share_a"):
        at = np.ascontiguousarray(np.asarray(a, np.int64).T)  # [ma, k]
        blocks = (
            at.reshape(t, br, s, bc).transpose(0, 2, 1, 3).reshape(t * s, br, bc)
        ).astype(np.int32)
        stack = _share_stack(
            blocks, len(plan.scheme.fa_powers), dp.a_pos_h, dp.sa_pos_h,
            plan.field.p, rng,
        )
        # the numpy stack goes straight into the jitted kernel: an eager
        # jnp.asarray here costs more than the kernel's own conversion
        return polyeval(dp.va, stack, p=plan.field.p)


def share_b(plan: CMPCPlan, b: np.ndarray, rng: np.random.Generator) -> jnp.ndarray:
    sh = plan.shapes
    s, t = plan.scheme.s, plan.scheme.t
    br, bc = sh.blk_b
    dp = device_plan(plan)
    with TRACER.span("protocol.phase1.share_b"):
        bm = np.asarray(b, np.int64)
        blocks = (
            bm.reshape(s, br, t, bc).transpose(0, 2, 1, 3).reshape(s * t, br, bc)
        ).astype(np.int32)
        stack = _share_stack(
            blocks, len(plan.scheme.fb_powers), dp.b_pos_h, dp.sb_pos_h,
            plan.field.p, rng,
        )
        return polyeval(dp.vb, stack, p=plan.field.p)


# ----------------------------------------------------------------------
# Phase 2 — workers compute and communicate
# ----------------------------------------------------------------------
def worker_multiply(plan: CMPCPlan, fa: jnp.ndarray, fb: jnp.ndarray) -> jnp.ndarray:
    """H(alpha_n) = F_A(alpha_n) @ F_B(alpha_n), batched over workers."""
    with TRACER.span("protocol.phase2.worker_multiply"):
        return mod_matmul(fa, fb, p=plan.field.p)


def degree_reduce(
    plan: CMPCPlan,
    h: jnp.ndarray,
    rng: np.random.Generator,
    worker_ids: Optional[Sequence[int]] = None,
) -> jnp.ndarray:
    """Dense (single-host) simulation of the Phase-2 exchange.

    Every worker n forms G_n(x) (eq. 19) and evaluates it at every other
    worker's alpha; the receivers sum into I(alpha_{n'}) (eq. 20).  Here
    that is two modular matmuls:

      I[n'] = sum_n mix[n, n'] * H[n]  +  sum_w (sum_n R_w^(n)) vnoise[n', w]

    ``worker_ids`` selects which n_workers (of n_total provisioned)
    serve Phase 2 — straggler mitigation; default = the primary set.
    Returns I evaluations for *all* provisioned workers [n_total, ...].
    """
    p = plan.field.p
    n = plan.n_workers
    dp = device_plan(plan)
    with TRACER.span("protocol.phase2.degree_reduce"):
        ids, mix_t = _phase2_selection(plan, worker_ids)
        blk = h.shape[-2:]
        h_sel = h[jnp.asarray(ids)]
        h_flat = h_sel.reshape(n, -1)
        i_flat = mod_matmul(mix_t, h_flat, p=p)  # [n_total, blk]
        # Workers' blinding terms R_w^{(n)}: each of the n Phase-2
        # workers contributes z random matrices; only their sum enters
        # I(x).
        r = plan.field.random(rng, (n, plan.scheme.z) + blk)
        r_sum = np.sum(r, axis=0) % p  # [z, blk]
        noise_flat = mod_matmul(
            dp.vnoise,
            jnp.asarray(r_sum.reshape(plan.scheme.z, -1).astype(np.int32)),
            p=p,
        )
        i_evals = (
            i_flat.astype(jnp.uint32) + noise_flat.astype(jnp.uint32)
        ) % jnp.uint32(p)
        return i_evals.astype(jnp.int32).reshape((plan.n_total,) + blk)


# ----------------------------------------------------------------------
# worker-subset selection (shared by run / run_batched / the runtime)
# ----------------------------------------------------------------------
def _phase2_selection(
    plan: CMPCPlan, worker_ids: Optional[Sequence[int]]
) -> Tuple[np.ndarray, jnp.ndarray]:
    """(sender ids, device mix.T) for a Phase-2 worker subset.

    ``None`` is the primary-prefix fast path: the pre-transposed device
    constant from ``device_plan``.  Any explicit subset routes through
    the plan's cached subset matrices.
    """
    if worker_ids is None:
        return np.arange(plan.n_workers), device_plan(plan).mix_t
    ids = np.asarray(worker_ids)
    mix = plan.phase2_matrix_cached(ids)
    return ids, jnp.asarray((mix.T % plan.field.p).astype(np.int32))


def _decode_selection(
    plan: CMPCPlan, worker_ids: Optional[Sequence[int]]
) -> Tuple[np.ndarray, np.ndarray]:
    """(responder ids, decode matrix) for a Phase-3 responder subset."""
    if worker_ids is None:
        return np.arange(plan.decode_threshold), plan.decode_w
    ids = np.asarray(worker_ids)
    return ids, plan.decode_matrix_cached(ids)


def assemble_y(plan: CMPCPlan, coeffs: np.ndarray) -> np.ndarray:
    """Lay the first t^2 coefficients of I(x) out as Y (eq. 21).

    coeffs: [>= t^2, blk_flat]; coefficient g = i + t*l is output block
    (row i, col l).  Vectorized transpose — no per-block Python loop.
    """
    t = plan.scheme.t
    br, bc = plan.shapes.blk_y
    blocks = np.asarray(coeffs)[: t * t].reshape(t, t, br, bc)  # [l, i, ., .]
    return blocks.transpose(1, 2, 0, 3).reshape(plan.shapes.ma, plan.shapes.mb)


# ----------------------------------------------------------------------
# Phase 3 — master reconstructs Y = A^T B
# ----------------------------------------------------------------------
def reconstruct(
    plan: CMPCPlan,
    i_evals: jnp.ndarray,
    worker_ids: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """Interpolate I(x) from t^2 + z responses and assemble Y.

    ``worker_ids`` is the responder subset (any ``decode_threshold``
    indices into the provisioned pool); the default is the primary
    prefix, whose decode matrix is precomputed on the plan.
    """
    thr = plan.decode_threshold
    with TRACER.span("protocol.phase3.reconstruct"):
        ids, w = _decode_selection(plan, worker_ids)
        sel = np.asarray(i_evals)[ids].reshape(thr, -1)
        coeffs = plan.field.matmul(w, sel)  # [thr, blk_flat]
        return assemble_y(plan, coeffs)


def reconstruct_corrected(
    plan: CMPCPlan,
    i_evals: jnp.ndarray,
    worker_ids: Sequence[int],
    e: int,
    rng: Optional[np.random.Generator] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Byzantine-tolerant reconstruction: decode Y from ``thr + 2e``
    responses of which up to ``e`` may be arbitrarily corrupted.

    The error-correcting counterpart of :func:`reconstruct` —
    Berlekamp-Welch over the responder subset instead of plain
    interpolation (see :mod:`repro.core.bw_decode`).  Returns
    ``(y, corrected_ids)`` where ``corrected_ids`` names the responders
    identified as corrupt; raises
    :class:`~repro.core.bw_decode.BWDecodeError` past the budget.
    """
    from .bw_decode import bw_decode_evals  # deferred: keeps import light

    evals = np.asarray(i_evals)
    coeffs, corrected = bw_decode_evals(
        plan, evals.reshape(evals.shape[0], -1), np.asarray(worker_ids), e,
        rng=rng,
    )
    return assemble_y(plan, coeffs), corrected


def reconstruct_coded_only(
    plan: CMPCPlan, h: jnp.ndarray, worker_ids: Optional[Sequence[int]] = None
) -> np.ndarray:
    """Coded-computation decode (no Phase 2): interpolate H(x) directly.

    Used for validating decodability of the underlying AGE/PolyDot codes
    (Theorem 6); the master learns garbage coefficients, so this mode
    does NOT provide master-side privacy.
    """
    n = plan.n_workers
    ids = np.arange(n) if worker_ids is None else np.asarray(worker_ids)
    if ids.size != n:
        raise ValueError(f"coded decode needs exactly {n} evaluations")
    v = plan.field.vandermonde(plan.alphas[ids], plan.scheme.h_powers)
    vinv = plan.field.inv_matrix(v)
    sel = np.asarray(h)[ids].reshape(n, -1)
    coeffs = plan.field.matmul(vinv, sel)
    t = plan.scheme.t
    br, bc = plan.shapes.blk_y
    y = np.zeros((plan.shapes.ma, plan.shapes.mb), np.int64)
    for i in range(t):
        for l in range(t):
            blkc = coeffs[plan.important_idx[i, l]].reshape(br, bc)
            y[i * br : (i + 1) * br, l * bc : (l + 1) * bc] = blkc
    return y


# ----------------------------------------------------------------------
# batched device-resident engine
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DevicePlan:
    """Device-resident constants of one CMPCPlan.

    Everything the jitted batched pipeline needs, shipped once as int32:
    share Vandermondes, the Phase-2 mixing matrix (pre-transposed), the
    blinding Vandermonde, the Phase-3 decode matrix, and the index maps
    that replace the host-side Python loops of ``_block_stack_a/_b`` and
    ``reconstruct`` with gather/scatter ``jnp`` ops built once per plan.
    """

    va: jnp.ndarray  # [n_total, |P(F_A)|]
    vb: jnp.ndarray  # [n_total, |P(F_B)|]
    mix_t: jnp.ndarray  # [n_total, n_workers]  (plan.mix.T mod p)
    vnoise: jnp.ndarray  # [n_total, z]
    decode_w: jnp.ndarray  # [thr, thr]
    a_pos: jnp.ndarray  # [t*s] block (i,j) -> row of the F_A coeff stack
    sa_pos: jnp.ndarray  # [z]   secret power -> row of the F_A stack
    b_pos: jnp.ndarray  # [s*t] block (k,l) -> row of the F_B coeff stack
    sb_pos: jnp.ndarray  # [z]
    ids2: jnp.ndarray  # [n_workers] default Phase-2 worker set
    ids3: jnp.ndarray  # [thr] default Phase-3 responder set
    # host copies of the scatter maps for the numpy share path of ``run``
    a_pos_h: np.ndarray = None
    sa_pos_h: np.ndarray = None
    b_pos_h: np.ndarray = None
    sb_pos_h: np.ndarray = None


def _positions(all_powers, powers) -> np.ndarray:
    pos = {u: idx for idx, u in enumerate(all_powers)}
    return np.array([pos[u] for u in powers], np.int32)


def device_plan(plan: CMPCPlan) -> DevicePlan:
    """Build (and cache on the plan) the device-resident constants."""
    cached = plan.__dict__.get("_device_plan")
    if cached is not None:
        return cached
    sch = plan.scheme
    p = plan.field.p
    amap = sch.coded.a_power_map()
    bmap = sch.coded.b_power_map()
    a_pos = np.zeros(sch.t * sch.s, np.int32)
    fa_index = {u: idx for idx, u in enumerate(sch.fa_powers)}
    for (i, j), u in amap.items():
        a_pos[i * sch.s + j] = fa_index[u]
    b_pos = np.zeros(sch.s * sch.t, np.int32)
    fb_index = {u: idx for idx, u in enumerate(sch.fb_powers)}
    for (k, l), u in bmap.items():
        b_pos[k * sch.t + l] = fb_index[u]
    dp = DevicePlan(
        va=jnp.asarray((plan.va % p).astype(np.int32)),
        vb=jnp.asarray((plan.vb % p).astype(np.int32)),
        mix_t=jnp.asarray((plan.mix.T % p).astype(np.int32)),
        vnoise=jnp.asarray((plan.vnoise % p).astype(np.int32)),
        decode_w=jnp.asarray((plan.decode_w % p).astype(np.int32)),
        a_pos=jnp.asarray(a_pos),
        sa_pos=jnp.asarray(_positions(sch.fa_powers, sch.sa)),
        b_pos=jnp.asarray(b_pos),
        sb_pos=jnp.asarray(_positions(sch.fb_powers, sch.sb)),
        ids2=jnp.arange(plan.n_workers, dtype=jnp.int32),
        ids3=jnp.arange(plan.decode_threshold, dtype=jnp.int32),
        a_pos_h=a_pos,
        sa_pos_h=_positions(sch.fa_powers, sch.sa),
        b_pos_h=b_pos,
        sb_pos_h=_positions(sch.fb_powers, sch.sb),
    )
    object.__setattr__(plan, "_device_plan", dp)
    return dp


def _key_words(key: jnp.ndarray) -> jnp.ndarray:
    """A JAX PRNG key as the (2,) uint32 word pair the counter-based
    mask stream (``gf.field_mask`` / the fused kernels) consumes.
    Accepts classic raw ``uint32[2]`` keys and new-style typed keys."""
    if hasattr(key, "dtype") and key.dtype == jnp.uint32:
        return key.reshape(-1)
    return jax.random.key_data(key).reshape(-1).astype(jnp.uint32)


@functools.partial(
    jax.jit,
    static_argnames=("p", "s", "t", "z", "na", "nb", "backend", "fused_masks"),
)
def _share_batched_jit(
    a: jnp.ndarray,
    b: jnp.ndarray,
    key: jnp.ndarray,
    va: jnp.ndarray,
    vb: jnp.ndarray,
    a_pos: jnp.ndarray,
    sa_pos: jnp.ndarray,
    b_pos: jnp.ndarray,
    sb_pos: jnp.ndarray,
    *,
    p: int,
    s: int,
    t: int,
    z: int,
    na: int,
    nb: int,
    backend: str,
    fused_masks: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Phase 1 for a batch of products, on device.

    a: [batch, k, ma], b: [batch, k, mb] int32 in [0, p).  Returns
    (F_A(alpha_n), F_B(alpha_n)) stacked [batch, n_total, ., .] — the
    index-based block scatter replaces _block_stack_a/_b.

    ``fused_masks`` switches the z secret coefficients from materialized
    PRNG draws scattered into the stack to the counter-based threefry
    stream fused into the share-evaluation kernel (``polyeval_masked``):
    the secret rows stay zero and the Vandermonde columns of the secret
    powers multiply in-tile mask values instead.  Decode correctness is
    draw-independent (secrets occupy non-important coefficients), so
    both routes yield bit-identical Y.
    """
    batch, k, ma = a.shape
    mb = b.shape[-1]
    bra, bca = ma // t, k // s  # F_A coefficient block
    brb, bcb = k // s, mb // t  # F_B coefficient block
    k1, k2 = jax.random.split(key, 2)

    at = jnp.swapaxes(a, -1, -2)  # [batch, ma, k]
    a_blocks = (
        at.reshape(batch, t, bra, s, bca)
        .transpose(0, 1, 3, 2, 4)
        .reshape(batch, t * s, bra, bca)
    )
    stack_a = jnp.zeros((batch, na, bra, bca), jnp.int32)
    stack_a = stack_a.at[:, a_pos].set(a_blocks)
    b_blocks = (
        b.reshape(batch, s, brb, t, bcb)
        .transpose(0, 1, 3, 2, 4)
        .reshape(batch, s * t, brb, bcb)
    )
    stack_b = jnp.zeros((batch, nb, brb, bcb), jnp.int32)
    stack_b = stack_b.at[:, b_pos].set(b_blocks)
    if fused_masks:
        # secret coefficients never materialize: V[:, secret] @ R(key)
        # is generated inside the matmul tile on the pallas backends
        fa = polyeval_masked(
            va, stack_a, jnp.take(va, sa_pos, axis=1), _key_words(k1),
            p=p, backend=backend,
        )
        fb = polyeval_masked(
            vb, stack_b, jnp.take(vb, sb_pos, axis=1), _key_words(k2),
            p=p, backend=backend,
        )
        return fa, fb
    stack_a = stack_a.at[:, sa_pos].set(random_field_device(k1, (batch, z, bra, bca), p))
    stack_b = stack_b.at[:, sb_pos].set(random_field_device(k2, (batch, z, brb, bcb), p))
    fa = polyeval(va, stack_a, p=p, backend=backend)  # [batch, n_total, bra, bca]
    fb = polyeval(vb, stack_b, p=p, backend=backend)
    return fa, fb


def share_batched(
    plan: CMPCPlan,
    a: jnp.ndarray,
    b: jnp.ndarray,
    key,
    backend: str = "auto",
    fused_masks: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sources evaluate a whole batch of share pairs in one jitted call.

    a: [batch, k, ma], b: [batch, k, mb] int32 in [0, p); ``key`` is a
    JAX PRNG key (secrets are drawn on device — or generated inside the
    share kernel when ``fused_masks``).  Entry point for the sharded
    batched engine and the batched edge runtime.
    """
    dp = device_plan(plan)
    with TRACER.span(
        "protocol.phase1.share_batched", batch=int(a.shape[0]), backend=backend
    ):
        return _share_batched_jit(
            a, b, key, dp.va, dp.vb, dp.a_pos, dp.sa_pos, dp.b_pos, dp.sb_pos,
            p=plan.field.p,
            s=plan.scheme.s,
            t=plan.scheme.t,
            z=plan.scheme.z,
            na=len(plan.scheme.fa_powers),
            nb=len(plan.scheme.fb_powers),
            backend=backend,
            fused_masks=fused_masks,
        )


@functools.partial(jax.jit, static_argnames=("p", "t", "backend"))
def _decode_batched_jit(
    i_evals: jnp.ndarray,
    decode_w: jnp.ndarray,
    ids3: jnp.ndarray,
    *,
    p: int,
    t: int,
    backend: str,
) -> jnp.ndarray:
    """Phase 3 on device: mod_matmul with the int32 decode matrix, then
    an index-based block gather replaces the ``reconstruct`` loops.

    i_evals: [batch, n_total, bry, bcy]; returns y [batch, ma, mb].
    """
    batch, _, bry, bcy = i_evals.shape
    sel = jnp.take(i_evals, ids3, axis=1).reshape(batch, ids3.shape[0], bry * bcy)
    coeffs = mod_matmul(decode_w, sel, p=p, backend=backend)
    # coefficient g = i + t*l of I(x) is output block (row i, col l)
    y_blocks = coeffs[:, : t * t].reshape(batch, t, t, bry, bcy)  # [b, l, i, ., .]
    return y_blocks.transpose(0, 2, 3, 1, 4).reshape(batch, t * bry, t * bcy)


@functools.partial(
    jax.jit,
    static_argnames=(
        "p", "s", "t", "z", "n_workers", "na", "nb", "backend", "fused_masks",
    ),
)
def _run_batched_jit(
    a: jnp.ndarray,
    b: jnp.ndarray,
    key: jnp.ndarray,
    va: jnp.ndarray,
    vb: jnp.ndarray,
    mix_t: jnp.ndarray,
    vnoise: jnp.ndarray,
    decode_w: jnp.ndarray,
    a_pos: jnp.ndarray,
    sa_pos: jnp.ndarray,
    b_pos: jnp.ndarray,
    sb_pos: jnp.ndarray,
    ids2: jnp.ndarray,
    ids3: jnp.ndarray,
    *,
    p: int,
    s: int,
    t: int,
    z: int,
    n_workers: int,
    na: int,
    nb: int,
    backend: str,
    fused_masks: bool = False,
) -> jnp.ndarray:
    """All three protocol phases for a batch of products, on device.

    a: [batch, k, ma], b: [batch, k, mb] int32 in [0, p).
    Returns y: [batch, ma, mb] int32 with y = A^T B mod p per element.
    """
    batch, k, ma = a.shape
    mb = b.shape[-1]
    kshare, k3 = jax.random.split(key, 2)

    # Phase 1 — shared with the sharded engine (inlined under this jit).
    fa, fb = _share_batched_jit(
        a, b, kshare, va, vb, a_pos, sa_pos, b_pos, sb_pos,
        p=p, s=s, t=t, z=z, na=na, nb=nb, backend=backend,
        fused_masks=fused_masks,
    )

    # Phase 2 — worker multiply + dense degree-reduction exchange.
    h = mod_matmul(fa, fb, p=p, backend=backend)  # [batch, n_total, bra, bcb]
    bry, bcy = ma // t, mb // t
    blk_flat = bry * bcy
    h_flat = jnp.take(h, ids2, axis=1).reshape(batch, n_workers, blk_flat)
    # Each Phase-2 worker contributes z blinding matrices R_w^{(n)}, but
    # only their sum over workers enters I(x) — and a sum of i.i.d.
    # uniforms mod p is itself uniform, so the dense single-host
    # simulation draws the summed term directly (n_workers x less PRNG
    # volume; the reference ``degree_reduce`` keeps per-worker draws).
    if fused_masks:
        # summed blinding generated inside the mixing matmul's tiles:
        # I = mix.T @ H + Vnoise @ R(k3), masks never materialized
        i_evals = mod_matmul_masked(
            mix_t, h_flat, vnoise, _key_words(k3), p=p, backend=backend
        )
    else:
        i_flat = mod_matmul(mix_t, h_flat, p=p, backend=backend)  # [b, n_total, .]
        r_sum = random_field_device(k3, (batch, z, blk_flat), p)
        noise = mod_matmul(vnoise, r_sum, p=p, backend=backend)
        i_evals = (
            (i_flat.astype(jnp.uint32) + noise.astype(jnp.uint32)) % jnp.uint32(p)
        ).astype(jnp.int32)

    # Phase 3 — shared with the sharded engine.
    return _decode_batched_jit(
        i_evals.reshape(batch, -1, bry, bcy), decode_w, ids3,
        p=p, t=t, backend=backend,
    )


def _prep_batched_operands(
    plan: CMPCPlan, a: np.ndarray, b: np.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Validate and promote operands to int32 [batch, k, m] device arrays."""
    a = jnp.asarray(np.asarray(a) % plan.field.p, jnp.int32)
    b = jnp.asarray(np.asarray(b) % plan.field.p, jnp.int32)
    if a.ndim == 2:
        a = a[None]
    if b.ndim == 2:
        b = b[None]
    if a.ndim != 3 or b.ndim != 3 or a.shape[0] != b.shape[0]:
        raise ValueError(f"expected [batch, k, m] operands, got {a.shape} {b.shape}")
    sh = plan.shapes
    if a.shape[1:] != (sh.k, sh.ma) or b.shape[1:] != (sh.k, sh.mb):
        raise ValueError(
            f"operands {a.shape[1:]}/{b.shape[1:]} disagree with plan "
            f"shapes ({sh.k}, {sh.ma})/({sh.k}, {sh.mb})"
        )
    return a, b


def batch_trace(
    plan: CMPCPlan,
    batch: int = 1,
    n_receivers: Optional[int] = None,
    n_responses: Optional[int] = None,
) -> Trace:
    """Corollary-12 communication accounting for ``batch`` products.

    Phase 1 provisions every worker (spares included); Phase 2's
    receivers likewise span all ``n_total`` provisioned workers — spares
    must receive I(alpha_n) too, since Phase 3 decodes from any of them
    (each of the ``n_workers`` senders reaches the other n_total - 1).
    The edge runtime overrides ``n_receivers`` with the *live* pool
    (dropouts receive nothing) and ``n_responses`` with the responses
    actually arrived at acceptance; the defaults are the idealized
    full-pool / threshold counts of the protocol paths.
    """
    sh = plan.shapes
    t = plan.scheme.t
    blk_y = (sh.ma // t) * (sh.mb // t)
    if n_receivers is None:
        n_receivers = plan.n_total
    if n_responses is None:
        n_responses = plan.decode_threshold
    return Trace(
        phase1_source_to_worker=batch
        * plan.n_total
        * (sh.blk_a[0] * sh.blk_a[1] + sh.blk_b[0] * sh.blk_b[1]),
        phase2_worker_to_worker=batch * plan.n_workers * (n_receivers - 1) * blk_y,
        phase3_worker_to_master=batch * n_responses * blk_y,
        elem_bytes=plan.field.elem_bytes,
    )


def _phase3_device_selection(
    plan: CMPCPlan, phase3_ids: Optional[Sequence[int]]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(device ids3, device decode matrix) for a responder subset."""
    dp = device_plan(plan)
    if phase3_ids is None:
        return dp.ids3, dp.decode_w
    ids3_h, decode_w_h = _decode_selection(plan, phase3_ids)
    return (
        jnp.asarray(ids3_h.astype(np.int32)),
        jnp.asarray((decode_w_h % plan.field.p).astype(np.int32)),
    )


def run_batched(
    plan: CMPCPlan,
    a: np.ndarray,
    b: np.ndarray,
    seed: int = 0,
    phase2_ids: Optional[Sequence[int]] = None,
    phase3_ids: Optional[Sequence[int]] = None,
    backend: str = "auto",
    fused_masks: bool = False,
) -> Tuple[np.ndarray, Trace]:
    """Batched protocol: Y[i] = A[i]^T B[i] mod p for a batch of products.

    a: [batch, k, ma], b: [batch, k, mb] (a single 2D operand pair is
    promoted to batch 1).  The whole pipeline — share evaluation, worker
    multiply, degree reduction and Phase-3 decode — runs inside one
    jitted, device-resident computation; plan constants are shipped once
    via ``device_plan`` and shared across calls and batch elements.
    Per-example secret shares and blinding terms come from the JAX PRNG
    (folded from ``seed``), so results are reproducible per seed but the
    randomness differs from the numpy path of ``run``.

    ``fused_masks`` generates the Phase-1 secret coefficients and the
    Phase-2 summed blinding term inside the matmul kernels (counter-based
    threefry streams) instead of materializing them; Y is unaffected —
    decode exactness holds for any draw — so fused and unfused runs
    agree bit-for-bit.

    Returns (y [batch, ma, mb] int64, Trace for the whole batch).
    """
    a, b = _prep_batched_operands(plan, a, b)
    dp = device_plan(plan)
    p = plan.field.p
    if phase2_ids is None:
        ids2 = dp.ids2
        mix_t = dp.mix_t
    else:
        ids2_h, mix_t = _phase2_selection(plan, phase2_ids)
        ids2 = jnp.asarray(ids2_h.astype(np.int32))
    ids3, decode_w = _phase3_device_selection(plan, phase3_ids)

    # All three phases execute inside one jit, so the span covers the
    # whole dispatch (phase split is only visible on the sharded path).
    with TRACER.span(
        "protocol.run_batched", batch=int(a.shape[0]), backend=backend
    ):
        y = _run_batched_jit(
            a,
            b,
            jax.random.PRNGKey(seed),
            dp.va,
            dp.vb,
            mix_t,
            dp.vnoise,
            decode_w,
            dp.a_pos,
            dp.sa_pos,
            dp.b_pos,
            dp.sb_pos,
            ids2,
            ids3,
            p=p,
            s=plan.scheme.s,
            t=plan.scheme.t,
            z=plan.scheme.z,
            n_workers=plan.n_workers,
            na=len(plan.scheme.fa_powers),
            nb=len(plan.scheme.fb_powers),
            backend=backend,
            fused_masks=fused_masks,
        )
    return np.asarray(y, np.int64), batch_trace(plan, int(a.shape[0]))


def _sum_traces(traces: Sequence[Trace]) -> Trace:
    """Aggregate per-residue traces whose wire widths may differ (CRT
    primes of different byte widths): element counts sum, the combined
    width is the widest residue's (an upper bound on the byte view)."""
    out = Trace(elem_bytes=max(t.elem_bytes for t in traces))
    for t in traces:
        out.phase1_source_to_worker += t.phase1_source_to_worker
        out.phase2_worker_to_worker += t.phase2_worker_to_worker
        out.phase3_worker_to_master += t.phase3_worker_to_master
    return out


def run_batched_crt(
    plans: Sequence[CMPCPlan],
    a: np.ndarray,
    b: np.ndarray,
    seed: int = 0,
    phase2_ids: Optional[Sequence[int]] = None,
    phase3_ids: Optional[Sequence[int]] = None,
    backend: str = "auto",
    fused_masks: bool = False,
) -> Tuple[np.ndarray, Trace]:
    """CRT multi-prime batched protocol: Y mod prod(p_i) from one
    ``run_batched`` per residue plan.

    ``plans`` hold the same scheme/shapes over *distinct* prime fields
    (one plan per CRT residue); operands are arbitrary int64 (reduced
    per field inside ``run_batched``), and the residue outputs combine
    on the host via Garner's algorithm into int64 in [0, prod(p_i)).
    This widens dynamic range without deeper limb arithmetic: fixed-point
    headroom scales with the prime product at one extra protocol pass
    per extra prime.  The returned Trace sums all residue passes.
    """
    primes = [plan.field.p for plan in plans]
    if len(set(primes)) != len(primes):
        raise ValueError(f"CRT plans must use distinct primes, got {primes}")
    residues, traces = [], []
    with TRACER.span("protocol.run_batched_crt", primes=len(primes)):
        for i, plan in enumerate(plans):
            y, tr = run_batched(
                plan, a, b, seed=seed + 31 * i,
                phase2_ids=phase2_ids, phase3_ids=phase3_ids,
                backend=backend, fused_masks=fused_masks,
            )
            residues.append(y)
            traces.append(tr)
    return crt_combine(residues, primes), _sum_traces(traces)


def run_batched_sharded(
    plan: CMPCPlan,
    a: np.ndarray,
    b: np.ndarray,
    mesh,
    axis: str = "workers",
    mode: str = "all_to_all",
    seed: int = 0,
    phase2_ids: Optional[Sequence[int]] = None,
    phase3_ids: Optional[Sequence[int]] = None,
    backend: str = "auto",
) -> Tuple[np.ndarray, Trace]:
    """Batched protocol with the *distributed* Phase 2 on a device mesh.

    Same contract as ``run_batched``, but the degree-reduction exchange
    is the ``shard_map`` collective of
    ``repro.core.distributed.run_phase2_sharded`` (``mode`` selects
    ``all_to_all`` / ``psum`` / ``psum_scatter``): workers live as
    shards on the ``axis`` mesh axis, each shard multiplies its own
    shares, and the whole batch rides one collective.  Phases 1 and 3
    are the same jitted device kernels as ``run_batched``
    (``_share_batched_jit`` / ``_decode_batched_jit``).

    ``phase2_ids`` is the Phase-2 sender subset (e.g. the fastest
    ``n_workers`` picked by the edge scheduler) and routes through the
    plan's cached subset mix matrices; ``phase3_ids`` is the responder
    subset for the decode.  Unlike ``run_batched``'s summed-blinding
    shortcut, the exchange keeps faithful *per-worker* blinding draws
    R_w^{(n)} — they are sharded with their workers.

    Returns (y [batch, ma, mb] int64, Trace for the whole batch).
    """
    from .distributed import run_phase2_sharded  # local: avoid cycle

    a, b = _prep_batched_operands(plan, a, b)
    p = plan.field.p
    batch = int(a.shape[0])
    kshare, knoise = jax.random.split(jax.random.PRNGKey(seed), 2)
    with TRACER.span(
        "protocol.run_batched_sharded", batch=batch, mode=mode, backend=backend
    ):
        fa, fb = share_batched(plan, a, b, kshare, backend=backend)

        n = plan.n_workers
        blk_y = plan.shapes.blk_y
        noise = np.asarray(
            random_field_device(knoise, (batch, n, plan.scheme.z) + blk_y, p)
        )
        with TRACER.span("protocol.phase2.sharded_exchange", mode=mode):
            i_evals = run_phase2_sharded(
                plan,
                fa,
                fb,
                noise,
                mesh,
                axis=axis,
                mode=mode,
                matmul_backend=backend,
                worker_ids=None if phase2_ids is None else np.asarray(phase2_ids),
            )  # [batch, n_total, bry, bcy]

        ids3, decode_w = _phase3_device_selection(plan, phase3_ids)
        with TRACER.span("protocol.phase3.decode_batched"):
            y = _decode_batched_jit(
                jnp.asarray(i_evals), decode_w, ids3,
                p=p, t=plan.scheme.t, backend=backend,
            )
    return np.asarray(y, np.int64), batch_trace(plan, batch)


# ----------------------------------------------------------------------
# end-to-end simulation
# ----------------------------------------------------------------------
def run(
    plan: CMPCPlan,
    a: np.ndarray,
    b: np.ndarray,
    seed: int = 0,
    phase2_ids: Optional[Sequence[int]] = None,
    phase3_ids: Optional[Sequence[int]] = None,
) -> Tuple[np.ndarray, Trace]:
    """Full protocol: returns (Y = A^T B mod p, communication trace)."""
    rng = np.random.default_rng(seed)
    with TRACER.span("protocol.run"):
        fa = share_a(plan, a, rng)
        fb = share_b(plan, b, rng)
        h = worker_multiply(plan, fa, fb)
        i_evals = degree_reduce(plan, h, rng, worker_ids=phase2_ids)
        y = reconstruct(plan, i_evals, worker_ids=phase3_ids)
    return y, batch_trace(plan, 1)
