"""The three-phase CMPC protocol engine.

Faithful execution of Algorithm 3 (AGE-CMPC) / Section IV-A
(PolyDot-CMPC) over GF(p):

Phase 1  sources evaluate F_A(alpha_n), F_B(alpha_n) and send one share
         pair to each worker,
Phase 2  every worker computes H(alpha_n) = F_A(alpha_n) F_B(alpha_n),
         forms G_n(x) (eq. 19) and exchanges evaluations; each worker
         sums the received values into I(alpha_n) (eq. 20),
Phase 3  the master reconstructs I(x) from any t^2 + z responses and
         reads Y = A^T B off the first t^2 coefficients (eq. 21).

This module operates on *stacked worker arrays* (leading axis = worker)
so the same code runs single-host (vmapped) or sharded over a mesh axis
via ``repro.core.distributed``.  All modular compute routes through the
``modmatmul`` kernel ops so the TPU path uses the Pallas kernel.

A ``Trace`` records the byte movement of each phase, matching the
communication-overhead accounting of Corollary 12.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.modmatmul.ops import mod_matmul, polyeval
from .gf import Field
from .planner import BlockShapes, CMPCPlan


@dataclasses.dataclass
class Trace:
    """Scalar-movement accounting (field elements, not bytes)."""

    phase1_source_to_worker: int = 0
    phase2_worker_to_worker: int = 0
    phase3_worker_to_master: int = 0

    @property
    def total(self) -> int:
        return (
            self.phase1_source_to_worker
            + self.phase2_worker_to_worker
            + self.phase3_worker_to_master
        )


def _block_stack_a(plan: CMPCPlan, a: np.ndarray) -> np.ndarray:
    """Coefficient stack of C_A: blocks of A^T laid out on fa_powers."""
    sh = plan.shapes
    at = np.asarray(a).T  # [ma, k]
    br, bc = sh.blk_a
    amap = plan.scheme.coded.a_power_map()
    pos = {u: idx for idx, u in enumerate(plan.scheme.fa_powers)}
    stack = np.zeros((len(plan.scheme.fa_powers), br, bc), np.int64)
    for (i, j), u in amap.items():
        stack[pos[u]] = at[i * br : (i + 1) * br, j * bc : (j + 1) * bc]
    return stack


def _block_stack_b(plan: CMPCPlan, b: np.ndarray) -> np.ndarray:
    sh = plan.shapes
    b = np.asarray(b)
    br, bc = sh.blk_b
    bmap = plan.scheme.coded.b_power_map()
    pos = {u: idx for idx, u in enumerate(plan.scheme.fb_powers)}
    stack = np.zeros((len(plan.scheme.fb_powers), br, bc), np.int64)
    for (k, l), u in bmap.items():
        stack[pos[u]] = b[k * br : (k + 1) * br, l * bc : (l + 1) * bc]
    return stack


def _fill_secrets(
    plan: CMPCPlan, stack: np.ndarray, secret_powers, all_powers, rng: np.random.Generator
) -> np.ndarray:
    pos = {u: idx for idx, u in enumerate(all_powers)}
    for u in secret_powers:
        stack[pos[u]] = plan.field.random(rng, stack.shape[1:])
    return stack


# ----------------------------------------------------------------------
# Phase 1 — sources share data with workers
# ----------------------------------------------------------------------
def share_a(plan: CMPCPlan, a: np.ndarray, rng: np.random.Generator) -> jnp.ndarray:
    """Source 1: F_A(alpha_n) for every provisioned worker.

    Returns int32 [n_total, ma/t, k/s].
    """
    stack = _block_stack_a(plan, a)
    stack = _fill_secrets(plan, stack, plan.scheme.sa, plan.scheme.fa_powers, rng)
    va = jnp.asarray(plan.va.astype(np.int32))
    return polyeval(va, jnp.asarray(stack.astype(np.int32)), p=plan.field.p)


def share_b(plan: CMPCPlan, b: np.ndarray, rng: np.random.Generator) -> jnp.ndarray:
    stack = _block_stack_b(plan, b)
    stack = _fill_secrets(plan, stack, plan.scheme.sb, plan.scheme.fb_powers, rng)
    vb = jnp.asarray(plan.vb.astype(np.int32))
    return polyeval(vb, jnp.asarray(stack.astype(np.int32)), p=plan.field.p)


# ----------------------------------------------------------------------
# Phase 2 — workers compute and communicate
# ----------------------------------------------------------------------
def worker_multiply(plan: CMPCPlan, fa: jnp.ndarray, fb: jnp.ndarray) -> jnp.ndarray:
    """H(alpha_n) = F_A(alpha_n) @ F_B(alpha_n), batched over workers."""
    return mod_matmul(fa, fb, p=plan.field.p)


def degree_reduce(
    plan: CMPCPlan,
    h: jnp.ndarray,
    rng: np.random.Generator,
    worker_ids: Optional[Sequence[int]] = None,
) -> jnp.ndarray:
    """Dense (single-host) simulation of the Phase-2 exchange.

    Every worker n forms G_n(x) (eq. 19) and evaluates it at every other
    worker's alpha; the receivers sum into I(alpha_{n'}) (eq. 20).  Here
    that is two modular matmuls:

      I[n'] = sum_n mix[n, n'] * H[n]  +  sum_w (sum_n R_w^(n)) vnoise[n', w]

    ``worker_ids`` selects which n_workers (of n_total provisioned)
    serve Phase 2 — straggler mitigation; default = the primary set.
    Returns I evaluations for *all* provisioned workers [n_total, ...].
    """
    p = plan.field.p
    n = plan.n_workers
    if worker_ids is None:
        ids = np.arange(n)
        mix = plan.mix
    else:
        ids = np.asarray(worker_ids)
        mix = plan.phase2_matrix(ids)
    blk = h.shape[-2:]
    h_sel = h[jnp.asarray(ids)]
    h_flat = h_sel.reshape(n, -1)
    i_flat = mod_matmul(
        jnp.asarray((mix.T % p).astype(np.int32)), h_flat, p=p
    )  # [n_total, blk]
    # Workers' blinding terms R_w^{(n)}: each of the n Phase-2 workers
    # contributes z random matrices; only their sum enters I(x).
    r = plan.field.random(rng, (n, plan.scheme.z) + blk)
    r_sum = np.sum(r, axis=0) % p  # [z, blk]
    noise_flat = mod_matmul(
        jnp.asarray((plan.vnoise % p).astype(np.int32)),
        jnp.asarray(r_sum.reshape(plan.scheme.z, -1).astype(np.int32)),
        p=p,
    )
    i_evals = (i_flat.astype(jnp.uint32) + noise_flat.astype(jnp.uint32)) % jnp.uint32(p)
    return i_evals.astype(jnp.int32).reshape((plan.n_total,) + blk)


# ----------------------------------------------------------------------
# Phase 3 — master reconstructs Y = A^T B
# ----------------------------------------------------------------------
def reconstruct(
    plan: CMPCPlan,
    i_evals: jnp.ndarray,
    worker_ids: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """Interpolate I(x) from t^2 + z responses and assemble Y."""
    thr = plan.decode_threshold
    if worker_ids is None:
        ids = np.arange(thr)
        w = plan.decode_w
    else:
        ids = np.asarray(worker_ids)
        w = plan.decode_matrix(ids)
    sel = np.asarray(i_evals)[ids].reshape(thr, -1)
    coeffs = plan.field.matmul(w, sel)  # [thr, blk_flat]
    t = plan.scheme.t
    br, bc = plan.shapes.blk_y
    y = np.zeros((plan.shapes.ma, plan.shapes.mb), np.int64)
    for i in range(t):
        for l in range(t):
            blkc = coeffs[i + t * l].reshape(br, bc)
            y[i * br : (i + 1) * br, l * bc : (l + 1) * bc] = blkc
    return y


def reconstruct_coded_only(
    plan: CMPCPlan, h: jnp.ndarray, worker_ids: Optional[Sequence[int]] = None
) -> np.ndarray:
    """Coded-computation decode (no Phase 2): interpolate H(x) directly.

    Used for validating decodability of the underlying AGE/PolyDot codes
    (Theorem 6); the master learns garbage coefficients, so this mode
    does NOT provide master-side privacy.
    """
    n = plan.n_workers
    ids = np.arange(n) if worker_ids is None else np.asarray(worker_ids)
    if ids.size != n:
        raise ValueError(f"coded decode needs exactly {n} evaluations")
    v = plan.field.vandermonde(plan.alphas[ids], plan.scheme.h_powers)
    vinv = plan.field.inv_matrix(v)
    sel = np.asarray(h)[ids].reshape(n, -1)
    coeffs = plan.field.matmul(vinv, sel)
    t = plan.scheme.t
    br, bc = plan.shapes.blk_y
    y = np.zeros((plan.shapes.ma, plan.shapes.mb), np.int64)
    for i in range(t):
        for l in range(t):
            blkc = coeffs[plan.important_idx[i, l]].reshape(br, bc)
            y[i * br : (i + 1) * br, l * bc : (l + 1) * bc] = blkc
    return y


# ----------------------------------------------------------------------
# end-to-end simulation
# ----------------------------------------------------------------------
def run(
    plan: CMPCPlan,
    a: np.ndarray,
    b: np.ndarray,
    seed: int = 0,
    phase2_ids: Optional[Sequence[int]] = None,
    phase3_ids: Optional[Sequence[int]] = None,
) -> Tuple[np.ndarray, Trace]:
    """Full protocol: returns (Y = A^T B mod p, communication trace)."""
    rng = np.random.default_rng(seed)
    fa = share_a(plan, a, rng)
    fb = share_b(plan, b, rng)
    h = worker_multiply(plan, fa, fb)
    i_evals = degree_reduce(plan, h, rng, worker_ids=phase2_ids)
    y = reconstruct(plan, i_evals, worker_ids=phase3_ids)

    sh = plan.shapes
    n = plan.n_workers
    t = plan.scheme.t
    trace = Trace(
        phase1_source_to_worker=plan.n_total
        * (sh.blk_a[0] * sh.blk_a[1] + sh.blk_b[0] * sh.blk_b[1]),
        phase2_worker_to_worker=n * (n - 1) * (sh.ma // t) * (sh.mb // t),
        phase3_worker_to_master=plan.decode_threshold * (sh.ma // t) * (sh.mb // t),
    )
    return y, trace
