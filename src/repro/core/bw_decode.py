"""Berlekamp-Welch error-correcting decode over GF(p).

The Phase-3 responses are evaluations of the degree-``thr - 1``
polynomial I(x) at distinct points — a Reed-Solomon codeword — so a
Byzantine worker that responds with garbage is a *symbol error*, not a
protocol failure.  Given ``k >= thr + 2e`` evaluations of which at most
``e`` are corrupted, Berlekamp-Welch recovers I(x) exactly and names
the corrupted evaluation points (the Maddah-Ali adversarial-MPC line,
arXiv:2004.04985 / 1908.04255, applied to the CMPC decode).

The key system: find a monic *error locator* ``E(x)`` of degree ``e``
and ``Q(x)`` of degree ``< thr + e`` with

    Q(x_i) = y_i * E(x_i)        for every received evaluation i.

Writing ``E(x) = x^e + sum_{j<e} lam_j x^j`` this is linear in the
``thr + 2e`` unknowns ``(q, lam)``.  With at most ``e`` errors the
system is consistent (take E = the true locator padded with roots at 0
and Q = I*E) and *every* solution satisfies ``Q = I * E`` exactly (the
classic argument: two solutions' cross-difference ``Q1*E2 - Q2*E1`` has
degree ``< thr + 2e`` but vanishes at ``k >= thr + 2e`` points), so one
particular solution of the possibly-singular system suffices —
``Field.solve_any`` pins free variables to zero.  The corrupted rows
are exactly where the recovered I(x) mismatches the evaluation.

Vector payloads (each worker returns a whole block of I(alpha_n), and
the batched runtime folds the batch in as well) share one error
pattern: a corrupt worker is corrupt for every payload column.  So the
locator is found ONCE on a random GF(p) linear combination of the
columns — a corrupt row survives the combination unless its garbage
happens to dot to the true value (probability 1/p per trial) — and the
full payload is then decoded from ``thr`` clean rows and verified
against every other clean row.  A fluked combination fails that
verification and retries with a fresh combination vector.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..obs.metrics import REGISTRY
from ..obs.tracer import TRACER
from .gf import Field


class BWDecodeError(ValueError):
    """No consistent Berlekamp-Welch decode within the error budget."""


def bw_system_size(thr: int, e: int) -> int:
    """Responses needed to correct ``e`` errors: ``thr + 2e``."""
    return int(thr) + 2 * int(e)


def _bw_locate(
    field: Field, xs: np.ndarray, v: np.ndarray, u: np.ndarray, thr: int, e: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Scalar Berlekamp-Welch: recover the combined polynomial and the
    error rows from one codeword ``u`` of evaluations at ``xs``.

    ``v`` is the Vandermonde of ``xs`` on powers ``0..thr+e-1`` (the
    ``Q`` block; its first ``e`` columns double as the low-order ``E``
    block and column ``e`` as the monic term).  Returns
    ``(coeffs [thr], err_rows)`` or raises :class:`BWDecodeError` when
    more than ``e`` rows are corrupted.
    """
    p = field.p
    u = field.asarray(u)
    if e == 0:
        a = v[:, :thr]
        rhs = u
    else:
        lam_block = (-(u[:, None] * v[:, :e])) % p
        a = np.concatenate([v[:, : thr + e], lam_block], axis=1)
        rhs = (u * v[:, e]) % p
    try:
        x = field.solve_any(a, rhs)
    except ValueError as exc:
        raise BWDecodeError(
            f"no Berlekamp-Welch solution within error budget e={e} "
            f"({u.size} evaluations, threshold {thr})"
        ) from exc
    if e == 0:
        coeffs = x
    else:
        q, lam = x[: thr + e], x[thr + e :]
        locator = np.concatenate([lam, np.ones(1, np.int64)])  # monic deg e
        quo, rem = field.poly_divmod(q, locator)
        if np.any(rem != 0):
            raise BWDecodeError(
                f"error locator does not divide Q(x): more than e={e} "
                f"corrupted evaluations among {u.size}"
            )
        coeffs = np.zeros(thr, np.int64)
        coeffs[: min(quo.size, thr)] = quo[:thr]
    err = np.flatnonzero(field.poly_eval(coeffs, xs) != u)
    if err.size > e:
        raise BWDecodeError(
            f"{err.size} mismatching evaluations exceed error budget e={e}"
        )
    return coeffs, err


def _combine(
    field: Field, ys: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    """Random GF(p) linear combination of the payload columns."""
    if ys.shape[1] == 1:
        return ys[:, 0]
    r = field.random(rng, ys.shape[1])
    return field.matmul(ys, r[:, None])[:, 0]


def bw_interpolate(
    field: Field,
    xs: np.ndarray,
    ys: np.ndarray,
    thr: int,
    e: int,
    rng: Optional[np.random.Generator] = None,
    max_combine_tries: int = 4,
) -> Tuple[np.ndarray, np.ndarray]:
    """Error-correcting interpolation from ``k >= thr + 2e`` evaluations.

    ``xs``: [k] distinct evaluation points; ``ys``: [k] or [k, payload]
    evaluations of a degree-``< thr`` polynomial (vector payloads share
    one error pattern — whole rows are corrupt or clean).  Returns
    ``(coeffs [thr, payload], err_rows)`` with ``err_rows`` the sorted
    row indices identified (and corrected) as corrupt.  Raises
    :class:`BWDecodeError` when more than ``e`` rows are corrupted.
    """
    xs = field.asarray(np.atleast_1d(xs))
    ys = field.asarray(ys)
    squeeze = ys.ndim == 1
    if squeeze:
        ys = ys[:, None]
    k = int(xs.size)
    if ys.shape[0] != k:
        raise ValueError(f"{k} points but {ys.shape[0]} evaluation rows")
    if e < 0:
        raise ValueError("error budget e must be >= 0")
    if k < bw_system_size(thr, e):
        raise ValueError(
            f"need >= thr + 2e = {bw_system_size(thr, e)} evaluations to "
            f"correct e={e} errors, got {k}"
        )
    if np.unique(xs).size != k:
        raise ValueError("evaluation points must be distinct")
    rng = rng or np.random.default_rng(0)
    v = field.vandermonde(xs, range(thr + e))
    for _ in range(max_combine_tries):
        u = _combine(field, ys, rng)
        coeffs_u, err = _bw_locate(field, xs, v, u, thr, e)
        del coeffs_u  # the locator is what matters; decode the payload below
        clean = np.setdiff1d(np.arange(k), err)
        sub = clean[:thr]
        coeffs = field.solve(v[sub][:, :thr], ys[sub])
        pred = field.matmul(v[clean][:, :thr], coeffs)
        if np.array_equal(pred, ys[clean]):
            err = _tighten_errors(field, v[:, :thr], ys, coeffs, err)
            return (coeffs[:, 0] if squeeze else coeffs), err
        # The combination dotted a corrupt row to its true value (prob
        # 1/p per row per trial) and the row slipped into the clean set:
        # redraw and relocate.
    raise BWDecodeError(
        f"payload verification failed {max_combine_tries} times — "
        f"more than e={e} corrupted rows"
    )


def _tighten_errors(
    field: Field,
    v_thr: np.ndarray,
    ys: np.ndarray,
    coeffs: np.ndarray,
    err: np.ndarray,
) -> np.ndarray:
    """Keep only flagged rows that actually mismatch the full payload
    (a spurious locator root at a clean point flags nothing real)."""
    if not err.size:
        return err
    pred = field.matmul(v_thr[err], coeffs)
    return err[np.any(pred != ys[err], axis=1)]


def bw_decode_evals(
    plan,
    i_evals: np.ndarray,
    worker_ids: np.ndarray,
    e: int,
    rng: Optional[np.random.Generator] = None,
    max_combine_tries: int = 4,
) -> Tuple[np.ndarray, np.ndarray]:
    """Plan-aware Berlekamp-Welch decode of Phase-3 responses.

    ``i_evals``: [n_total, payload] worker-stacked I(alpha_n) rows (only
    the ``worker_ids`` rows are read); ``worker_ids``: the responder
    subset, ``>= thr + 2e`` of them, in arrival (fastest-first) order so
    the final clean interpolation uses the fastest clean responders.
    Returns ``(coeffs [thr, payload], corrected_ids)`` where
    ``corrected_ids`` are the worker ids identified as corrupt (sorted).
    Raises :class:`BWDecodeError` when more than ``e`` rows are corrupt.

    Subset matrices route through the plan's caches
    (:meth:`~repro.core.planner.CMPCPlan.bw_decode_matrices` for the
    locator system, ``decode_matrix_cached`` for the clean
    interpolation, ``decode_check_matrix`` for verification), so a
    recurring fastest subset pays one Gauss-Jordan total.
    """
    field = plan.field
    thr = plan.decode_threshold
    ids = np.asarray(worker_ids)
    k = int(ids.size)
    if k < bw_system_size(thr, e):
        raise ValueError(
            f"need >= thr + 2e = {bw_system_size(thr, e)} responders to "
            f"correct e={e} errors, got {k}"
        )
    flat = field.asarray(i_evals).reshape(i_evals.shape[0], -1)
    xs = plan.alphas[ids]
    v = plan.bw_decode_matrices(ids, e)  # [k, thr+e] cached Vandermonde
    check = plan.decode_check_matrix()  # [n_total, thr]
    rng = rng or np.random.default_rng(0)
    ys = flat[ids]
    for attempt in range(max_combine_tries):
        REGISTRY.counter("bw.combine_attempts").inc()
        u = _combine(field, ys, rng)
        _, err = _bw_locate(field, xs, v, u, thr, e)
        clean_ids = ids[np.setdiff1d(np.arange(k), err)]
        sub = np.sort(clean_ids[:thr])  # canonical key for the plan cache
        w_dec = plan.decode_matrix_cached(sub)
        coeffs = field.matmul(w_dec, flat[sub])
        pred = field.matmul(check[clean_ids], coeffs)
        ok = np.array_equal(pred, flat[clean_ids])
        if TRACER.enabled:
            TRACER.event(
                "bw_decode.combine", attempt=attempt, e=int(e),
                n_responders=k, n_flagged=int(err.size), ok=bool(ok),
            )
        if ok:
            bad = ids[err]
            if bad.size:
                pred_bad = field.matmul(check[bad], coeffs)
                bad = bad[np.any(pred_bad != flat[bad], axis=1)]
            return coeffs, np.sort(bad)
    REGISTRY.counter("bw.combine_exhausted").inc()
    raise BWDecodeError(
        f"payload verification failed {max_combine_tries} times — "
        f"more than e={e} corrupted responders among {k}"
    )
