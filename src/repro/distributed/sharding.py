"""Sharding rules: logical axes -> mesh PartitionSpecs.

Parallelism layout (GSPMD):

* ``model`` axis: tensor parallel — vocab, attention heads, FFN hidden,
  experts (expert parallelism), recurrent-state heads,
* ``data`` axis: batch data parallel + optional FSDP (parameter d_model
  dims sharded over data; XLA inserts the gather/reduce-scatter pair),
* ``pod`` axis (multi-pod mesh): outermost data parallel — parameters
  are replicated across pods and gradients all-reduce over the slow
  inter-pod links (optionally compressed, see train.grad_compress),
* long-context decode (batch 1): the KV/seq dimension of caches is
  sharded over ``data`` instead of batch (context parallelism).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig

# ----------------------------------------------------------------------
# activation sharding constraints
# ----------------------------------------------------------------------
# GSPMD propagates *parameter* shardings onto activations unless told
# otherwise — with FSDP params that unshards the batch dimension.  Model
# code calls ``constrain(x, logical_axes)`` at layer boundaries; the
# step builders install concrete rules for the duration of tracing.
_TLS = threading.local()


def activation_rules(mesh: Mesh, long_context: bool = False) -> Dict[str, Any]:
    da = data_axes(mesh)
    b_ax = da if len(da) > 1 else (da[0] if da else None)
    return {
        "mesh": mesh,
        "batch": None if long_context else b_ax,
        "seq": b_ax if long_context else None,
        "heads": "model",
        "experts": "model",
        "vocab": "model",
    }


@contextlib.contextmanager
def use_activation_rules(rules: Optional[Dict[str, Any]]):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield
    finally:
        _TLS.rules = prev


def constrain(x, axes: Tuple[Optional[str], ...]):
    """Apply a sharding constraint by logical axis names (no-op when no
    rules are installed — smoke tests and single-device runs)."""
    rules = getattr(_TLS, "rules", None)
    if rules is None:
        return x
    mesh = rules["mesh"]
    names = []
    for dim, a in zip(x.shape, axes):
        m = rules.get(a) if a else None
        if isinstance(m, str) and dim % mesh.shape[m] != 0:
            m = None
        if isinstance(m, tuple):
            total = 1
            for ax in m:
                total *= mesh.shape[ax]
            if dim % total != 0:
                m = None
        names.append(m)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*names)))


def _is_info(x):
    # duck-typed to avoid a circular import with models.common
    return type(x).__name__ == "ParamInfo"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_rules(mesh: Mesh, fsdp: bool = True) -> Dict[str, Any]:
    return {
        "vocab": "model",
        "heads": "model",
        "ff": "model",
        "experts": "model",
        "embed": "data" if (fsdp and "data" in mesh.shape) else None,
        "lora": None,
        "layers": None,
        "state": None,
    }


def param_pspecs(abstract: Any, mesh: Mesh, fsdp: bool = True) -> Any:
    rules = param_rules(mesh, fsdp)

    def spec(info) -> P:
        if len(info.shape) <= 1:
            return P()  # replicate vectors/scalars (norm scales, biases)
        names = []
        used = set()
        for dim, ax in zip(info.shape, info.axes):
            mesh_ax = rules.get(ax) if ax is not None else None
            if mesh_ax is not None and dim % mesh.shape[mesh_ax] != 0:
                mesh_ax = None  # indivisible dims stay replicated
            if mesh_ax in used:
                mesh_ax = None  # a mesh axis shards at most one dim
            if mesh_ax is not None:
                used.add(mesh_ax)
            names.append(mesh_ax)
        return P(*names)

    return jax.tree.map(spec, abstract, is_leaf=_is_info)


def param_shardings(abstract: Any, mesh: Mesh, fsdp: bool = True) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_pspecs(abstract, mesh, fsdp),
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------------------------------------------------------
# batch specs
# ----------------------------------------------------------------------
def batch_pspecs(batch_abstract: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    da = data_axes(mesh)
    b_ax = da if len(da) > 1 else (da[0] if da else None)

    def spec(path, s):
        rest = (None,) * (len(s.shape) - 1)
        return P(b_ax, *rest)

    return jax.tree_util.tree_map_with_path(spec, batch_abstract)


def batch_shardings(batch_abstract, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        batch_pspecs(batch_abstract, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------------------------------------------------------
# cache specs (decode)
# ----------------------------------------------------------------------
_TRAILING = {
    # name -> (trailing_rank, trailing logical axes)
    ("k", 4): ("batch", "seq", "model", None),
    ("v", 4): ("batch", "seq", "model", None),
    ("k_rope", 3): ("batch", "seq", None),
    ("state", 4): ("batch", "model", None, None),
    ("conv", 3): ("batch", None, "model"),
    ("n", 3): ("batch", "model", None),
    ("n", 2): ("batch", "model"),
    ("c", 4): ("batch", "model", None, None),
    ("c", 2): ("batch", "model"),
    ("h", 2): ("batch", "model"),
    ("m", 2): ("batch", "model"),
    ("enc_out", 3): ("batch", None, None),
}


def cache_pspecs(
    cfg: ModelConfig, cache_abstract: Any, mesh: Mesh, long_context: bool = False
) -> Any:
    """Spec tree mirroring a cache tree.  ``long_context`` switches to
    context parallelism: seq over data, batch replicated."""
    da = data_axes(mesh)
    b_ax = da if len(da) > 1 else (da[0] if da else None)
    sub = {
        "batch": None if long_context else b_ax,
        "seq": b_ax if long_context else None,
        "model": "model",
    }

    def spec(path, s):
        name = None
        for k in reversed(path):
            kk = getattr(k, "key", None)
            if isinstance(kk, str):
                name = kk
                break
        if name == "idx" or name == "enc_len" or len(s.shape) == 0:
            return P()
        # mla latent cache: family-specific "c"
        if name == "c" and cfg.mla is not None and len(s.shape) >= 3:
            trail = ("batch", "seq", None)
        else:
            trail = None
            for r in range(len(s.shape), 0, -1):
                if (name, r) in _TRAILING:
                    trail = _TRAILING[(name, r)]
                    break
            if trail is None:
                return P()
        lead = (None,) * (len(s.shape) - len(trail))
        names = []
        for dim, ax in zip(s.shape[len(lead):], trail):
            m = sub.get(ax) if isinstance(ax, str) else ax
            if isinstance(m, str) and dim % mesh.shape[m] != 0:
                m = None
            if isinstance(m, tuple):
                total = 1
                for a in m:
                    total *= mesh.shape[a]
                if dim % total != 0:
                    m = None
            names.append(m)
        # KV caches dominate decode HBM.  If the heads dim could not take
        # the model axis (kv heads not divisible by it), shard the SEQ
        # dim over "model" instead (flash-decode combines partial
        # softmax across shards; GSPMD inserts the reduction).
        used = {n for n in names if n is not None} | {
            a for n in names if isinstance(n, tuple) for a in n
        }
        if "model" not in used and "seq" in trail:
            si = trail.index("seq")
            dim = s.shape[len(lead) + si]
            cur = names[si]
            cand = (
                ("model",) if cur is None
                else (cur + ("model",) if isinstance(cur, tuple) else (cur, "model"))
            )
            total = 1
            for a in cand:
                total *= mesh.shape[a]
            if dim % total == 0:
                names[si] = cand if len(cand) > 1 else "model"
        return P(*lead, *names)

    return jax.tree_util.tree_map_with_path(spec, cache_abstract)


def cache_shardings(cfg, cache_abstract, mesh, long_context=False):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        cache_pspecs(cfg, cache_abstract, mesh, long_context),
        is_leaf=lambda x: isinstance(x, P),
    )
