"""GPipe-style pipeline parallelism over a mesh axis.

For multi-pod topologies the ``pod`` axis can run as a *pipeline* axis
instead of outer data parallelism: layers are split into S stages, each
stage lives on one slice of the axis, and micro-batches stream through
with ``ppermute`` hops between stages.  Implemented with ``shard_map``
so stage code is explicit (no GSPMD guessing), using the classic
rotating-buffer schedule: at step k, stage s processes micro-batch
(k - s); bubble = (S - 1) / (S - 1 + M).

This is the building block for "PP across pods, TP+FSDP within a pod";
tested for exact equivalence with the single-device forward in
tests/test_pipeline.py.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def pipeline_forward(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,  # leaves with leading [S] stage axis
    x_micro: jnp.ndarray,  # [M, micro_batch, ...] micro-batches
    mesh: Mesh,
    axis: str = "stage",
):
    """Run M micro-batches through S = mesh.shape[axis] stages.

    ``stage_fn(params_s, x)`` applies one stage.  Returns [M, ...]
    outputs (as produced by the last stage).
    """
    s = mesh.shape[axis]
    m = x_micro.shape[0]
    steps = m + s - 1

    def local(params_local, xs_local):
        # params_local: stage-s params ([1, ...] leaves); xs_local: all
        # micro-batches, only stage 0 consumes them.
        params_s = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs_local[0])  # current activation
        outs = jnp.zeros((steps,) + xs_local.shape[1:], xs_local.dtype)

        def step(carry, k):
            buf, outs = carry
            # stage 0 ingests micro-batch k (if in range), others take
            # the value passed from the previous stage
            feed = jnp.where(
                sid == 0,
                xs_local[jnp.clip(k, 0, m - 1)],
                buf,
            )
            y = stage_fn(params_s, feed)
            # pass activations down the pipe: stage i -> i+1
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)]
            )
            outs = outs.at[k].set(y)  # last stage's y is the result
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(steps))
        return outs[None]  # [1, steps, ...] stage-local

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(None)),
        out_specs=P(axis),
        check_vma=False,
    )
    outs = fn(stage_params, x_micro)  # [S, steps, ...]
    # micro-batch j exits the last stage at step j + (S - 1)
    return outs[s - 1, s - 1 : s - 1 + m]
