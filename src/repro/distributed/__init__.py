"""Distribution: sharding rules, activation constraints, pipeline parallelism."""
