"""Span-based tracer with two clocks: wall time and the simulated
event-loop clock.

The repo's signals live on two different time axes.  Kernel launches,
plan builds, and protocol phases happen in *wall* time; the edge
scheduler's replays happen on the *simulated* clock of
``runtime.scheduler._replay_events`` (share arrivals, the Phase-2
barrier, response arrivals, decode acceptance).  One ``Tracer`` records
both, tagging every event with its clock, so the exporter
(``repro.obs.export``) can render a replay as a flame chart of
workers x phases on one track while real wall-clock spans land on a
separate track.

Design constraints, in order:

1. **Off by default, near-zero overhead when disabled.**  Every
   recording entry point starts with one ``self.enabled`` check;
   ``span()`` returns a module-level singleton no-op context manager
   when disabled, so the instrumented hot path allocates *nothing* —
   no span objects, no dicts, no ids (regression-tested).
2. **Zero dependencies.**  ``threading`` + ``time`` + ``itertools``.
3. **Deterministic simulated events.**  Sim-clock records carry only
   caller-provided timestamps and attributes, so two byte-identical
   replays produce byte-identical sim-track traces (the wall track is
   inherently machine-dependent and is kept separable).

Record shape (a plain dict per event, see ``Tracer.events``):

``kind``    ``"span"`` | ``"instant"``
``clock``   ``"wall"`` | ``"sim"``
``name``    span/event name (taxonomy in ``docs/observability.md``)
``id``      unique int (> 0) per record
``parent``  enclosing wall-span id (0 at top level; sim records may
            link to anything via attrs instead)
``track``   wall: thread id; sim: a ``(lane, index)`` tuple such as
            ``("worker", 3)`` or ``("replay", 0)``
``t0, t1``  spans: start/end on the record's clock (wall: seconds from
            ``time.perf_counter``; sim: the caller's simulated units)
``t``       instants: the single timestamp
``attrs``   caller attributes (JSON-serializable values expected)
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

# Hard cap on buffered events: a runaway loop with tracing enabled
# degrades to dropped events (counted) instead of unbounded memory.
MAX_EVENTS_DEFAULT = 1_000_000

SimTrack = Tuple[str, int]


class _DisabledSpan:
    """Singleton no-op returned by ``span()`` while tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_DisabledSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_DisabledSpan":
        return self

    @property
    def id(self) -> int:
        return 0


_DISABLED_SPAN = _DisabledSpan()


class Span:
    """A live wall-clock span; use as a context manager.

    The record is appended on ``__exit__`` (so the event list is
    completion-ordered, like Chrome ``"X"`` events).  ``set()`` adds
    attributes mid-flight.
    """

    __slots__ = ("_tracer", "name", "attrs", "id", "parent", "t0", "_track")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.id = tracer._next_id()
        self.parent = 0
        self.t0 = 0.0
        self._track = 0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        self.parent = stack[-1] if stack else 0
        stack.append(self.id)
        self._track = threading.get_ident()
        self.t0 = tr._clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = tr._clock()
        stack = tr._stack()
        if stack and stack[-1] == self.id:
            stack.pop()
        tr._record(
            {
                "kind": "span",
                "clock": "wall",
                "name": self.name,
                "id": self.id,
                "parent": self.parent,
                "track": self._track,
                "t0": self.t0,
                "t1": t1,
                "attrs": self.attrs,
            }
        )
        return False


class Tracer:
    """Thread-safe two-clock event recorder (module docstring)."""

    def __init__(self, max_events: int = MAX_EVENTS_DEFAULT, clock=time.perf_counter):
        self.enabled = False
        self.max_events = int(max_events)
        self._clock = clock
        self._events: List[dict] = []
        self._dropped = 0
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()

    # -- lifecycle -----------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> "Tracer":
        with self._lock:
            self._events = []
            self._dropped = 0
            self._ids = itertools.count(1)
        return self

    @property
    def events(self) -> List[dict]:
        """Snapshot of the recorded events (copy; safe to mutate)."""
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        return self._dropped

    def sim_events(self) -> List[dict]:
        """Only the simulated-clock records — the deterministic track."""
        return [e for e in self.events if e["clock"] == "sim"]

    # -- recording -----------------------------------------------------
    def span(self, name: str, **attrs):
        """Wall-clock span context manager; a shared no-op when disabled."""
        if not self.enabled:
            return _DISABLED_SPAN
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> int:
        """Wall-clock instant; returns the event id (0 when disabled)."""
        if not self.enabled:
            return 0
        stack = self._stack()
        eid = self._next_id()
        self._record(
            {
                "kind": "instant",
                "clock": "wall",
                "name": name,
                "id": eid,
                "parent": stack[-1] if stack else 0,
                "track": threading.get_ident(),
                "t": self._clock(),
                "attrs": attrs,
            }
        )
        return eid

    def sim_span(
        self,
        name: str,
        t0: float,
        t1: float,
        track: SimTrack = ("sim", 0),
        **attrs,
    ) -> int:
        """Record a completed span on the simulated clock.

        ``track`` names the flame-chart lane, e.g. ``("worker", 3)`` or
        ``("replay", 0)``.  Returns the record id (0 when disabled).
        """
        if not self.enabled:
            return 0
        eid = self._next_id()
        self._record(
            {
                "kind": "span",
                "clock": "sim",
                "name": name,
                "id": eid,
                "parent": 0,
                "track": (str(track[0]), int(track[1])),
                "t0": float(t0),
                "t1": float(t1),
                "attrs": attrs,
            }
        )
        return eid

    def sim_event(
        self, name: str, t: float, track: SimTrack = ("sim", 0), **attrs
    ) -> int:
        """Instant on the simulated clock; returns id (0 when disabled)."""
        if not self.enabled:
            return 0
        eid = self._next_id()
        self._record(
            {
                "kind": "instant",
                "clock": "sim",
                "name": name,
                "id": eid,
                "parent": 0,
                "track": (str(track[0]), int(track[1])),
                "t": float(t),
                "attrs": attrs,
            }
        )
        return eid

    # -- internals -----------------------------------------------------
    def _next_id(self) -> int:
        return next(self._ids)

    def _stack(self) -> List[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = []
            self._local.stack = stack
        return stack

    def _record(self, rec: dict) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self._dropped += 1
                return
            self._events.append(rec)


# The process-wide default tracer every instrumented module consults.
TRACER = Tracer()


def get_tracer() -> Tracer:
    return TRACER


def enable() -> Tracer:
    return TRACER.enable()


def disable() -> Tracer:
    return TRACER.disable()
