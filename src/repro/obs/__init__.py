"""Unified observability: two-clock tracing, metrics, exporters.

``repro.obs`` is imported *by* ``repro.core`` and ``repro.runtime``
(the instrumented layers), so nothing here may import them back —
the registry's default cache probes defer their planner imports to
snapshot time for exactly that reason.

Quick use::

    from repro import obs
    obs.enable()
    ... run a replay / benchmark ...
    obs.write_chrome("trace.json", obs.TRACER, metrics=obs.snapshot())

Span taxonomy and metric names: ``docs/observability.md``.
"""
from .tracer import (  # noqa: F401
    TRACER,
    Tracer,
    Span,
    disable,
    enable,
    get_tracer,
)
from .metrics import (  # noqa: F401
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    snapshot,
)
from .export import (  # noqa: F401
    to_chrome,
    to_jsonl,
    validate_chrome,
    write_chrome,
    write_jsonl,
)

__all__ = [
    "TRACER",
    "Tracer",
    "Span",
    "enable",
    "disable",
    "get_tracer",
    "REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "snapshot",
    "to_chrome",
    "to_jsonl",
    "validate_chrome",
    "write_chrome",
    "write_jsonl",
]
