"""Process-wide metrics registry: counters, gauges, histograms, probes.

Before this module the repo's counters were scattered, each with its
own spelling: ``planner.subset_cache_info()``,
``planner.plan_cache_info()["replans"]``, the (previously uncounted)
``decode_check_matrix`` memo, ad-hoc fields inside benchmark reports.
The registry absorbs them behind one ``snapshot()`` API without
deprecating anything — the legacy functions keep working and the
registry *delegates* to them through probes (callables evaluated at
snapshot time), so there is exactly one source of truth per counter.

Three owned instrument kinds plus probes:

* ``Counter``   — monotonically increasing int (``inc``),
* ``Gauge``     — last-write-wins float (``set``),
* ``Histogram`` — bounded reservoir of observations with
                  count/mean/p50/p95/max summary (the reservoir keeps
                  the most recent ``maxlen`` values),
* probes        — named zero-arg callables merged into the snapshot
                  under ``"probes"``; registration replaces (latest
                  wins) and a raising probe reports its error string
                  instead of breaking the snapshot.

Everything is thread-safe and cheap enough to leave on
unconditionally: an ``inc()`` is a dict lookup plus an int add.  The
module-level :data:`REGISTRY` is what the instrumented modules use;
``snapshot()`` is JSON-serializable by construction.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict

import numpy as np

HISTOGRAM_MAXLEN = 4096


class Counter:
    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    __slots__ = ("_values", "_count", "_lock")

    def __init__(self, maxlen: int = HISTOGRAM_MAXLEN):
        self._values: deque = deque(maxlen=maxlen)
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self._values.append(float(v))
            self._count += 1

    def summary(self) -> dict:
        """count/mean/p50/p95/max over the retained reservoir; an empty
        histogram reports zeros (defined, never a division error)."""
        with self._lock:
            vals = list(self._values)
            count = self._count
        if not vals:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0}
        arr = np.asarray(vals)
        return {
            "count": count,
            "mean": float(arr.mean()),
            "p50": float(np.percentile(arr, 50)),
            "p95": float(np.percentile(arr, 95)),
            "max": float(arr.max()),
        }


class MetricsRegistry:
    """Name -> instrument store with get-or-create accessors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._probes: Dict[str, Callable[[], dict]] = {}

    # -- accessors (get-or-create) -------------------------------------
    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    def _get(self, store: dict, name: str, factory):
        inst = store.get(name)
        if inst is None:
            with self._lock:
                inst = store.setdefault(name, factory())
        return inst

    # -- probes --------------------------------------------------------
    def register_probe(self, name: str, fn: Callable[[], dict]) -> None:
        """Delegate a snapshot section to ``fn`` (latest wins)."""
        with self._lock:
            self._probes[name] = fn

    # -- snapshot / reset ----------------------------------------------
    def snapshot(self) -> dict:
        """One JSON-ready view of every instrument and probe."""
        out = {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self._histograms.items())
            },
            "probes": {},
        }
        for name, fn in sorted(self._probes.items()):
            try:
                out["probes"][name] = fn()
            except Exception as exc:  # a broken probe must not kill the snapshot
                out["probes"][name] = {"error": f"{type(exc).__name__}: {exc}"}
        return out

    def reset(self) -> None:
        """Drop owned instruments (probes — delegated state — stay)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY


def snapshot() -> dict:
    return REGISTRY.snapshot()


# ----------------------------------------------------------------------
# default probes: the three legacy cache-stat spellings, delegated.
# Imports are deferred to probe-call time so repro.obs stays importable
# from inside repro.core (the planner imports the tracer).
# ----------------------------------------------------------------------
def _plan_cache_probe() -> dict:
    from ..core.planner import plan_cache_info

    return plan_cache_info()


def _subset_cache_probe() -> dict:
    from ..core.planner import subset_cache_info

    return subset_cache_info()


def _decode_check_cache_probe() -> dict:
    from ..core.planner import decode_check_cache_info

    return decode_check_cache_info()


REGISTRY.register_probe("plan_cache", _plan_cache_probe)
REGISTRY.register_probe("subset_cache", _subset_cache_probe)
REGISTRY.register_probe("decode_check_cache", _decode_check_cache_probe)
