"""Exporters: Chrome/Perfetto ``trace.json`` and a flat JSONL log.

The Chrome trace-event JSON object format (loadable by Perfetto's UI
and ``chrome://tracing``) renders the tracer's two clocks as two
*processes*:

* pid 1 ``wall-clock`` — real-time spans (protocol phases, plan
  builds, kernel lowering events), one thread lane per OS thread,
* pid 2 ``simulated-replay`` — the scheduler's event-loop clock, one
  lane per simulated track: ``worker N`` lanes carry each worker's
  share->compute and exchange->response spans (the flame chart of
  workers x phases), ``replay K`` lanes carry whole-replay spans,
  barriers, BW attempts, and decode acceptance.

Simulated timestamps are unitless model time; the export maps one
simulated unit to one second (1e6 µs), so a replay with unit latency
renders on a readable scale.  Wall timestamps are rebased to the
earliest wall event.

``to_chrome`` also embeds a metrics snapshot under the top-level
``repro_metrics`` key — Perfetto ignores unknown top-level keys, and
``tools/trace_report.py`` reads it back for cache hit rates and byte
accounting.  ``validate_chrome`` is the schema check behind
``make trace-check`` and the tracer tests.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple, Union

from .tracer import Tracer

WALL_PID = 1
SIM_PID = 2

# Fixed lane bases keep sim tids (and thus the exported JSON) stable
# across runs; lanes outside the table are enumerated deterministically
# after it.
_LANE_TID_BASE = {"sim": 10, "replay": 100, "pipeline": 500, "worker": 1000}
_UNKNOWN_LANE_BASE = 20000
_UNKNOWN_LANE_STRIDE = 1000


def _events_of(source: Union[Tracer, List[dict]]) -> List[dict]:
    return source.events if isinstance(source, Tracer) else list(source)


def _sim_tids(events: List[dict]) -> Dict[Tuple[str, int], int]:
    tracks = sorted(
        {tuple(e["track"]) for e in events if e["clock"] == "sim"}
    )
    lanes = sorted({lane for lane, _ in tracks})
    bases = dict(_LANE_TID_BASE)
    extra = _UNKNOWN_LANE_BASE
    for lane in lanes:
        if lane not in bases:
            bases[lane] = extra
            extra += _UNKNOWN_LANE_STRIDE
    return {(lane, idx): bases[lane] + idx for lane, idx in tracks}


def _wall_tids(events: List[dict]) -> Dict[int, int]:
    threads = sorted({e["track"] for e in events if e["clock"] == "wall"})
    return {t: i + 1 for i, t in enumerate(threads)}


def to_chrome(
    source: Union[Tracer, List[dict]],
    metrics: Optional[dict] = None,
) -> dict:
    """Render tracer records as a Perfetto-loadable trace object."""
    events = _events_of(source)
    sim_tid = _sim_tids(events)
    wall_tid = _wall_tids(events)
    wall_t0 = min(
        (e["t0"] if e["kind"] == "span" else e["t"]
         for e in events if e["clock"] == "wall"),
        default=0.0,
    )

    out: List[dict] = [
        {"name": "process_name", "ph": "M", "pid": WALL_PID,
         "args": {"name": "wall-clock"}},
        {"name": "process_sort_index", "ph": "M", "pid": WALL_PID,
         "args": {"sort_index": 1}},
        {"name": "process_name", "ph": "M", "pid": SIM_PID,
         "args": {"name": "simulated-replay"}},
        {"name": "process_sort_index", "ph": "M", "pid": SIM_PID,
         "args": {"sort_index": 0}},
    ]
    for (lane, idx), tid in sorted(sim_tid.items(), key=lambda kv: kv[1]):
        out.append(
            {"name": "thread_name", "ph": "M", "pid": SIM_PID, "tid": tid,
             "args": {"name": f"{lane} {idx}"}}
        )
        out.append(
            {"name": "thread_sort_index", "ph": "M", "pid": SIM_PID,
             "tid": tid, "args": {"sort_index": tid}}
        )
    for thread, tid in wall_tid.items():
        out.append(
            {"name": "thread_name", "ph": "M", "pid": WALL_PID, "tid": tid,
             "args": {"name": f"thread {tid}"}}
        )

    for e in events:
        sim = e["clock"] == "sim"
        pid = SIM_PID if sim else WALL_PID
        tid = sim_tid[tuple(e["track"])] if sim else wall_tid[e["track"]]
        args = dict(e["attrs"])
        args["trace_id"] = e["id"]
        if e["parent"]:
            args["parent_id"] = e["parent"]
        if e["kind"] == "span":
            t0 = e["t0"] if sim else e["t0"] - wall_t0
            dur = max(0.0, e["t1"] - e["t0"])
            out.append(
                {"name": e["name"], "cat": e["clock"], "ph": "X",
                 "ts": t0 * 1e6, "dur": dur * 1e6, "pid": pid, "tid": tid,
                 "args": args}
            )
        else:
            t = e["t"] if sim else e["t"] - wall_t0
            out.append(
                {"name": e["name"], "cat": e["clock"], "ph": "i",
                 "ts": t * 1e6, "s": "t", "pid": pid, "tid": tid,
                 "args": args}
            )

    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    if metrics is not None:
        trace["repro_metrics"] = metrics
    if isinstance(source, Tracer) and source.dropped:
        trace["repro_dropped_events"] = source.dropped
    return trace


def write_chrome(
    path: str,
    source: Union[Tracer, List[dict]],
    metrics: Optional[dict] = None,
) -> dict:
    trace = to_chrome(source, metrics=metrics)
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return trace


def to_jsonl(source: Union[Tracer, List[dict]]) -> str:
    """Flat one-record-per-line event log (raw tracer records)."""
    lines = []
    for e in _events_of(source):
        rec = dict(e)
        if isinstance(rec.get("track"), tuple):
            rec["track"] = list(rec["track"])
        lines.append(json.dumps(rec, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(path: str, source: Union[Tracer, List[dict]]) -> None:
    with open(path, "w") as f:
        f.write(to_jsonl(source))


# ----------------------------------------------------------------------
# schema validation (make trace-check / tests)
# ----------------------------------------------------------------------
_VALID_PH = {"X", "i", "M"}
_META_NAMES = {
    "process_name", "process_sort_index", "thread_name", "thread_sort_index",
}


def validate_chrome(trace: dict) -> List[str]:
    """Return schema problems (empty list == Perfetto-loadable).

    Checks the trace-event contract this exporter relies on: a
    ``traceEvents`` list; every event JSON-serializable with a known
    ``ph``; complete events with numeric non-negative durations and
    integer pid/tid; instants with a scope; metadata events naming
    processes/threads.
    """
    problems: List[str] = []
    if not isinstance(trace, dict):
        return ["trace is not a JSON object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as exc:
        problems.append(f"trace not JSON-serializable: {exc}")
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _VALID_PH:
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        if ph == "M":
            if e.get("name") not in _META_NAMES:
                problems.append(f"{where}: unknown metadata name {e.get('name')!r}")
            if not isinstance(e.get("args"), dict):
                problems.append(f"{where}: metadata without args object")
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            problems.append(f"{where}: missing event name")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                problems.append(f"{where}: {key} not an int")
        if not isinstance(e.get("ts"), (int, float)):
            problems.append(f"{where}: ts not numeric")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: complete event with bad dur {dur!r}")
        if ph == "i" and e.get("s") not in ("g", "p", "t"):
            problems.append(f"{where}: instant without a valid scope")
    return problems
