"""MiniCPM-2B [arXiv:2404.06395; hf].

Llama-like dense decoder with mu-parameterisation (scaled embeddings,
depth-scaled residuals, scaled logits) and the WSD (warmup-stable-decay)
learning-rate schedule (see repro.train.optimizer.wsd_schedule).
40L, d_model 2304, 36 heads (kv=36 -> MHA), d_ff 5760, vocab 122753.
"""
import math

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    head_dim=64,
    tie_embeddings=True,
    scale_emb=12.0,
    scale_residual=1.4 / math.sqrt(40),
    logit_scale=256.0 / 2304.0,
    rope_theta=10_000.0,
    remat_policy="full",
    sub_quadratic=False,
)

# training recipe marker consumed by launch/train.py
LR_SCHEDULE = "wsd"
