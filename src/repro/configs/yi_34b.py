"""Yi-34B [arXiv:2403.04652; hf]: llama-arch GQA.

60L, d_model 7168, 56 heads, 8 KV heads, d_ff 20480, vocab 64000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    head_dim=128,
    rope_theta=5_000_000.0,
    remat_policy="full",
    sub_quadratic=False,
)
