"""DeepSeek-V2-Lite-16B [arXiv:2405.04434; hf].

27L, d_model 2048, 16 heads with MLA (kv_lora 512, rope head 64),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff 1408; the first
layer keeps a dense FFN (d_ff 10944).  Vocab 102400.
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    rope_theta=10_000.0,
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        num_experts_per_tok=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        dense_layers=(0,),
        d_ff_dense=10_944,
        # optimized layout (EXPERIMENTS.md §Perf, dbrx cell): group-local
        # dispatch + expert-TP
        dispatch_groups=16,
        expert_tp=True,
    ),
    remat_policy="full",
    sub_quadratic=False,
)
