"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

40L, d_model 5120, 32 heads with explicit head_dim 128, 8 KV heads,
d_ff 14336, vocab 131072, 128k context (rope theta 1M).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=131_072,
    head_dim=128,
    rope_theta=1_000_000.0,
    remat_policy="full",
    sub_quadratic=False,
)
