from .base import (  # noqa: F401
    ModelConfig,
    MoEConfig,
    MLAConfig,
    SSMConfig,
    XLSTMConfig,
    HybridConfig,
    SHAPES,
    ShapeConfig,
    reduced,
    shape_applicable,
)
from .registry import ARCH_NAMES, all_configs, get_config, get_shape  # noqa: F401
