"""InternVL2-26B backbone [arXiv:2404.16821; hf].

InternLM2-20B language backbone (48L, d_model 6144, 48 heads GQA kv=8,
d_ff 16384, vocab 92553).  The InternViT vision tower is a STUB:
``input_specs`` provides precomputed patch embeddings that are
prepended to the token embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_553,
    head_dim=128,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_len=1024,
    remat_policy="full",
    sub_quadratic=False,
)
