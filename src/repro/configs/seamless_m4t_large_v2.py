"""SeamlessM4T-Large-v2 backbone [arXiv:2308.11596; hf].

Encoder-decoder transformer backbone ONLY; the speech frontend is a
stub (``input_specs`` supplies precomputed frame embeddings).  24 enc +
24 dec layers, d_model 1024, 16 heads, d_ff 8192, vocab 256206.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    head_dim=64,
    frontend="audio",
    frontend_len=4096,
    remat_policy="full",
    sub_quadratic=False,
)
