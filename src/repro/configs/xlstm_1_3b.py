"""xLSTM-1.3B [arXiv:2405.04517; unverified]: sLSTM + mLSTM blocks.

48L, d_model 2048, 4 heads, vocab 50304; recurrent (sub-quadratic) so
the long_500k cell runs.  d_ff = 0: the xLSTM block carries its own
up/down projection (proj_factor 2).
"""
from .base import ModelConfig, SSMConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0),
    ssm=SSMConfig(chunk=64),  # chunk size reused by the mLSTM dual form
    remat_policy="full",
    sub_quadratic=True,
)
