"""Qwen2-72B [arXiv:2407.10671; hf]: GQA with QKV bias.

80L, d_model 8192, 64 heads, 8 KV heads, d_ff 29568, vocab 152064.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    remat_policy="full",
    sub_quadratic=False,
)
