"""DBRX-132B [hf:databricks/dbrx-base; unverified]: fine-grained MoE.

40L, d_model 6144, 48 heads (GQA kv=8), 16 experts top-4 with expert
d_ff 10752, vocab 100352.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10_752,
    vocab_size=100_352,
    head_dim=128,
    rope_theta=500_000.0,
    moe=MoEConfig(
        num_experts=16,
        num_experts_per_tok=4,
        d_ff_expert=10_752,
        capacity_factor=1.25,
        # optimized layout (EXPERIMENTS.md §Perf): group-local dispatch +
        # expert-TP — 5x less collective time than flat expert-parallel
        dispatch_groups=16,
        expert_tp=True,
    ),
    remat_policy="full",
    sub_quadratic=False,
)
