"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import ModelConfig, SHAPES, ShapeConfig, reduced, shape_applicable  # noqa: F401

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "yi-34b": "yi_34b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2-72b": "qwen2_72b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-26b": "internvl2_26b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in _MODULES}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
