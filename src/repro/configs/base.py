"""Configuration schema for the model zoo and workload shapes.

Every assigned architecture is a ``ModelConfig``; every workload cell is
a ``ShapeConfig``.  ``reduced()`` produces the CPU-smoke-test variant of
a config (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # group-local dispatch: argsort/scatter stay within token groups
    # (aligned to data shards); 1 = flat global dispatch
    dispatch_groups: int = 1
    # expert-TP: shard the expert FFN hidden dim over "model" instead of
    # the experts dim — dispatch/combine stay shard-local and only
    # [tokens, d] partial sums cross the mesh (vs k*capacity-amplified
    # buffers under expert parallelism)
    expert_tp: bool = False
    # layers that stay dense (e.g. deepseek-v2 first layer), by index
    dense_layers: Tuple[int, ...] = ()
    d_ff_dense: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8  # every k-th block is sLSTM, rest mLSTM
    proj_factor: float = 2.0
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: shared attention block woven between SSM layers."""

    shared_attn_every: int = 6
    lora_rank: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | encdec | ssm | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MiniCPM-style mu-parameterisation
    scale_emb: float = 1.0
    scale_residual: float = 1.0
    logit_scale: float = 1.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stub: precomputed embeddings prepended to tokens
    frontend: Optional[str] = None  # "audio" | "vision"
    frontend_len: int = 0  # patches/frames per example (train shapes)
    # execution
    scan_layers: bool = True
    remat_policy: str = "none"  # none | full | dots
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # capability flags
    sub_quadratic: bool = False  # can run long_500k
    has_decode: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables are padded to a multiple of 256 so the
        vocab dim shards cleanly over the model axis; the loss masks the
        padded logit columns (exact — see chunked_softmax_xent)."""
        return (self.vocab_size + 255) // 256 * 256

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe", "vlm"):
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            if self.mla:
                m = self.mla
                attn = (
                    d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + d * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + self.num_heads * m.v_head_dim * d
                )
            if self.moe:
                moe_l = l - len(self.moe.dense_layers)
                total_e = self.moe.num_experts + self.moe.num_shared_experts
                ffn = moe_l * 3 * d * self.moe.d_ff_expert * total_e + moe_l * d * self.moe.num_experts
                ffn += len(self.moe.dense_layers) * 3 * d * (self.moe.d_ff_dense or self.d_ff)
            else:
                ffn = l * 3 * d * self.d_ff
            return emb + l * attn + ffn
        if self.family == "encdec":
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            enc = self.enc_layers * (attn + 3 * d * self.d_ff)
            dec = self.dec_layers * (2 * attn + 3 * d * self.d_ff)
            return emb + enc + dec
        if self.family == "ssm":
            # xLSTM: projections dominate
            return emb + l * int(6 * d * d)
        if self.family == "hybrid":
            ssm = l * int(5.5 * d * d)
            shared = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d + 3 * d * self.d_ff
            return emb + ssm + shared
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if not self.moe:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.mla:
            m = self.mla
            attn = (
                d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + d * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + self.num_heads * m.v_head_dim * d
            )
        moe_l = l - len(self.moe.dense_layers)
        act_e = self.moe.num_experts_per_tok + self.moe.num_shared_experts
        ffn = moe_l * 3 * d * self.moe.d_ff_expert * act_e
        ffn += len(self.moe.dense_layers) * 3 * d * (self.moe.d_ff_dense or self.d_ff)
        return emb + l * attn + ffn


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention; decode needs a decoder."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False
    if shape.kind == "decode" and not cfg.has_decode:
        return False
    return True


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frontend_len=8 if cfg.frontend else 0,
        enc_layers=min(cfg.enc_layers, 2),
        dec_layers=min(cfg.dec_layers, 2),
        remat_policy="none",
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            num_experts_per_tok=min(cfg.moe.num_experts_per_tok, 2),
            d_ff_expert=64,
            d_ff_dense=128 if cfg.moe.dense_layers else 0,
            capacity_factor=8.0,  # dropless at smoke scale: decode == forward
        )
    if cfg.mla:
        kw["mla"] = MLAConfig(
            kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16
        )
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, slstm_every=2)
    if cfg.hybrid:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, shared_attn_every=2, lora_rank=8)
    return dataclasses.replace(cfg, **kw)
