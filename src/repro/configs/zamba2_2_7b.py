"""Zamba2-2.7B [arXiv:2411.15242; hf]: Mamba2 trunk + shared attention.

54 Mamba2 layers (d_model 2560, ssm_state 64) with ONE shared
attention+MLP block (32 heads, d_ff 10240) applied every 6 layers with
per-invocation LoRA.  Sub-quadratic: runs long_500k.
"""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10_240,
    vocab_size=32_000,
    head_dim=80,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    hybrid=HybridConfig(shared_attn_every=6, lora_rank=64),
    remat_policy="full",
    sub_quadratic=True,
)
