"""Shared model machinery: spec-carrying parameters, norms, RoPE.

Parameters are declared as ``ParamInfo`` leaves (shape + logical axes +
initializer).  The same declaration drives three consumers:

* ``materialize``       — real arrays for smoke tests / the ~100M example
* ``abstract``          — ShapeDtypeStructs for the multi-pod dry-run
* ``partition_specs``   — logical axes -> mesh ``PartitionSpec`` via rules

Logical axis vocabulary: ``vocab, embed, heads, kv_heads, head_dim, ff,
experts, layers, state, lora, seq`` (None = replicated dim).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | small
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Dict[str, Any]


def _is_info(x) -> bool:
    return isinstance(x, ParamInfo)


def materialize(tree: ParamTree, rng: jax.Array) -> ParamTree:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_info)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for info, key in zip(leaves, keys):
        if info.init == "zeros":
            arr = jnp.zeros(info.shape, info.dtype)
        elif info.init == "ones":
            arr = jnp.ones(info.shape, info.dtype)
        elif info.init == "embed":
            arr = jax.random.normal(key, info.shape, info.dtype) * 0.02
        elif info.init == "small":
            arr = jax.random.normal(key, info.shape, info.dtype) * 0.006
        else:  # fan-in scaled normal
            fan_in = info.shape[-2] if len(info.shape) >= 2 else info.shape[-1]
            arr = jax.random.normal(key, info.shape, info.dtype) / np.sqrt(max(fan_in, 1))
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract(tree: ParamTree) -> ParamTree:
    return jax.tree.map(
        lambda i: jax.ShapeDtypeStruct(i.shape, i.dtype), tree, is_leaf=_is_info
    )


def partition_specs(tree: ParamTree, rules: Dict[str, Any]) -> ParamTree:
    """Map logical axes to mesh axes.  ``rules[axis]`` may be a mesh axis
    name, a tuple of mesh axes, or None."""

    def spec(info: ParamInfo) -> P:
        return P(*[rules.get(a) if a is not None else None for a in info.axes])

    return jax.tree.map(spec, tree, is_leaf=_is_info)


def count_params(tree: ParamTree) -> int:
    return sum(
        int(np.prod(i.shape))
        for i in jax.tree.leaves(tree, is_leaf=_is_info)
        if isinstance(i, (ParamInfo, jax.ShapeDtypeStruct))
    ) or sum(int(x.size) for x in jax.tree.leaves(tree))


# ----------------------------------------------------------------------
# numerics
# ----------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * w.astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0
) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def remat_wrap(fn: Callable, policy: str) -> Callable:
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {policy}")


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, z_weight: float = 0.0):
    """Token cross-entropy with optional z-loss; logits [..., V] fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_weight:
        loss = loss + z_weight * jnp.square(lse)
    return loss


def chunked_softmax_xent(
    x: jnp.ndarray,  # [B, T, d] final hidden states
    head: jnp.ndarray,  # [d, V_padded]
    labels: jnp.ndarray,  # [B, T]; -1 = ignore
    logit_scale: float = 1.0,
    chunk: int = 16_384,
    n_vocab: int = 0,  # real vocab; padded columns >= n_vocab are masked
) -> jnp.ndarray:
    """Cross-entropy without ever materialising [B, T, V] logits.

    Tokens are processed in checkpointed chunks: at peak only one
    [chunk, V] logits block exists (vocab-sharded under GSPMD), which is
    what makes 150k-vocab x 1M-token train steps fit.  Exact — not an
    approximation.
    """
    b, t, d = x.shape
    n = b * t
    chunk = min(chunk, n)
    xf = x.reshape(n, d)
    lf = labels.reshape(n)
    pad = (-n) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        lf = jnp.pad(lf, (0, pad), constant_values=-1)
    nchunk = xf.shape[0] // chunk
    xc = xf.reshape(nchunk, chunk, d)
    lc = lf.reshape(nchunk, chunk)

    vpad = head.shape[-1]
    col_ok = None
    if n_vocab and n_vocab < vpad:
        col_ok = (jnp.arange(vpad) < n_vocab)[None, :]

    def body(carry, inp):
        xs, ls = inp
        from ..distributed.sharding import constrain
        xs = constrain(xs, ("batch", None))
        logits = constrain(
            (xs @ head.astype(xs.dtype)).astype(jnp.float32) * logit_scale,
            ("batch", "vocab"),
        )
        if col_ok is not None:
            logits = jnp.where(col_ok, logits, -1e30)
        per = softmax_xent(logits, jnp.maximum(ls, 0))
        mask = (ls >= 0).astype(jnp.float32)
        return (carry[0] + jnp.sum(per * mask), carry[1] + jnp.sum(mask)), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xc, lc)
    )
    return loss_sum / jnp.maximum(count, 1.0)
