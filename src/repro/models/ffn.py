"""Feed-forward blocks: SwiGLU MLP and token-choice MoE.

The MoE uses sort-based capacity dispatch (no giant one-hot tensors):
(token, k) pairs are ordered by expert id, ranked within their expert,
dropped past capacity, scattered into a dense [experts, capacity, d]
buffer, run through batched expert matmuls, and combined back with the
router gates.  Shapes are fully static — dry-run friendly — and the
experts axis carries the ``experts`` logical axis so expert parallelism
falls out of the sharding rules.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from ..distributed.sharding import constrain
from .common import ParamInfo


def mlp_params(d: int, ff: int) -> Dict[str, ParamInfo]:
    return {
        "w_gate": ParamInfo((d, ff), ("embed", "ff")),
        "w_up": ParamInfo((d, ff), ("embed", "ff")),
        "w_down": ParamInfo((ff, d), ("ff", "embed")),
    }


def mlp(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    return (
        jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    ) @ p["w_down"].astype(dt)


# ----------------------------------------------------------------------
# MoE
# ----------------------------------------------------------------------
def moe_params(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    m = cfg.moe
    d, ffe = cfg.d_model, m.d_ff_expert
    e = m.num_experts
    e_ax = None if m.expert_tp else "experts"
    p = {
        "router": ParamInfo((d, e), ("embed", None), init="small"),
        "w_gate": ParamInfo((e, d, ffe), (e_ax, "embed", "ff")),
        "w_up": ParamInfo((e, d, ffe), (e_ax, "embed", "ff")),
        "w_down": ParamInfo((e, ffe, d), (e_ax, "ff", "embed")),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_params(d, ffe * m.num_shared_experts)
    return p


def moe_ffn(
    p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, d].  Returns (out, aux_loss).

    ``dispatch_groups > 1`` switches to group-local dispatch: the
    argsort/rank/scatter machinery runs independently inside G token
    groups (aligned with the data shards), so GSPMD never gathers the
    global token array — only the [G, E, C, d] expert buffer crosses
    shards (the minimal expert-parallel all-to-all).  See
    EXPERIMENTS.md §Perf (dbrx hillclimb).
    """
    m = cfg.moe
    dt = x.dtype
    b, t, d = x.shape
    n = b * t
    e, k = m.num_experts, m.num_experts_per_tok
    g = max(1, m.dispatch_groups)
    while n % g:
        g -= 1
    ng = n // g  # tokens per group
    cap = int(max(1, (ng * k * m.capacity_factor) // e))

    xf = x.reshape(g, ng, d)
    xf = constrain(xf, ("batch", None, None))
    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)  # [g, ng, e]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # [g, ng, k]
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style, global statistics)
    me = probs.mean((0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (n * k)
    aux = m.router_aux_weight * e * jnp.sum(me * ce)

    # ----- group-local sort-based dispatch ------------------------------------
    flat_e = eidx.reshape(g, ng * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # pairs grouped by expert
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jnp.zeros((g, e), jnp.int32).at[
        jnp.arange(g)[:, None], flat_e
    ].add(1)
    offsets = jnp.concatenate(
        [jnp.zeros((g, 1), jnp.int32), jnp.cumsum(counts, axis=-1)[:, :-1]], axis=-1
    )
    rank_in_expert = jnp.arange(ng * k, dtype=jnp.int32)[None] - jnp.take_along_axis(
        offsets, sorted_e, axis=-1
    )
    keep = rank_in_expert < cap
    token_of = order // k  # source token within group

    gi = jnp.arange(g)[:, None]
    slot_e = jnp.where(keep, sorted_e, e - 1)
    slot_c = jnp.where(keep, rank_in_expert, cap - 1)
    contrib = jnp.where(keep[..., None], jnp.take_along_axis(
        xf, token_of[..., None], axis=1
    ), 0.0)
    e_ax = None if m.expert_tp else "experts"
    buf = jnp.zeros((g, e, cap, d), dt)
    buf = buf.at[gi, slot_e, slot_c].add(contrib, mode="drop")
    buf = constrain(buf, ("batch", e_ax, None, None))

    # ----- expert compute: expert parallel (experts over "model") or
    # expert-TP (FFN hidden over "model"; dispatch stays shard-local) ---
    hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt)))
    hidden = hidden * jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    if m.expert_tp:
        hidden = constrain(hidden, ("batch", None, None, "heads"))
    out_buf = constrain(
        jnp.einsum("gecf,efd->gecd", hidden, p["w_down"].astype(dt)),
        ("batch", e_ax, None, None),
    )

    # ----- combine (group-local) ----------------------------------------------
    pair_gate = jnp.take_along_axis(gates.reshape(g, ng * k), order, axis=-1).astype(dt)
    gathered = out_buf[gi, slot_e, slot_c] * jnp.where(
        keep, pair_gate, 0.0
    )[..., None]
    out = jnp.zeros((g, ng, d), dt).at[gi, token_of].add(gathered)
    out = constrain(out, ("batch", None, None))

    if "shared" in p:
        out = out + mlp(p["shared"], xf)
    return out.reshape(b, t, d), aux
