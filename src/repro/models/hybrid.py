"""Recurrent-family assemblies: xLSTM (ssm family) and Zamba2 (hybrid).

xLSTM groups layers as [1 sLSTM + (k-1) mLSTM] * G so each group scans
its uniform mLSTM stack (``num_layers % slstm_every == 0``).

Zamba2: a trunk of Mamba2 layers with ONE globally-shared attention+MLP
block applied every ``shared_attn_every`` layers; each invocation gets
its own low-rank LoRA delta on the shared projections and its own KV
cache.  Both are sub-quadratic and run the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .attention import gqa_attention, gqa_cache_spec, gqa_params
from .common import ParamInfo, remat_wrap, rms_norm, softmax_xent
from .ffn import mlp, mlp_params
from .lm import _embed_tokens, _logits, stack_infos
from .ssm import (
    mamba_cache_spec,
    mamba_decode_step,
    mamba_params,
    mamba_scan,
)
from .xlstm import (
    mlstm_cache_spec,
    mlstm_decode_step,
    mlstm_params,
    mlstm_scan,
    slstm_cache_spec,
    slstm_decode_step,
    slstm_params,
    slstm_scan,
)


# ----------------------------------------------------------------------
# xLSTM
# ----------------------------------------------------------------------
def xlstm_abstract(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    k = cfg.xlstm.slstm_every
    assert cfg.num_layers % k == 0, "num_layers must divide slstm_every"
    g = cfg.num_layers // k
    per_s = {"ln": ParamInfo((d,), ("embed",), init="ones"), "core": slstm_params(cfg)}
    per_m = {"ln": ParamInfo((d,), ("embed",), init="ones"), "core": mlstm_params(cfg)}
    return {
        "embed": ParamInfo((v, d), ("vocab", "embed"), init="embed"),
        "slstm": stack_infos(per_s, g),
        "mlstm": stack_infos(stack_infos(per_m, k - 1), g),
        "final_norm": ParamInfo((d,), ("embed",), init="ones"),
        "lm_head": ParamInfo((d, v), ("embed", "vocab")),
    }


def xlstm_forward(
    cfg: ModelConfig, params, batch, caches=None, positions=None, head_mode="full",
    prefill=False,
):
    dt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(cfg, params, batch["tokens"], dt)
    decode = caches is not None and not prefill

    def m_body(xc, inp):
        xc = constrain(xc, ("batch", "seq", None))
        pl, cache_l = inp
        h = rms_norm(xc, pl["ln"], cfg.norm_eps)
        if decode:
            out, nc = mlstm_decode_step(pl["core"], h, cache_l, cfg)
        elif prefill:
            out, nc = mlstm_scan(pl["core"], h, cfg, return_state=True)
        else:
            out, nc = mlstm_scan(pl["core"], h, cfg), None
        return xc + out, nc

    def group(xc, inp):
        ps, pm, cs, cm = inp
        h = rms_norm(xc, ps["ln"], cfg.norm_eps)
        if decode:
            out, ncs = slstm_decode_step(ps["core"], h, cs, cfg)
        elif prefill:
            out, ncs = slstm_scan(ps["core"], h, cfg, return_state=True)
        else:
            out, ncs = slstm_scan(ps["core"], h, cfg), None
        xc = xc + out
        xc, ncm = jax.lax.scan(m_body, xc, (pm, cm))
        return xc, (ncs, ncm)

    group = remat_wrap(group, cfg.remat_policy)
    cs = caches["slstm"] if decode else None
    cm = caches["mlstm"] if decode else None
    x, (ncs, ncm) = jax.lax.scan(group, x, (params["slstm"], params["mlstm"], cs, cm))
    new_caches = {"slstm": ncs, "mlstm": ncm} if (decode or prefill) else None
    return _logits(cfg, params, x, head_mode), new_caches, jnp.zeros((), jnp.float32)


def xlstm_cache_abstract(cfg: ModelConfig, batch: int, max_len: int):
    k = cfg.xlstm.slstm_every
    g = cfg.num_layers // k
    s = slstm_cache_spec(cfg, batch)
    m = mlstm_cache_spec(cfg, batch)
    stk = lambda tree, *dims: jax.tree.map(
        lambda sp: jax.ShapeDtypeStruct(dims + sp.shape, sp.dtype), tree
    )
    return {"slstm": stk(s, g), "mlstm": stk(m, g, k - 1)}


# ----------------------------------------------------------------------
# Zamba2
# ----------------------------------------------------------------------
def zamba_abstract(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    hb = cfg.hybrid
    n_inv = (cfg.num_layers + hb.shared_attn_every - 1) // hb.shared_attn_every
    r = hb.lora_rank
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    per_m = {"ln": ParamInfo((d,), ("embed",), init="ones"), "core": mamba_params(cfg)}
    shared = {
        "ln_attn": ParamInfo((d,), ("embed",), init="ones"),
        "ln_mlp": ParamInfo((d,), ("embed",), init="ones"),
        "attn": gqa_params(cfg),
        "mlp": mlp_params(d, cfg.d_ff),
    }
    lora = {
        "a_q": ParamInfo((n_inv, d, r), (None, "embed", "lora"), init="small"),
        "b_q": ParamInfo((n_inv, r, h * hd), (None, "lora", "heads"), init="zeros"),
    }
    return {
        "embed": ParamInfo((v, d), ("vocab", "embed"), init="embed"),
        "mamba": stack_infos(per_m, cfg.num_layers),
        "shared": shared,
        "lora": lora,
        "final_norm": ParamInfo((d,), ("embed",), init="ones"),
        "lm_head": ParamInfo((d, v), ("embed", "vocab")),
    }


def _shared_block(cfg, shared, lora, inv, x, positions, cache_inv):
    """Apply the shared attention+MLP block with invocation-``inv`` LoRA."""
    dt = x.dtype
    h = rms_norm(x, shared["ln_attn"], cfg.norm_eps)
    a_q = jax.lax.dynamic_index_in_dim(lora["a_q"], inv, 0, keepdims=False)
    b_q = jax.lax.dynamic_index_in_dim(lora["b_q"], inv, 0, keepdims=False)
    delta_q = (h @ a_q.astype(dt)) @ b_q.astype(dt)
    attn, new_cache = gqa_attention(shared["attn"], h, positions, cfg, cache=cache_inv)
    x = x + attn + delta_q
    h = rms_norm(x, shared["ln_mlp"], cfg.norm_eps)
    return x + mlp(shared["mlp"], h), new_cache


def zamba_forward(
    cfg: ModelConfig, params, batch, caches=None, positions=None, head_mode="full",
    prefill=False,
):
    """Grouped execution: the shared attention block fires at layers
    0, k, 2k, ... — the trunk is reshaped to [n_inv, k] so each
    invocation uses STATIC indices into the shared KV cache and LoRA
    stacks (the previous cond-in-scan formulation copied the multi-GB
    shared cache on every layer; see EXPERIMENTS.md §Perf, zamba cell)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(cfg, params, batch["tokens"], dt)
    decode = caches is not None and not prefill
    use_cache = caches is not None
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    k = cfg.hybrid.shared_attn_every
    n_layers = cfg.num_layers
    assert n_layers % k == 0, "num_layers must divide shared_attn_every"
    n_inv = n_layers // k

    # regroup the stacked per-layer trees into [n_inv, k, ...]
    regroup = lambda tree: jax.tree.map(
        lambda a: a.reshape((n_inv, k) + a.shape[1:]), tree
    )
    pm_g = regroup(params["mamba"])
    cm_g = regroup(caches["mamba"]) if decode else None

    def mamba_body(xc, inp):
        xc = constrain(xc, ("batch", "seq", None))
        pm, cm = inp
        h = rms_norm(xc, pm["ln"], cfg.norm_eps)
        if decode:
            out, ncm = mamba_decode_step(pm["core"], h, cm, cfg)
        elif prefill:
            out, ncm = mamba_scan(pm["core"], h, cfg, return_state=True)
        else:
            out, ncm = mamba_scan(pm["core"], h, cfg), None
        return xc + out, ncm

    mamba_body = remat_wrap(mamba_body, cfg.remat_policy)

    new_shared = caches["shared"] if use_cache else None
    new_mamba = []
    for inv in range(n_inv):
        cache_inv = (
            jax.tree.map(lambda c, _i=inv: c[_i], caches["shared"])
            if use_cache
            else None
        )
        x, nc = _shared_block(
            cfg, params["shared"], params["lora"], inv, x, positions, cache_inv
        )
        if use_cache:
            new_shared = jax.tree.map(
                lambda buf, c, _i=inv: buf.at[_i].set(c), new_shared, nc
            )
        pm_i = jax.tree.map(lambda a, _i=inv: a[_i], pm_g)
        cm_i = jax.tree.map(lambda a, _i=inv: a[_i], cm_g) if decode else None
        x, ncm = jax.lax.scan(mamba_body, x, (pm_i, cm_i))
        new_mamba.append(ncm)

    new_caches = None
    if use_cache or prefill:
        ncm_all = jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_mamba)
        new_caches = {
            "shared": new_shared if use_cache else None,
            "mamba": ncm_all,
        }
    return _logits(cfg, params, x, head_mode), new_caches, jnp.zeros((), jnp.float32)


def zamba_cache_abstract(cfg: ModelConfig, batch: int, max_len: int):
    hb = cfg.hybrid
    n_inv = (cfg.num_layers + hb.shared_attn_every - 1) // hb.shared_attn_every
    attn = gqa_cache_spec(cfg, batch, max_len)
    stk = lambda tree, *dims: jax.tree.map(
        lambda sp: jax.ShapeDtypeStruct(dims + sp.shape, sp.dtype), tree
    )
    return {
        "shared": stk(attn, n_inv),
        "mamba": stk(mamba_cache_spec(cfg, batch), cfg.num_layers),
    }
