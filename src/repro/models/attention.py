"""Attention blocks: GQA (with optional QKV bias), MLA (DeepSeek-V2
latent attention with compressed KV cache), and cross-attention.

All functions are pure: ``(params, inputs, cache) -> (out, cache)``.
KV caches are preallocated fixed-length buffers updated with
``dynamic_update_slice`` so decode steps lower to static HLO.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .common import ParamInfo, apply_rope


# ----------------------------------------------------------------------
# GQA
# ----------------------------------------------------------------------
def gqa_params(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamInfo]:
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    p = {
        "wq": ParamInfo((d, h * hd), ("embed", "heads")),
        "wk": ParamInfo((d, kv * hd), ("embed", "heads")),
        "wv": ParamInfo((d, kv * hd), ("embed", "heads")),
        "wo": ParamInfo((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = ParamInfo((h * hd,), ("heads",), init="zeros")
        p["bk"] = ParamInfo((kv * hd,), ("heads",), init="zeros")
        p["bv"] = ParamInfo((kv * hd,), ("heads",), init="zeros")
    return p


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    b, t, _ = x.shape
    return x.reshape(b, t, n, -1)


def _sdpa_naive(
    q: jnp.ndarray,  # [B, Tq, H, hd]
    k: jnp.ndarray,  # [B, Tk, KV, hd]
    v: jnp.ndarray,  # [B, Tk, KV, hd_v]
    mask: Optional[jnp.ndarray],  # [B|1, Tq, Tk] bool
    scale: float,
) -> jnp.ndarray:
    """Reference attention; materialises [B, H, Tq, Tk] (tests only)."""
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, tq, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, :, :][:, :, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, tq, h * v.shape[-1])


def _divisor_chunk(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def _sdpa_chunked(
    q: jnp.ndarray,  # [B, Tq, H, hd]
    k: jnp.ndarray,  # [B, S, KV, hd]
    v: jnp.ndarray,  # [B, S, KV, hd_v]
    scale: float,
    q_positions: Optional[jnp.ndarray] = None,  # [Tq] absolute (None = not causal)
    kv_limit: Optional[jnp.ndarray] = None,  # scalar: keys >= limit invalid
    kv_valid: Optional[jnp.ndarray] = None,  # [B|1, S] extra key mask
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax (flash-style) attention: never materialises the
    [Tq, S] score matrix; peak extra memory is one [qc, kc] block per
    head.  Handles causal masking via absolute positions, cache-validity
    limits, and arbitrary key masks — the single attention primitive for
    train, prefill (cache write), decode, and cross-attention."""
    b, tq, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    hv = v.shape[-1]
    qc = _divisor_chunk(tq, q_chunk)
    kc = _divisor_chunk(s, k_chunk)
    nq, nk = tq // qc, s // kc

    qg = q.reshape(b, nq, qc, kvh, g, hd)
    kg = k.reshape(b, nk, kc, kvh, hd)
    vg = v.reshape(b, nk, kc, kvh, hv)
    qpos = None if q_positions is None else q_positions.reshape(nq, qc)
    kvv = None if kv_valid is None else jnp.broadcast_to(
        kv_valid, (kv_valid.shape[0], s)
    ).reshape(-1, nk, kc)

    def q_step(_, iq):
        qb = qg[:, iq]  # [b, qc, kv, g, hd]
        m0 = jnp.full((b, kvh, g, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, hv), jnp.float32)

        @jax.checkpoint
        def kv_step(carry, ik):
            # checkpointed: the [qc, kc] probability block is recomputed
            # in the backward instead of being stacked across all
            # (nq, nk) pairs — without this the saved residuals become
            # the full [Tq, S] score matrix again.
            m, l, acc = carry
            kb = kg[:, ik]  # [b, kc, kv, hd]
            vb = vg[:, ik]
            sc = jnp.einsum(
                "bqkgd,bskd->bkgqs", qb, kb, preferred_element_type=jnp.float32
            ) * scale  # [b, kv, g, qc, kc]
            kpos = ik * kc + jnp.arange(kc)
            mask = jnp.ones((1, 1, 1, qc, kc), bool)
            if qpos is not None:
                mask = mask & (kpos[None, :] <= qpos[iq][:, None])[None, None, None]
            if kv_limit is not None:
                mask = mask & (kpos < kv_limit)[None, None, None, None, :]
            if kvv is not None:
                mask = mask & kvv[:, ik][:, None, None, None, :]
            sc = jnp.where(mask, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [b, kv, g, qc, hv]
        return None, out.transpose(0, 3, 1, 2, 4)  # [b, qc, kv, g, hv]

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # [nq, b, qc, kv, g, hv]
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tq, h * hv)
    return out.astype(q.dtype)


def gqa_attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # [B, T, d]
    positions: jnp.ndarray,  # [B, T]
    cfg: ModelConfig,
    kv_x: Optional[jnp.ndarray] = None,  # cross attention source
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    causal: bool = True,
    use_rope: bool = True,
    kv_valid: Optional[jnp.ndarray] = None,  # [Tk] or [B, Tk] bool
    impl: str = "chunked",
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    h, kv = cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype
    src = x if kv_x is None else kv_x
    q = x @ p["wq"].astype(dt)
    k = src @ p["wk"].astype(dt)
    v = src @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = constrain(_split_heads(q, h), ("batch", "seq", "heads", None))
    k = constrain(_split_heads(k, kv), ("batch", "seq", "heads", None))
    v = constrain(_split_heads(v, kv), ("batch", "seq", "heads", None))
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(q.shape[-1])

    if cache is None:
        if use_rope:
            kpos = positions if kv_x is None else jnp.arange(src.shape[1])[None, :]
            k = apply_rope(k, kpos, cfg.rope_theta)
        if impl == "naive":
            tq, tk = q.shape[1], k.shape[1]
            mask = None
            if causal:
                mask = (jnp.arange(tk)[None, :] <= jnp.arange(tq)[:, None])[None]
            if kv_valid is not None:
                kvm = (
                    kv_valid[:, None, :] if kv_valid.ndim == 2 else kv_valid[None, None, :]
                )
                mask = kvm if mask is None else (mask & kvm)
            out = _sdpa_naive(q, k, v, mask, scale)
        else:
            kvv = None
            if kv_valid is not None:
                kvv = kv_valid if kv_valid.ndim == 2 else kv_valid[None, :]
            out = _sdpa_chunked(
                q, k, v, scale,
                q_positions=positions[0] if causal else None,
                kv_valid=kvv,
            )
        return out @ p["wo"].astype(dt), None

    # decode/prefill-with-cache: append T tokens at cache["idx"], attend
    # causally over the valid prefix (works for T == 1 and T == seq).
    idx = cache["idx"]
    if use_rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, 1)
    tq = q.shape[1]
    out = _sdpa_chunked(
        q, ck.astype(dt), cv.astype(dt), scale, q_positions=idx + jnp.arange(tq)
    )
    new_cache = {"k": ck, "v": cv, "idx": idx + tq}
    return out @ p["wo"].astype(dt), new_cache


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, kv, hd), dtype),
        "idx": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ----------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV with decoupled RoPE head
# ----------------------------------------------------------------------
def mla_params(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": ParamInfo((d, h * qd), ("embed", "heads")),
        "w_dkv": ParamInfo((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None)),
        "w_uk": ParamInfo((m.kv_lora_rank, h * m.qk_nope_head_dim), (None, "heads")),
        "w_uv": ParamInfo((m.kv_lora_rank, h * m.v_head_dim), (None, "heads")),
        "wo": ParamInfo((h * m.v_head_dim, d), ("heads", "embed")),
    }


def mla_attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Absorbed-form MLA: with q' = [q_nope W_uk | q_rope] and
    k' = [c | k_rope] the score is exactly a single-kv-head attention in
    the (r + rd)-dim latent space with v' = c — so the flash-chunked
    GQA primitive is reused and the cache stays compressed."""
    m = cfg.mla
    h = cfg.num_heads
    dt = x.dtype
    b, t, _ = x.shape
    nd, rd, vd, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank

    q = (x @ p["wq"].astype(dt)).reshape(b, t, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = x @ p["w_dkv"].astype(dt)  # [B, T, r + rd]
    c, k_rope = ckv[..., :r], ckv[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        idx = cache["idx"]
        c = jax.lax.dynamic_update_slice_in_dim(cache["c"], c.astype(cache["c"].dtype), idx, 1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx, 1
        )
        new_cache = {"c": c, "k_rope": k_rope, "idx": idx + t}
        c = c.astype(dt)
        k_rope = k_rope.astype(dt)
        q_positions = idx + jnp.arange(t)
    else:
        new_cache = None
        q_positions = jnp.arange(t)

    wuk = p["w_uk"].astype(dt).reshape(r, h, nd)
    q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, wuk)  # [B,T,H,r]
    q_prime = jnp.concatenate([q_lat, q_rope], axis=-1)  # [B,T,H,r+rd]
    k_prime = jnp.concatenate([c, k_rope], axis=-1)[:, :, None, :]  # [B,S,1,r+rd]
    v_prime = c[:, :, None, :]  # [B,S,1,r]
    ctx = _sdpa_chunked(
        q_prime,
        k_prime,
        v_prime,
        scale=1.0 / math.sqrt(nd + rd),
        q_positions=q_positions,
    ).reshape(b, t, h, r)
    wuv = p["w_uv"].astype(dt).reshape(r, h, vd)
    out = jnp.einsum("bthr,rhv->bthv", ctx, wuv).reshape(b, t, h * vd)
    return out @ p["wo"].astype(dt), new_cache


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dtype),
        "idx": jax.ShapeDtypeStruct((), jnp.int32),
    }
