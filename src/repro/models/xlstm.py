"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM
(scalar memory, sequential scan), following arXiv:2405.04517.

mLSTM per head (dim P): matrix memory C in R^{P x P}, normalizer n:

    C_t = f_t C_{t-1} + i_t v_t k_t^T
    n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_t^T q_t|, 1)

with exponentially-gated i/f stabilized by a running max m_t.  Training
uses a chunked form (decay products inside the chunk, scan across
chunks) — the same dual-form pattern as the SSD kernel.  sLSTM keeps
per-unit scalar state and is inherently sequential: a ``lax.scan`` over
time with a cheap body.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import ParamInfo, rms_norm


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads
    d_in = int(cfg.xlstm.proj_factor * d)
    p = d_in // h
    return d, h, d_in, p


def mlstm_params(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    d, h, d_in, _ = _dims(cfg)
    return {
        "w_up": ParamInfo((d, 2 * d_in), ("embed", "heads")),
        "w_q": ParamInfo((d_in, d_in), (None, "heads")),
        "w_k": ParamInfo((d_in, d_in), (None, "heads")),
        "w_v": ParamInfo((d_in, d_in), (None, "heads")),
        "w_if": ParamInfo((d_in, 2 * h), ("heads", None), init="small"),
        "b_if": ParamInfo((2 * h,), (None,), init="zeros"),
        "norm_w": ParamInfo((d_in,), ("heads",), init="ones"),
        "w_down": ParamInfo((d_in, d), ("heads", "embed")),
    }


def _mlstm_gates(p, xv, h):
    gf = xv @ p["w_if"].astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    logi, logf = gf[..., :h], gf[..., h:]
    # log f via log-sigmoid (forget in (0,1)), i exponential
    logf = jax.nn.log_sigmoid(logf)
    return logi, logf


def mlstm_scan(
    p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig, return_state: bool = False
):
    """Chunked-parallel mLSTM over a full sequence. x: [B, T, d]."""
    d, h, d_in, hd = _dims(cfg)
    dt_ = x.dtype
    b, t, _ = x.shape
    up = x @ p["w_up"].astype(dt_)
    xv, gate = up[..., :d_in], up[..., d_in:]
    q = (xv @ p["w_q"].astype(dt_)).reshape(b, t, h, hd)
    k = (xv @ p["w_k"].astype(dt_)).reshape(b, t, h, hd) / jnp.sqrt(hd).astype(dt_)
    v = (xv @ p["w_v"].astype(dt_)).reshape(b, t, h, hd)
    logi, logf = _mlstm_gates(p, xv.astype(jnp.float32), h)  # [B,T,H]

    qc = cfg.ssm.chunk if cfg.ssm else 64
    qn = min(qc, t)
    while t % qn:
        qn //= 2
    nchunk = t // qn
    qs = q.reshape(b, nchunk, qn, h, hd)
    ks = k.reshape(b, nchunk, qn, h, hd)
    vs = v.reshape(b, nchunk, qn, h, hd)
    li = logi.reshape(b, nchunk, qn, h)
    lf = logf.reshape(b, nchunk, qn, h)

    def chunk(carry, inp):
        c_state, n_state, m_state = carry  # [B,H,P,P], [B,H,P], [B,H]
        qk, kk, vk, lik, lfk = inp
        cumf = jnp.cumsum(lfk, axis=1)  # [B,q,H]
        # stabilizer: m = max(running max of (cumf + li - step contributions))
        # within-chunk log weights: w[q_, s] = cumf_q - cumf_s + li_s  (s <= q_)
        logw = cumf[:, :, None, :] - cumf[:, None, :, :] + lik[:, None, :, :]
        tri = (jnp.arange(qn)[:, None] >= jnp.arange(qn)[None, :])[None, :, :, None]
        logw = jnp.where(tri, logw, -jnp.inf)
        # inter-chunk log weight for the carried state: cumf_q + m_state
        log_inter = cumf + m_state[:, None, :]  # [B,q,H]
        m_new = jnp.maximum(jnp.max(jnp.where(tri, logw, -jnp.inf), axis=2), log_inter)
        w = jnp.exp(logw - m_new[:, :, None, :])  # [B,q,s,H]
        scores = jnp.einsum("bqhp,bshp->bqsh", qk, kk).astype(jnp.float32)
        intra = jnp.einsum("bqsh,bshp->bqhp", w * scores, vk.astype(jnp.float32))
        inter_scale = jnp.exp(log_inter - m_new)  # [B,q,H]
        inter = jnp.einsum("bqhp,bhvp->bqhv", qk.astype(jnp.float32), c_state) * inter_scale[..., None]
        norm_intra = jnp.einsum("bqsh,bshp->bqhp", w, kk.astype(jnp.float32))
        denom = jnp.einsum("bqhp,bqhp->bqh", qk.astype(jnp.float32), norm_intra) + \
            jnp.einsum("bqhp,bhp->bqh", qk.astype(jnp.float32), n_state) * inter_scale
        # scale-invariant stabiliser: max(|n^T q|, 1) in unscaled units is
        # max(|denom|, exp(-m)) on the m-scaled carried quantities.
        hvec = (intra + inter) / jnp.maximum(jnp.abs(denom), jnp.exp(-m_new))[..., None]
        # carry update (decay to end of chunk, renormalized to m_new_end)
        m_end = m_new[:, -1, :]
        decay_end = jnp.exp(cumf[:, -1:, :] - cumf + lik - m_end[:, None, :])  # [B,q,H]
        c_contrib = jnp.einsum(
            "bqh,bqhv,bqhp->bhvp", decay_end, vk.astype(jnp.float32), kk.astype(jnp.float32)
        )
        carry_scale = jnp.exp(cumf[:, -1, :] + m_state - m_end)
        c_new = c_state * carry_scale[:, :, None, None] + c_contrib
        n_new = n_state * carry_scale[:, :, None] + jnp.einsum(
            "bqh,bqhp->bhp", decay_end, kk.astype(jnp.float32)
        )
        return (c_new, n_new, m_end), hvec

    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    inputs = tuple(jnp.moveaxis(a, 1, 0) for a in (qs, ks, vs, li, lf))
    (cf, nf, mf), hs = jax.lax.scan(chunk, (c0, n0, m0), inputs)
    hvec = jnp.moveaxis(hs, 0, 1).reshape(b, t, d_in).astype(dt_)
    hvec = rms_norm(hvec, p["norm_w"], 1e-5) * jax.nn.silu(gate)
    out = hvec @ p["w_down"].astype(dt_)
    if return_state:
        return out, {"c": cf, "n": nf, "m": mf}
    return out


def mlstm_decode_step(p, x, cache, cfg: ModelConfig):
    d, h, d_in, hd = _dims(cfg)
    dt_ = x.dtype
    b = x.shape[0]
    up = x[:, 0] @ p["w_up"].astype(dt_)
    xv, gate = up[..., :d_in], up[..., d_in:]
    q = (xv @ p["w_q"].astype(dt_)).reshape(b, h, hd).astype(jnp.float32)
    k = ((xv @ p["w_k"].astype(dt_)) / jnp.sqrt(hd).astype(dt_)).reshape(b, h, hd).astype(jnp.float32)
    v = (xv @ p["w_v"].astype(dt_)).reshape(b, h, hd).astype(jnp.float32)
    logi, logf = _mlstm_gates(p, xv.astype(jnp.float32), h)  # [B,H]
    c, n, m = cache["c"], cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m, logi)
    fdec = jnp.exp(logf + m - m_new)
    iexp = jnp.exp(logi - m_new)
    c = c * fdec[:, :, None, None] + iexp[:, :, None, None] * jnp.einsum("bhv,bhp->bhvp", v, k)
    n = n * fdec[:, :, None] + iexp[:, :, None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q)), jnp.exp(-m_new))
    hvec = jnp.einsum("bhp,bhvp->bhv", q, c) / denom[:, :, None]
    hvec = hvec.reshape(b, d_in).astype(dt_)
    hvec = rms_norm(hvec, p["norm_w"], 1e-5) * jax.nn.silu(gate)
    out = (hvec @ p["w_down"].astype(dt_))[:, None, :]
    return out, {"c": c, "n": n, "m": m_new}


def mlstm_cache_spec(cfg: ModelConfig, batch: int):
    _, h, d_in, hd = _dims(cfg)
    return {
        "c": jax.ShapeDtypeStruct((batch, h, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, hd), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, h), jnp.float32),
    }


# ----------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------
def slstm_params(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    d, h, d_in, _ = _dims(cfg)
    return {
        "w_up": ParamInfo((d, 2 * d_in), ("embed", "heads")),
        "w_gates": ParamInfo((d_in, 4 * d_in), (None, "heads")),
        "r_gates": ParamInfo((d_in, 4 * d_in), (None, "heads"), init="small"),
        "b_gates": ParamInfo((4 * d_in,), ("heads",), init="zeros"),
        "norm_w": ParamInfo((d_in,), ("heads",), init="ones"),
        "w_down": ParamInfo((d_in, d), ("heads", "embed")),
    }


def _slstm_cell(p, xt, state):
    """One sLSTM step.  xt: [B, d_in] f32; state: (c, n, hprev, m)."""
    c, n, hprev, m = state
    gates = xt @ p["w_gates"].astype(jnp.float32) + hprev @ p["r_gates"].astype(
        jnp.float32
    ) + p["b_gates"].astype(jnp.float32)
    zi, ii, fi, oi = jnp.split(gates, 4, axis=-1)
    zt = jnp.tanh(zi)
    ot = jax.nn.sigmoid(oi)
    logf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(logf + m, ii)
    fdec = jnp.exp(logf + m - m_new)
    iexp = jnp.exp(ii - m_new)
    c_new = fdec * c + iexp * zt
    n_new = fdec * n + iexp
    h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_scan(
    p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig, return_state: bool = False
):
    d, h, d_in, hd = _dims(cfg)
    dt_ = x.dtype
    b, t, _ = x.shape
    up = x @ p["w_up"].astype(dt_)
    xv, gate = up[..., :d_in].astype(jnp.float32), up[..., d_in:]

    def step(state, xt):
        new = _slstm_cell(p, xt, state)
        return new, new[2]

    z = jnp.zeros((b, d_in), jnp.float32)
    state0 = (z, z, z, jnp.full((b, d_in), -1e30, jnp.float32))
    (cf, nf, hf, mf), hs = jax.lax.scan(step, state0, jnp.moveaxis(xv, 1, 0))
    hvec = jnp.moveaxis(hs, 0, 1).astype(dt_)
    hvec = rms_norm(hvec, p["norm_w"], 1e-5) * jax.nn.silu(gate)
    out = hvec @ p["w_down"].astype(dt_)
    if return_state:
        return out, {"c": cf, "n": nf, "h": hf, "m": mf}
    return out


def slstm_decode_step(p, x, cache, cfg: ModelConfig):
    d, h, d_in, hd = _dims(cfg)
    dt_ = x.dtype
    b = x.shape[0]
    up = x[:, 0] @ p["w_up"].astype(dt_)
    xv, gate = up[..., :d_in].astype(jnp.float32), up[..., d_in:]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, hnew, m = _slstm_cell(p, xv, state)
    hvec = hnew.astype(dt_)
    hvec = rms_norm(hvec, p["norm_w"], 1e-5) * jax.nn.silu(gate)
    out = (hvec @ p["w_down"].astype(dt_))[:, None, :]
    return out, {"c": c, "n": n, "h": hnew, "m": m}


def slstm_cache_spec(cfg: ModelConfig, batch: int):
    _, _, d_in, _ = _dims(cfg)
    f = lambda: jax.ShapeDtypeStruct((batch, d_in), jnp.float32)
    return {"c": f(), "n": f(), "h": f(), "m": f()}
