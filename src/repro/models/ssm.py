"""Mamba2 (SSD) blocks: chunked training scan + O(1) decode updates.

The selective state space recurrence per head (state N, head dim P):

    S_t = exp(A dt_t) S_{t-1} + dt_t x_t B_t^T      S in R^{P x N}
    y_t = S_t C_t + D x_t

Training uses the chunked dual form: within-chunk terms are an
attention-like matmul against the decay-products matrix, cross-chunk
state is carried by ``lax.scan`` — sub-quadratic in sequence length and
TPU-friendly (all chunk math is MXU matmuls).  Decode is a single
recurrence step on a cached state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import ParamInfo


def mamba_params(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    h = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return {
        "w_in": ParamInfo((d, 2 * d_in + 2 * s.d_state + h), ("embed", "heads")),
        "conv_w": ParamInfo((s.d_conv, conv_dim), (None, "heads")),
        "conv_b": ParamInfo((conv_dim,), ("heads",), init="zeros"),
        "a_log": ParamInfo((h,), ("heads",), init="zeros"),
        "d_skip": ParamInfo((h,), ("heads",), init="ones"),
        "dt_bias": ParamInfo((h,), ("heads",), init="zeros"),
        "norm_w": ParamInfo((d_in,), ("heads",), init="ones"),
        "w_out": ParamInfo((d_in, d), ("heads", "embed")),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * s.d_state], axis=-1)
    return z, xbc, dt, d_in, h


def _conv_step(conv_state, xbc, w, b):
    """Causal depthwise conv for one step. conv_state: [B, K-1, C]."""
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return jax.nn.silu(out), window[:, 1:, :]


def mamba_scan(
    p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig, return_state: bool = False
):
    """Full-sequence (training/prefill) pass.  x: [B, T, d]."""
    s = cfg.ssm
    dt_ = x.dtype
    b, t, _ = x.shape
    proj = x @ p["w_in"].astype(dt_)
    z, xbc, dtr, d_in, h = _split_proj(cfg, proj)

    # causal depthwise conv over time
    k = s.d_conv
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv_tail = pad[:, t:, :]  # last k-1 raw inputs -> decode conv state
    windows = jnp.stack([pad[:, i : i + t, :] for i in range(k)], axis=2)  # [B,T,K,C]
    xbc = jax.nn.silu(jnp.einsum("btkc,kc->btc", windows, p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_))

    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)
    xs = xs.reshape(b, t, h, s.head_dim)
    dt_act = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,T,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H]
    adt = a[None, None, :] * dt_act  # [B,T,H] (negative)

    q = min(s.chunk, t)
    while t % q:
        q -= 1
    nchunk = t // q
    # reshape to chunks
    xs_c = xs.reshape(b, nchunk, q, h, s.head_dim)
    b_c = bmat.reshape(b, nchunk, q, s.d_state)
    c_c = cmat.reshape(b, nchunk, q, s.d_state)
    adt_c = adt.reshape(b, nchunk, q, h)
    dt_c = dt_act.reshape(b, nchunk, q, h)

    def chunk_step(state, inp):
        # state: [B, H, P, N]
        xs_k, b_k, c_k, adt_k, dt_k = inp  # [B,q,...]
        cum = jnp.cumsum(adt_k, axis=1)  # [B,q,H]
        # inter-chunk: y_inter[q] = C_q . S_prev^T . exp(cum_q)
        y_inter = jnp.einsum("bqn,bhpn->bqhp", c_k, state.astype(jnp.float32)) * jnp.exp(cum)[..., None]
        # decay matrix L[q, s] = exp(cum_q - cum_s) for s <= q
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # [B,q,s,H]
        tri = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, :, :, None]
        l_mat = jnp.where(tri, jnp.exp(diff), 0.0)  # [B,q,s,H]
        cb = jnp.einsum("bqn,bsn->bqs", c_k, b_k)[..., None]  # [B,q,s,1]
        w = cb * l_mat * dt_k[:, None, :, :]  # [B,q,s,H]
        y_intra = jnp.einsum("bqsh,bshp->bqhp", w.astype(dt_), xs_k)
        # state update
        decay_end = jnp.exp(cum[:, -1:, :] - cum)  # [B,q,H]
        contrib = jnp.einsum(
            "bqh,bqhp,bqn->bhpn", (decay_end * dt_k).astype(dt_), xs_k, b_k
        )
        new_state = state * jnp.exp(cum[:, -1, :]).astype(dt_)[:, :, None, None] + contrib
        return new_state, (y_inter.astype(dt_) + y_intra)

    state0 = jnp.zeros((b, h, s.head_dim, s.d_state), dt_)
    inputs = tuple(
        jnp.moveaxis(v, 1, 0) for v in (xs_c, b_c, c_c, adt_c, dt_c)
    )
    final_state, ys = jax.lax.scan(chunk_step, state0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, s.head_dim)
    y = y + p["d_skip"].astype(dt_)[None, None, :, None] * xs
    y = y.reshape(b, t, d_in)
    # gated RMS norm then output projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(dt_) * p["norm_w"].astype(dt_)
    out = y @ p["w_out"].astype(dt_)
    if return_state:
        return out, {"state": final_state, "conv": conv_tail}
    return out


def mamba_decode_step(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # [B, 1, d]
    cache: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    s = cfg.ssm
    dt_ = x.dtype
    b = x.shape[0]
    proj = x[:, 0] @ p["w_in"].astype(dt_)
    z, xbc, dtr, d_in, h = _split_proj(cfg, proj)
    xbc, conv_state = _conv_step(cache["conv"], xbc, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    xs, bvec, cvec = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)
    xs = xs.reshape(b, h, s.head_dim)
    dt_act = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(a[None] * dt_act).astype(dt_)  # [B,H]
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt_act.astype(dt_), xs, bvec
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cvec) + p["d_skip"].astype(dt_)[None, :, None] * xs
    y = y.reshape(b, d_in) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(dt_) * p["norm_w"].astype(dt_)
    out = (y @ p["w_out"].astype(dt_))[:, None, :]
    return out, {"state": state, "conv": conv_state}


def mamba_cache_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return {
        "state": jax.ShapeDtypeStruct((batch, h, s.head_dim, s.d_state), dtype),
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim), dtype),
    }
