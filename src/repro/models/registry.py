"""Model registry: one uniform interface over all families.

``build_model(cfg)`` returns a ``Model`` exposing:

* ``abstract_params()``  — ParamInfo tree (drives init / dry-run / sharding)
* ``init(rng)``          — materialized parameters
* ``loss(params, batch)``— scalar train loss + metrics
* ``forward``            — logits (prefill path)
* ``decode_step``        — one-token step with caches
* ``cache_abstract``     — ShapeDtypeStruct cache tree
* ``batch_spec(shape)``  — abstract input batch for a ShapeConfig cell
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import hybrid, lm
from .common import ParamInfo, materialize


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    abstract_params: Callable[[], Dict[str, Any]]
    loss: Callable
    forward: Callable
    decode_step: Callable
    cache_abstract: Callable
    prefill: Optional[Callable] = None  # (params, batch, caches) -> (last_logits, caches)
    # Private-inference split (decoder families): one decode step that
    # stops at the final-normed hidden state, plus the lm-head matrix
    # (logit_scale folded in) — the serving engine multiplies the two
    # under CMPC instead of running the local head.
    hidden_step: Optional[Callable] = None  # (params, tok, caches, pos) -> (hidden, caches)
    head_matrix: Optional[Callable] = None  # (params) -> [d_model, vocab]

    def init(self, rng: jax.Array) -> Dict[str, Any]:
        return materialize(self.abstract_params(), rng)

    def init_cache(self, batch: int, max_len: int):
        """Concrete initial caches.  Stabiliser leaves (``m``) start at
        -1e30 (empty-history max); everything else at zero."""

        def leaf(path, s):
            last = path[-1]
            name = getattr(last, "key", None) or str(last)
            if name == "m":
                return jnp.full(s.shape, -1e30, s.dtype)
            return jnp.zeros(s.shape, s.dtype)

        return jax.tree_util.tree_map_with_path(
            leaf, self.cache_abstract(batch, max_len)
        )

    # ------------------------------------------------------------------
    def batch_spec(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """Abstract inputs for one workload cell (no device allocation)."""
        cfg = self.cfg
        b, t = shape.global_batch, shape.seq_len
        tok = lambda n: jax.ShapeDtypeStruct((b, n), jnp.int32)
        emb = lambda n: jax.ShapeDtypeStruct((b, n, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            dec_t = 1 if shape.kind == "decode" else max(t // 8, 16)
            spec = {"frames": emb(t), "tokens": tok(dec_t)}
            if shape.kind == "train":
                spec["labels"] = tok(dec_t)
            return spec
        if cfg.family == "vlm":
            pt = min(cfg.frontend_len, t // 4)
            if shape.kind == "decode":
                return {"tokens": tok(1)}
            spec = {"patches": emb(pt), "tokens": tok(t - pt)}
            if shape.kind == "train":
                spec["labels"] = tok(t - pt)
            return spec
        if shape.kind == "decode":
            return {"tokens": tok(1)}
        spec = {"tokens": tok(t)}
        if shape.kind == "train":
            spec["labels"] = tok(t)
        return spec


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            abstract_params=lambda: lm.decoder_abstract(cfg),
            loss=lambda p, b: lm.decoder_loss(cfg, p, b),
            forward=lambda p, b: lm.decoder_forward(cfg, p, b)[0],
            decode_step=lambda p, tok, caches, pos: lm.decoder_decode_step(
                cfg, p, tok, caches, pos
            ),
            cache_abstract=lambda batch, max_len: lm.decoder_cache_abstract(
                cfg, batch, max_len
            ),
            prefill=lambda p, b, caches: lm.decoder_prefill(cfg, p, b, caches),
            hidden_step=lambda p, tok, caches, pos: lm.decoder_hidden_step(
                cfg, p, tok, caches, pos
            ),
            head_matrix=lambda p: lm.head_matrix(cfg, p),
        )
    if fam == "encdec":

        def _decode_step(p, tok, caches, pos):
            logits, new_layers = lm.decode_stack(
                cfg,
                p,
                tok,
                caches["enc_out"],
                {"layers": caches["layers"]},
                pos,
                enc_len=caches.get("enc_len"),
            )
            return logits, {**caches, "layers": new_layers["layers"]}

        def _cache_abstract(batch, max_len):
            c = lm.encdec_cache_abstract(cfg, batch, max_len)
            c["enc_out"] = jax.ShapeDtypeStruct(
                (batch, max_len, cfg.d_model), jnp.bfloat16
            )
            c["enc_len"] = jax.ShapeDtypeStruct((), jnp.int32)
            return c

        def _prefill(p, b, caches):
            """Encode the (stub-frontend) source and prefill the decoder."""
            enc_out = lm.encode(cfg, p, b["frames"])
            pad = caches["enc_out"].shape[1] - enc_out.shape[1]
            enc_buf = jnp.pad(enc_out, ((0, 0), (0, pad), (0, 0))).astype(
                caches["enc_out"].dtype
            )
            logits, new_layers = lm.decode_stack(
                cfg,
                p,
                b["tokens"],
                enc_out,
                {"layers": caches["layers"]},
                head_mode="last",
            )
            return logits, {
                **caches,
                "enc_out": enc_buf,
                "enc_len": jnp.int32(enc_out.shape[1]),
                "layers": new_layers["layers"],
            }

        return Model(
            cfg=cfg,
            abstract_params=lambda: lm.encdec_abstract(cfg),
            loss=lambda p, b: lm.encdec_loss(cfg, p, b),
            forward=lambda p, b: lm.decode_stack(
                cfg, p, b["tokens"], lm.encode(cfg, p, b["frames"])
            )[0],
            decode_step=_decode_step,
            cache_abstract=_cache_abstract,
            prefill=_prefill,
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            abstract_params=lambda: hybrid.xlstm_abstract(cfg),
            loss=lambda p, b: _generic_loss(cfg, hybrid.xlstm_forward, p, b),
            forward=lambda p, b: hybrid.xlstm_forward(cfg, p, b)[0],
            decode_step=lambda p, tok, caches, pos: hybrid.xlstm_forward(
                cfg, p, {"tokens": tok}, caches=caches, positions=pos
            )[:2],
            cache_abstract=lambda batch, max_len: hybrid.xlstm_cache_abstract(
                cfg, batch, max_len
            ),
            prefill=lambda p, b, caches: hybrid.xlstm_forward(
                cfg, p, b, caches=caches, head_mode="last", prefill=True
            )[:2],
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            abstract_params=lambda: hybrid.zamba_abstract(cfg),
            loss=lambda p, b: _generic_loss(cfg, hybrid.zamba_forward, p, b),
            forward=lambda p, b: hybrid.zamba_forward(cfg, p, b)[0],
            decode_step=lambda p, tok, caches, pos: hybrid.zamba_forward(
                cfg, p, {"tokens": tok}, caches=caches, positions=pos
            )[:2],
            cache_abstract=lambda batch, max_len: hybrid.zamba_cache_abstract(
                cfg, batch, max_len
            ),
            prefill=lambda p, b, caches: hybrid.zamba_forward(
                cfg, p, b, caches=caches, head_mode="last", prefill=True
            )[:2],
        )
    raise KeyError(f"unknown family {fam}")


def _generic_loss(cfg, fwd, params, batch):
    from .common import chunked_softmax_xent
    from .lm import _head

    hidden, _, aux = fwd(cfg, params, batch, head_mode="none")
    loss = chunked_softmax_xent(
        hidden, _head(cfg, params), batch["labels"], logit_scale=cfg.logit_scale,
        n_vocab=cfg.vocab_size,
    )
    return loss + aux, {"xent": loss, "aux": aux}
