"""Decoder-only and encoder-decoder language models.

Assembly rules:

* parameters for the repeated trunk are *stacked* along a leading
  ``layers`` axis and executed with ``lax.scan`` — compile time is
  O(1) in depth, which keeps the 512-device dry-runs tractable,
* the block body is wrapped with the configured remat policy,
* caches are scan xs/ys so decode lowers to a single fused while-loop,
* MoE aux losses ride in the scan carry.

Families covered here: ``dense``, ``moe`` (incl. MLA attention and
deepseek-style dense first layers), ``vlm`` (vision-embed stub +
decoder trunk), ``encdec`` (audio-frame stub encoder + text decoder).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .attention import (
    gqa_attention,
    gqa_cache_spec,
    gqa_params,
    mla_attention,
    mla_cache_spec,
    mla_params,
)
from .common import (
    ParamInfo,
    chunked_softmax_xent,
    materialize,
    remat_wrap,
    rms_norm,
    softmax_xent,
)
from .ffn import mlp, mlp_params, moe_ffn, moe_params


def _is_info(x):
    return isinstance(x, ParamInfo)


def stack_infos(tree, n: int):
    return jax.tree.map(
        lambda i: ParamInfo((n,) + i.shape, ("layers",) + i.axes, i.init, i.dtype),
        tree,
        is_leaf=_is_info,
    )


# ----------------------------------------------------------------------
# decoder-only block
# ----------------------------------------------------------------------
def _block_infos(cfg: ModelConfig, moe_layer: bool) -> Dict[str, Any]:
    d = cfg.d_model
    p: Dict[str, Any] = {
        "ln_attn": ParamInfo((d,), ("embed",), init="ones"),
        "ln_mlp": ParamInfo((d,), ("embed",), init="ones"),
    }
    p["attn"] = mla_params(cfg) if cfg.mla else gqa_params(cfg)
    if moe_layer and cfg.moe:
        p["moe"] = moe_params(cfg)
    else:
        ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense) else cfg.d_ff
        p["mlp"] = mlp_params(d, ff)
    return p


def _block_apply(
    cfg: ModelConfig,
    p: Dict[str, Any],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    res_scale = jnp.asarray(cfg.scale_residual, x.dtype)
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    if cfg.mla:
        attn_out, new_cache = mla_attention(p["attn"], h, positions, cfg, cache=cache)
    else:
        attn_out, new_cache = gqa_attention(p["attn"], h, positions, cfg, cache=cache)
    x = x + attn_out * res_scale
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        ffn_out, aux = moe_ffn(p["moe"], h, cfg)
    else:
        ffn_out = mlp(p["mlp"], h)
    x = x + ffn_out * res_scale
    return x, new_cache, aux


# ----------------------------------------------------------------------
# decoder-only model
# ----------------------------------------------------------------------
def decoder_abstract(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    dense_set = set(cfg.moe.dense_layers) if cfg.moe else set()
    n_scan = cfg.num_layers - len(dense_set)
    params: Dict[str, Any] = {
        "embed": ParamInfo((v, d), ("vocab", "embed"), init="embed"),
        "final_norm": ParamInfo((d,), ("embed",), init="ones"),
        "layers": stack_infos(_block_infos(cfg, moe_layer=True), n_scan),
    }
    for i in sorted(dense_set):
        params[f"dense_layer_{i}"] = _block_infos(cfg, moe_layer=False)
    if not cfg.tie_embeddings:
        params["lm_head"] = ParamInfo((d, v), ("embed", "vocab"))
    return params


def _trunk(
    cfg: ModelConfig,
    params: Dict[str, Any],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    caches: Optional[Dict] = None,
):
    """Run all blocks (dense prologue layers + scanned trunk)."""
    aux_total = jnp.zeros((), jnp.float32)
    dense_set = sorted(set(cfg.moe.dense_layers)) if cfg.moe else []
    for i in dense_set:
        c = caches[f"dense_{i}"] if caches else None
        x, nc, aux = _block_apply(cfg, params[f"dense_layer_{i}"], x, positions, c)
        aux_total = aux_total + aux
        if caches:
            caches = dict(caches)
            caches[f"dense_{i}"] = nc

    def body(carry, inp):
        xc, aux_c = carry
        xc = constrain(xc, ("batch", "seq", None))
        pl, cache_l = inp
        xo, new_cache, aux = _block_apply(cfg, pl, xc, positions, cache_l)
        xo = constrain(xo, ("batch", "seq", None))
        return (xo, aux_c + aux), new_cache

    body = remat_wrap(body, cfg.remat_policy)
    scan_caches = caches["layers"] if caches else None
    if cfg.scan_layers:
        (x, aux_total), new_scan_caches = jax.lax.scan(
            body, (x, aux_total), (params["layers"], scan_caches)
        )
    else:
        n = jax.tree.leaves(params["layers"])[0].shape[0]
        new_list = []
        for i in range(n):
            pl = jax.tree.map(lambda a: a[i], params["layers"])
            cl = jax.tree.map(lambda a: a[i], scan_caches) if scan_caches is not None else None
            (x, aux_total), nc = body((x, aux_total), (pl, cl))
            new_list.append(nc)
        new_scan_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if caches else None
        )
    new_caches = None
    if caches is not None:
        new_caches = dict(caches)
        new_caches["layers"] = new_scan_caches
    return x, new_caches, aux_total


def _head(cfg: ModelConfig, params) -> jnp.ndarray:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _logits(cfg: ModelConfig, params, x, head_mode: str = "full"):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if head_mode == "none":
        return x
    if head_mode == "last":
        x = x[:, -1:]
    dt = x.dtype
    return (x @ _head(cfg, params).astype(dt)) * jnp.asarray(cfg.logit_scale, dt)


def _embed_tokens(cfg: ModelConfig, params, tokens, dtype):
    x = params["embed"][tokens].astype(dtype)
    return x * jnp.asarray(cfg.scale_emb, dtype)


def decoder_forward(
    cfg: ModelConfig,
    params: Dict[str, Any],
    batch: Dict[str, jnp.ndarray],
    caches: Optional[Dict] = None,
    positions: Optional[jnp.ndarray] = None,
    head_mode: str = "full",
):
    """Returns (logits | hidden, new_caches, aux)."""
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens, dt)
    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(dt), x], axis=1)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x = constrain(x, ("batch", "seq", None))
    x, new_caches, aux = _trunk(cfg, params, x, positions, caches)
    return _logits(cfg, params, x, head_mode), new_caches, aux


def decoder_loss(cfg: ModelConfig, params, batch):
    hidden, _, aux = decoder_forward(cfg, params, batch, head_mode="none")
    labels = batch["labels"]
    if cfg.family == "vlm" and "patches" in batch:
        pad = jnp.full(batch["patches"].shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = chunked_softmax_xent(
        hidden, _head(cfg, params), labels, logit_scale=cfg.logit_scale,
        n_vocab=cfg.vocab_size,
    )
    return loss + aux, {"xent": loss, "aux": aux}


def decoder_cache_abstract(cfg: ModelConfig, batch: int, max_len: int):
    spec = mla_cache_spec if cfg.mla else gqa_cache_spec
    per_layer = spec(cfg, batch, max_len)
    dense_set = sorted(set(cfg.moe.dense_layers)) if cfg.moe else []
    n_scan = cfg.num_layers - len(dense_set)
    caches: Dict[str, Any] = {
        "layers": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_scan,) + s.shape, s.dtype), per_layer
        )
    }
    for i in dense_set:
        caches[f"dense_{i}"] = per_layer
    return caches


def decoder_decode_step(cfg: ModelConfig, params, tokens, caches, positions):
    """One decode step: tokens [B, 1]; positions [B, 1] absolute."""
    logits, new_caches, _ = decoder_forward(
        cfg, params, {"tokens": tokens}, caches=caches, positions=positions
    )
    return logits, new_caches


def decoder_prefill(cfg: ModelConfig, params, batch, caches):
    """Prefill: write the prompt into the caches, return last logits."""
    logits, new_caches, _ = decoder_forward(
        cfg, params, batch, caches=caches, head_mode="last"
    )
    return logits, new_caches


def decoder_hidden_step(cfg: ModelConfig, params, tokens, caches, positions):
    """One decode step stopping at the final-normed hidden state
    (``head_mode="none"``): tokens [B, 1] -> hidden [B, 1, d_model].

    The private-inference split point: the public trunk runs on-device
    up to here, and the lm-head matmul — the part multiplying the
    *private* head matrix — routes through the CMPC serving engine
    (``hidden @ head_matrix``) instead of the local ``_logits`` path.
    """
    hidden, new_caches, _ = decoder_forward(
        cfg, params, {"tokens": tokens}, caches=caches, positions=positions,
        head_mode="none",
    )
    return hidden, new_caches


def head_matrix(cfg: ModelConfig, params) -> jnp.ndarray:
    """The lm-head weight [d_model, vocab] with ``logit_scale`` folded
    in, so ``hidden @ head_matrix(cfg, params)`` equals the full-head
    logits — the private source-2 operand the serving engine holds."""
    return _head(cfg, params) * jnp.asarray(cfg.logit_scale)


# ----------------------------------------------------------------------
# encoder-decoder (seamless-style backbone; modality frontend is a stub)
# ----------------------------------------------------------------------
def _enc_block_infos(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "ln_attn": ParamInfo((d,), ("embed",), init="ones"),
        "ln_mlp": ParamInfo((d,), ("embed",), init="ones"),
        "attn": gqa_params(cfg),
        "mlp": mlp_params(d, cfg.d_ff),
    }


def _dec_block_infos(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "ln_self": ParamInfo((d,), ("embed",), init="ones"),
        "ln_cross": ParamInfo((d,), ("embed",), init="ones"),
        "ln_mlp": ParamInfo((d,), ("embed",), init="ones"),
        "self_attn": gqa_params(cfg),
        "cross_attn": gqa_params(cfg, cross=True),
        "mlp": mlp_params(d, cfg.d_ff),
    }


def encdec_abstract(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    return {
        "embed": ParamInfo((v, d), ("vocab", "embed"), init="embed"),
        "enc_layers": stack_infos(_enc_block_infos(cfg), cfg.enc_layers),
        "enc_norm": ParamInfo((d,), ("embed",), init="ones"),
        "dec_layers": stack_infos(_dec_block_infos(cfg), cfg.dec_layers),
        "final_norm": ParamInfo((d,), ("embed",), init="ones"),
        "lm_head": ParamInfo((d, v), ("embed", "vocab")),
    }


def encode(cfg: ModelConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: [B, Te, d] precomputed modality embeddings (stub frontend)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dt)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(carry, pl):
        xc = carry
        xc = constrain(xc, ("batch", "seq", None))
        h = rms_norm(xc, pl["ln_attn"], cfg.norm_eps)
        attn, _ = gqa_attention(pl["attn"], h, positions, cfg, causal=False)
        xc = xc + attn
        h = rms_norm(xc, pl["ln_mlp"], cfg.norm_eps)
        return xc + mlp(pl["mlp"], h), None

    body = remat_wrap(body, cfg.remat_policy)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block_apply(cfg, pl, x, positions, enc_out, cache, enc_valid=None):
    h = rms_norm(x, pl["ln_self"], cfg.norm_eps)
    attn, new_cache = gqa_attention(pl["self_attn"], h, positions, cfg, cache=cache)
    x = x + attn
    h = rms_norm(x, pl["ln_cross"], cfg.norm_eps)
    cross, _ = gqa_attention(
        pl["cross_attn"],
        h,
        positions,
        cfg,
        kv_x=enc_out,
        causal=False,
        use_rope=False,
        kv_valid=enc_valid,
    )
    x = x + cross
    h = rms_norm(x, pl["ln_mlp"], cfg.norm_eps)
    return x + mlp(pl["mlp"], h), new_cache


def decode_stack(
    cfg: ModelConfig,
    params,
    tokens,
    enc_out,
    caches=None,
    positions=None,
    head_mode="full",
    enc_len=None,
):
    dt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(cfg, params, tokens, dt)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    enc_valid = None
    if enc_len is not None:
        enc_valid = jnp.arange(enc_out.shape[1]) < enc_len

    def body(carry, inp):
        xc = constrain(carry, ("batch", "seq", None))
        pl, cache_l = inp
        xo, nc = _dec_block_apply(cfg, pl, xc, positions, enc_out, cache_l, enc_valid)
        return xo, nc

    body = remat_wrap(body, cfg.remat_policy)
    scan_caches = caches["layers"] if caches else None
    x, new_scan = jax.lax.scan(body, x, (params["dec_layers"], scan_caches))
    new_caches = {"layers": new_scan} if caches is not None else None
    return _logits(cfg, params, x, head_mode), new_caches


def encdec_loss(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    hidden, _ = decode_stack(cfg, params, batch["tokens"], enc_out, head_mode="none")
    loss = chunked_softmax_xent(
        hidden, _head(cfg, params), batch["labels"], logit_scale=cfg.logit_scale,
        n_vocab=cfg.vocab_size,
    )
    return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}


def encdec_cache_abstract(cfg: ModelConfig, batch: int, max_len: int):
    per_layer = gqa_cache_spec(cfg, batch, max_len)
    return {
        "layers": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.dec_layers,) + s.shape, s.dtype),
            per_layer,
        )
    }
