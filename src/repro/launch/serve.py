"""Production serving driver: batched prefill + decode with the sharded
KV cache layout of the decode_32k / long_500k cells.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --reduced \
        --batch 4 --prompt-len 32 --gen-len 32
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from ..configs import SHAPES, get_config, reduced as reduce_cfg
from ..models import build_model
from .mesh import describe, make_elastic_mesh, make_mesh
from .steps import build_decode_step, build_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="elastic")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    if args.mesh == "elastic":
        mesh = make_elastic_mesh()
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    print(f"serving {args.arch} on {describe(mesh)}")

    max_len = args.prompt_len + args.gen_len
    shape = dataclasses.replace(
        SHAPES["decode_32k"], seq_len=max_len, global_batch=args.batch
    )
    pre_shape = dataclasses.replace(
        SHAPES["prefill_32k"], seq_len=args.prompt_len, global_batch=args.batch
    )
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(args.batch, max_len)
        prefill = jax.jit(model.prefill)
        decode = jax.jit(model.decode_step)

        t0 = time.time()
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch = {
                "frames": rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32),
                "tokens": prompts[:, :1],
            }
        logits, cache = prefill(params, batch, cache)
        jax.block_until_ready(logits)
        t_pre = time.time() - t0

        tok = np.asarray(jnp_argmax(logits, cfg.vocab_size))
        t0 = time.time()
        steps = 0
        for i in range(args.gen_len - 1):
            pos = np.full((args.batch, 1), args.prompt_len + i, np.int32)
            logits, cache = decode(params, tok[:, None], cache, pos)
            tok = np.asarray(jnp_argmax(logits, cfg.vocab_size))
            steps += 1
        jax.block_until_ready(logits)
        dt = time.time() - t0
    print(f"prefill: {t_pre * 1e3:.1f} ms for {args.prompt_len} x {args.batch} tokens")
    print(f"decode : {dt / max(steps,1) * 1e3:.2f} ms/step (batch {args.batch})")


def jnp_argmax(logits, vocab):
    import jax.numpy as jnp

    return jnp.argmax(logits[:, -1, :vocab], axis=-1).astype(jnp.int32)


if __name__ == "__main__":
    main()
