"""Production serving driver: batched prefill + decode with the sharded
KV cache layout of the decode_32k / long_500k cells.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --reduced \
        --batch 4 --prompt-len 32 --gen-len 32

``--private-head`` keeps the transformer trunk local but routes every
decode step's lm-head matmul (``hidden @ W_head``) through the CMPC
serving engine: the head matrix stays the layer owner's private
operand, each step's hidden states are a request against it, and the
reported latencies are the engine's simulated protocol time.  Decoder
families only (dense / moe / vlm), and practical with ``--reduced``.
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from ..configs import SHAPES, get_config, reduced as reduce_cfg
from ..models import build_model
from .mesh import describe, make_elastic_mesh, make_mesh
from .steps import build_decode_step, build_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="elastic")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument(
        "--private-head", action="store_true",
        help="run each decode step's lm-head matmul under CMPC via the "
        "serving engine (decoder families only)",
    )
    ap.add_argument(
        "--workers", type=int, default=16,
        help="simulated edge pool size for --private-head",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    if args.mesh == "elastic":
        mesh = make_elastic_mesh()
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    print(f"serving {args.arch} on {describe(mesh)}")

    max_len = args.prompt_len + args.gen_len
    shape = dataclasses.replace(
        SHAPES["decode_32k"], seq_len=max_len, global_batch=args.batch
    )
    pre_shape = dataclasses.replace(
        SHAPES["prefill_32k"], seq_len=args.prompt_len, global_batch=args.batch
    )
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(args.batch, max_len)
        prefill = jax.jit(model.prefill)
        decode = jax.jit(model.decode_step)

        t0 = time.time()
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch = {
                "frames": rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32),
                "tokens": prompts[:, :1],
            }
        logits, cache = prefill(params, batch, cache)
        jax.block_until_ready(logits)
        t_pre = time.time() - t0

        tok = np.asarray(jnp_argmax(logits, cfg.vocab_size))
        t0 = time.time()
        if args.private_head:
            steps, report, worst = _decode_private_head(
                args, cfg, model, params, cache, tok
            )
        else:
            steps = 0
            for i in range(args.gen_len - 1):
                pos = np.full((args.batch, 1), args.prompt_len + i, np.int32)
                logits, cache = decode(params, tok[:, None], cache, pos)
                tok = np.asarray(jnp_argmax(logits, cfg.vocab_size))
                steps += 1
            jax.block_until_ready(logits)
        dt = time.time() - t0
    print(f"prefill: {t_pre * 1e3:.1f} ms for {args.prompt_len} x {args.batch} tokens")
    print(f"decode : {dt / max(steps,1) * 1e3:.2f} ms/step (batch {args.batch})")
    if args.private_head:
        s = report.summary()
        print(
            f"private head: {s['replays']} protocol replays over {steps} steps "
            f"on {args.workers} workers, sim latency p50 {s['p50_latency']:.3f}s "
            f"p95 {s['p95_latency']:.3f}s, max |logit err| {worst:.3e}"
        )


def _decode_private_head(args, cfg, model, params, cache, tok):
    """Greedy decode with every step's lm-head matmul served by the
    CMPC engine.  Rows / head columns / the contraction dim are
    zero-padded up to the construction's divisibility (s | k, t | rows,
    t | out); zero padding contributes zero in the field, so the sliced
    logits are the exact fixed-point head product."""
    from ..core.constructions import PlanConfig
    from ..runtime.pool import ShiftedExponential, sample_trace
    from ..serve import ServingEngine

    if model.hidden_step is None or model.head_matrix is None:
        raise SystemExit(
            "--private-head needs a decoder family with a split lm head; "
            f"family {cfg.family!r} does not expose one"
        )
    step = jax.jit(model.hidden_step)
    w = np.asarray(model.head_matrix(params), np.float64)  # [d_model, vocab]
    plan_cfg = PlanConfig()
    k, vocab = w.shape
    pad_k = (-k) % plan_cfg.s
    pad_out = (-vocab) % plan_cfg.t
    pad_rows = (-args.batch) % plan_cfg.t
    traces = [
        sample_trace(
            args.workers, ShiftedExponential(0.1, 0.5), seed=s, net_scale=0.3
        )
        for s in range(4)
    ]
    engine = ServingEngine(
        np.pad(w, ((0, pad_k), (0, pad_out))), traces, plan_cfg, seed=0
    )
    arrival, worst, steps = 0.0, 0.0, 0
    for i in range(args.gen_len - 1):
        pos = np.full((args.batch, 1), args.prompt_len + i, np.int32)
        hidden, cache = step(params, tok[:, None], cache, pos)
        x = np.asarray(hidden[:, -1, :], np.float64)
        # The next head matmul cannot be requested before the previous
        # token is known: arrivals chain on completions.
        req = engine.submit(np.pad(x, ((0, pad_rows), (0, pad_k))), arrival)
        engine.run()
        if req.y is None:
            raise SystemExit(
                f"step {i}: request shed ({req.shed_reason}); a pool of "
                f"{args.workers} workers cannot serve the head — raise --workers"
            )
        logits = req.y[: args.batch, :vocab]
        worst = max(worst, float(np.abs(logits - x @ w).max()))
        tok = logits.argmax(-1).astype(np.int32)
        arrival = req.completion
        steps += 1
    return steps, engine.report(), worst


def jnp_argmax(logits, vocab):
    import jax.numpy as jnp

    return jnp.argmax(logits[:, -1, :vocab], axis=-1).astype(jnp.int32)


if __name__ == "__main__":
    main()
