import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x applicable shape x mesh) cell this lowers and
compiles the real step function (train_step / prefill_step /
serve_step) against ShapeDtypeStruct stand-ins on the production mesh —
no allocation — and records:

* ``memory_analysis``      (per-device bytes: proves it fits HBM)
* ``cost_analysis``        (HLO FLOPs / bytes for the roofline)
* collective bytes by kind (parsed from optimized HLO; cost_analysis
  does not expose them)

Results land as one JSON per cell under ``--out`` so the sweep is
resumable after a crash — the harness skips cells whose JSON exists.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback
from collections import defaultdict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config, registry as cfg_registry, shape_applicable
from ..models.registry import build_model
from .hlo_cost import analyze as hlo_analyze
from .mesh import make_production_mesh
from .steps import build_step

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Sum result-shape bytes of every collective op in optimized HLO.

    ``-done`` halves of async pairs are skipped so each collective is
    counted once.  Result bytes approximate per-participant wire bytes
    (all-reduce is ring-counted 2x by the roofline module).
    """
    totals: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[1][:120]:
            continue
        kind = m.group(1)
        rhs = line.split("=", 1)[1]
        prefix = rhs.split(kind)[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(prefix):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[kind] += nbytes
        counts[kind] += 1
    return dict(totals), dict(counts)


def _mem_dict(mem) -> Dict[str, int]:
    out = {}
    for field in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        try:
            out[field] = int(getattr(mem, field))
        except Exception:
            pass
    return out


def _cost_dict(cost) -> Dict[str, float]:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
        try:
            v = cost[k] if not hasattr(cost, "get") else cost.get(k)
            if v is not None:
                out[k.replace(" ", "_")] = float(v)
        except Exception:
            pass
    return out


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    verbose: bool = True,
    hlo_path: str = None,
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not shape_applicable(cfg, shape):
        record["status"] = "skipped"
        record["reason"] = (
            "long_500k needs sub-quadratic attention"
            if shape_name == "long_500k"
            else "no decode path"
        )
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    model = build_model(cfg)
    bundle = build_step(model, mesh, shape)
    if shape.kind != "train":
        cache_abs = model.cache_abstract(shape.global_batch, shape.seq_len)
        record["cache_bytes"] = int(
            sum(
                int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                for s in jax.tree.leaves(cache_abs)
            )
        )

    t0 = time.time()
    with mesh:
        lowered = bundle.lower()
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind}] memory_analysis:", mem)
        print(f"[{arch} x {shape_name} x {mesh_kind}] cost_analysis:",
              {k: v for k, v in _cost_dict(cost).items()})
    hlo = compiled.as_text()
    if hlo_path:
        import gzip

        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    coll, coll_counts = collective_bytes(hlo)
    # loop-aware walker: multiplies scan/while bodies by trip counts
    # (XLA's cost_analysis counts them once)
    walk = hlo_analyze(hlo)

    record.update(
        status="ok",
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        n_devices=int(mesh.devices.size),
        memory=_mem_dict(mem),
        cost=_cost_dict(cost),
        walker={
            "flops": walk.flops,
            "bytes": walk.bytes,
            "transcendentals": walk.transcendentals,
            "collective_bytes": walk.collectives,
            "collective_counts": walk.collective_counts,
        },
        collective_bytes=coll,
        collective_counts=coll_counts,
        hlo_bytes=len(hlo),
    )
    return record


def cells(arch_sel: str, shape_sel: str, mesh_sel: str):
    archs = cfg_registry.ARCH_NAMES if arch_sel == "all" else tuple(arch_sel.split(","))
    shapes = tuple(SHAPES) if shape_sel == "all" else tuple(shape_sel.split(","))
    meshes = ("single", "multi") if mesh_sel == "both" else (mesh_sel,)
    for a in archs:
        for s in shapes:
            for m in meshes:
                yield a, s, m


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true",
                    help="store gzipped optimized HLO next to each record")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape_name, mesh_kind in cells(args.arch, args.shape, args.mesh):
        path = os.path.join(args.out, f"{arch}__{shape_name}__{mesh_kind}.json")
        if os.path.exists(path) and not args.force:
            print(f"skip (exists): {path}")
            continue
        print(f"=== dry-run {arch} x {shape_name} x {mesh_kind} ===", flush=True)
        try:
            rec = run_cell(arch, shape_name, mesh_kind,
                           hlo_path=path[:-5] + ".hlo.gz" if args.save_hlo else None)
        except Exception as e:  # fault-tolerant sweep: record and continue
            rec = {
                "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "error", "error": repr(e),
                "traceback": traceback.format_exc()[-4000:],
            }
            failures += 1
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"-> {rec.get('status')} ({path})", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
