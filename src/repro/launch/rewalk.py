"""Refresh the walker-derived fields of dry-run records from the saved
gzipped HLO — lets the cost model iterate without recompiling.

    PYTHONPATH=src python -m repro.launch.rewalk results/dryrun
"""
import glob
import gzip
import json
import sys

from .dryrun import collective_bytes
from .hlo_cost import analyze


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    for path in sorted(glob.glob(f"{out}/*.json")):
        hlo_path = path[:-5] + ".hlo.gz"
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        try:
            hlo = gzip.open(hlo_path, "rt").read()
        except FileNotFoundError:
            print(f"no hlo for {path}; skipping")
            continue
        walk = analyze(hlo)
        coll, counts = collective_bytes(hlo)
        rec["walker"] = {
            "flops": walk.flops,
            "bytes": walk.bytes,
            "transcendentals": walk.transcendentals,
            "collective_bytes": walk.collectives,
            "collective_counts": walk.collective_counts,
        }
        rec["collective_bytes"] = coll
        rec["collective_counts"] = counts
        json.dump(rec, open(path, "w"), indent=1)
        print(f"rewalked {path}")


if __name__ == "__main__":
    main()
