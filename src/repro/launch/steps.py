"""Jitted step builders shared by the trainer, the server, and dryrun.

Each builder returns (step_fn, abstract_inputs, in_shardings,
out_shardings) so callers can either execute on real data or
``jit(...).lower(*abstract).compile()`` for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.sharding import (
    activation_rules,
    batch_shardings,
    cache_shardings,
    data_axes,
    param_shardings,
    use_activation_rules,
)


def _with_rules(fn, rules):
    def wrapped(*args):
        with use_activation_rules(rules):
            return fn(*args)

    return wrapped
from ..models.common import abstract as abstract_params_tree
from ..models.registry import Model
from ..train.optimizer import AdamWConfig, AdamWState, adamw_update, get_schedule


def _replicated(mesh):
    return NamedSharding(mesh, P())


def abstract_opt_state(params_abs) -> AdamWState:
    f32 = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t
    )
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32(params_abs), nu=f32(params_abs)
    )


def opt_state_shardings(param_sh, mesh) -> AdamWState:
    return AdamWState(step=_replicated(mesh), mu=param_sh, nu=param_sh)


@dataclasses.dataclass
class StepBundle:
    fn: Any
    args_abstract: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jit().lower(*self.args_abstract)


# ----------------------------------------------------------------------
def build_train_step(
    model: Model,
    mesh: Mesh,
    shape: ShapeConfig,
    lr: float = 3e-4,
    schedule: str = "cosine",
    total_steps: int = 10_000,
    fsdp: bool = True,
    microbatch_seqs: int = 2,
) -> StepBundle:
    """Train step with microbatched gradient accumulation: the global
    batch is split so each data shard sees ``microbatch_seqs`` sequences
    per micro-step; activations peak at one micro-step while gradients
    accumulate in f32 (sharded like the parameters).  Communication is
    overlapped naturally: each micro-step's grads stay local, a single
    reduction happens inside the optimizer update."""
    opt_cfg = AdamWConfig(lr=get_schedule(schedule, lr, total_steps))
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    n_micro = max(1, shape.global_batch // max(1, dp * microbatch_seqs))
    while shape.global_batch % n_micro:
        n_micro -= 1

    def train_step(params, opt_state, batch):
        def split(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

        micro = jax.tree.map(split, batch)
        gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def micro_step(carry, mb):
            gsum, loss_sum, aux_sum = carry
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, mb
            )
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, loss_sum + loss, aux_sum + metrics["aux"]), None

        zero = jnp.zeros((), jnp.float32)
        (gsum, loss_sum, aux_sum), _ = jax.lax.scan(
            micro_step, (gzero, zero, zero), micro
        )
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        loss = loss_sum / n_micro
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {
            "loss": loss, "xent": loss, "aux": aux_sum / n_micro, **om
        }

    params_abs = abstract_params_tree(model.abstract_params())
    opt_abs = abstract_opt_state(params_abs)
    batch_abs = model.batch_spec(shape)

    p_sh = param_shardings(model.abstract_params(), mesh, fsdp)
    o_sh = opt_state_shardings(p_sh, mesh)
    b_sh = batch_shardings(batch_abs, mesh)
    rep = _replicated(mesh)
    metric_names = ("loss", "xent", "aux", "grad_norm", "lr")
    out_sh = (p_sh, o_sh, {k: rep for k in metric_names})
    return StepBundle(
        fn=_with_rules(train_step, activation_rules(mesh)),
        args_abstract=(params_abs, opt_abs, batch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=out_sh,
        donate_argnums=(0, 1),
    )


# ----------------------------------------------------------------------
def build_decode_step(
    model: Model, mesh: Mesh, shape: ShapeConfig, fsdp: bool = True
) -> StepBundle:
    """One-token serve step with a KV/state cache of shape.seq_len."""
    cfg = model.cfg
    b = shape.global_batch
    long_ctx = b < mesh.shape.get("data", 1)

    def serve_step(params, caches, tokens, positions):
        logits, new_caches = model.decode_step(params, tokens, caches, positions)
        return logits, new_caches

    params_abs = abstract_params_tree(model.abstract_params())
    cache_abs = model.cache_abstract(b, shape.seq_len)
    tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    p_sh = param_shardings(model.abstract_params(), mesh, fsdp)
    c_sh = cache_shardings(cfg, cache_abs, mesh, long_context=long_ctx)
    da = data_axes(mesh)
    b_ax = da if len(da) > 1 else (da[0] if da else None)
    tok_sh = NamedSharding(mesh, P(None if long_ctx else b_ax, None))
    logits_sh = NamedSharding(mesh, P(None if long_ctx else b_ax, None, "model"))
    return StepBundle(
        fn=_with_rules(serve_step, activation_rules(mesh, long_context=long_ctx)),
        args_abstract=(params_abs, cache_abs, tok_abs, pos_abs),
        in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
        out_shardings=(logits_sh, c_sh),
        donate_argnums=(1,),
    )


# ----------------------------------------------------------------------
def build_prefill_step(
    model: Model, mesh: Mesh, shape: ShapeConfig, fsdp: bool = True
) -> StepBundle:
    cfg = model.cfg
    b = shape.global_batch

    def prefill_step(params, batch, caches):
        return model.prefill(params, batch, caches)

    params_abs = abstract_params_tree(model.abstract_params())
    batch_abs = model.batch_spec(shape)
    cache_abs = model.cache_abstract(b, shape.seq_len)

    p_sh = param_shardings(model.abstract_params(), mesh, fsdp)
    b_sh = batch_shardings(batch_abs, mesh)
    c_sh = cache_shardings(cfg, cache_abs, mesh, long_context=False)
    da = data_axes(mesh)
    b_ax = da if len(da) > 1 else (da[0] if da else None)
    logits_sh = NamedSharding(mesh, P(b_ax, None, "model"))
    return StepBundle(
        fn=_with_rules(prefill_step, activation_rules(mesh)),
        args_abstract=(params_abs, batch_abs, cache_abs),
        in_shardings=(p_sh, b_sh, c_sh),
        out_shardings=(logits_sh, c_sh),
        donate_argnums=(2,),
    )


def build_step(model: Model, mesh: Mesh, shape: ShapeConfig, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(model, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(model, mesh, shape)
    if shape.kind == "decode":
        return build_decode_step(model, mesh, shape)
    raise KeyError(shape.kind)
