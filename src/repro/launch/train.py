"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 100 --mesh 1x1 --reduced --ckpt-dir results/run0

Features: elastic mesh construction, sharded train step (FSDP + TP +
microbatched grad accumulation), WSD/cosine schedules, atomic
checkpointing with auto-resume, deterministic restartable data, int8
gradient compression across the pod axis (--compress-grads, multi-pod).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import SHAPES, get_config, reduced as reduce_cfg
from ..data.pipeline import DataConfig, SyntheticLM
from ..models import build_model
from ..train.optimizer import AdamWState, adamw_init
from .mesh import describe, make_elastic_mesh, make_mesh
from .steps import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", default="elastic", help="'elastic' or DxM like 4x2")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default=None, help="cosine|wsd (arch default)")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--microbatch-seqs", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    schedule = args.schedule or ("wsd" if args.arch == "minicpm-2b" else "cosine")
    model = build_model(cfg)

    if args.mesh == "elastic":
        mesh = make_elastic_mesh()
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    print(f"training {args.arch} on {describe(mesh)}; schedule={schedule}")

    shape = dataclasses.replace(
        SHAPES["train_4k"], seq_len=args.seq_len, global_batch=args.global_batch
    )
    bundle = build_train_step(
        model, mesh, shape, lr=args.lr, schedule=schedule,
        total_steps=args.steps, microbatch_seqs=args.microbatch_seqs,
    )
    with mesh:
        step_fn = bundle.jit()
        params = model.init(jax.random.PRNGKey(0))
        from ..train.optimizer import AdamWConfig, get_schedule

        opt = adamw_init(params, AdamWConfig(lr=get_schedule(schedule, args.lr, args.steps)))

        start = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep=3)
            if mgr.latest_step() is not None:
                start, state = mgr.restore({"params": params, "opt": opt._asdict()})
                params, opt = state["params"], AdamWState(**state["opt"])
                print(f"auto-resumed from step {start}")

        data = SyntheticLM(
            DataConfig(cfg.vocab_size, args.seq_len, args.global_batch),
            process_index=jax.process_index(),
            process_count=jax.process_count(),
        )
        t0 = time.time()
        tokens_per_step = args.seq_len * args.global_batch
        for i in range(start, args.steps):
            params, opt, metrics = step_fn(params, opt, data.batch(i))
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                done = i - start + 1
                print(
                    f"step {i:5d} loss {float(metrics['loss']):.4f} "
                    f"lr {float(metrics['lr']):.2e} "
                    f"gnorm {float(metrics['grad_norm']):.2f} "
                    f"{tokens_per_step * done / max(dt, 1e-9):,.0f} tok/s"
                )
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, {"params": params, "opt": opt._asdict()})
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt._asdict()})
    print("done")


if __name__ == "__main__":
    main()
