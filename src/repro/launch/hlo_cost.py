"""Exact-ish HLO cost walker with while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE,
which silently undercounts everything inside ``lax.scan`` — and this
framework deliberately scans over layers / attention blocks /
micro-batches.  This walker parses the optimized HLO text, computes

* FLOPs            (2*M*N*K per dot, batch-aware),
* traffic bytes    (operand+result bytes at fusion/dot/collective/copy
                    boundaries — an HBM-traffic model),
* collective bytes (result bytes by collective kind),

per computation and multiplies through ``while`` trip counts (read from
the loop-condition constant) and call/fusion edges.  Validated against
cost_analysis on loop-free programs and against N x single-iteration
programs for loops (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s([a-z][a-z0-9\-]*)\((.*)$"
)
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?([%\w.,\- ]+)\}?"
)
# Ops that move HBM data at computation top level.  Layout/view ops
# (reshape, transpose, broadcast, iota, pad, slice) are free-or-fused on
# TPU and excluded from the traffic model.
_TRAFFIC_OPS = frozenset(
    {
        "fusion", "dot", "convolution", "copy",
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute", "dynamic-slice", "dynamic-update-slice",
        "gather", "scatter", "reduce", "sort", "concatenate",
        "select-and-scatter", "custom-call",
    }
)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        return n
    return 0


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    args: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        current = None
        for line in text.splitlines():
            stripped = line.strip()
            header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
            if header and "=" not in stripped.split("(")[0]:
                current = header.group(2)
                self.computations[current] = []
                if header.group(1):
                    self.entry = current
                continue
            if stripped.startswith("}"):
                continue
            m = _OP_RE.match(line)
            if m and current is not None:
                name, type_str, opcode, args = m.groups()
                self.computations[current].append(Op(name, type_str, opcode, args))

    # ------------------------------------------------------------------
    def _symbols(self, comp: str) -> Dict[str, str]:
        return {op.name: op.type_str for op in self.computations.get(comp, [])}

    def _trip_count(self, cond_comp: str) -> int:
        best = 1
        for op in self.computations.get(cond_comp, []):
            if op.opcode == "constant":
                cm = re.search(r"constant\((-?\d+)\)", "constant(" + op.args)
                if cm:
                    best = max(best, int(cm.group(1)))
        return best

    def _dot_flops(self, op: Op, symbols: Dict[str, str]) -> float:
        out_elems = _shape_elems(op.type_str)
        kdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.args)
        operands = re.findall(r"%?([\w.\-]+)", op.args.split(")")[0])
        lhs_shape = None
        for o in operands:
            if o in symbols:
                lhs_shape = symbols[o]
                break
        if not (kdims and lhs_shape):
            return 2.0 * out_elems  # conservative fallback
        dims_m = _SHAPE_RE.search(lhs_shape)
        if not dims_m:
            return 2.0 * out_elems
        lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
        k = 1
        for idx in (int(i) for i in kdims.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
        return 2.0 * out_elems * k

    # ------------------------------------------------------------------
    def cost_of(self, comp: str, in_fusion: bool = False) -> Cost:
        """``in_fusion``: inside a fused computation the intermediates
        live in registers/VMEM — count FLOPs but not HBM traffic."""
        key = (comp, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        self._memo[key] = total  # guards cycles
        symbols = self._symbols(comp)
        for op in self.computations.get(comp, []):
            called = []
            for cm in _CALLED_RE.finditer(op.args):
                for ref in cm.group(1).split(","):
                    ref = ref.strip().lstrip("%")
                    if ref in self.computations:
                        called.append((cm.group(0).split("=")[0], ref))

            if op.opcode == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.args)
                cm2 = re.search(r"condition=%?([\w.\-]+)", op.args)
                if bm:
                    body = bm.group(1)
                if cm2:
                    cond = cm2.group(1)
                trips = self._trip_count(cond) if cond else 1
                if body in self.computations:
                    total.add(self.cost_of(body, in_fusion), mult=trips)
                continue

            if op.opcode == "conditional":
                branch_costs = [self.cost_of(c, in_fusion) for _, c in called]
                if branch_costs:
                    worst = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    total.add(worst)
                continue

            for _, c in called:
                total.add(self.cost_of(c, in_fusion or op.opcode == "fusion"))

            if op.opcode == "dot":
                total.flops += self._dot_flops(op, symbols)
            elif op.opcode in ("exponential", "tanh", "log", "power", "rsqrt",
                               "logistic", "sqrt", "sine", "cosine"):
                total.transcendentals += _shape_elems(op.type_str)

            if op.opcode in _TRAFFIC_OPS and not in_fusion:
                arg_list = op.args.split("), ")[0]
                operand_names = [
                    o for o in re.findall(r"%([\w.\-]+)", arg_list) if o in symbols
                ]
                if op.opcode == "fusion" and re.search(
                    r"calls=%?wrapped_(broadcast|iota|concatenate)?_?computation", op.args
                ) and re.search(r"calls=%?wrapped_(broadcast|iota)", op.args):
                    # XLA:CPU materialises broadcast/iota as standalone
                    # kLoop fusions; on TPU these fuse into consumers
                    # (zero HBM traffic) — skip.
                    pass
                elif op.opcode in ("dynamic-slice", "gather"):
                    # reads only the slice it produces
                    total.bytes += 2 * _shape_bytes(op.type_str)
                elif op.opcode in ("dynamic-update-slice", "scatter"):
                    # writes only the update region (aliased buffer)
                    upd_idx = 1 if op.opcode == "dynamic-update-slice" else 2
                    if len(operand_names) > upd_idx:
                        total.bytes += 2 * _shape_bytes(symbols[operand_names[upd_idx]])
                    else:
                        total.bytes += 2 * _shape_bytes(op.type_str)
                else:
                    # pred-dtype tensors are mask artifacts (recomputed
                    # on the fly inside TPU kernels): exclude.
                    res_b = _shape_bytes(op.type_str)
                    has_idx = any(
                        re.fullmatch(r"s32\[\]\S*", symbols[o].strip())
                        or symbols[o].strip().startswith("s32[]")
                        for o in operand_names
                    )
                    op_bytes = []
                    for o in operand_names:
                        ts = symbols[o]
                        if ts.lstrip("(").startswith("pred"):
                            continue
                        ob = _shape_bytes(ts)
                        # fused dynamic-slice: a fusion carrying a scalar
                        # s32 index + an operand >> its result reads only
                        # one slice of that operand per call.
                        if op.opcode == "fusion" and has_idx and ob > 8 * max(res_b, 1):
                            ob = res_b
                        op_bytes.append(ob)
                    b = 0 if op.type_str.lstrip("(").startswith("pred") else res_b
                    # fused dynamic-update-slice: result is the whole
                    # aliased buffer but only the update slice is written.
                    if (
                        op.opcode == "fusion"
                        and has_idx
                        and op_bytes
                        and res_b > 8 * max(op_bytes)
                    ):
                        b = 2 * max(op_bytes)
                        total.bytes += b
                    else:
                        total.bytes += b + sum(op_bytes)

            if op.opcode in _COLLECTIVES and "-done" not in op.opcode:
                b = _shape_bytes(op.type_str)
                total.collectives[op.opcode] = total.collectives.get(op.opcode, 0.0) + b
                total.collective_counts[op.opcode] = (
                    total.collective_counts.get(op.opcode, 0.0) + 1
                )
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            # fall back: largest computation
            self.entry = max(self.computations, key=lambda c: len(self.computations[c]))
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
