"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the
``pod`` axis is outermost data parallelism over the inter-pod links.

``make_elastic_mesh`` builds the largest (data, model) grid over
whatever devices are currently alive — elastic scaling: checkpoints are
topology-agnostic (see checkpoint.manager) so a job can restart on a
shrunken fleet.
"""
from __future__ import annotations

import math
from typing import Optional

import jax

# jax.sharding.AxisType (and the axis_types= kwarg of jax.make_mesh)
# only exist on newer JAX releases; on older installs every axis is
# implicitly Auto, so the kwarg is simply dropped.
try:
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed JAX
    AxisType = None


def _axis_kw(n):
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kw(len(axes)))


def make_elastic_mesh(model_parallel: Optional[int] = None):
    """Largest (data, model) grid over the live device set."""
    n = len(jax.devices())
    if model_parallel is None:
        model_parallel = min(16, n)
        while n % model_parallel:
            model_parallel //= 2
    data = n // model_parallel
    return jax.make_mesh((data, model_parallel), ("data", "model"), **_axis_kw(2))


def describe(mesh) -> str:
    return f"mesh{dict(mesh.shape)} over {mesh.devices.size} devices"
