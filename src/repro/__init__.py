"""CMPX: coded multi-party computation (AGE-CMPC / PolyDot-CMPC) as a
first-class substrate in a multi-pod JAX training/serving framework."""
__version__ = "0.1.0"
