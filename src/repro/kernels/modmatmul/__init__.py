from .kernel import modmatmul_pallas  # noqa: F401
from .ops import mod_matmul, polyeval  # noqa: F401
from .ref import modmatmul_jnp_ref, modmatmul_ref  # noqa: F401
