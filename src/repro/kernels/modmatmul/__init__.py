from .kernel import (  # noqa: F401
    modmatmul_int32_pallas,
    modmatmul_masked_pallas,
    modmatmul_pallas,
)
from .ops import (  # noqa: F401
    autotune_tiles,
    mod_matmul,
    mod_matmul_crt,
    mod_matmul_masked,
    pick_tiles,
    polyeval,
    polyeval_masked,
    register_tile_chooser,
)
from .ref import modmatmul_jnp_ref, modmatmul_ref  # noqa: F401
