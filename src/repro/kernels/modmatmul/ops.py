"""Jitted public wrapper around the GF(p) matmul kernel.

Handles padding to tile multiples, batching, tile selection, and
backend dispatch:

* ``"pallas"``    — the Pallas TPU kernel (compiled on TPU, interpret
                     mode elsewhere; interpret executes the kernel body
                     in Python for correctness validation on CPU).
                     Batched operands lower to ONE ``pallas_call`` with
                     the batch on the leading grid axis — no
                     vmap-of-padded-2D launches — and an unbatched
                     operand is shared across the batch axis by its
                     index map instead of being broadcast.
* ``"f32limb"``   — portable jnp path with identical limb math (native
                     ``dot_general`` batching, see ``core.gf``),
* ``"auto"``      — pallas on TPU backends, f32limb otherwise.

Tile sizes adapt to the operand shape (``pick_tiles``) unless pinned
explicitly; at the protocol's small per-worker blocks the fixed
128x128x256 tiling of earlier revisions spent most of the MXU work on
padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.gf import P_DEFAULT, mod_matmul_f32
from ...obs.metrics import REGISTRY
from ...obs.tracer import TRACER
from .kernel import modmatmul_pallas


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pick_tiles(m: int, k: int, n: int) -> tuple:
    """Choose (bm, bn, bk) from the actual operand shape.

    Alignment floors come from the TPU layout: sublane (second-to-minor)
    tiles are multiples of 8, lane (minor) tiles multiples of 128.
    Small dims get a single right-sized tile instead of padding up to
    the historical 128/128/256; ``bk <= LAZY_K`` (k <= 128) additionally
    enables the kernel's lazy-reduction path.  Caps keep the worst-case
    VMEM block footprint (a + b + out) around 1 MiB.
    """
    bm = _round_up(m, 8) if m <= 256 else 128
    bn = _round_up(n, 128) if n <= 512 else 128
    bk = 128 if k <= 128 else 256
    return bm, bn, bk


def padded_shape(m: int, k: int, n: int, tiles: tuple) -> tuple:
    """(M, K, N) after padding each dim up to its tile multiple."""
    bm, bn, bk = tiles
    return _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)


def padding_waste(m: int, k: int, n: int, tiles: tuple) -> float:
    """Fraction of MXU MACs spent on padding for one [M,K]@[K,N] product."""
    mp, kp, np_ = padded_shape(m, k, n, tiles)
    return 1.0 - (m * k * n) / float(mp * kp * np_)


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[-2]) % mult0
    p1 = (-x.shape[-1]) % mult1
    if p0 or p1:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, p0), (0, p1)]
        x = jnp.pad(x, pad)
    return x


def _flatten_batch(x: jnp.ndarray, batch: tuple) -> jnp.ndarray:
    """Collapse leading batch dims to one axis; an operand whose batch
    dims are absent or all 1 stays 2D (shared across the kernel's batch
    grid axis — never materialized per element)."""
    nbatch = 1
    for d in x.shape[:-2]:
        nbatch *= d
    if nbatch == 1:
        return x.reshape(x.shape[-2:])
    if x.shape[:-2] != batch:
        x = jnp.broadcast_to(x, batch + x.shape[-2:])
    return x.reshape((-1,) + x.shape[-2:])


@functools.partial(
    jax.jit, static_argnames=("p", "backend", "bm", "bn", "bk", "interpret")
)
def mod_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    p: int = P_DEFAULT,
    backend: str = "auto",
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """a [..., M, K] @ b [..., K, N] mod p (int32), batched over leading dims.

    Batch dims of ``a`` and ``b`` must broadcast against each other; one
    side may omit them entirely (e.g. a 2D constant matrix against a
    batched operand) — the unbatched side is contracted in place, never
    broadcast.  Tile sizes default to ``pick_tiles`` of the actual shape.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "f32limb"

    # This body runs at trace time (the wrapper is jitted), so each
    # event records one *compilation*'s backend + tile choice — the
    # shape/backend signature, not a per-call sample.
    if backend == "f32limb":
        REGISTRY.counter("kernels.modmatmul_lowerings").inc()
        if TRACER.enabled:
            TRACER.event(
                "modmatmul.lower", backend="f32limb",
                m=int(a.shape[-2]), k=int(a.shape[-1]), n=int(b.shape[-1]),
            )
        return mod_matmul_f32(a, b, p)

    if backend != "pallas":
        raise ValueError(f"unknown backend {backend}")

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    m, k = a.shape[-2:]
    n = b.shape[-1]
    tm, tn, tk = pick_tiles(m, k, n)
    bm = bm or tm
    bn = bn or tn
    bk = bk or tk
    REGISTRY.counter("kernels.modmatmul_lowerings").inc()
    if TRACER.enabled:
        TRACER.event(
            "modmatmul.lower", backend="pallas",
            m=int(m), k=int(k), n=int(n),
            bm=int(bm), bn=int(bn), bk=int(bk), interpret=bool(interpret),
        )
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)

    call = functools.partial(
        modmatmul_pallas, p=p, bm=bm, bn=bn, bk=bk, interpret=interpret
    )
    if a.ndim == 2 and b.ndim == 2:
        out = call(ap, bp)
    else:
        batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        out = call(_flatten_batch(ap, batch), _flatten_batch(bp, batch))
        out = out.reshape(batch + (ap.shape[-2], bp.shape[-1]))
    return out[..., :m, :n]


def polyeval(
    vander: jnp.ndarray, coeffs: jnp.ndarray, p: int = P_DEFAULT, **kw
) -> jnp.ndarray:
    """Evaluate matrix-coefficient polynomials at many points.

    vander: [N, K] powers matrix (alpha_n ** power_k mod p)
    coeffs: [..., K, R, C] stacked matrix coefficients (leading batch
            dims allowed: the same points evaluate every batch element)
    returns [..., N, R, C]: F(alpha_n) = sum_k vander[n, k] * coeffs[k].
    """
    *batch, k, r, c = coeffs.shape
    flat = mod_matmul(vander, coeffs.reshape(tuple(batch) + (k, r * c)), p=p, **kw)
    return flat.reshape(tuple(batch) + (vander.shape[0], r, c))
