"""Jitted public wrapper around the GF(p) matmul kernel.

Handles padding to tile multiples, batching (vmap over leading dims),
and backend selection:

* ``"pallas"``    — the Pallas TPU kernel (compiled on TPU, interpret
                     mode elsewhere; interpret executes the kernel body
                     in Python for correctness validation on CPU),
* ``"f32limb"``   — portable jnp path with identical limb math,
* ``"auto"``      — pallas on TPU backends, f32limb otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.gf import P_DEFAULT, mod_matmul_f32
from .kernel import modmatmul_pallas


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[-2]) % mult0
    p1 = (-x.shape[-1]) % mult1
    if p0 or p1:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, p0), (0, p1)]
        x = jnp.pad(x, pad)
    return x


@functools.partial(
    jax.jit, static_argnames=("p", "backend", "bm", "bn", "bk", "interpret")
)
def mod_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    p: int = P_DEFAULT,
    backend: str = "auto",
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """a [..., M, K] @ b [..., K, N] mod p (int32), batched over leading dims.

    Batch dims of ``a`` and ``b`` must broadcast against each other; one
    side may omit them entirely (e.g. a 2D constant matrix against a
    batched operand) — the unbatched side is broadcast before vmapping.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "f32limb"

    if backend == "f32limb":
        if b.ndim == 2:
            # mod_matmul_f32 natively supports [..., M, K] @ [K, N].
            return mod_matmul_f32(a, b, p)
        # batched rhs: broadcast the unbatched side, vmap the portable path
        batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        af = jnp.broadcast_to(a, batch + a.shape[-2:]).reshape((-1,) + a.shape[-2:])
        bf = jnp.broadcast_to(b, batch + b.shape[-2:]).reshape((-1,) + b.shape[-2:])
        out = jax.vmap(lambda x, y: mod_matmul_f32(x, y, p))(af, bf)
        return out.reshape(batch + out.shape[-2:])

    if backend != "pallas":
        raise ValueError(f"unknown backend {backend}")

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    m, k = a.shape[-2:]
    n = b.shape[-1]
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)

    call = functools.partial(
        modmatmul_pallas, p=p, bm=bm, bn=bn, bk=bk, interpret=interpret
    )
    if a.ndim == 2 and b.ndim == 2:
        out = call(ap, bp)
    else:
        batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        af = jnp.broadcast_to(ap, batch + ap.shape[-2:]).reshape((-1,) + ap.shape[-2:])
        bf = jnp.broadcast_to(bp, batch + bp.shape[-2:]).reshape((-1,) + bp.shape[-2:])
        out = jax.vmap(call)(af, bf).reshape(batch + (ap.shape[-2], bp.shape[-1]))
    return out[..., :m, :n]


def polyeval(
    vander: jnp.ndarray, coeffs: jnp.ndarray, p: int = P_DEFAULT, **kw
) -> jnp.ndarray:
    """Evaluate matrix-coefficient polynomials at many points.

    vander: [N, K] powers matrix (alpha_n ** power_k mod p)
    coeffs: [..., K, R, C] stacked matrix coefficients (leading batch
            dims allowed: the same points evaluate every batch element)
    returns [..., N, R, C]: F(alpha_n) = sum_k vander[n, k] * coeffs[k].
    """
    *batch, k, r, c = coeffs.shape
    flat = mod_matmul(vander, coeffs.reshape(tuple(batch) + (k, r * c)), p=p, **kw)
    return flat.reshape(tuple(batch) + (vander.shape[0], r, c))
