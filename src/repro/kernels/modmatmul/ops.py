"""Jitted public wrapper around the GF(p) matmul kernels.

Handles padding to tile multiples, batching, tile selection, and
backend dispatch:

* ``"pallas"``       — the Pallas f32-limb kernel (compiled on TPU,
                        interpret mode elsewhere; interpret executes the
                        kernel body in Python for correctness validation
                        on CPU).  Batched operands lower to ONE
                        ``pallas_call`` with the batch on the leading
                        grid axis — no vmap-of-padded-2D launches — and
                        an unbatched operand is shared across the batch
                        axis by its index map instead of being broadcast.
* ``"pallas_int32"`` — the native-integer Pallas kernel: int32 limb
                        dots + in-tile uint32 Barrett reduction, so one
                        tile covers contraction depths the f32 kernel
                        must chunk at 256 (targets integer-capable
                        accelerator generations; validated everywhere
                        via interpret mode).
* ``"f32limb"``      — portable jnp path with the f32 limb math (native
                        ``dot_general`` batching, see ``core.gf``),
* ``"int32"``        — portable native-integer tier: chunk-batched limb
                        dots feeding a uint32 accumulator with ONE
                        Barrett recombination (``core.gf
                        .mod_matmul_int32``) — the deep-K fast path on
                        CPU, where per-chunk reductions dominate
                        ``f32limb``.
* ``"auto"``         — pallas on TPU backends; elsewhere ``int32`` once
                        the contraction is deeper than one 256 chunk
                        (and within the uint32 accumulator bound),
                        ``f32limb`` otherwise.

Tile sizes adapt to the operand shape *per backend* (``pick_tiles``)
unless pinned explicitly; ``register_tile_chooser`` swaps the policy for
a backend and ``autotune_tiles`` measures candidate tilings on the live
device and pins the winner.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from ...core.gf import (
    CHUNK_K,
    INT32_ACC_K,
    P_DEFAULT,
    crt_combine,
    field_mask,
    mod_add,
    mod_matmul_f32,
    mod_matmul_int32,
)
from ...obs.metrics import REGISTRY
from ...obs.tracer import TRACER
from .kernel import (
    INT32_KERNEL_MAX_BK,
    modmatmul_masked_pallas,
    modmatmul_pallas,
)

_PALLAS_VARIANTS = {"pallas": "f32", "pallas_int32": "int32"}


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# ----------------------------------------------------------------------
# tile selection: per-backend choosers + autotune hooks
# ----------------------------------------------------------------------
def _pick_tiles_f32(m: int, k: int, n: int) -> tuple:
    """Default tiles for the f32-limb kernel.

    Alignment floors come from the TPU layout: sublane (second-to-minor)
    tiles are multiples of 8, lane (minor) tiles multiples of 128.
    Small dims get a single right-sized tile instead of padding up to
    the historical 128/128/256; ``bk <= LAZY_K`` (k <= 128) additionally
    enables the kernel's lazy-reduction path.  Caps keep the worst-case
    VMEM block footprint (a + b + out) around 1 MiB.
    """
    bm = _round_up(m, 8) if m <= 256 else 128
    bn = _round_up(n, 128) if n <= 512 else 128
    bk = 128 if k <= 128 else 256
    return bm, bn, bk


def _pick_tiles_int32(m: int, k: int, n: int) -> tuple:
    """Default tiles for the native-int32 kernel: same M/N policy, but
    the K tile is freed from the 2**24 f32 ceiling — deeper bk means
    fewer Barrett recombinations per output tile.  Capped at 2048 to
    keep the int32 operand blocks inside the ~1 MiB VMEM budget."""
    bm = _round_up(m, 8) if m <= 256 else 128
    bn = _round_up(n, 128) if n <= 512 else 128
    bk = min(_round_up(k, 128), 2048)
    return bm, bn, bk


_TILE_CHOOSERS = {
    "pallas": _pick_tiles_f32,
    "pallas_int32": _pick_tiles_int32,
}

# (backend, m, k, n) -> tiles pinned by autotune_tiles / register_tile_cache
_AUTOTUNE_CACHE: dict = {}


def register_tile_chooser(backend: str, chooser) -> None:
    """Install a tile-selection policy for one pallas backend.

    ``chooser(m, k, n) -> (bm, bn, bk)``.  The hook point for
    hardware-specific tuning tables (the A100-style per-shape chooser
    pattern); ``autotune_tiles`` uses the measured route instead.
    """
    _TILE_CHOOSERS[backend] = chooser


def pick_tiles(m: int, k: int, n: int, backend: str = "pallas") -> tuple:
    """Choose (bm, bn, bk) from the operand shape, per backend.

    Exact-shape autotune pins (``autotune_tiles``) take precedence over
    the backend's registered chooser.
    """
    pinned = _AUTOTUNE_CACHE.get((backend, m, k, n))
    if pinned is not None:
        return pinned
    return _TILE_CHOOSERS.get(backend, _pick_tiles_f32)(m, k, n)


def autotune_tiles(
    m: int,
    k: int,
    n: int,
    backend: str = "pallas",
    p: int = P_DEFAULT,
    batch: int = 1,
    candidates=None,
    repeats: int = 3,
    interpret: bool | None = None,
) -> tuple:
    """Measure candidate tilings on the live device and pin the winner.

    Runs ``mod_matmul`` with each candidate ``(bm, bn, bk)`` on
    synthetic operands of the given shape (compile excluded, best of
    ``repeats``), stores the fastest in the exact-shape autotune cache,
    and returns it — subsequent ``pick_tiles``/``mod_matmul`` calls for
    that (backend, shape) use the tuned tiles automatically.  Default
    candidates bracket the chooser's pick with neighboring K depths and
    M/N splits.
    """
    if backend not in _PALLAS_VARIANTS:
        raise ValueError(f"autotune_tiles supports pallas backends, got {backend}")
    bm0, bn0, bk0 = _TILE_CHOOSERS.get(backend, _pick_tiles_f32)(m, k, n)
    if candidates is None:
        bks = {bk0, max(128, bk0 // 2), bk0 * 2}
        bk_cap = 256 if backend == "pallas" else INT32_KERNEL_MAX_BK - 1
        candidates = sorted(
            {(bm0, bn0, min(bk, bk_cap)) for bk in bks}
            | {(max(8, bm0 // 2), bn0, bk0), (bm0, max(128, bn0 // 2), bk0)}
        )
    rng_a = jax.random.PRNGKey(0)
    shape_a = (batch, m, k) if batch > 1 else (m, k)
    shape_b = (batch, k, n) if batch > 1 else (k, n)
    a = jax.random.randint(rng_a, shape_a, 0, p, dtype=jnp.int32)
    b = jax.random.randint(jax.random.PRNGKey(1), shape_b, 0, p, dtype=jnp.int32)
    best, best_t = None, float("inf")
    for bm, bn, bk in candidates:
        try:
            run = functools.partial(
                mod_matmul, a, b, p=p, backend=backend,
                bm=bm, bn=bn, bk=bk, interpret=interpret,
            )
            run().block_until_ready()  # compile
            t = min(
                _timed(run) for _ in range(max(1, repeats))
            )
        except Exception:
            continue  # candidate invalid for this backend/shape
        if t < best_t:
            best, best_t = (bm, bn, bk), t
    if best is None:
        raise RuntimeError(f"no autotune candidate succeeded for {backend}")
    _AUTOTUNE_CACHE[(backend, m, k, n)] = best
    return best


def _timed(run) -> float:
    t0 = time.perf_counter()
    run().block_until_ready()
    return time.perf_counter() - t0


def padded_shape(m: int, k: int, n: int, tiles: tuple) -> tuple:
    """(M, K, N) after padding each dim up to its tile multiple."""
    bm, bn, bk = tiles
    return _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)


def padding_waste(m: int, k: int, n: int, tiles: tuple) -> float:
    """Fraction of MXU MACs spent on padding for one [M,K]@[K,N] product."""
    mp, kp, np_ = padded_shape(m, k, n, tiles)
    return 1.0 - (m * k * n) / float(mp * kp * np_)


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[-2]) % mult0
    p1 = (-x.shape[-1]) % mult1
    if p0 or p1:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, p0), (0, p1)]
        x = jnp.pad(x, pad)
    return x


def _flatten_batch(x: jnp.ndarray, batch: tuple) -> jnp.ndarray:
    """Collapse leading batch dims to one axis; an operand whose batch
    dims are absent or all 1 stays 2D (shared across the kernel's batch
    grid axis — never materialized per element)."""
    nbatch = 1
    for d in x.shape[:-2]:
        nbatch *= d
    if nbatch == 1:
        return x.reshape(x.shape[-2:])
    if x.shape[:-2] != batch:
        x = jnp.broadcast_to(x, batch + x.shape[-2:])
    return x.reshape((-1,) + x.shape[-2:])


def _resolve_auto(k: int) -> str:
    """The ``"auto"`` policy at one call's (static) contraction depth."""
    if jax.default_backend() == "tpu":
        return "pallas"
    if CHUNK_K < k and _round_up(k, CHUNK_K) <= INT32_ACC_K:
        # deeper than one exact-f32 chunk: the uint32-accumulator path
        # skips the per-chunk reductions the f32limb scan must pay
        return "int32"
    return "f32limb"


@functools.partial(
    jax.jit, static_argnames=("p", "backend", "bm", "bn", "bk", "interpret")
)
def mod_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    p: int = P_DEFAULT,
    backend: str = "auto",
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """a [..., M, K] @ b [..., K, N] mod p (int32), batched over leading dims.

    Batch dims of ``a`` and ``b`` must broadcast against each other; one
    side may omit them entirely (e.g. a 2D constant matrix against a
    batched operand) — the unbatched side is contracted in place, never
    broadcast.  Tile sizes default to ``pick_tiles`` of the actual shape
    and backend.
    """
    if backend == "auto":
        backend = _resolve_auto(int(a.shape[-1]))

    # This body runs at trace time (the wrapper is jitted), so each
    # event records one *compilation*'s backend + tile choice — the
    # shape/backend signature, not a per-call sample.
    if backend in ("f32limb", "int32"):
        REGISTRY.counter("kernels.modmatmul_lowerings").inc()
        if TRACER.enabled:
            TRACER.event(
                "modmatmul.lower", backend=backend,
                m=int(a.shape[-2]), k=int(a.shape[-1]), n=int(b.shape[-1]),
            )
        fn = mod_matmul_f32 if backend == "f32limb" else mod_matmul_int32
        return fn(a, b, p)

    variant = _PALLAS_VARIANTS.get(backend)
    if variant is None:
        raise ValueError(f"unknown backend {backend}")

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    m, k = a.shape[-2:]
    n = b.shape[-1]
    tm, tn, tk = pick_tiles(m, k, n, backend=backend)
    bm = bm or tm
    bn = bn or tn
    bk = bk or tk
    REGISTRY.counter("kernels.modmatmul_lowerings").inc()
    if TRACER.enabled:
        TRACER.event(
            "modmatmul.lower", backend=backend,
            m=int(m), k=int(k), n=int(n),
            bm=int(bm), bn=int(bn), bk=int(bk), interpret=bool(interpret),
        )
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)

    call = functools.partial(
        modmatmul_pallas, p=p, bm=bm, bn=bn, bk=bk, interpret=interpret,
        variant=variant,
    )
    if a.ndim == 2 and b.ndim == 2:
        out = call(ap, bp)
    else:
        batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        out = call(_flatten_batch(ap, batch), _flatten_batch(bp, batch))
        out = out.reshape(batch + (ap.shape[-2], bp.shape[-1]))
    return out[..., :m, :n]


@functools.partial(
    jax.jit, static_argnames=("p", "backend", "bm", "bn", "bk", "interpret")
)
def mod_matmul_masked(
    a: jnp.ndarray,
    b: jnp.ndarray,
    v: jnp.ndarray,
    key: jnp.ndarray,
    p: int = P_DEFAULT,
    backend: str = "auto",
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """``a @ b + v @ R(key)  (mod p)`` — blinding fused into the matmul.

    ``v`` is a 2D [M, z] constant (secret/blinding Vandermonde columns);
    R is the deterministic counter-based mask
    ``field_mask(key, batch + (z, N), p)`` where ``batch`` is the
    broadcast batch of ``a`` and ``b`` and N is the logical output
    width.  On the pallas backends R is generated *inside* the matmul
    tile (threefry on program-id-derived counters — the mask array never
    exists); the portable backends compute the identical values via
    ``field_mask`` inside the same jit.  All backends are bit-identical
    for a given ``key``.
    """
    if backend == "auto":
        backend = _resolve_auto(int(a.shape[-1]))
    m, k = a.shape[-2:]
    n = b.shape[-1]
    z = v.shape[-1]
    if v.ndim != 2 or v.shape[0] != m:
        raise ValueError(f"v must be [M={m}, z], got {v.shape}")
    if a.ndim == 2 and b.ndim == 2:
        batch = ()
    else:
        batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])

    variant = _PALLAS_VARIANTS.get(backend)
    if variant is None:
        # portable route: mask materializes only as a jit-internal value
        mm = mod_matmul(a, b, p=p, backend=backend)
        mask = field_mask(key, tuple(batch) + (z, n), p)
        return mod_add(mm, mod_matmul(v, mask, p=p, backend=backend), p)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tm, tn, tk = pick_tiles(m, k, n, backend=backend)
    bm = bm or tm
    bn = bn or tn
    bk = bk or tk
    REGISTRY.counter("kernels.modmatmul_lowerings").inc()
    if TRACER.enabled:
        TRACER.event(
            "modmatmul.lower", backend=backend, fused_mask=True,
            m=int(m), k=int(k), n=int(n),
            bm=int(bm), bn=int(bn), bk=int(bk), interpret=bool(interpret),
        )
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    vp = _pad_to(v, bm, 1)  # zero rows past M contribute nothing
    call = functools.partial(
        modmatmul_masked_pallas, p=p, ncols=int(n), bm=bm, bn=bn, bk=bk,
        interpret=interpret, variant=variant,
    )
    if not batch:
        out = call(ap, bp, vp, key)
    else:
        out = call(_flatten_batch(ap, batch), _flatten_batch(bp, batch), vp, key)
        out = out.reshape(tuple(batch) + (ap.shape[-2], bp.shape[-1]))
    return out[..., :m, :n]


def mod_matmul_crt(
    a,
    b,
    primes: tuple = (65521, 65519),
    backend: str = "auto",
    **kw,
):
    """Wide-range exact matmul via CRT over several 16-bit primes.

    Computes a @ b mod prod(primes): one residue matmul per prime on the
    selected backend, combined on the host with Garner's algorithm.
    Operands may be any integers (numpy int64 welcome — they are reduced
    per prime); the result is int64 in [0, prod(primes)), exact whenever
    the true product fits the combined modulus.  This is the dynamic-
    range escape hatch: depth/magnitude that would overflow a single
    16-bit field costs one extra residue pass instead of deeper limbs.
    """
    import numpy as np

    primes = tuple(int(q) for q in primes)
    if len(set(primes)) != len(primes):
        raise ValueError(f"CRT primes must be distinct, got {primes}")
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    residues = [
        np.asarray(
            mod_matmul(
                jnp.asarray((a % q).astype(np.int32)),
                jnp.asarray((b % q).astype(np.int32)),
                p=q, backend=backend, **kw,
            ),
            np.int64,
        )
        for q in primes
    ]
    return crt_combine(residues, primes)


def polyeval(
    vander: jnp.ndarray, coeffs: jnp.ndarray, p: int = P_DEFAULT, **kw
) -> jnp.ndarray:
    """Evaluate matrix-coefficient polynomials at many points.

    vander: [N, K] powers matrix (alpha_n ** power_k mod p)
    coeffs: [..., K, R, C] stacked matrix coefficients (leading batch
            dims allowed: the same points evaluate every batch element)
    returns [..., N, R, C]: F(alpha_n) = sum_k vander[n, k] * coeffs[k].
    """
    *batch, k, r, c = coeffs.shape
    flat = mod_matmul(vander, coeffs.reshape(tuple(batch) + (k, r * c)), p=p, **kw)
    return flat.reshape(tuple(batch) + (vander.shape[0], r, c))


def polyeval_masked(
    vander: jnp.ndarray,
    coeffs: jnp.ndarray,
    vsecret: jnp.ndarray,
    key: jnp.ndarray,
    p: int = P_DEFAULT,
    **kw,
) -> jnp.ndarray:
    """``polyeval`` with the z secret coefficients fused into the kernel.

    Evaluates F(alpha_n) = V @ coeffs + Vsecret @ R(key) where
    ``vsecret`` holds the Vandermonde columns of the secret powers and R
    is the counter-based mask playing the secret coefficient draws —
    generated in-tile on the pallas backends, so the secrets never exist
    as an array.  ``coeffs`` must carry zeros at the secret rows.
    """
    *batch, k, r, c = coeffs.shape
    flat = mod_matmul_masked(
        vander, coeffs.reshape(tuple(batch) + (k, r * c)), vsecret, key, p=p, **kw
    )
    return flat.reshape(tuple(batch) + (vander.shape[0], r, c))
