"""Differential fuzzing library for the GF(p) matmul backends.

Every backend must agree bit-for-bit with the host oracle — an
object-dtype (arbitrary-precision) integer matmul reduced mod p — on
every shape, prime, and operand distribution.  This module generates
the cases and runs the comparison; ``tests/test_kernel_fuzz.py`` drives
it through the (offline-capable) hypothesis shim and
``tools/fuzz_kernels.py`` / ``make fuzz-kernels`` give it a CLI and a
CI budget.

Case space:

* engines — the portable paths (``f32limb``, ``int32``), the Pallas
  kernels in interpret mode (``pallas``, ``pallas_int32``), and the
  dual-prime ``crt`` route (checked against the oracle mod p1*p2),
* layouts — both operands batched, either side 2D (shared across the
  batch via the kernel's index maps), both 2D,
* primes — small, mid, and the adjacent 16-bit maximals 65519/65521,
* operand modes — ``uniform`` draws; ``high_limb`` (both 8-bit limbs
  dense-high, maximizing every partial product); ``near_p`` (values
  within 8 of p, the Barrett conditional-subtract edge); ``maximal``
  (all p-1, the worst-case accumulator drive); ``sparse`` (mostly
  zeros — exercises padding and init steps).

Shapes are deliberately unaligned (primes, tile-boundary +/- 1) so the
padding and slicing paths fuzz too; a slice of deep-K shapes (> 256)
steers into the int32 tier's chunked accumulator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .ops import mod_matmul, mod_matmul_crt

PRIMES = (3, 251, 257, 4093, 40961, 65519, 65521)
CRT_PRIMES = (65521, 65519)
MODES = ("uniform", "high_limb", "near_p", "maximal", "sparse")
LAYOUTS = ("batched", "lhs2d", "rhs2d", "2d")


def _engine(backend: str) -> Callable:
    def run(a, b, p):
        import jax.numpy as jnp

        out = mod_matmul(
            jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
            p=p, backend=backend,
        )
        return np.asarray(out, np.int64)

    return run


def _engine_crt(a, b, p):
    # p is ignored: the CRT route is checked mod prod(CRT_PRIMES)
    return np.asarray(mod_matmul_crt(a, b, primes=CRT_PRIMES), np.int64)


ENGINES: Dict[str, Callable] = {
    "f32limb": _engine("f32limb"),
    "int32": _engine("int32"),
    "pallas": _engine("pallas"),
    "pallas_int32": _engine("pallas_int32"),
    "crt": _engine_crt,
}


@dataclasses.dataclass(frozen=True)
class Case:
    """One differential-fuzz case: a (shape, prime, distribution) point."""

    batch: int
    m: int
    k: int
    n: int
    p: int
    mode: str
    layout: str
    seed: int

    def describe(self) -> str:
        return (
            f"B={self.batch} M={self.m} K={self.k} N={self.n} p={self.p} "
            f"mode={self.mode} layout={self.layout} seed={self.seed}"
        )


@dataclasses.dataclass
class Mismatch:
    case: Case
    engine: str
    n_bad: int
    first_bad: tuple
    got: int
    want: int

    def describe(self) -> str:
        return (
            f"{self.engine}: {self.n_bad} wrong elements, first at "
            f"{self.first_bad} (got {self.got}, want {self.want}) "
            f"[{self.case.describe()}]"
        )


def sample_case(rng: np.random.Generator, deep_k: bool = False) -> Case:
    """Draw one case; ``deep_k`` steers K past the 256-chunk boundary
    into the int32 tier's multi-chunk accumulator."""
    # unaligned by construction: primes and tile-boundary neighbours
    dims = (1, 2, 3, 5, 7, 9, 13, 17, 31, 33, 40)
    kdims = (257, 260, 300, 511, 513) if deep_k else dims + (127, 128, 129)
    return Case(
        batch=int(rng.choice((1, 2, 3))),
        m=int(rng.choice(dims)),
        k=int(rng.choice(kdims)),
        n=int(rng.choice(dims)),
        p=int(rng.choice(PRIMES)),
        mode=str(rng.choice(MODES)),
        layout=str(rng.choice(LAYOUTS)),
        seed=int(rng.integers(0, 2**31)),
    )


def operands(case: Case) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize the adversarial operand pair for a case (int64 host
    arrays in [0, p), shaped per the case layout)."""
    rng = np.random.default_rng(case.seed)
    p = case.p
    sa: tuple = (case.batch, case.m, case.k)
    sb: tuple = (case.batch, case.k, case.n)
    if case.layout in ("lhs2d", "2d"):
        sa = sa[1:]
    if case.layout in ("rhs2d", "2d"):
        sb = sb[1:]

    def draw(shape):
        if case.mode == "uniform":
            return rng.integers(0, p, shape, dtype=np.int64)
        if case.mode == "maximal":
            return np.full(shape, p - 1, np.int64)
        if case.mode == "near_p":
            return p - 1 - rng.integers(0, min(8, p - 1) + 1, shape, dtype=np.int64)
        if case.mode == "high_limb":
            # both 8-bit limbs dense-high: maximal limb products without
            # leaving [0, p)
            hi = rng.integers(192, 256, shape, dtype=np.int64)
            lo = rng.integers(192, 256, shape, dtype=np.int64)
            return np.minimum(hi * 256 + lo, p - 1)
        if case.mode == "sparse":
            x = rng.integers(0, p, shape, dtype=np.int64)
            return np.where(rng.random(shape) < 0.9, 0, x)
        raise ValueError(f"unknown mode {case.mode}")

    return draw(sa), draw(sb)


def oracle(a: np.ndarray, b: np.ndarray, p: int) -> np.ndarray:
    """Exact host reference: arbitrary-precision integer matmul mod p."""
    prod = np.asarray(a, np.object_) @ np.asarray(b, np.object_)
    return (prod % p).astype(np.int64)


def check_case(case: Case, engines: Optional[List[str]] = None) -> List[Mismatch]:
    """Run one case through the selected engines; return all mismatches."""
    a, b = operands(case)
    want = oracle(a, b, case.p)
    pbig = 1
    for q in CRT_PRIMES:
        pbig *= q
    want_crt = oracle(a, b, pbig)
    out = []
    for name in engines or list(ENGINES):
        got = ENGINES[name](a, b, case.p)
        ref = want_crt if name == "crt" else want
        if got.shape != ref.shape:
            out.append(Mismatch(case, name, -1, ("shape",), 0, 0))
            continue
        bad = got != ref
        if bad.any():
            idx = tuple(int(i) for i in np.argwhere(bad)[0])
            out.append(
                Mismatch(
                    case, name, int(bad.sum()), idx,
                    int(got[idx]), int(ref[idx]),
                )
            )
    return out


def run_fuzz(
    examples: int = 24,
    seed: int = 0,
    engines: Optional[List[str]] = None,
    deep_every: int = 4,
    verbose: bool = False,
) -> List[Mismatch]:
    """The harness: ``examples`` random cases (every ``deep_every``-th
    steered deep-K), all engines differentially checked per case.
    Deterministic per seed.  Returns the accumulated mismatches."""
    rng = np.random.default_rng(seed)
    mismatches: List[Mismatch] = []
    for i in range(examples):
        case = sample_case(rng, deep_k=deep_every > 0 and i % deep_every == 0)
        found = check_case(case, engines=engines)
        mismatches.extend(found)
        if verbose:
            status = "MISMATCH" if found else "ok"
            print(f"[{i + 1}/{examples}] {status}  {case.describe()}")
    return mismatches
