"""Pallas TPU kernel: exact GF(p) matrix multiplication, p < 2**16.

TPU adaptation of the paper's worker hot loop H(alpha_n) =
F_A(alpha_n) * F_B(alpha_n) over a prime field.  GPU implementations of
field matmul use 32/64-bit integer MACs; the TPU MXU is a *floating
point* systolic array, so we re-think the arithmetic instead of porting:

* field elements (< 2**16) are split into two 8-bit limbs,
* limb products (< 2**16) are accumulated on the MXU in f32 — any
  partial sum of <= 256 such products stays below 2**24, the largest
  integer f32 represents exactly,
* the inner (contraction) dimension is therefore tiled at ``bk = 256``
  and a Barrett-free reduction (x - floor(x/p)*p, exact in f32 for
  x < 2**24) runs once per tile,
* limb recombination multiplies by (2**16 mod p) and (2**8 mod p) so
  every intermediate stays < 2**24.

Tiles are MXU-aligned (multiples of 128 on M/N).  The accumulator lives
in the output VMEM block; the K grid axis is ``arbitrary`` (sequential)
so accumulation is race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.gf import P_DEFAULT

# JAX renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams across
# releases; resolve whichever this install provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

LIMB = 256.0


def _modf32(x, p):
    # floor(x/p) in f32 can be off by one ulp; correct both directions.
    r = x - jnp.floor(x / p) * p
    r = jnp.where(r < 0, r + p, r)
    return jnp.where(r >= p, r - p, r)


def _mulmod_const(x, c: int, p: int):
    """x * c mod p with x in [0, p) f32, exact for any p < 2**16: split x
    into 8-bit limbs so each product stays below 2**24."""
    pf = float(p)
    c_hi = float((c * 256) % p)
    c_lo = float(c % p)
    x_hi = jnp.floor(x / LIMB)
    x_lo = x - x_hi * LIMB
    return _modf32(_modf32(x_hi * c_hi, pf) + _modf32(x_lo * c_lo, pf), pf)


def _modmatmul_kernel(a_ref, b_ref, o_ref, *, p: int):
    """One (bm, bn) output tile; K-axis accumulation across grid dim 2."""
    pf = float(p)
    f_hihi = (1 << 16) % p  # 2**16 mod p
    f_mid = (1 << 8) % p  # 2**8 mod p

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    af = a_ref[...].astype(jnp.float32)
    bf = b_ref[...].astype(jnp.float32)
    a_hi = jnp.floor(af / LIMB)
    a_lo = af - a_hi * LIMB
    b_hi = jnp.floor(bf / LIMB)
    b_lo = bf - b_hi * LIMB

    # Four MXU matmuls per tile; each single dot accumulates <= bk=256
    # products of 8-bit limbs -> partial sums < 2**24, exact in f32.
    # The two cross dots are reduced separately before adding: their raw
    # sum can reach ~2**25 and lose the low bit.
    hh = _modf32(jnp.dot(a_hi, b_hi, preferred_element_type=jnp.float32), pf)
    mid = _modf32(
        _modf32(jnp.dot(a_hi, b_lo, preferred_element_type=jnp.float32), pf)
        + _modf32(jnp.dot(a_lo, b_hi, preferred_element_type=jnp.float32), pf),
        pf,
    )
    ll = _modf32(jnp.dot(a_lo, b_lo, preferred_element_type=jnp.float32), pf)

    tile = _modf32(_mulmod_const(hh, f_hihi, p) + _mulmod_const(mid, f_mid, p) + ll, pf)
    acc = o_ref[...].astype(jnp.float32)
    o_ref[...] = _modf32(acc + tile, pf).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("p", "bm", "bn", "bk", "interpret")
)
def modmatmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    p: int = P_DEFAULT,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """a [M, K] @ b [K, N] mod p; int32 in [0, p). Shapes must be
    multiples of the block sizes (ops.py handles padding)."""
    if p >= 1 << 16:
        raise ValueError("kernel requires p < 2**16")
    if bk > 256:
        raise ValueError("bk must be <= 256 for exact f32 accumulation")
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, (bm, bn, bk))

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_modmatmul_kernel, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)
