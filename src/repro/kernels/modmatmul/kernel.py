"""Pallas TPU kernel: exact GF(p) matrix multiplication, p < 2**16.

TPU adaptation of the paper's worker hot loop H(alpha_n) =
F_A(alpha_n) * F_B(alpha_n) over a prime field.  GPU implementations of
field matmul use 32/64-bit integer MACs; the TPU MXU is a *floating
point* systolic array, so we re-think the arithmetic instead of porting:

* field elements (< 2**16) are split into two 8-bit limbs,
* limb products (< 2**16) are accumulated on the MXU in f32 — any
  partial sum of <= 256 such products stays below 2**24, the largest
  integer f32 represents exactly,
* the inner (contraction) dimension is therefore tiled at ``bk <= 256``
  and a Barrett-free reduction (x - floor(x/p)*p, exact in f32 for
  x < 2**24) runs once per tile,
* at ``bk <= LAZY_K`` (128) reductions are *lazy*: the two cross-limb
  dots are summed raw before one reduction (2*128*255**2 < 2**24), and
  the raw low-limb dot plus the running accumulator fold into the
  final reduction (3*(p-1) + 128*255**2 < 2**24),
* limb recombination multiplies by (2**16 mod p) and (2**8 mod p) so
  every intermediate stays < 2**24.

Batching: the protocol's worker/batch axis is a *grid* axis — one
``pallas_call`` computes ``[B, M, K] @ [B, K, N]`` with grid
``(B, M/bm, N/bn, K/bk)`` instead of a vmap of padded 2D launches.  An
unbatched operand (e.g. a constant mixing or decode matrix against a
batched stack) keeps its 2D shape and is indexed batch-invariantly, so
it is never broadcast or copied per batch element.

Tiles are MXU-aligned (M tiles are sublane multiples of 8, N/K tiles
lane multiples of 128 — ``ops.pick_tiles`` chooses them from the actual
operand shape).  The accumulator lives in the output VMEM block; the K
grid axis is ``arbitrary`` (sequential) so accumulation is race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.gf import LAZY_K, P_DEFAULT

# JAX renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams across
# releases; resolve whichever this install provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

LIMB = 256.0


def _modf32(x, p):
    # floor(x/p) in f32 can be off by one ulp; correct both directions.
    r = x - jnp.floor(x / p) * p
    r = jnp.where(r < 0, r + p, r)
    return jnp.where(r >= p, r - p, r)


def _mulmod_const(x, c: int, p: int):
    """x * c mod p with x in [0, p) f32, exact for any p < 2**16: split x
    into 8-bit limbs so each product stays below 2**24."""
    pf = float(p)
    c_hi = float((c * 256) % p)
    c_lo = float(c % p)
    x_hi = jnp.floor(x / LIMB)
    x_lo = x - x_hi * LIMB
    return _modf32(_modf32(x_hi * c_hi, pf) + _modf32(x_lo * c_lo, pf), pf)


def _modmatmul_kernel(a_ref, b_ref, o_ref, *, p: int, lazy: bool, k_axis: int):
    """One (bm, bn) output tile; K-axis accumulation across grid axis
    ``k_axis``.  Batched refs carry a leading unit block axis that is
    dropped before the MXU dots."""
    pf = float(p)
    f_hihi = (1 << 16) % p  # 2**16 mod p
    f_mid = (1 << 8) % p  # 2**8 mod p

    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    af = a_ref[...]
    bf = b_ref[...]
    if af.ndim == 3:  # batched block [1, bm, bk]
        af = af[0]
    if bf.ndim == 3:
        bf = bf[0]
    af = af.astype(jnp.float32)
    bf = bf.astype(jnp.float32)
    a_hi = jnp.floor(af / LIMB)
    a_lo = af - a_hi * LIMB
    b_hi = jnp.floor(bf / LIMB)
    b_lo = bf - b_hi * LIMB

    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    # Four MXU matmuls per tile; each single dot accumulates <= bk<=256
    # products of 8-bit limbs -> partial sums < 2**24, exact in f32.
    hh = _modf32(dot(a_hi, b_hi), pf)
    if lazy:
        # bk <= 128: the raw cross-dot sum stays < 2**24, so one
        # reduction replaces three; the raw low-limb dot and the
        # accumulator fold into the final reduction below.
        mid = _modf32(dot(a_hi, b_lo) + dot(a_lo, b_hi), pf)
        ll = dot(a_lo, b_lo)
    else:
        # bk up to 256: the raw cross sum can reach ~2**25 and lose the
        # low bit — reduce each dot separately.
        mid = _modf32(
            _modf32(dot(a_hi, b_lo), pf) + _modf32(dot(a_lo, b_hi), pf), pf
        )
        ll = _modf32(dot(a_lo, b_lo), pf)

    tile = _mulmod_const(hh, f_hihi, p) + _mulmod_const(mid, f_mid, p) + ll
    if not lazy:
        tile = _modf32(tile, pf)
    acc = o_ref[...].astype(jnp.float32)
    # lazy: acc + tile < 3*(p-1) + 128*255**2 < 2**24 — still exact.
    o_ref[...] = _modf32(acc + tile.reshape(o_ref.shape), pf).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("p", "bm", "bn", "bk", "interpret")
)
def modmatmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    p: int = P_DEFAULT,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """a [B, M, K] or [M, K]  @  b [B, K, N] or [K, N] mod p.

    int32 in [0, p); M/N/K must be multiples of the block sizes
    (ops.py handles padding and tile selection).  Always a *single*
    ``pallas_call``: a batched operand puts B on the leading grid axis;
    a 2D operand is shared across that axis via its index map (no
    broadcast copies).  2D @ 2D keeps the classic 3-axis grid.
    """
    if p >= 1 << 16:
        raise ValueError("kernel requires p < 2**16")
    if bk > 256:
        raise ValueError("bk must be <= 256 for exact f32 accumulation")
    a_batched = a.ndim == 3
    b_batched = b.ndim == 3
    m, k = a.shape[-2:]
    k2, n = b.shape[-2:]
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    batch = None
    if a_batched or b_batched:
        batch = a.shape[0] if a_batched else b.shape[0]
        if a_batched and b_batched:
            assert a.shape[0] == b.shape[0], (a.shape, b.shape)

    lazy = bk <= LAZY_K
    kernel = functools.partial(
        _modmatmul_kernel,
        p=p,
        lazy=lazy,
        k_axis=2 if batch is None else 3,
    )
    if batch is None:
        grid = (m // bm, n // bn, k // bk)
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
        out_shape = (m, n)
    else:
        grid = (batch, m // bm, n // bn, k // bk)
        if a_batched:
            a_spec = pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk))
        else:
            a_spec = pl.BlockSpec((bm, bk), lambda bb, i, j, kk: (i, kk))
        if b_batched:
            b_spec = pl.BlockSpec((1, bk, bn), lambda bb, i, j, kk: (bb, kk, j))
        else:
            b_spec = pl.BlockSpec((bk, bn), lambda bb, i, j, kk: (kk, j))
        o_spec = pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j))
        out_shape = (batch, m, n)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",) * (len(grid) - 1) + ("arbitrary",)
        ),
        interpret=interpret,
    )(a, b)
