"""Pallas TPU kernel: exact GF(p) matrix multiplication, p < 2**16.

TPU adaptation of the paper's worker hot loop H(alpha_n) =
F_A(alpha_n) * F_B(alpha_n) over a prime field.  GPU implementations of
field matmul use 32/64-bit integer MACs; the TPU MXU is a *floating
point* systolic array, so we re-think the arithmetic instead of porting:

* field elements (< 2**16) are split into two 8-bit limbs,
* limb products (< 2**16) are accumulated on the MXU in f32 — any
  partial sum of <= 256 such products stays below 2**24, the largest
  integer f32 represents exactly,
* the inner (contraction) dimension is therefore tiled at ``bk <= 256``
  and a Barrett-free reduction (x - floor(x/p)*p, exact in f32 for
  x < 2**24) runs once per tile,
* at ``bk <= LAZY_K`` (128) reductions are *lazy*: the two cross-limb
  dots are summed raw before one reduction (2*128*255**2 < 2**24), and
  the raw low-limb dot plus the running accumulator fold into the
  final reduction (3*(p-1) + 128*255**2 < 2**24),
* limb recombination multiplies by (2**16 mod p) and (2**8 mod p) so
  every intermediate stays < 2**24.

Batching: the protocol's worker/batch axis is a *grid* axis — one
``pallas_call`` computes ``[B, M, K] @ [B, K, N]`` with grid
``(B, M/bm, N/bn, K/bk)`` instead of a vmap of padded 2D launches.  An
unbatched operand (e.g. a constant mixing or decode matrix against a
batched stack) keeps its 2D shape and is indexed batch-invariantly, so
it is never broadcast or copied per batch element.

Tiles are MXU-aligned (M tiles are sublane multiples of 8, N/K tiles
lane multiples of 128 — ``ops.pick_tiles`` chooses them from the actual
operand shape).  The accumulator lives in the output VMEM block; the K
grid axis is ``arbitrary`` (sequential) so accumulation is race-free.

Two arithmetic variants share the launch/grid machinery
(``variant="f32" | "int32"``):

* **f32** — the limb schedule above, bound by the 2**24 f32 ceiling
  (``bk <= 256``).
* **int32** — integer limb split (``>> 8``, ``& 255``), limb dots
  accumulated with ``preferred_element_type=int32`` and recombined per
  K step through a pure-uint32 Barrett reduction
  (``gf.barrett_reduce_u32``); the accumulator bound widens to 2**31
  (``bk <= INT32_KERNEL_MAX_BK``), so deep contractions need no
  K-tiling at all.

``modmatmul_masked_pallas`` additionally fuses the protocol's blinding
masks into the tile: a counter-based threefry2x32 stream (matching
``gf.field_mask`` bit-for-bit) is generated from the tile's grid
position and added to the output block on the last K step — the mask
is never materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.gf import (
    LAZY_K,
    P_DEFAULT,
    _barrett_recombine,
    barrett_reduce_u32,
    threefry2x32,
)

# JAX renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams across
# releases; resolve whichever this install provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

LIMB = 256.0

# Per-tile contraction bound for the native-int32 kernel: each raw
# signed-int32 limb dot accumulates bk products of 8-bit limbs, so
# bk * 255**2 must stay below 2**31.
INT32_KERNEL_MAX_BK = (1 << 31) // (255 * 255)  # 33025 -> bk <= 33024 padded


def _modf32(x, p):
    # floor(x/p) in f32 can be off by one ulp; correct both directions.
    r = x - jnp.floor(x / p) * p
    r = jnp.where(r < 0, r + p, r)
    return jnp.where(r >= p, r - p, r)


def _mulmod_const(x, c: int, p: int):
    """x * c mod p with x in [0, p) f32, exact for any p < 2**16: split x
    into 8-bit limbs so each product stays below 2**24."""
    pf = float(p)
    c_hi = float((c * 256) % p)
    c_lo = float(c % p)
    x_hi = jnp.floor(x / LIMB)
    x_lo = x - x_hi * LIMB
    return _modf32(_modf32(x_hi * c_hi, pf) + _modf32(x_lo * c_lo, pf), pf)


def _modmatmul_kernel(a_ref, b_ref, o_ref, *, p: int, lazy: bool, k_axis: int):
    """One (bm, bn) output tile; K-axis accumulation across grid axis
    ``k_axis``.  Batched refs carry a leading unit block axis that is
    dropped before the MXU dots."""
    pf = float(p)
    f_hihi = (1 << 16) % p  # 2**16 mod p
    f_mid = (1 << 8) % p  # 2**8 mod p

    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    af = a_ref[...]
    bf = b_ref[...]
    if af.ndim == 3:  # batched block [1, bm, bk]
        af = af[0]
    if bf.ndim == 3:
        bf = bf[0]
    af = af.astype(jnp.float32)
    bf = bf.astype(jnp.float32)
    a_hi = jnp.floor(af / LIMB)
    a_lo = af - a_hi * LIMB
    b_hi = jnp.floor(bf / LIMB)
    b_lo = bf - b_hi * LIMB

    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    # Four MXU matmuls per tile; each single dot accumulates <= bk<=256
    # products of 8-bit limbs -> partial sums < 2**24, exact in f32.
    hh = _modf32(dot(a_hi, b_hi), pf)
    if lazy:
        # bk <= 128: the raw cross-dot sum stays < 2**24, so one
        # reduction replaces three; the raw low-limb dot and the
        # accumulator fold into the final reduction below.
        mid = _modf32(dot(a_hi, b_lo) + dot(a_lo, b_hi), pf)
        ll = dot(a_lo, b_lo)
    else:
        # bk up to 256: the raw cross sum can reach ~2**25 and lose the
        # low bit — reduce each dot separately.
        mid = _modf32(
            _modf32(dot(a_hi, b_lo), pf) + _modf32(dot(a_lo, b_hi), pf), pf
        )
        ll = _modf32(dot(a_lo, b_lo), pf)

    tile = _mulmod_const(hh, f_hihi, p) + _mulmod_const(mid, f_mid, p) + ll
    if not lazy:
        tile = _modf32(tile, pf)
    acc = o_ref[...].astype(jnp.float32)
    # lazy: acc + tile < 3*(p-1) + 128*255**2 < 2**24 — still exact.
    o_ref[...] = _modf32(acc + tile.reshape(o_ref.shape), pf).astype(jnp.int32)


def _modmatmul_int32_kernel(a_ref, b_ref, o_ref, *, p: int, k_axis: int):
    """Native-integer tile: int32 limb dots + uint32 Barrett recombination.

    The limb split is integer (``>> 8`` / ``& 255``), the four dots
    accumulate in *signed int32* (exact while bk * 255**2 < 2**31 —
    enforced at launch), and the recombination runs the shared uint32
    Barrett helpers from ``core.gf``.  No f32 anywhere, so there is no
    2**24 exactness ceiling and no 256-deep chunk reductions: one tile
    covers up to ~33k contraction depth with a single recombination.
    Cross-step accumulation needs only a conditional subtract (both
    addends already sit in [0, p)).
    """
    pu = jnp.uint32(p)

    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ai = a_ref[...]
    bi = b_ref[...]
    if ai.ndim == 3:  # batched block [1, bm, bk]
        ai = ai[0]
    if bi.ndim == 3:
        bi = bi[0]
    a_hi = ai >> 8
    a_lo = ai & 255
    b_hi = bi >> 8
    b_lo = bi & 255

    dot = functools.partial(jnp.dot, preferred_element_type=jnp.int32)
    hh = dot(a_hi, b_hi).astype(jnp.uint32)
    # the two cross dots are each < 2**31 before the cast; their uint32
    # sum has a full 2**32 of headroom
    mid = dot(a_hi, b_lo).astype(jnp.uint32) + dot(a_lo, b_hi).astype(jnp.uint32)
    ll = dot(a_lo, b_lo).astype(jnp.uint32)
    tile = _barrett_recombine(hh, mid, ll, p)

    s = o_ref[...].astype(jnp.uint32) + tile.reshape(o_ref.shape)
    o_ref[...] = jnp.where(s >= pu, s - pu, s).astype(jnp.int32)


def _apply_fused_mask(
    o_ref, v_ref, key_ref, *, p: int, z: int, ncols: int, bn: int,
    k_axis: int, nk: int, batched: bool,
):
    """Add ``v @ R`` to the finished output tile, generating R in-tile.

    R is the counter-based threefry stream of ``core.gf.field_mask`` for
    shape [batch, z, ncols]: element (bb, zi, col) has flat counter
    ``(bb*z + zi) * ncols + col``, so each tile derives exactly its own
    mask slice from program ids — the [batch, z, ncols] array is never
    materialized.  Runs only on the *last* K step, after the matmul
    accumulation for this tile has finished.  Columns past ``ncols``
    (N padding) generate garbage that the caller slices off; rows of
    ``v`` past the logical M are zero-padded by the caller.
    """
    pu = jnp.uint32(p)
    # program ids must be read OUTSIDE the pl.when body: inside the cond
    # branch the primitive survives into the jaxpr un-rewritten and has
    # no lowering off-kernel (breaks interpret mode on CPU).
    j = pl.program_id(2 if batched else 1)
    bbu = pl.program_id(0).astype(jnp.uint32) if batched else None

    @pl.when(pl.program_id(k_axis) == nk - 1)
    def _mask():
        k0 = key_ref[0, 0]
        k1 = key_ref[0, 1]
        cols = (
            j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
        ).astype(jnp.uint32)
        v = v_ref[...].astype(jnp.uint32)  # [bm, z]
        acc = jnp.zeros((v.shape[0], bn), jnp.uint32)
        for zi in range(z):
            rowu = bbu * jnp.uint32(z) + jnp.uint32(zi) if batched else jnp.uint32(zi)
            ctr = rowu * jnp.uint32(ncols) + cols
            r0, _ = threefry2x32(k0, k1, ctr, jnp.zeros_like(ctr))
            r = barrett_reduce_u32(r0, p)  # [1, bn] mask row
            # v (< p) times r (< p) fits uint32; reduce per term so the
            # accumulator stays <= z*p (z < 2**16 keeps it wrap-free)
            acc = acc + barrett_reduce_u32(v[:, zi : zi + 1] * r, p)
        contrib = barrett_reduce_u32(acc, p)
        s = o_ref[...].astype(jnp.uint32) + contrib.reshape(o_ref.shape)
        o_ref[...] = jnp.where(s >= pu, s - pu, s).astype(jnp.int32)


def _grid_and_specs(a, b, bm: int, bn: int, bk: int):
    """Shared launch geometry: grid, operand/output BlockSpecs, and the
    K grid-axis index for the f32, int32, and fused-mask kernels."""
    a_batched = a.ndim == 3
    b_batched = b.ndim == 3
    m, k = a.shape[-2:]
    k2, n = b.shape[-2:]
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    batch = None
    if a_batched or b_batched:
        batch = a.shape[0] if a_batched else b.shape[0]
        if a_batched and b_batched:
            assert a.shape[0] == b.shape[0], (a.shape, b.shape)

    if batch is None:
        grid = (m // bm, n // bn, k // bk)
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
        out_shape = (m, n)
        k_axis = 2
    else:
        grid = (batch, m // bm, n // bn, k // bk)
        if a_batched:
            a_spec = pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk))
        else:
            a_spec = pl.BlockSpec((bm, bk), lambda bb, i, j, kk: (i, kk))
        if b_batched:
            b_spec = pl.BlockSpec((1, bk, bn), lambda bb, i, j, kk: (bb, kk, j))
        else:
            b_spec = pl.BlockSpec((bk, bn), lambda bb, i, j, kk: (kk, j))
        o_spec = pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j))
        out_shape = (batch, m, n)
        k_axis = 3
    return grid, a_spec, b_spec, o_spec, out_shape, batch, k_axis


def _launch(kernel, grid, in_specs, o_spec, out_shape, interpret, operands):
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=list(in_specs),
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",) * (len(grid) - 1) + ("arbitrary",)
        ),
        interpret=interpret,
    )(*operands)


def _base_kernel(variant: str, p: int, bk: int, k_axis: int):
    """The unmasked tile body for a kernel variant ("f32" | "int32")."""
    if variant == "f32":
        if bk > 256:
            raise ValueError("bk must be <= 256 for exact f32 accumulation")
        return functools.partial(
            _modmatmul_kernel, p=p, lazy=bk <= LAZY_K, k_axis=k_axis
        )
    if variant != "int32":
        raise ValueError(f"unknown kernel variant {variant}")
    if bk * 255 * 255 >= 1 << 31:
        raise ValueError(
            f"int32 kernel: bk={bk} overflows the signed-int32 limb-dot "
            f"accumulator (needs bk * 255**2 < 2**31, i.e. bk <= "
            f"{INT32_KERNEL_MAX_BK - 1}) — it would wrap silently"
        )
    return functools.partial(_modmatmul_int32_kernel, p=p, k_axis=k_axis)


@functools.partial(
    jax.jit, static_argnames=("p", "bm", "bn", "bk", "interpret", "variant")
)
def modmatmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    p: int = P_DEFAULT,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
    variant: str = "f32",
) -> jnp.ndarray:
    """a [B, M, K] or [M, K]  @  b [B, K, N] or [K, N] mod p.

    int32 in [0, p); M/N/K must be multiples of the block sizes
    (ops.py handles padding and tile selection).  Always a *single*
    ``pallas_call``: a batched operand puts B on the leading grid axis;
    a 2D operand is shared across that axis via its index map (no
    broadcast copies).  2D @ 2D keeps the classic 3-axis grid.

    ``variant`` selects the tile arithmetic: ``"f32"`` is the limb-dot
    MXU kernel (bk <= 256), ``"int32"`` the native-integer tier
    (integer limb dots + uint32 Barrett; bk bounded only by the int32
    accumulator, so deep contractions fit in one tile).
    """
    if p >= 1 << 16:
        raise ValueError("kernel requires p < 2**16")
    grid, a_spec, b_spec, o_spec, out_shape, _, k_axis = _grid_and_specs(
        a, b, bm, bn, bk
    )
    kernel = _base_kernel(variant, p, bk, k_axis)
    return _launch(kernel, grid, [a_spec, b_spec], o_spec, out_shape, interpret, (a, b))


def modmatmul_int32_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    p: int = P_DEFAULT,
    bm: int = 128,
    bn: int = 128,
    bk: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Convenience alias: the native-int32 variant of the Pallas kernel."""
    return modmatmul_pallas(
        a, b, p=p, bm=bm, bn=bn, bk=bk, interpret=interpret, variant="int32"
    )


@functools.partial(
    jax.jit,
    static_argnames=("p", "ncols", "bm", "bn", "bk", "interpret", "variant"),
)
def modmatmul_masked_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    v: jnp.ndarray,
    key: jnp.ndarray,
    p: int = P_DEFAULT,
    ncols: int = 0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
    variant: str = "f32",
) -> jnp.ndarray:
    """Fused blinding: ``a @ b + v @ R(key)  (mod p)`` in one kernel.

    ``v`` is a 2D [M, z] constant (the secret/blinding Vandermonde
    columns, zero-padded rows past the logical M) and R is the
    counter-based threefry mask of ``core.gf.field_mask`` for shape
    [batch, z, ncols] — generated *inside* the output tile on the last
    K step, never materialized.  ``ncols`` is the logical (pre-padding)
    N, which anchors the per-column counters; ``key`` is a (2,) uint32
    word pair.  Output matches
    ``mod_matmul(a, b) + v @ field_mask(key, (batch, z, ncols))``
    bit-exactly.
    """
    if p >= 1 << 16:
        raise ValueError("kernel requires p < 2**16")
    grid, a_spec, b_spec, o_spec, out_shape, batch, k_axis = _grid_and_specs(
        a, b, bm, bn, bk
    )
    z = v.shape[-1]
    nbatch = 1 if batch is None else batch
    if nbatch * z * ncols >= 1 << 32:
        raise ValueError(
            f"fused mask counter space exhausted: batch*z*ncols = "
            f"{nbatch * z * ncols} >= 2**32 — counters would wrap and "
            f"reuse mask values"
        )
    batched = batch is not None
    if batched:
        v_spec = pl.BlockSpec((bm, z), lambda bb, i, j, kk: (i, 0))
        key_spec = pl.BlockSpec((1, 2), lambda bb, i, j, kk: (0, 0))
    else:
        v_spec = pl.BlockSpec((bm, z), lambda i, j, kk: (i, 0))
        key_spec = pl.BlockSpec((1, 2), lambda i, j, kk: (0, 0))
    base = _base_kernel(variant, p, bk, k_axis)
    nk = grid[k_axis]

    def kernel(a_ref, b_ref, v_ref, key_ref, o_ref):
        base(a_ref, b_ref, o_ref)
        _apply_fused_mask(
            o_ref, v_ref, key_ref,
            p=p, z=z, ncols=ncols, bn=bn, k_axis=k_axis, nk=nk, batched=batched,
        )

    key2 = jnp.asarray(key, jnp.uint32).reshape(1, 2)
    return _launch(
        kernel, grid, [a_spec, b_spec, v_spec, key_spec], o_spec, out_shape,
        interpret, (a, b, v, key2),
    )
