"""Pure oracle for the modmatmul kernel.

Exact int64 host arithmetic (numpy), chunked so partial sums never
overflow, plus a jnp oracle built from the same limb identity the
kernel uses (usable under jit for property tests).
"""
from __future__ import annotations

import numpy as np

from ...core.gf import Field, P_DEFAULT, mod_matmul_f32


def modmatmul_ref(a, b, p: int = P_DEFAULT) -> np.ndarray:
    """Ground-truth a @ b mod p on the host (numpy int64)."""
    return Field(p).matmul(np.asarray(a), np.asarray(b))


def modmatmul_jnp_ref(a, b, p: int = P_DEFAULT):
    """Portable jnp oracle (f32 limb math, no Pallas)."""
    return mod_matmul_f32(a, b, p)
