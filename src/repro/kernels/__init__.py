"""Pallas TPU kernels for the paper's compute hot-spot: GF(p) matrix
multiplication (the worker Phase-2 product H = F_A * F_B).  Each kernel
ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py (the
jitted public wrapper) and ref.py (oracle)."""
