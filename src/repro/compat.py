"""JAX API-drift shims.

The codebase targets current JAX, but must degrade gracefully on older
installs (this container ships 0.4.x).  Each shim resolves the newest
spelling first:

* ``shard_map``: ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old), where the replication
  check kwarg is ``check_vma`` vs ``check_rep``.

``jax.experimental.pallas.tpu`` CompilerParams naming drift is handled
locally in ``repro.kernels.modmatmul.kernel``.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map`` with the new-API signature."""
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return _shard_map(f, **kwargs)
