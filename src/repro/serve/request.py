"""Request model and load report for the private-inference serving tier.

A :class:`Request` is one user's secure-matmul demand: activation rows
``x`` against the engine's private weight matrix, stamped with a
simulated arrival time and an optional absolute deadline (its SLO).
The engine moves it through a small lifecycle::

    queued ──admit──> admitted ──decode──> done
       └────shed────> shed            (deadline hopeless / pool unfit)

All timestamps live on the *simulated* clock of the replayed worker
traces — the same clock the runtime's event loop and the tracer's sim
spans use — so deadline accounting is exact and deterministic per
seed.  :class:`EngineReport` aggregates a finished run into the
numbers the serving benchmark publishes: sustained throughput and
latency percentiles, plus the SLO/admission census.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

#: Request lifecycle states.
QUEUED = "queued"
ADMITTED = "admitted"
DONE = "done"
SHED = "shed"


@dataclasses.dataclass
class Request:
    """One secure-matmul request against the engine's weight matrix."""

    rid: int
    x: np.ndarray  # [rows, k] activation rows (source-1 operand)
    arrival: float  # simulated submission time
    deadline: Optional[float]  # absolute SLO deadline, None = best-effort
    state: str = QUEUED
    launch: float = math.nan  # Phase-1 upload start of the serving replay
    completion: float = math.nan  # decode acceptance (absolute)
    replay: int = -1  # session replay index that served it
    shed_reason: Optional[str] = None
    y: Optional[np.ndarray] = None  # [rows, out] decoded activations

    @property
    def latency(self) -> float:
        """Arrival-to-decode latency (nan unless served)."""
        return self.completion - self.arrival

    @property
    def queue_wait(self) -> float:
        """Arrival-to-launch wait (nan unless launched)."""
        return self.launch - self.arrival

    @property
    def met_deadline(self) -> bool:
        """Served and inside its SLO (best-effort requests always
        count as met once served; shed requests never do)."""
        if self.state != DONE:
            return False
        if self.deadline is None:
            return True
        return bool(self.completion <= self.deadline + 1e-9)


@dataclasses.dataclass
class EngineReport:
    """Aggregate outcome of one :meth:`ServingEngine.run`."""

    requests: List[Request]
    replays: int  # protocol replays launched
    makespan: float  # first arrival -> last decode acceptance

    @property
    def served(self) -> List[Request]:
        return [r for r in self.requests if r.state == DONE]

    @property
    def shed(self) -> List[Request]:
        return [r for r in self.requests if r.state == SHED]

    @property
    def deadline_misses(self) -> int:
        """Served requests that blew their SLO (shed counts separately)."""
        return sum(1 for r in self.served if not r.met_deadline)

    @property
    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.served])

    @property
    def throughput(self) -> float:
        """Served requests per unit simulated time over the makespan."""
        if self.makespan <= 0:
            return 0.0
        return len(self.served) / self.makespan

    def percentile(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if lat.size else math.nan

    def summary(self) -> dict:
        """The benchmark-facing scalar view (BENCH_serve.json leaves)."""
        return {
            "requests": len(self.requests),
            "served": len(self.served),
            "shed": len(self.shed),
            "deadline_misses": self.deadline_misses,
            "replays": self.replays,
            "makespan": round(self.makespan, 9),
            "throughput": round(self.throughput, 9),
            "p50_latency": round(self.percentile(50), 9),
            "p95_latency": round(self.percentile(95), 9),
            "p99_latency": round(self.percentile(99), 9),
        }
