"""Continuous-batching serving engine for private matmul traffic.

``ServingEngine`` multiplexes many users' requests into the batched
CMPC protocol: requests queue with simulated arrival times, an
admission controller driven by the runtime's fitted
:class:`~repro.runtime.metrics.PoolEstimate` sheds or defers load the
pool cannot carry, and admitted requests fold into protocol replays
appended to an in-flight :class:`~repro.runtime.PipelineSession` — the
request -> batch -> protocol path the ROADMAP's serving tier calls for.

Batching discipline (``mode``):

* ``"continuous"`` — a new batch launches as soon as fewer than
  ``pipe_depth`` replays remain in flight (``session.ready_at``),
  i.e. its Phase-1 upload runs *inside* the tail replay's
  Phase-2/Phase-3 window.  Requests that arrived while the pipeline
  was busy ride the very next upload instead of waiting for the pool
  to drain — that is what bounds tail latency under load.
* ``"boundary"`` — a new batch waits for every in-flight replay to
  decode (``ready_at(1)``): the classic batch-boundary server the
  benchmark compares against.

Admission control: before each launch the engine predicts the replay's
service time from its fitted pool estimate (or the shared
:class:`~repro.runtime.AutoPlanner`'s, when one drives construction
selection) and

* **sheds** a request whose deadline the prediction already rules out
  (``launch + predicted_service > deadline``), and
* **defers** load when pool-health estimates disagree or degrade — a
  recent-window estimate predicting more than ``degrade_factor`` times
  the all-history service (or predicting infeasibility while history
  says healthy) halves the admission cap until the estimates
  reconverge.

Pool reconfiguration: the pipeline's serialized occupancy assumes one
worker set, so when the trace source (e.g. an ``ElasticPool``) changes
size the engine drains in-flight work, rebuilds the session at
``base_time = busy_until()`` (the reconfiguration barrier), re-fits
the construction's spares to the new pool, and resets the hybrid
escalation state; the estimator's observations survive — the master
pool is the same physical fleet, and a post-shrink prediction on the
smaller pool is exactly what makes admission shed.

Byzantine posture: ``decode_mode="hybrid"`` (the default) starts every
pool in cheap detect mode and escalates to Berlekamp-Welch correction
after the first rejected responder — threaded through every replay the
engine launches via the session's shared
:class:`~repro.runtime.HybridState`.

Everything is deterministic per seed: arrivals, traces, the event
loop, and therefore every latency percentile the report publishes.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import List, Optional, Sequence, Union

import numpy as np

from ..core.constructions import PlanConfig
from ..core.gf import Field
from ..core.layers import choose_scales
from ..core.planner import BlockShapes, CMPCPlan, get_plan_for
from ..obs.metrics import REGISTRY
from ..obs.tracer import TRACER
from ..runtime.metrics import estimate_pool, observed_run
from ..runtime.pipeline import PipelineRun, PipelineSession
from ..runtime.pool import ElasticPool, WorkerTrace
from ..runtime.scheduler import DEFAULT_SUBSET_TRIES, HybridState
from .request import DONE, SHED, EngineReport, Request

TraceSource = Union[WorkerTrace, ElasticPool, Sequence[WorkerTrace]]


def _trace_list(traces: TraceSource) -> List[WorkerTrace]:
    """Normalize a trace source to a (cycled) list of per-replay traces."""
    if isinstance(traces, WorkerTrace):
        return [traces]
    if isinstance(traces, ElasticPool):
        return list(traces)
    out = list(traces)
    if not out or not all(isinstance(t, WorkerTrace) for t in out):
        raise ValueError(
            "traces must be a WorkerTrace, an ElasticPool, or a non-empty "
            "sequence of WorkerTrace"
        )
    return out


class ServingEngine:
    """Request queue + continuous batcher over one private weight matrix.

    ``w``: [k, out] — the layer owner's private operand (every request
    multiplies against it; per-request fixed-point scales are chosen
    from each request's own activation range, so one engine serves
    requests of very different magnitudes exactly).

    Usage: ``submit()`` requests (simulated arrival stamps), then one
    ``run()`` to drain the queue; ``report.requests`` carries each
    request's full lifecycle.  ``submit`` after ``run`` starts a new
    load wave on the same engine clock.
    """

    def __init__(
        self,
        w: np.ndarray,
        traces: TraceSource,
        config: Optional[PlanConfig] = None,
        *,
        field: Optional[Field] = None,
        seed: int = 0,
        mode: str = "continuous",
        pipe_depth: int = 2,
        max_batch: int = 8,
        slo: Optional[float] = None,
        admission: bool = True,
        degrade_factor: float = 3.0,
        recent_window: int = 5,
        decode_mode: str = "hybrid",
        verify_extras="auto",
        error_budget="auto",
        master_decode_cost: float = 0.0,
        max_subset_tries: int = DEFAULT_SUBSET_TRIES,
        backend: str = "auto",
        mesh=None,
        axis: str = "workers",
        exchange_mode: str = "all_to_all",
        planner=None,
        plan_seed: int = 0,
        validate: bool = False,
    ):
        if mode not in ("continuous", "boundary"):
            raise ValueError(f"mode must be 'continuous' or 'boundary', got {mode!r}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.w = np.asarray(w, np.float64)
        if self.w.ndim != 2:
            raise ValueError(f"w must be [k, out], got {self.w.shape}")
        self.config = config or PlanConfig()
        self.field = field or Field()
        self.seed = seed
        self.mode = mode
        if pipe_depth < 2:
            raise ValueError(
                f"pipe_depth must be >= 2 (1 is 'boundary' mode), got {pipe_depth}"
            )
        self.pipe_depth = int(pipe_depth)
        self.max_batch = int(max_batch)
        self.slo = slo
        self.admission = admission
        self.degrade_factor = float(degrade_factor)
        self.recent_window = int(recent_window)
        self.planner = planner
        self.validate = validate
        self._session_kw = dict(
            verify_extras=verify_extras,
            master_decode_cost=master_decode_cost,
            mesh=mesh,
            axis=axis,
            mode=exchange_mode,
            backend=backend,
            plan_seed=plan_seed,
            decode_mode=decode_mode,
            error_budget=error_budget,
            max_subset_tries=max_subset_tries,
        )
        self._decode_mode = decode_mode
        self._plan_seed = plan_seed
        k, out = self.w.shape
        if k % self.config.s:
            raise ValueError(
                f"s={self.config.s} must divide w's inner dim k={k}"
            )
        if out % self.config.t:
            raise ValueError(
                f"t={self.config.t} must divide w's output dim {out}"
            )

        self._traces = _trace_list(traces)
        self._t_idx = 0
        self._rows: Optional[int] = None  # per-request row count, fixed
        self._wq_cache: dict = {}  # scale -> encoded W
        self._queue: List[Request] = []
        self._all: List[Request] = []
        self._next_rid = 0
        self._obs: list = []  # engine-side ObservedRun history
        self._session: Optional[PipelineSession] = None
        self._pool_n: Optional[int] = None
        self._cfg_fit: Optional[PlanConfig] = None
        self._clock = 0.0  # reconfiguration barrier carries across sessions
        self._replays_total = 0  # across sessions/reconfigurations

    # -- submission ------------------------------------------------------

    def submit(
        self,
        x: np.ndarray,
        arrival: float,
        deadline: Optional[float] = None,
    ) -> Request:
        """Queue one request: ``x`` [rows, k] activation rows arriving
        at simulated time ``arrival``.  ``deadline`` is absolute; when
        ``None`` and the engine has an ``slo``, it defaults to
        ``arrival + slo``.  Returns the live :class:`Request` record
        (mutated in place as the engine serves it)."""
        x = np.asarray(x, np.float64)
        if x.ndim == 1:
            x = x[None]
        if x.ndim != 2 or x.shape[1] != self.w.shape[0]:
            raise ValueError(
                f"x must be [rows, k={self.w.shape[0]}], got {x.shape}"
            )
        if self._rows is None:
            if x.shape[0] % self.config.t:
                raise ValueError(
                    f"t={self.config.t} must divide request rows {x.shape[0]}"
                )
            self._rows = int(x.shape[0])
        elif x.shape[0] != self._rows:
            raise ValueError(
                f"request rows {x.shape[0]} != engine rows {self._rows} "
                "(one batched plan serves every request)"
            )
        if deadline is None and self.slo is not None:
            deadline = float(arrival) + float(self.slo)
        req = Request(
            rid=self._next_rid,
            x=x,
            arrival=float(arrival),
            deadline=deadline,
        )
        self._next_rid += 1
        self._queue.append(req)
        self._all.append(req)
        REGISTRY.counter("serve.requests").inc()
        return req

    # -- pool health / admission ----------------------------------------

    def _estimate_all(self):
        if self.planner is not None:
            return self.planner.estimate()
        return estimate_pool(self._obs)

    def _predicted_service(self) -> tuple:
        """(service prediction or None, degraded flag).

        The prediction is the more pessimistic of the all-history and
        recent-window fits; ``degraded`` flags the two disagreeing by
        more than ``degrade_factor`` (or recent infeasibility), which
        is the defer signal.  ``None`` = no observations yet: admit
        optimistically and let the first replays train the estimator.
        """
        cfg = self._cfg_fit
        args = (cfg.n_workers, cfg.decode_threshold, self._pool_n)
        est_all = self._estimate_all()
        pred_all = (
            est_all.predict_completion(*args) if est_all.n_runs else None
        )
        pred_recent = None
        if len(self._obs) >= self.recent_window:
            est_recent = estimate_pool(self._obs[-self.recent_window:])
            pred_recent = est_recent.predict_completion(*args)
        if pred_all is None and pred_recent is None:
            return None, False
        degraded = (
            pred_all is not None
            and pred_recent is not None
            and math.isfinite(pred_all)
            and (
                not math.isfinite(pred_recent)
                or pred_recent > self.degrade_factor * pred_all
            )
        )
        finite = [
            p for p in (pred_all, pred_recent)
            if p is not None and math.isfinite(p)
        ]
        predicted = max(finite) if finite else float("inf")
        return predicted, degraded

    def _shed(self, req: Request, t: float, reason: str) -> None:
        req.state = SHED
        req.shed_reason = reason
        REGISTRY.counter("serve.shed").inc()
        if TRACER.enabled:
            TRACER.sim_event(
                "serve.shed", float(t), track=("request", req.rid),
                request=req.rid, reason=reason,
            )

    def _admit(self, t_launch: float) -> List[Request]:
        """FIFO admission over requests already arrived at ``t_launch``,
        shedding hopeless deadlines and halving the cap while the pool
        estimates disagree (degraded => defer the tail to later
        launches).  Mutates the queue; returns the admitted batch."""
        candidates = [r for r in self._queue if r.arrival <= t_launch + 1e-12]
        if not self.admission:
            batch = candidates[: self.max_batch]
            for r in batch:
                self._queue.remove(r)
            return batch
        predicted, degraded = self._predicted_service()
        cap = self.max_batch if not degraded else max(1, self.max_batch // 2)
        admitted: List[Request] = []
        for r in candidates:
            if len(admitted) == cap:
                break  # deferred to a later launch, not shed
            if (
                r.deadline is not None
                and predicted is not None
                and t_launch + predicted > r.deadline + 1e-9
            ):
                self._queue.remove(r)
                self._shed(r, t_launch, "deadline")
                continue
            self._queue.remove(r)
            admitted.append(r)
        return admitted

    # -- session / pool management --------------------------------------

    def _peek_trace(self) -> WorkerTrace:
        return self._traces[self._t_idx % len(self._traces)]

    def _reconfigure(self, n: int) -> bool:
        """(Re)build the session for a pool of ``n`` workers at the
        reconfiguration barrier.  Returns False when the pool cannot
        seat the construction (caller sheds the remaining queue)."""
        if self._session is not None:
            self._clock = self._session.busy_until()
        try:
            cfg = self.config.fit_to_pool(n)
        except ValueError:
            return False
        self._pool_n = n
        self._cfg_fit = cfg
        hybrid = (
            HybridState() if self._decode_mode == "hybrid" else None
        )
        if self.planner is not None:
            self._session = PipelineSession(
                None, planner=self.planner, seed=self.seed,
                base_time=self._clock, hybrid_state=hybrid,
                **self._session_kw,
            )
        else:
            plan = self._plan_for(cfg)
            self._session = PipelineSession(
                plan, seed=self.seed, base_time=self._clock,
                hybrid_state=hybrid, **self._session_kw,
            )
        return True

    def _plan_for(self, cfg: PlanConfig) -> CMPCPlan:
        k, out = self.w.shape
        shapes = BlockShapes(
            k=k, ma=self._rows, mb=out, s=cfg.s, t=cfg.t
        )
        return get_plan_for(cfg, shapes, field=self.field, seed=self._plan_seed)

    def _wq(self, scale: int) -> np.ndarray:
        wq = self._wq_cache.get(scale)
        if wq is None:
            wq = self.field.encode(self.w, scale)
            self._wq_cache[scale] = wq
        return wq

    # -- the batcher loop ------------------------------------------------

    def run(self) -> EngineReport:
        """Drain the queue: admit, launch, decode, account.  Returns the
        :class:`EngineReport`; every submitted request ends ``done`` or
        ``shed`` — a drained queue leaves nothing in flight."""
        k_dim, out = self.w.shape
        with TRACER.span("serve.run", requests=len(self._queue)):
            while self._queue:
                trace = self._peek_trace()
                if self._pool_n != trace.n:
                    if not self._reconfigure(trace.n):
                        # Pool cannot seat the construction: nothing this
                        # engine launches can complete — shed the queue.
                        t = self._clock
                        for r in list(self._queue):
                            self._shed(r, t, "pool")
                        self._queue.clear()
                        break
                t_ready = self._session.ready_at(
                    self.pipe_depth if self.mode == "continuous" else 1
                )
                t_launch = max(t_ready, min(r.arrival for r in self._queue))
                batch = self._admit(t_launch)
                if not batch:
                    continue  # everything eligible was shed; queue shrank
                self._t_idx += 1
                scales = [
                    choose_scales(
                        k_dim,
                        float(np.abs(r.x).max() + 1e-9),
                        float(np.abs(self.w).max() + 1e-9),
                        self.field.p,
                    )
                    for r in batch
                ]
                aq = np.stack([
                    self.field.encode(r.x.T, s) for r, s in zip(batch, scales)
                ])  # [batch, k, rows]
                bq = np.stack([self._wq(s) for s in scales])  # [batch, k, out]
                replay = self._session.append(
                    aq, bq, trace, not_before=t_launch,
                    obs_attrs={"n_requests": len(batch)},
                )
                self._obs.append(observed_run(replay.metrics, start=replay.start))
                self._replays_total += 1
                REGISTRY.counter("serve.replays").inc()
                yq = np.asarray(replay.y)  # [batch, rows, out] field values
                for i, (r, s) in enumerate(zip(batch, scales)):
                    if self.validate:
                        want = self.field.matmul(aq[i].T, bq[i])
                        if not np.array_equal(yq[i], want):
                            raise AssertionError(
                                f"request {r.rid}: decode disagrees with the "
                                f"field oracle on replay {replay.index}"
                            )
                    r.y = self.field.decode(yq[i], s * s)
                    r.state = DONE
                    r.launch = replay.start
                    r.completion = replay.completion
                    r.replay = replay.index
                    if not r.met_deadline:
                        REGISTRY.counter("serve.deadline_miss").inc()
                    if TRACER.enabled:
                        rtrack = ("request", r.rid)
                        TRACER.sim_span(
                            "serve.queue", r.arrival, replay.start,
                            track=rtrack, request=r.rid, replay=replay.index,
                        )
                        TRACER.sim_span(
                            "serve.service", replay.start, replay.completion,
                            track=rtrack, request=r.rid, replay=replay.index,
                            deadline_met=r.met_deadline,
                        )
        return self.report()

    def report(self) -> EngineReport:
        done = [r for r in self._all if r.state == DONE]
        makespan = 0.0
        if done:
            makespan = max(r.completion for r in done) - min(
                r.arrival for r in self._all
            )
        return EngineReport(
            requests=list(self._all),
            replays=self._replays_total,
            makespan=makespan,
        )

    def pipeline_result(self) -> PipelineRun:
        """The underlying session's :class:`PipelineRun` (current pool's
        session only — earlier sessions end at reconfigurations)."""
        if self._session is None:
            raise ValueError("nothing launched yet")
        return self._session.result()
