"""Private-inference serving tier: request -> batch -> protocol.

The one-off demos ran a secure matmul per call; this package turns the
batched protocol + edge runtime into a *serving engine* for many
concurrent users:

* ``request`` — the :class:`Request` lifecycle (queued -> admitted ->
  done, or shed) on the simulated clock, and the :class:`EngineReport`
  the load benchmark publishes (throughput, latency percentiles, SLO
  census),
* ``engine``  — :class:`ServingEngine`: a request queue feeding a
  continuous batcher that appends replays to an in-flight
  ``runtime.PipelineSession`` (no batch boundaries), with
  ``PoolEstimate``-driven admission control (shed hopeless deadlines,
  defer when pool-health estimates disagree), hybrid Byzantine decode,
  elastic-pool reconfiguration barriers, and live ``AutoPlanner``
  feeding.

Everything downstream of ``submit()`` is deterministic per seed —
arrivals, traces, admission, and every published percentile.
"""
from .engine import ServingEngine  # noqa: F401
from .request import (  # noqa: F401
    ADMITTED,
    DONE,
    QUEUED,
    SHED,
    EngineReport,
    Request,
)
