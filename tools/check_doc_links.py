#!/usr/bin/env python
"""Check that relative links in README.md and docs/*.md resolve.

Scans markdown links ``[text](target)`` and inline reference paths,
skips absolute URLs (http/https/mailto) and pure anchors, strips
``#fragment`` suffixes, and resolves each remaining target relative to
the file that contains it.  Exits non-zero listing every broken link —
the CI docs smoke step runs this so a moved file or a typo'd path
fails the build instead of rotting in the docs.

Usage: python tools/check_doc_links.py [repo_root]
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: str) -> list:
    files = []
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        files.append(readme)
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        files.extend(
            os.path.join(docs, f)
            for f in sorted(os.listdir(docs))
            if f.endswith(".md")
        )
    return files


def broken_links(root: str) -> list:
    broken = []
    for path in doc_files(root):
        with open(path) as f:
            text = f.read()
        base = os.path.dirname(path)
        for target in LINK_RE.findall(text):
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.join(base, rel)):
                broken.append((os.path.relpath(path, root), target))
    return broken


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))
    )
    files = doc_files(root)
    if not files:
        print("no README.md or docs/*.md found", file=sys.stderr)
        return 1
    broken = broken_links(root)
    for path, target in broken:
        print(f"BROKEN {path}: ({target})", file=sys.stderr)
    print(f"checked {len(files)} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
