#!/usr/bin/env python
"""Differential fuzz of the GF(p) matmul backends against the host oracle.

Runs ``repro.kernels.modmatmul.fuzz.run_fuzz``: random (B, M, K, N)
shapes, primes, and adversarial operand distributions through every
backend (f32limb, int32, pallas-interpret, pallas_int32-interpret, CRT),
each checked bit-for-bit against an arbitrary-precision host matmul.
Deterministic per seed; exits 1 on any mismatch.

Usage: python tools/fuzz_kernels.py [--examples 24] [--seed 0]
                                    [--engines f32limb int32 ...] [-q]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--examples", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engines", nargs="*", default=None)
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()

    from repro.kernels.modmatmul.fuzz import ENGINES, run_fuzz

    engines = args.engines or list(ENGINES)
    unknown = [e for e in engines if e not in ENGINES]
    if unknown:
        ap.error(f"unknown engines {unknown}; known: {list(ENGINES)}")

    mismatches = run_fuzz(
        examples=args.examples, seed=args.seed, engines=engines,
        verbose=not args.quiet,
    )
    if mismatches:
        print(f"\n{len(mismatches)} ORACLE MISMATCHES:")
        for m in mismatches:
            print("  " + m.describe())
        return 1
    print(
        f"fuzz ok: {args.examples} cases x {len(engines)} engines "
        f"(seed {args.seed}), zero oracle mismatches"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
