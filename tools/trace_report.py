#!/usr/bin/env python
"""Summarize a Chrome/Perfetto trace emitted by the observability layer.

Reads the ``BENCH_edge.trace.json`` sidecar (or any trace written by
:func:`repro.obs.write_chrome`) and prints, without needing the Perfetto
UI:

* **per-phase durations** — p50/p95/max per span name, wall-clock and
  simulated-clock tracks reported separately (wall in microseconds, sim
  in simulated seconds),
* **straggler attribution** — per worker lane, total simulated time in
  ``phase2.compute`` and mean ``phase3.respond`` latency, slowest lanes
  first: the workers that push the fastest-subset barrier out,
* **cache hit rates and counters** — from the embedded ``repro_metrics``
  snapshot (plan / subset / decode-check probes, registry counters),
* **bytes per link** — the ``pipeline``/``replay`` span attributes that
  carry wire-byte totals, when present.

Usage: python tools/trace_report.py [BENCH_edge.trace.json] [--top 8]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict


def pct(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q / 100 * (len(xs) - 1))))
    return xs[i]


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def complete_events(trace: dict):
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "X":
            yield ev


def phase_table(trace: dict) -> list:
    """[(clock, name, count, p50, p95, max)] — wall rows in us, sim in s."""
    by_name = defaultdict(list)
    for ev in complete_events(trace):
        clock = "wall" if ev.get("pid") == 1 else "sim"
        by_name[(clock, ev["name"])].append(float(ev.get("dur", 0.0)))
    rows = []
    for (clock, name), durs in sorted(by_name.items()):
        scale = 1.0 if clock == "wall" else 1e-6  # sim ts are s * 1e6
        rows.append(
            (
                clock,
                name,
                len(durs),
                pct(durs, 50) * scale,
                pct(durs, 95) * scale,
                max(durs) * scale,
            )
        )
    return rows


def straggler_table(trace: dict, top: int) -> list:
    """Slowest worker lanes by total phase2.compute sim time."""
    compute = defaultdict(float)
    respond = defaultdict(list)
    for ev in complete_events(trace):
        if ev.get("pid") != 2:
            continue
        lane = ev.get("tid")
        if ev["name"] == "phase2.compute":
            compute[lane] += float(ev.get("dur", 0.0)) * 1e-6
        elif ev["name"] == "phase3.respond":
            respond[lane].append(float(ev.get("dur", 0.0)) * 1e-6)
    lanes = sorted(compute, key=lambda w: -compute[w])[:top]
    names = thread_names(trace)
    return [
        (
            names.get((2, w), str(w)),
            compute[w],
            sum(respond[w]) / len(respond[w]) if respond[w] else 0.0,
        )
        for w in lanes
    ]


def thread_names(trace: dict) -> dict:
    out = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            out[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return out


def serving_table(trace: dict) -> dict:
    """Queueing-vs-protocol attribution from the serving tier's request
    lanes: every served request carries a ``serve.queue`` span
    (arrival -> launch) and a ``serve.service`` span (launch ->
    completion, the protocol replay it rode), so the split says whether
    latency went to waiting for admission or to the protocol itself."""
    queue, service = [], []
    shed = 0
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "X" and ev["name"] == "serve.queue":
            queue.append(float(ev.get("dur", 0.0)) * 1e-6)
        elif ev.get("ph") == "X" and ev["name"] == "serve.service":
            service.append(float(ev.get("dur", 0.0)) * 1e-6)
        elif ev.get("ph") == "i" and ev.get("name") == "serve.shed":
            shed += 1
    if not (queue or service or shed):
        return {}
    q_tot, s_tot = sum(queue), sum(service)
    return {
        "requests": len(service),
        "shed": shed,
        "queue_total_s": q_tot,
        "queue_mean_s": q_tot / len(queue) if queue else 0.0,
        "service_total_s": s_tot,
        "service_mean_s": s_tot / len(service) if service else 0.0,
        "queueing_fraction": q_tot / (q_tot + s_tot) if q_tot + s_tot else 0.0,
    }


def cache_lines(trace: dict) -> list:
    metrics = trace.get("repro_metrics", {})
    lines = []
    for probe, info in sorted(metrics.get("probes", {}).items()):
        if not isinstance(info, dict) or "error" in info:
            lines.append(f"  {probe}: unavailable ({info!r})")
            continue
        hits = info.get("hits", 0)
        misses = info.get("misses", 0)
        total = hits + misses
        rate = hits / total if total else 0.0
        extra = {
            k: v for k, v in info.items() if k not in ("hits", "misses")
        }
        lines.append(
            f"  {probe}: {hits}/{total} hits ({rate:.1%})"
            + (f"  {extra}" if extra else "")
        )
    for name, val in sorted(metrics.get("counters", {}).items()):
        lines.append(f"  counter {name}: {val}")
    for name, val in sorted(metrics.get("gauges", {}).items()):
        lines.append(f"  gauge {name}: {val:g}")
    return lines


def byte_lines(trace: dict) -> list:
    """Wire-byte attributes carried on replay/pipeline spans."""
    lines = []
    for ev in complete_events(trace):
        args = ev.get("args", {})
        for key in sorted(args):
            if "bytes" in key:
                lines.append(f"  {ev['name']}: {key}={args[key]}")
    return lines


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "path",
        nargs="?",
        default=os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_edge.trace.json",
        ),
    )
    ap.add_argument("--top", type=int, default=8, help="straggler lanes shown")
    args = ap.parse_args()
    if not os.path.exists(args.path):
        print(
            f"{args.path}: not found (run `make bench-edge TRACE=1` first)",
            file=sys.stderr,
        )
        return 1
    trace = load(args.path)
    n = sum(1 for _ in complete_events(trace))
    print(f"{args.path}: {len(trace.get('traceEvents', []))} events ({n} spans)")
    if trace.get("repro_dropped_events"):
        print(f"  WARNING: {trace['repro_dropped_events']} events dropped at cap")

    print("\nper-phase durations (wall in us, sim in simulated s):")
    print(f"  {'clock':<5} {'span':<34} {'count':>6} {'p50':>10} {'p95':>10} {'max':>10}")
    for clock, name, count, p50, p95, mx in phase_table(trace):
        print(
            f"  {clock:<5} {name:<34} {count:>6} {p50:>10.4g} {p95:>10.4g} {mx:>10.4g}"
        )

    stragglers = straggler_table(trace, args.top)
    if stragglers:
        print(f"\nstraggler attribution (top {len(stragglers)} lanes by compute):")
        print(f"  {'lane':<12} {'compute_s':>10} {'respond_mean_s':>15}")
        for lane, comp, resp in stragglers:
            print(f"  {lane:<12} {comp:>10.4g} {resp:>15.4g}")

    serving = serving_table(trace)
    if serving:
        print("\nserving attribution (sim s):")
        print(
            f"  {serving['requests']} requests served, {serving['shed']} shed; "
            f"queueing {serving['queue_total_s']:.4g}s "
            f"(mean {serving['queue_mean_s']:.4g}) vs protocol "
            f"{serving['service_total_s']:.4g}s "
            f"(mean {serving['service_mean_s']:.4g}) — "
            f"{serving['queueing_fraction']:.1%} of latency is queueing"
        )

    caches = cache_lines(trace)
    if caches:
        print("\ncaches and counters:")
        for line in caches:
            print(line)

    bytes_ = byte_lines(trace)
    if bytes_:
        print("\nwire bytes:")
        for line in bytes_[: args.top]:
            print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
