#!/usr/bin/env python
"""Fail when version-drifting JAX API spellings leak out of the shims.

JAX has renamed three APIs this repo depends on, and each rename is
absorbed in exactly one place:

* ``shard_map``        — ``jax.shard_map`` vs
                          ``jax.experimental.shard_map.shard_map``
                          (and ``check_vma`` vs ``check_rep``), shimmed
                          in ``src/repro/compat.py``,
* ``AxisType``         — ``jax.sharding.AxisType`` / the ``axis_types=``
                          kwarg of ``jax.make_mesh``, probed in
                          ``src/repro/launch/mesh.py``,
* ``CompilerParams``   — ``pltpu.CompilerParams`` vs the older
                          ``pltpu.TPUCompilerParams``, resolved in
                          ``src/repro/kernels/modmatmul/kernel.py``.

Any *other* module spelling these raw (an attribute access, a
``from jax... import``, or a ``getattr(mod, "...")`` probe) reopens the
version drift the shims exist to close.  This linter walks the AST of
every Python file under src/, tests/, benchmarks/, examples/, and
tools/ — comments and docstrings can mention the names freely; code
cannot.  Importing the *shimmed* symbols (``repro.compat.shard_map``,
``repro.launch.mesh`` helpers) is of course fine: only imports from
``jax``-rooted modules and raw attribute/getattr spellings count.

Usage: python tools/check_api_shims.py [repo_root]
"""
from __future__ import annotations

import ast
import os
import sys

# Attribute / import names that must only appear inside their shim.
BANNED = {"shard_map", "AxisType", "CompilerParams", "TPUCompilerParams"}

# The shim modules (relative to the repo root) allowed to spell them.
ALLOWED = {
    os.path.join("src", "repro", "compat.py"),
    os.path.join("src", "repro", "launch", "mesh.py"),
    os.path.join("src", "repro", "kernels", "modmatmul", "kernel.py"),
}

SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def _is_jax_module(name: str) -> bool:
    return name == "jax" or name.startswith("jax.")


class _Visitor(ast.NodeVisitor):
    def __init__(self):
        self.hits = []  # (lineno, description)

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in BANNED:
            self.hits.append((node.lineno, f"attribute .{node.attr}"))
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            parts = set(alias.name.split("."))
            if parts & BANNED:
                self.hits.append((node.lineno, f"import {alias.name}"))
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        mod_parts = set(mod.split("."))
        if mod_parts & BANNED:
            self.hits.append((node.lineno, f"from {mod} import ..."))
        elif _is_jax_module(mod):
            for alias in node.names:
                if alias.name in BANNED:
                    self.hits.append(
                        (node.lineno, f"from {mod} import {alias.name}")
                    )
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        # getattr(mod, "CompilerParams") probes re-open the drift too.
        func = node.func
        if isinstance(func, ast.Name) and func.id == "getattr":
            for arg in node.args[1:]:
                if isinstance(arg, ast.Constant) and arg.value in BANNED:
                    self.hits.append(
                        (node.lineno, f'getattr(..., "{arg.value}")')
                    )
        self.generic_visit(node)


def python_files(root: str) -> list:
    files = []
    for d in SCAN_DIRS:
        top = os.path.join(root, d)
        if not os.path.isdir(top):
            continue
        for dirpath, _, names in os.walk(top):
            for name in sorted(names):
                if name.endswith(".py"):
                    files.append(os.path.join(dirpath, name))
    return sorted(files)


def violations(root: str) -> list:
    out = []
    for path in python_files(root):
        rel = os.path.relpath(path, root)
        if rel in ALLOWED:
            continue
        with open(path) as f:
            source = f.read()
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError as exc:
            out.append((rel, exc.lineno or 0, f"syntax error: {exc.msg}"))
            continue
        visitor = _Visitor()
        visitor.visit(tree)
        out.extend((rel, lineno, what) for lineno, what in visitor.hits)
    return out


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))
    )
    files = python_files(root)
    if not files:
        print("no python files found", file=sys.stderr)
        return 1
    bad = violations(root)
    for rel, lineno, what in bad:
        print(
            f"SHIM-BYPASS {rel}:{lineno}: {what} — route through "
            f"repro.compat / repro.launch.mesh / the pallas kernel shim",
            file=sys.stderr,
        )
    print(f"checked {len(files)} files, {len(bad)} shim bypasses")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
