#!/usr/bin/env python
"""Diff freshly regenerated BENCH_*.json against the committed snapshots.

The benchmark JSONs mix two kinds of leaves:

* **deterministic** — worker counts, seeded simulated completion times,
  ratios of simulated times, decode-subset statistics, oracle flags.
  These must match the committed snapshot *exactly*: a drift means the
  protocol/runtime behaviour changed, not the machine.
* **wall-clock** — ``*_us*`` microsecond timings measured on whatever
  machine ran the benchmark.  These scale with machine speed, so each
  fresh/committed ratio is normalized by the *median* ratio across all
  wall-clock leaves (the machine-speed estimate) and must stay within a
  tolerance band of it.  Pure wall-clock ratios (``speedup``,
  ``amortization``) are already dimensionless and get the band directly.

The committed baseline is read from git (``git show <ref>:<file>``), so
the tool needs no extra snapshot files; run the benchmarks first, then
this.  A missing baseline (file not in the ref) is reported and
skipped — the commit that introduces a benchmark has nothing to diff.

Usage: python tools/bench_diff.py [--ref HEAD] [--band 2.5]
                                  [--files BENCH_protocol.json ...]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

DEFAULT_FILES = ("BENCH_protocol.json", "BENCH_edge.json", "BENCH_serve.json")

# Required top-level sections per benchmark file.  A regenerated JSON
# missing one of these means a report section silently fell out of the
# harness (the leaf diff only catches that when a baseline exists).
KNOWN_SCHEMA = {
    "BENCH_protocol.json": (
        "bench", "config", "batches", "phases_us", "padding_waste",
        "sharded_batched", "int_backends",
    ),
    "BENCH_edge.json": (
        "bench", "config", "scenarios", "per_link", "pipelined",
        "adaptive", "byzantine", "batched_replay", "sharded_batched",
        "subset_cache",
    ),
    "BENCH_serve.json": ("bench", "config", "load", "admission"),
}

# Leaf-key fragments measured in host microseconds (machine-dependent).
WALLCLOCK_MARKERS = ("_us", "us_per")
# Dimensionless ratios of wall-clock measurements.
RATIO_KEYS = {"speedup", "speedup_vs_pr1", "amortization"}


def flatten(node, prefix="") -> dict:
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(flatten(v, f"{prefix}[{i}]"))
    else:
        out[prefix] = node
    return out


def leaf_key(path: str) -> str:
    """Last dict key on the path (list indices stripped)."""
    return path.rsplit(".", 1)[-1].split("[")[0]


def is_wallclock(path: str) -> bool:
    """Any path component carrying a microsecond marker makes the leaf
    wall-clock: ``phases_us.reduce`` is a timing even though the leaf
    key is just the phase name."""
    return any(
        m in part
        for part in path.split(".")
        for m in WALLCLOCK_MARKERS
    )


def is_ratio(path: str) -> bool:
    return leaf_key(path) in RATIO_KEYS


def committed_json(root: str, name: str, ref: str):
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:{name}"],
            cwd=root,
            capture_output=True,
            check=True,
        ).stdout
    except subprocess.CalledProcessError:
        return None
    return json.loads(blob)


def diff_file(root: str, name: str, ref: str, band: float) -> list:
    """Return a list of problem strings for one benchmark file."""
    path = os.path.join(root, name)
    if not os.path.exists(path):
        return [f"{name}: fresh file missing (run the benchmark first)"]
    with open(path) as f:
        fresh = json.load(f)
    # Known-schema check runs even without a baseline: the commit that
    # introduces a section still proves the harness emits it.
    problems = [
        f"{name}: schema: missing top-level section {k!r}"
        for k in KNOWN_SCHEMA.get(name, ())
        if k not in fresh
    ]
    base = committed_json(root, name, ref)
    if base is None:
        print(f"{name}: no baseline at {ref}, schema check only")
        return problems
    fb, ff = flatten(base), flatten(fresh)

    for p in sorted(set(fb) - set(ff)):
        problems.append(f"{name}: leaf removed: {p}")
    for p in sorted(set(ff) - set(fb)):
        problems.append(f"{name}: leaf added: {p}")

    shared = sorted(set(fb) & set(ff))
    ratios = []  # (path, fresh/committed) over wall-clock leaves
    for p in shared:
        old, new = fb[p], ff[p]
        if is_wallclock(p):
            if isinstance(old, (int, float)) and isinstance(new, (int, float)):
                if old > 0 and new > 0:
                    ratios.append((p, new / old))
                elif (old > 0) != (new > 0):
                    problems.append(
                        f"{name}: {p}: wall-clock sign flip {old} -> {new}"
                    )
        elif is_ratio(p):
            if old > 0 and not (1.0 / band <= new / old <= band):
                problems.append(
                    f"{name}: {p}: timing ratio {old} -> {new} drifted "
                    f"beyond {band}x"
                )
        else:
            same = (
                abs(new - old) <= 1e-9 * max(1.0, abs(old))
                if isinstance(old, float) and isinstance(new, float)
                else old == new
            )
            if not same:
                problems.append(
                    f"{name}: {p}: deterministic leaf changed "
                    f"{old!r} -> {new!r}"
                )

    if ratios:
        med = sorted(r for _, r in ratios)[len(ratios) // 2]
        for p, r in ratios:
            if not (med / band <= r <= med * band):
                problems.append(
                    f"{name}: {p}: wall-clock ratio {r:.2f} outside "
                    f"{band}x band around machine-speed median {med:.2f}"
                )
        print(
            f"{name}: {len(ratios)} wall-clock leaves, machine-speed "
            f"median {med:.2f}x vs baseline"
        )
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ref", default="HEAD", help="git ref for the baseline")
    ap.add_argument(
        "--band",
        type=float,
        default=2.5,
        help="allowed wall-clock spread around the machine-speed median",
    )
    ap.add_argument("--files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument(
        "--root",
        default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    args = ap.parse_args()

    problems = []
    checked = 0
    for name in args.files:
        # Trace sidecars (BENCH_*.trace.json) are observability output —
        # wall-clock spans differ on every run by construction, so they
        # are never diffed even when listed explicitly.
        if name.endswith(".trace.json"):
            print(f"{name}: trace sidecar, skipped")
            continue
        checked += 1
        problems.extend(diff_file(args.root, name, args.ref, args.band))
    for msg in problems:
        print(f"BENCH-DRIFT {msg}", file=sys.stderr)
    print(f"checked {checked} files, {len(problems)} drifts")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
