#!/usr/bin/env python
"""Smoke-check the tracing layer end to end: run a small replay and an
adaptive decision with the tracer on, export the Chrome/Perfetto trace,
and verify it is schema-valid and structurally complete.

Structural bar (the same one `make bench-edge TRACE=1` must clear):

* schema-valid per :func:`repro.obs.validate_chrome`,
* wall spans for all three protocol phases,
* per-worker scheduler events (share / compute / respond lanes),
* at least one ``autoplan.decide`` event whose id is echoed back as a
  ``decision_id`` on a replay span (the decision -> replay link),
* the metrics snapshot embedded under ``repro_metrics`` with all three
  cache probes reporting.

Exit 0 when everything holds; nonzero with one line per problem.
Run via ``make trace-check`` (needs PYTHONPATH=src).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import numpy as np

REQUIRED_WALL_PREFIXES = (
    "protocol.phase1.",
    "protocol.phase2.",
    "protocol.phase3.",
)
REQUIRED_SIM_NAMES = ("replay", "phase1.share", "phase2.compute", "phase3.respond")
REQUIRED_PROBES = ("plan_cache", "subset_cache", "decode_check_cache")


def build_trace():
    """One batched replay plus a short adaptive stream, traced."""
    from repro import obs
    from repro.core import protocol
    from repro.core.constructions import PlanConfig
    from repro.core.planner import BlockShapes, get_plan_for
    from repro.runtime import AutoPlanner, run_adaptive_over_pool, run_over_pool
    from repro.runtime.pool import sample_trace

    obs.TRACER.clear()
    obs.enable()
    cfg = PlanConfig("age", 2, 2, 2).resolved()
    m = 4
    plan = get_plan_for(cfg, BlockShapes(k=m, ma=m, mb=m, s=2, t=2), seed=0)
    rng = np.random.default_rng(0)
    a = rng.integers(0, plan.field.p, (m, m))
    b = rng.integers(0, plan.field.p, (m, m))
    want = plan.field.matmul(plan.field.asarray(a).T, plan.field.asarray(b))

    # Direct protocol path: phase1/2/3 wall spans including reconstruct
    # (the scheduler decodes in its own loop, so only this path emits
    # protocol.phase3.reconstruct).
    y, _ = protocol.run(plan, a, b, seed=0)
    assert np.array_equal(y, want), "trace-check protocol.run != oracle"

    res = run_over_pool(plan, a, b, sample_trace(plan.n_total, seed=1), seed=0)
    assert np.array_equal(res.y, want), "trace-check replay decode != oracle"

    K, batch = 3, 2
    ab = rng.integers(0, plan.field.p, (K, batch, m, m))
    bb = rng.integers(0, plan.field.p, (K, batch, m, m))
    traces = [sample_trace(cfg.n_total + 2, seed=10 + k) for k in range(K)]
    planner = AutoPlanner([PlanConfig("age", 2, 2, 2)], cost_m=m)
    run_adaptive_over_pool(planner, ab, bb, traces, seed=0)
    return obs


def check(obs) -> list:
    problems = []
    chrome = obs.to_chrome(obs.TRACER, metrics=obs.snapshot())
    problems += [f"schema: {p}" for p in obs.validate_chrome(chrome)]

    events = obs.TRACER.events
    names = {e["name"] for e in events}
    for prefix in REQUIRED_WALL_PREFIXES:
        if not any(n.startswith(prefix) for n in names):
            problems.append(f"no wall span named {prefix}*")
    for name in REQUIRED_SIM_NAMES:
        if name not in names:
            problems.append(f"no sim event named {name!r}")
    worker_lanes = {
        tuple(e["track"])
        for e in events
        if e["clock"] == "sim" and e["track"][0] == "worker"
    }
    if len(worker_lanes) < 2:
        problems.append(f"expected >= 2 worker lanes, got {sorted(worker_lanes)}")

    decides = {e["id"] for e in events if e["name"] == "autoplan.decide"}
    if not decides:
        problems.append("no autoplan.decide event")
    linked = {
        e["attrs"].get("decision_id")
        for e in events
        if e["name"] == "replay" and "decision_id" in e["attrs"]
    }
    if not linked:
        problems.append("no replay span carries a decision_id")
    elif not linked <= decides:
        problems.append(f"dangling decision_id(s): {sorted(linked - decides)}")

    metrics = chrome.get("repro_metrics", {})
    for probe in REQUIRED_PROBES:
        info = metrics.get("probes", {}).get(probe)
        if not isinstance(info, dict) or "error" in (info or {}):
            problems.append(f"probe {probe!r} not reporting: {info!r}")

    # The file round-trip the bench sidecar uses.
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "trace.json")
        obs.write_chrome(path, obs.TRACER, metrics=obs.snapshot())
        with open(path) as f:
            reloaded = json.load(f)
        problems += [f"reloaded schema: {p}" for p in obs.validate_chrome(reloaded)]
    return problems


def main() -> int:
    obs = build_trace()
    try:
        problems = check(obs)
    finally:
        obs.disable()
        obs.TRACER.clear()
    for msg in problems:
        print(f"TRACE-CHECK {msg}", file=sys.stderr)
    print(f"trace-check: {len(problems)} problems")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
