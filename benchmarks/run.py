"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--only fig2,fig3,...]

Prints ``name,us_per_call,derived`` CSV; per-table data lands under
results/bench/*.csv.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import (
        cmpc_comm,
        edge_runtime,
        example1,
        fig2,
        fig3,
        fig4,
        protocol_batch,
        protocol_scaling,
        roofline,
        serve_load,
    )

    modules = {
        "example1": example1,
        "fig2": fig2,
        "fig3": fig3,
        "fig4": fig4,
        "protocol_scaling": protocol_scaling,
        "protocol_batch": protocol_batch,
        "cmpc_comm": cmpc_comm,
        "edge_runtime": edge_runtime,
        "roofline": roofline,
        "serve_load": serve_load,
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules.items():
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']},{derived}")
        except Exception as e:  # keep the harness running
            failed += 1
            print(f"{name},ERROR,{e!r}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"{failed} benchmarks failed")


if __name__ == "__main__":
    main()
