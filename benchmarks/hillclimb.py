"""Perf hillclimb harness: compile a named variant of a cell, extract
roofline terms, and append to the iteration log.

    python -m benchmarks.hillclimb --cell dbrx-132b:train_4k \
        --variant moe_group16

Variants patch the architecture config (or step options) before
lowering; results land in results/hillclimb/<cell>__<variant>.json and
feed EXPERIMENTS.md §Perf.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
COLL_MULT = {"all-reduce": 2.0}


def _variants():
    return {
        "baseline": lambda cfg: cfg,
        # dbrx: group-local MoE dispatch (one group per data shard)
        "moe_group16": lambda cfg: dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=16)
        ),
        "moe_group64": lambda cfg: dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=64)
        ),
        "moe_expert_tp": lambda cfg: dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=16, expert_tp=True)
        ),
        # qwen: remat policy trade (save dots, recompute less)
        "remat_dots": lambda cfg: dataclasses.replace(cfg, remat_policy="dots"),
        "remat_none": lambda cfg: dataclasses.replace(cfg, remat_policy="none"),
    }


def run_variant(arch: str, shape_name: str, variant: str, step_kw=None):
    from repro.configs import SHAPES, get_config
    from repro.launch.hlo_cost import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step
    from repro.models.registry import build_model

    cfg = _variants()[variant](get_config(arch))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    model = build_model(cfg)
    bundle = build_step(model, mesh, shape, **(step_kw or {}))
    t0 = time.time()
    with mesh:
        compiled = bundle.lower().compile()
    walk = analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    wire = sum(v * COLL_MULT.get(k, 1.0) for k, v in walk.collectives.items())
    rec = {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "compile_s": round(time.time() - t0, 1),
        "compute_s": walk.flops / PEAK_FLOPS,
        "memory_s": walk.bytes / HBM_BW,
        "collective_s": wire / LINK_BW,
        "collective_bytes": walk.collectives,
        "hbm_temp_gb": mem.temp_size_in_bytes / 1e9,
        "hbm_args_gb": mem.argument_size_in_bytes / 1e9,
    }
    terms = {k: rec[k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["bottleneck"] = max(terms, key=terms.get)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    os.makedirs(args.out, exist_ok=True)
    rec = run_variant(arch, shape, args.variant)
    path = os.path.join(args.out, f"{arch}__{shape}__{args.variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
