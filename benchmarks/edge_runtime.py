"""Scheme comparison under edge conditions: PolyDot-CMPC vs AGE-CMPC
replayed over identical worker-pool traces.

The paper's headline claim is that AGE-CMPC needs fewer workers than
PolyDot-CMPC; at the edge that translates into completion time, because
fewer required workers means the fastest-subset barrier falls earlier
under the same straggler distribution.  This harness runs both schemes
through ``repro.runtime`` under per-scenario fault/latency models; the
trace is sampled once per (scenario, seed) at the *largest* pool size
and each scheme replays a prefix, so both face byte-identical worker
behaviour.  Every run's decode is validated against the host oracle
(``Field.matmul``) — a silent straggler-decode bug fails the benchmark.

Scenarios:

* ``all_fast``           — deterministic unit latency, no faults (the
                            paper's idealized setting; completion is
                            pure pipeline depth),
* ``stragglers_exp``     — shifted-exponential compute latency plus a
                            20% straggler population at 10x slowdown,
* ``dropouts``           — shifted-exponential latency with exactly
                            ``n_spare`` dropouts (the provisioned
                            tolerance, fully spent),
* ``heavy_tail_corrupt`` — Pareto-tailed latency plus one corrupted
                            responder; the master must spend one extra
                            confirmation before accepting a decode.

Five extra sections ride along:

* ``batched_replay``   — ``run_batch_over_pool`` replays a whole batch
                          of products through ONE straggler trace; the
                          event loop and decode-subset search are paid
                          once, so the per-product cost drops against a
                          loop of ``run_over_pool`` calls,
* ``sharded_batched``  — the same batched replay with the Phase-2
                          exchange on a REAL multi-device mesh
                          (``shard_map`` all_to_all driven by the
                          scheduler's fastest subset), in a subprocess
                          with ``--xla_force_host_platform_device_count``
                          so the forced device split cannot perturb the
                          single-device scenario numbers,
* ``per_link``         — link-resolved network models: asymmetric
                          uplink/downlink (last-mile edge) and a
                          clustered-edge topology (fast intra-cluster,
                          slow inter-cluster D2D); Phase-2 completion
                          becomes the max over each receiver's incoming
                          links, and both schemes replay byte-identical
                          ``(sender, receiver)`` delay matrices,
* ``pipelined``        — ``run_pipeline_over_pool`` keeps K batched
                          replays in flight with overlapping traces;
                          reports makespan vs the back-to-back
                          sequential replays, pipeline occupancy, and
                          the Phase-1/Phase-2 overlap reclaimed,
* ``byzantine``        — detect (confirm-and-retry) vs correct
                          (Berlekamp-Welch) corruption handling replayed
                          on byte-identical traces as the configured
                          corruption rate sweeps 0 -> 25%: per-rate p50
                          completion, responder overhead over the bare
                          decode threshold (thr + 2e vs thr + extras +
                          retries), decode failures, and the rate at
                          which correction's p50 crosses below
                          detection's,
* ``adaptive``         — the ``AutoPlanner`` feedback loop vs every
                          static candidate construction on
                          byte-identical traces, in two drifting
                          scenarios: ``degrading_links`` (the Phase-2
                          fabric slows 8x mid-stream via
                          ``TimeVaryingLinks`` — once mid-replay, then
                          permanently) and ``elastic_pool`` (an
                          ``ElasticPool`` shrinks 40 -> 22 -> 16, below
                          some candidates' worker counts).  Statics that
                          no longer fit a replay are reported with the
                          replays they *could* run; the planner switches
                          construction mid-stream and its per-replay
                          ``PlanConfig`` choices, switch/respare counts,
                          and fitted pool estimate land in the report.

Emits ``BENCH_edge.json`` at the repo root (``make bench-edge``) with
per-scenario completion statistics, worker counts, and the
PolyDot/AGE completion ratio, plus a CSV under results/bench/.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import constructions as C
from repro.core.constructions import PlanConfig
from repro.core.gf import Field
from repro.core.planner import (
    BlockShapes,
    get_plan,
    get_plan_for,
    subset_cache_info,
)
from repro.runtime import (
    AsymmetricLinks,
    AutoPlanner,
    ClusteredEdge,
    DecodeFailure,
    Deterministic,
    ElasticPool,
    FaultSpec,
    HeavyTail,
    ShiftedExponential,
    TimeVaryingLinks,
    UniformLinks,
    observed_run,
    run_adaptive_over_pool,
    run_batch_over_pool,
    run_over_pool,
    run_pipeline_over_pool,
    sample_trace,
    summarize,
)
from repro.obs import TRACER, snapshot, write_chrome
from repro.runtime.autoplan import _replay_seed

from .common import repo_root, run_sharded_child, timeit, write_csv

JSON_NAME = "BENCH_edge.json"

METHODS = ("polydot", "age")

# Batched-replay scenario: products per trace replay, and the forced
# host device count for the sharded child mesh.
BATCH_REPLAY = 8
SHARDED_DEVICES = 8

# Pipelined scenario: replays in flight and products per replay.
PIPELINE_DEPTH = 4
PIPELINE_BATCH = 4


def _per_link_report(plans, field, rng, m, pool, n_runs=8) -> dict:
    """Link-resolved scenarios: AGE vs PolyDot on identical link draws.

    The legacy scenarios model each worker with one scalar network
    delay; these sample a full ``(sender, receiver)`` matrix per trace
    so a receiver's Phase-2 completion is the max over its incoming
    links.  Both schemes share the pool, so the same trace object
    serves both — byte-identical links, not just byte-identical
    workers.
    """
    a = field.random(rng, (m, m))
    b = field.random(rng, (m, m))
    want = field.matmul(a.T, b)
    latency = ShiftedExponential(shift=1.0, scale=1.0)
    networks = {
        # last-mile edge: Phase-3 responses ride an uplink 5x slower
        # than the Phase-1 downlink
        "asymmetric_updown": AsymmetricLinks(
            latency, down_scale=0.1, d2d_scale=0.1, up_scale=0.5
        ),
        # devices hang off 3 access points: D2D inside a cluster is
        # 10x cheaper than crossing between clusters
        "clustered_edge": ClusteredEdge(
            latency, n_clusters=3, intra_scale=0.05, inter_scale=0.5,
            master_scale=0.1,
        ),
    }
    out = {}
    for name, network in networks.items():
        # ONE trace per run, sampled before the method loop: both
        # schemes replay the identical link matrix by construction,
        # not by seed coincidence.
        run_traces = [
            sample_trace(pool, latency, seed=3000 + run_i, network=network)
            for run_i in range(n_runs)
        ]
        per_method = {}
        for meth, plan in plans.items():
            results = []
            for run_i, trace in enumerate(run_traces):
                res = run_over_pool(plan, a, b, trace, seed=run_i)
                if not np.array_equal(res.y, want):
                    raise AssertionError(
                        f"{meth}/{name} run {run_i}: link-model decode "
                        f"disagrees with oracle"
                    )
                results.append(res.metrics)
            agg = summarize(results)
            agg["n_workers"] = plan.n_workers
            agg["oracle_validated"] = True
            per_method[meth] = agg
        per_method["polydot_over_age_p50"] = round(
            per_method["polydot"]["completion_p50"]
            / per_method["age"]["completion_p50"],
            4,
        )
        out[name] = per_method
    return out


def _pipeline_report(plans, field, rng, m, pool) -> dict:
    """K batched replays in flight vs back-to-back sequential replays.

    Each replay gets its own straggler trace (overlapping traces); the
    sequential baseline replays the identical traces through
    ``run_batch_over_pool`` one at a time, so the speedup isolates the
    pipelining — same subsets, same numerics, every decode of every
    in-flight replay validated against the host oracle.
    """
    K, batch = PIPELINE_DEPTH, PIPELINE_BATCH
    a = field.random(rng, (K, batch, m, m))
    b = field.random(rng, (K, batch, m, m))
    want = np.stack(
        [
            np.stack([field.matmul(a[k, i].T, b[k, i]) for i in range(batch)])
            for k in range(K)
        ]
    )
    latency = ShiftedExponential(shift=1.0, scale=1.0)
    faults = FaultSpec(straggler_frac=0.2, straggler_slowdown=10.0)
    traces = [
        sample_trace(pool, latency, faults, seed=5000 + k) for k in range(K)
    ]
    out = {"depth": K, "batch": batch}
    for meth, plan in plans.items():
        res = run_pipeline_over_pool(plan, a, b, traces, seed=9)
        if not np.array_equal(res.y, want):
            raise AssertionError(f"{meth}: pipelined decode disagrees with oracle")
        sequential = sum(
            run_batch_over_pool(plan, a[k], b[k], traces[k], seed=9)
            .metrics.completion_time
            for k in range(K)
        )
        pm = res.metrics
        out[meth] = {
            "makespan": round(pm.makespan, 4),
            "sequential_completion": round(sequential, 4),
            "pipeline_speedup": round(sequential / pm.makespan, 4),
            "occupancy": round(pm.occupancy, 4),
            "phase1_overlap": round(pm.phase1_overlap, 4),
            "products": pm.products,
            "wire_bytes_total": pm.trace.total_bytes,
            "oracle_validated": True,
        }
    out["polydot_over_age_makespan"] = round(
        out["polydot"]["makespan"] / out["age"]["makespan"], 4
    )
    return out


# Auto-planner scenarios: replays per scenario, products per replay,
# and the planner's knobs (estimator window, exploration ratio).
ADAPTIVE_BATCH = 2
ADAPTIVE_WINDOW = 5
ADAPTIVE_EXPLORE_RATIO = 1.5


def _adaptive_statics(candidates, traces, a, b, want, m, seed) -> dict:
    """Replay every static candidate over the exact traces the planner
    faces — same per-replay seeds (``_replay_seed``), same per-
    construction ``compute_scale`` work factors — so the comparison
    isolates the *decisions*, not the simulation draw.  A static that
    does not fit some replay's pool reports only the replays it could
    run (the planner has no such gap: it switches)."""
    K, batch = a.shape[0], a.shape[1]
    ref = AutoPlanner(candidates, cost_m=m)
    out = {}
    for cand in ref.candidates:
        wf = ref.work_factor(cand)
        times = []
        plans = {}
        for k, trace in enumerate(traces):
            if cand.n_workers > trace.n:
                continue
            cfg = cand.fit_to_pool(trace.n)
            if cfg.n_total not in plans:
                plans[cfg.n_total] = get_plan_for(
                    cfg, BlockShapes(k=m, ma=m, mb=m, s=cfg.s, t=cfg.t)
                )
            res = run_batch_over_pool(
                plans[cfg.n_total], a[k], b[k], trace,
                seed=_replay_seed(seed, k), compute_scale=wf,
            )
            for i in range(batch):
                if not np.array_equal(res.y[i], want[k][i]):
                    raise AssertionError(
                        f"static {cand.label()} replay {k}: decode "
                        f"disagrees with oracle"
                    )
            times.append(res.metrics.completion_time)
        out[cand.label()] = {
            "work_factor": round(wf, 4),
            "feasible_replays": len(times),
            "completion_p50": round(float(np.percentile(times, 50)), 4),
            "completion_mean": round(float(np.mean(times)), 4),
            "fits_all_replays": len(times) == K,
            "oracle_validated": True,
        }
    return out


def _adaptive_scenario(candidates, traces, field, rng, m, seed) -> dict:
    """One adaptive scenario: planner vs every static on shared traces."""
    K = len(traces)
    batch = ADAPTIVE_BATCH
    a = field.random(rng, (K, batch, m, m))
    b = field.random(rng, (K, batch, m, m))
    want = [
        [field.matmul(a[k, i].T, b[k, i]) for i in range(batch)]
        for k in range(K)
    ]
    statics = _adaptive_statics(candidates, traces, a, b, want, m, seed)
    planner = AutoPlanner(
        candidates,
        cost_m=m,
        window=ADAPTIVE_WINDOW,
        explore_ratio=ADAPTIVE_EXPLORE_RATIO,
    )
    run = run_adaptive_over_pool(planner, a, b, traces, seed=seed)
    for k in range(K):
        for i in range(batch):
            if not np.array_equal(run.y[k, i], want[k][i]):
                raise AssertionError(
                    f"adaptive replay {k}: decode disagrees with oracle"
                )
    times = np.array([rm.completion_time for rm in run.replay_metrics])
    adaptive_p50 = float(np.percentile(times, 50))
    full = {
        name: s["completion_p50"]
        for name, s in statics.items()
        if s["fits_all_replays"]
    }
    best = min(full.values())
    worst = max(full.values())
    return {
        "replays": K,
        "batch": batch,
        "pool_sizes": [t.n for t in traces],
        "statics": statics,
        "adaptive": {
            "completion_p50": round(adaptive_p50, 4),
            "completion_mean": round(float(times.mean()), 4),
            "oracle_validated": True,
            **run.planner.summary(),
        },
        # < 1: the planner beats even the best fully-feasible static;
        # the acceptance band tops out at 1.05 (exploration overhead).
        "adaptive_over_best_static_p50": round(adaptive_p50 / best, 4),
        "worst_static_over_adaptive_p50": round(worst / adaptive_p50, 4),
    }


def _adaptive_report(field, m) -> dict:
    """Auto-planner vs static constructions under drifting conditions.

    ``degrading_links``: a fixed pool whose Phase-2 fabric degrades 8x
    — first mid-replay (the scheduler resolves the link matrix at each
    replay's set-announcement time), then permanently.  The candidate
    set spans the real trade-off: age(2,2,3) has the lightest per-worker
    work, age(4,1,3) the shallowest barrier (N=13, threshold 4) at 1.37x
    work — link degradation moves the optimum from the former to the
    latter, and no static candidate is best in both regimes.

    ``elastic_pool``: membership shrinks 40 -> 22 -> 16; at 16 only
    age(4,1,3) still fits, so the planner is *forced* off anything else
    it preferred, while statics that need more workers simply cannot
    serve those replays.
    """
    latency = ShiftedExponential(shift=1.0, scale=0.5)
    network = UniformLinks(HeavyTail(shift=0.2, scale=0.2, alpha=1.6), scale=0.3)

    # -- degrading links over a fixed pool --------------------------------
    cands = [
        PlanConfig("age", 2, 2, 3),
        PlanConfig("polydot", 2, 2, 3),
        PlanConfig("age", 4, 1, 3),
        PlanConfig("age", 4, 2, 3),
    ]
    pool = max(c.n_workers for c in cands) + 3
    K, onset, factor, t_mid = 14, 5, 8.0, 1.6
    traces = []
    for k in range(K):
        tr = sample_trace(pool, latency, seed=4000 + k, network=network)
        if k == onset:
            # Degradation arrives mid-replay: links are still clean when
            # Phase 1 goes out, 8x slower by the Phase-2 exchange.
            tr = TimeVaryingLinks(((t_mid, factor),)).apply(tr)
        elif k > onset:
            tr = TimeVaryingLinks(((0.0, factor),)).apply(tr)
        traces.append(tr)
    rng = np.random.default_rng(40)
    degrading = _adaptive_scenario(cands, traces, field, rng, m, seed=17)
    degrading["onset_replay"] = onset
    degrading["link_factor"] = factor

    # -- elastic pool ------------------------------------------------------
    cands = [
        PlanConfig("age", 2, 2, 3),
        PlanConfig("polydot", 2, 2, 3),
        PlanConfig("age", 4, 1, 3),
    ]
    sizes = [40] * 4 + [22] * 4 + [16] * 4
    master = sample_trace(40, latency, seed=7000, network=network)
    epool = ElasticPool(master, tuple(tuple(range(sz)) for sz in sizes))
    traces = [epool.trace_for(k) for k in range(len(epool))]
    rng = np.random.default_rng(41)
    elastic = _adaptive_scenario(cands, traces, field, rng, m, seed=23)

    return {"degrading_links": degrading, "elastic_pool": elastic}


# Byzantine sweep: configured corruption rates and replays per rate.
BYZANTINE_RATES = (0.0, 0.05, 0.10, 0.15, 0.20, 0.25)
BYZANTINE_RUNS = 6


def _byzantine_report(plans, field, rng, m, pool, n_runs=BYZANTINE_RUNS) -> dict:
    """Detect vs correct corruption handling on byte-identical traces.

    For each configured corruption rate the SAME sampled traces replay
    under both strategies (``decode_mode="detect"`` resolves one extra
    confirming witness; ``"correct"`` resolves the error budget ``e``
    from the configured rate and waits for ``thr + 2e`` responders),
    so the comparison isolates the decode strategy.  Reported per rate:
    p50 completion, mean responder overhead over the bare threshold
    (the worker price of each strategy), detected/corrected counts, and
    decode failures; per method, the lowest rate at which correction's
    p50 completion crosses below detection's.
    """
    a = field.random(rng, (m, m))
    b = field.random(rng, (m, m))
    want = field.matmul(a.T, b)
    latency = ShiftedExponential(shift=1.0, scale=1.0)
    out = {
        "rates": list(BYZANTINE_RATES),
        "strategies": ["detect", "correct"],
        "runs_per_rate": n_runs,
    }
    rows = []
    for meth, plan in plans.items():
        thr = plan.decode_threshold
        per_rate = []
        for rate in BYZANTINE_RATES:
            faults = FaultSpec(corrupt_frac=rate)
            # one trace set per rate, replayed by BOTH strategies (and
            # both methods share the pool-sized prefix, like the
            # scenario section)
            traces = [
                sample_trace(
                    pool, latency, faults, seed=6000 + round(rate * 100) * 31 + i
                )
                for i in range(n_runs)
            ]
            entry = {"corrupt_frac": rate}
            for strategy in ("detect", "correct"):
                results = []
                failures = 0
                for run_i, trace in enumerate(traces):
                    try:
                        res = run_over_pool(
                            plan, a, b, trace, seed=run_i, decode_mode=strategy
                        )
                    except DecodeFailure:
                        failures += 1
                        continue
                    if not np.array_equal(res.y, want):
                        raise AssertionError(
                            f"{meth}/byzantine rate={rate} run {run_i} "
                            f"({strategy}): decode disagrees with oracle"
                        )
                    results.append(res.metrics)
                responses = [observed_run(r).thr_arrived for r in results]
                agg = summarize(results)
                entry[strategy] = {
                    "completion_p50": round(agg.get("completion_p50", float("nan")), 4),
                    "responses_mean": round(float(np.mean(responses)), 2)
                    if responses
                    else None,
                    "worker_overhead_mean": round(
                        float(np.mean(responses)) - thr, 2
                    )
                    if responses
                    else None,
                    "rejected_total": agg.get("rejected_total", 0),
                    "corrected_total": agg.get("corrected_total", 0),
                    "decode_failures": failures,
                    "oracle_validated": True,
                }
            d_p50 = entry["detect"]["completion_p50"]
            c_p50 = entry["correct"]["completion_p50"]
            entry["correct_over_detect_p50"] = (
                round(c_p50 / d_p50, 4) if d_p50 else None
            )
            per_rate.append(entry)
            for strategy in ("detect", "correct"):
                rows.append(
                    {
                        "method": meth,
                        "corrupt_frac": rate,
                        "strategy": strategy,
                        "completion_p50": entry[strategy]["completion_p50"],
                        "worker_overhead_mean": entry[strategy][
                            "worker_overhead_mean"
                        ],
                        "decode_failures": entry[strategy]["decode_failures"],
                    }
                )
            # first configured rate where correction's p50 completion is
            # no worse than detection's (None: detection never crossed)
        crossover = next(
            (
                e["corrupt_frac"]
                for e in per_rate
                if e["corrupt_frac"] > 0
                and e["correct_over_detect_p50"] is not None
                and e["correct_over_detect_p50"] <= 1.0
            ),
            None,
        )
        out[meth] = {
            "decode_threshold": thr,
            "per_rate": per_rate,
            "p50_crossover_rate": crossover,
        }
    write_csv("edge_byzantine", rows)
    return out


def _batched_replay_report(plans, field, rng, m) -> dict:
    """Per-method amortization of the batched replay vs a run loop."""
    a = field.random(rng, (BATCH_REPLAY, m, m))
    b = field.random(rng, (BATCH_REPLAY, m, m))
    want = np.stack([field.matmul(a[i].T, b[i]) for i in range(BATCH_REPLAY)])
    latency = ShiftedExponential(shift=1.0, scale=1.0)
    faults = FaultSpec(straggler_frac=0.2, straggler_slowdown=10.0)
    out = {}
    for meth, plan in plans.items():
        trace = sample_trace(plan.n_total, latency, faults, seed=77)
        res = run_batch_over_pool(plan, a, b, trace, seed=78)
        if not np.array_equal(res.y, want):
            raise AssertionError(f"{meth}: batched replay disagrees with oracle")

        def loop():
            for i in range(BATCH_REPLAY):
                run_over_pool(plan, a[i], b[i], trace, seed=78)

        loop_us = timeit(loop, repeat=3) / BATCH_REPLAY
        batched_us = (
            timeit(lambda: run_batch_over_pool(plan, a, b, trace, seed=78), repeat=3)
            / BATCH_REPLAY
        )
        out[meth] = {
            "batch": BATCH_REPLAY,
            "loop_us_per_product": round(loop_us, 1),
            "batched_us_per_product": round(batched_us, 1),
            "amortization": round(loop_us / batched_us, 2),
            "oracle_validated": True,
        }
    return out


def _sharded_child():
    """Child entry (multi-device host): the batched edge replay with the
    scheduler-driven shard_map Phase 2.  Prints ONE JSON line."""
    import jax
    from jax.sharding import Mesh

    field = Field()
    rng = np.random.default_rng(0)
    m, s, t, z, n_spare = 32, 2, 2, 3, 3
    shapes = BlockShapes(k=m, ma=m, mb=m, s=s, t=t)
    schemes = {meth: C.build_scheme(meth, s, t, z) for meth in METHODS}
    pool = max(sch.n_workers for sch in schemes.values()) + n_spare
    plans = {
        meth: get_plan(schemes[meth], shapes, n_spare=pool - sch.n_workers)
        for meth, sch in schemes.items()
    }
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    a = field.random(rng, (BATCH_REPLAY, m, m))
    b = field.random(rng, (BATCH_REPLAY, m, m))
    want = np.stack([field.matmul(a[i].T, b[i]) for i in range(BATCH_REPLAY)])
    latency = ShiftedExponential(shift=1.0, scale=1.0)
    faults = FaultSpec(straggler_frac=0.2, straggler_slowdown=10.0)
    out = {
        "devices": len(jax.devices()),
        "batch": BATCH_REPLAY,
        "mode": "all_to_all",
        "pool_size": pool,
        "methods": {},
    }
    for meth, plan in plans.items():
        trace = sample_trace(pool, latency, faults, seed=88)
        res = run_batch_over_pool(plan, a, b, trace, seed=89, mesh=mesh)
        if not np.array_equal(res.y, want):
            raise AssertionError(f"{meth}: sharded batched replay != oracle")
        us = (
            timeit(
                lambda: run_batch_over_pool(plan, a, b, trace, seed=89, mesh=mesh),
                repeat=3,
            )
            / BATCH_REPLAY
        )
        out["methods"][meth] = {
            "us_per_product": round(us, 1),
            # ONE replay's simulated completion (not a percentile — the
            # scenario percentiles live under "scenarios")
            "completion_time": round(res.metrics.completion_time, 4),
            "phase2_subset_nonprefix": bool(
                not np.array_equal(
                    res.metrics.phase2_ids, np.arange(plan.n_workers)
                )
            ),
        }
    out["validated"] = True
    print(json.dumps(out))


def _sharded_report() -> dict:
    return run_sharded_child("benchmarks.edge_runtime", SHARDED_DEVICES)


def _scenarios(n_spare: int):
    """(name, latency model, FaultSpec, explicit-fault kwargs)."""
    return [
        ("all_fast", Deterministic(1.0), FaultSpec(), {}),
        (
            "stragglers_exp",
            ShiftedExponential(shift=1.0, scale=1.0),
            FaultSpec(straggler_frac=0.2, straggler_slowdown=10.0),
            {},
        ),
        (
            "dropouts",
            ShiftedExponential(shift=1.0, scale=0.5),
            FaultSpec(),
            {"dropout_ids": list(range(n_spare))},
        ),
        (
            "heavy_tail_corrupt",
            HeavyTail(shift=1.0, scale=0.5, alpha=1.5),
            FaultSpec(),
            {"corrupt_ids": [1]},
        ),
    ]


def run(m: int = 32, s: int = 2, t: int = 2, z: int = 3, n_spare: int = 3,
        n_runs: int = 8):
    # Default (s, t, z) = (2, 2, 3): the smallest cell of the validation
    # grid where the schemes' worker counts actually separate (PolyDot 22
    # vs AGE 20), so the completion-time comparison exercises the
    # paper's worker-advantage claim rather than a tie.
    #
    # Both schemes share ONE physical pool — the edge setting is a fixed
    # set of devices, not a per-scheme provisioning budget.  Pool size =
    # (largest scheme's n_workers) + n_spare; the scheme that needs
    # fewer workers banks the difference as extra straggler slack, which
    # is exactly how the paper's worker-count advantage becomes a
    # completion-time advantage under load.
    #
    # TRACE=1 turns the observability layer on for the whole run and
    # writes a Perfetto-loadable sidecar (BENCH_edge.trace.json) next to
    # the report.  The report itself is byte-identical either way: the
    # tracer only *reads* already-decided timestamps, and the sidecar is
    # a separate file that bench_diff ignores.
    tracing = bool(os.environ.get("TRACE"))
    if tracing:
        TRACER.clear()
        TRACER.enable()
    field = Field()
    rng = np.random.default_rng(0)
    shapes = BlockShapes(k=m, ma=m, mb=m, s=s, t=t)
    schemes = {meth: C.build_scheme(meth, s, t, z) for meth in METHODS}
    pool = max(sch.n_workers for sch in schemes.values()) + n_spare
    plans = {
        meth: get_plan(schemes[meth], shapes, n_spare=pool - sch.n_workers)
        for meth, sch in schemes.items()
    }
    min_spare = min(p.n_spare for p in plans.values())
    a = field.random(rng, (m, m))
    b = field.random(rng, (m, m))
    want = field.matmul(a.T, b)

    scenarios = {}
    rows = []
    for name, latency, faults, explicit in _scenarios(min_spare):
        per_method = {}
        for meth, plan in plans.items():
            results = []
            wall_us = []
            for run_i in range(n_runs):
                # One trace per (scenario, seed) for the shared pool:
                # both schemes replay byte-identical worker behaviour.
                trace = sample_trace(pool, latency, faults, seed=1000 + run_i)
                if explicit:
                    trace = trace.with_faults(**explicit)
                w0 = time.perf_counter()
                res = run_over_pool(plan, a, b, trace, seed=run_i)
                wall_us.append((time.perf_counter() - w0) * 1e6)
                if not np.array_equal(res.y, want):
                    raise AssertionError(
                        f"{meth}/{name} run {run_i}: decode from subset "
                        f"{res.metrics.responder_ids} disagrees with oracle"
                    )
                results.append(res.metrics)
            agg = summarize(results)
            agg["n_workers"] = plans[meth].n_workers
            agg["n_total"] = plans[meth].n_total
            agg["decode_threshold"] = plans[meth].decode_threshold
            agg["wall_us_mean"] = round(float(np.mean(wall_us)), 1)
            agg["oracle_validated"] = True
            per_method[meth] = agg
            rows.append(
                {
                    "scenario": name,
                    "method": meth,
                    "n_workers": agg["n_workers"],
                    "n_total": agg["n_total"],
                    "completion_p50": round(agg["completion_p50"], 4),
                    "completion_p95": round(agg["completion_p95"], 4),
                    "effective_workers": round(agg["effective_workers_mean"], 2),
                    "wire_bytes_mean": agg["wire_bytes_mean"],
                }
            )
        per_method["polydot_over_age_p50"] = round(
            per_method["polydot"]["completion_p50"]
            / per_method["age"]["completion_p50"],
            4,
        )
        scenarios[name] = per_method

    csv_path = write_csv("edge_runtime", rows)
    report = {
        "bench": "edge_runtime",
        "config": {
            "m": m, "s": s, "t": t, "z": z, "n_runs": n_runs,
            "pool_size": pool,
            "n_spare": {meth: p.n_spare for meth, p in plans.items()},
            "dropouts_injected": min_spare,
            "worker_advantage_age_vs_polydot": plans["polydot"].n_workers
            - plans["age"].n_workers,
        },
        "scenarios": scenarios,
        "per_link": _per_link_report(plans, field, rng, m, pool, n_runs=n_runs),
        "pipelined": _pipeline_report(plans, field, rng, m, pool),
        "adaptive": _adaptive_report(field, m),
        "byzantine": _byzantine_report(plans, field, rng, m, pool),
        "batched_replay": _batched_replay_report(plans, field, rng, m),
        "sharded_batched": _sharded_report(),
        "subset_cache": subset_cache_info(),
    }
    json_path = os.path.join(repo_root(), JSON_NAME)
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    if tracing:
        trace_path = os.path.join(
            repo_root(), JSON_NAME.replace(".json", ".trace.json")
        )
        write_chrome(trace_path, TRACER, metrics=snapshot())
        print(f"trace: {trace_path} ({len(TRACER.events)} events)")

    ratio = scenarios["stragglers_exp"]["polydot_over_age_p50"]
    return [
        {
            "name": "edge_runtime",
            "us_per_call": scenarios["all_fast"]["age"]["wall_us_mean"],
            "derived": f"csv={csv_path} json={json_path} "
            f"N_polydot={plans['polydot'].n_workers} "
            f"N_age={plans['age'].n_workers} "
            f"straggler_p50_ratio_polydot/age={ratio} all_validated=True",
        }
    ]


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        _sharded_child()
    else:
        for row in run():
            print(f"{row['name']},{row['us_per_call']},{row['derived']}")
