"""Fig. 4(a-c): computation / storage / communication loads per worker.

m = 36000, z = 42, st = 36 (Corollaries 10-12 evaluated at each
method's required worker count)."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import closed_form as cf
from repro.core import constructions as C

from .common import write_csv

M, Z = 36_000, 42
PAIRS = [(1, 36), (2, 18), (3, 12), (4, 9), (6, 6), (9, 4), (12, 3), (18, 2), (36, 1)]


def run() -> List[Dict]:
    t0 = time.perf_counter()
    rows = []
    for s, t in PAIRS:
        n_by = {
            "age": cf.n_age_exact(s, t, Z)[0],
            "polydot": C.polydot_cmpc(s, t, Z).n_workers,
            "entangled": cf.n_entangled(s, t, Z),
        }
        for method, n in n_by.items():
            rows.append(
                {
                    "method": method,
                    "s": s,
                    "t": t,
                    "n_workers": n,
                    "computation_scalar_mults": cf.computation_overhead(M, s, t, Z, n),
                    "storage_scalars": cf.storage_overhead(M, s, t, Z, n),
                    "communication_scalars": cf.communication_overhead(M, t, n),
                }
            )
    elapsed = time.perf_counter() - t0
    path = write_csv("fig4_overheads", rows)

    # AGE dominates on every metric at every (s, t) — Section VII claims
    ok = True
    for s, t in PAIRS:
        sub = {r["method"]: r for r in rows if r["s"] == s and r["t"] == t}
        for metric in ("computation_scalar_mults", "storage_scalars", "communication_scalars"):
            ok &= sub["age"][metric] <= min(v[metric] for v in sub.values())
    return [
        {
            "name": "fig4_overheads",
            "us_per_call": round(elapsed * 1e6 / len(rows), 1),
            "derived": f"csv={path} age_dominates_all_metrics={ok}",
        }
    ]
