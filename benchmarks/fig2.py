"""Fig. 2: required workers vs number of colluding workers.

s = 4, t = 15, z in [1, 300]; AGE-CMPC (exact Algorithm-2/3 search),
PolyDot-CMPC (exact Algorithm 1), Entangled-CMPC / SSMM / GCSA-NA
(published formulas).  Also validates the paper's claimed crossovers.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import closed_form as cf
from repro.core import constructions as C

from .common import write_csv

S, T = 4, 15
Z_MAX = 300


def run() -> List[Dict]:
    t0 = time.perf_counter()
    rows = []
    for z in range(1, Z_MAX + 1):
        n_age, lam = cf.n_age_exact(S, T, z)
        rows.append(
            {
                "z": z,
                "age": n_age,
                "age_lambda_star": lam,
                "polydot": C.polydot_cmpc(S, T, z).n_workers,
                "entangled": cf.n_entangled(S, T, z),
                "ssmm": cf.n_ssmm(S, T, z),
                "gcsa_na": cf.n_gcsa_na(S, T, z),
            }
        )
    elapsed = time.perf_counter() - t0
    path = write_csv("fig2_workers_vs_z", rows)

    # paper-claimed structure (ties count as "best": at z=45 PolyDot
    # exactly ties SSMM at 1679 workers)
    assert all(r["age"] <= min(r["polydot"], r["entangled"], r["ssmm"], r["gcsa_na"]) for r in rows)
    by_z = {r["z"]: r for r in rows}

    def is_best(z, key):
        r = by_z[z]
        return r[key] <= min(r[k] for k in ("polydot", "entangled", "ssmm", "gcsa_na"))

    checks = {
        "ssmm_best_z<=48": all(is_best(z, "ssmm") for z in range(1, 49)),
        "polydot_best_49..180": all(is_best(z, "polydot") for z in range(49, 181)),
        "ent_gcsa_best_181..300": all(
            is_best(z, "entangled") or is_best(z, "gcsa_na") for z in range(181, 301)
        ),
    }
    return [
        {
            "name": "fig2_workers_vs_z",
            "us_per_call": round(elapsed * 1e6 / Z_MAX, 1),
            "derived": f"csv={path} " + " ".join(f"{k}={v}" for k, v in checks.items()),
        }
    ]
