"""Batched vs looped protocol execution: per-product wall time.

The paper accounts computation overhead *per multiplication*; this
benchmark measures how much of the Python/host overhead of ``run`` the
batched device-resident engine (``run_batched``) amortizes away.  For
each batch size it reports the per-product latency of

* ``loop``    — a Python loop of per-sample ``protocol.run`` calls,
* ``batched`` — one ``protocol.run_batched`` call over the whole batch,

plus the resulting speedup.  The batched path shares one jitted
computation and one plan's device constants across all products.
"""
from __future__ import annotations

import numpy as np

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.gf import Field
from repro.core.planner import BlockShapes, get_plan

from .common import timeit, write_csv

BATCHES = (1, 8, 32)


def run():
    field = Field()
    rng = np.random.default_rng(0)
    m, s, t, z = 64, 2, 2, 2
    sch = C.build_scheme("age", s, t, z)
    shapes = BlockShapes(k=m, ma=m, mb=m, s=s, t=t)
    plan = get_plan(sch, shapes)

    rows = []
    best = None
    for batch in BATCHES:
        a = field.random(rng, (batch, m, m))
        b = field.random(rng, (batch, m, m))

        def loop():
            for i in range(batch):
                proto.run(plan, a[i], b[i], seed=i)

        def batched():
            y, _ = proto.run_batched(plan, a, b, seed=0)
            np.asarray(y)

        loop_us = timeit(loop, repeat=3) / batch
        batched_us = timeit(batched, repeat=3) / batch
        speedup = loop_us / batched_us
        rows.append(
            {
                "batch": batch,
                "m": m,
                "n_workers": plan.n_workers,
                "loop_us_per_product": round(loop_us, 1),
                "batched_us_per_product": round(batched_us, 1),
                "speedup": round(speedup, 2),
            }
        )
        best = rows[-1]
    path = write_csv("protocol_batch", rows)
    return [
        {
            "name": "protocol_batch",
            "us_per_call": best["batched_us_per_product"],
            "derived": f"csv={path} batch={best['batch']} "
            f"speedup_vs_loop={best['speedup']}x",
        }
    ]
