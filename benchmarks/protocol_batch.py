"""Batched vs looped protocol execution: per-product wall time,
per-phase breakdown, and kernel padding-waste accounting.

The paper accounts computation overhead *per multiplication*; this
benchmark measures how much of the Python/host overhead of ``run`` the
batched device-resident engine (``run_batched``) amortizes away.  For
each batch size it reports the per-product latency of

* ``loop``    — a Python loop of per-sample ``protocol.run`` calls,
* ``batched`` — one ``protocol.run_batched`` call over the whole batch,

plus the resulting speedup and the speedup against the recorded PR-1
baseline of the batched engine itself (fixed-tile kernels, vmapped
padded-2D launches, per-worker PRNG blinding draws).

Besides the CSV under results/bench/, the run emits machine-readable
``BENCH_protocol.json`` at the repo root (``make bench-json``) so later
PRs can track the perf trajectory:

* ``batches``        — the table above,
* ``phases_us``      — wall time of each protocol phase (reference
                        path, batch of 1): share / multiply / reduce /
                        decode,
* ``padding_waste``  — per hot-matmul-shape fraction of MXU MACs spent
                        on padding under the fixed legacy 128/128/256
                        tiling vs the shape-adaptive ``pick_tiles``,
* ``sharded_batched``— the batched engine with the *distributed*
                        Phase 2 (``run_batched_sharded``): per exchange
                        mode, per-product latency on a forced
                        multi-device host mesh, validated bit-identical
                        against ``run_batched``.  Runs in a subprocess
                        so ``--xla_force_host_platform_device_count``
                        cannot perturb the main single-device numbers.
* ``int_backends``   — the native-integer kernel tier: deep-K
                        ``mod_matmul`` sweep (f32limb vs the int32
                        uint32-accumulator path, bit-validated per
                        shape), the dual-prime CRT protocol route, and
                        fused in-kernel blinding vs materialized masks
                        through ``run_batched`` — all on CPU, where the
                        int32 tier is the ``auto`` pick for deep
                        contractions.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.gf import Field
from repro.core.planner import BlockShapes, get_plan
from repro.kernels.modmatmul.ops import padding_waste, pick_tiles

from .common import repo_root, run_sharded_child, timeit, write_csv

BATCHES = (1, 8, 16, 32)

# Batched-engine per-product latency of the PR-1 revision (fixed
# 128/128/256 tiles, vmap-of-padded-2D kernel launches, broadcast
# constant matrices, per-worker blinding draws), measured on this
# benchmark's default config (m=64, age, s=t=z=2, CPU f32limb backend)
# before the batched/tile-adaptive kernel layer landed.  Kept as the
# reference point for the perf trajectory.
PR1_BASELINE_US = {1: 6995.5, 8: 3285.1, 16: 3033.8, 32: 3851.4}

FIXED_TILES = (128, 128, 256)  # the legacy hardcoded tiling

JSON_NAME = "BENCH_protocol.json"

# Sharded-batched scenario: forced host device count for the child mesh
# and the batch that rides each collective.
SHARDED_DEVICES = 8
SHARDED_BATCH = 16
SHARDED_MODES = ("all_to_all", "psum", "psum_scatter")


def _sharded_child():
    """Child entry (multi-device host): validate + time run_batched_sharded.

    Prints ONE JSON line; the parent embeds it under ``sharded_batched``.
    """
    import jax
    from jax.sharding import Mesh

    field = Field()
    rng = np.random.default_rng(0)
    m, s, t, z = 64, 2, 2, 2
    sch = C.build_scheme("age", s, t, z)
    shapes = BlockShapes(k=m, ma=m, mb=m, s=s, t=t)
    plan = get_plan(sch, shapes, n_spare=2)
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    a = field.random(rng, (SHARDED_BATCH, m, m))
    b = field.random(rng, (SHARDED_BATCH, m, m))
    want, _ = proto.run_batched(plan, a, b, seed=0)
    # a non-prefix sender subset exercises the cached subset mix path
    ids2 = np.arange(1, 1 + plan.n_workers)
    dense_us = (
        timeit(lambda: np.asarray(proto.run_batched(plan, a, b, seed=0)[0]), repeat=3)
        / SHARDED_BATCH
    )
    out = {
        "devices": len(jax.devices()),
        "batch": SHARDED_BATCH,
        "n_workers": plan.n_workers,
        "n_spare": plan.n_spare,
        "batched_dense_us_per_product": round(dense_us, 1),
        "modes": {},
    }
    for mode in SHARDED_MODES:
        y, _ = proto.run_batched_sharded(
            plan, a, b, mesh, mode=mode, seed=0, phase2_ids=ids2
        )
        if not np.array_equal(y, want):
            raise AssertionError(f"sharded mode {mode} disagrees with run_batched")
        us = (
            timeit(
                lambda: np.asarray(
                    proto.run_batched_sharded(plan, a, b, mesh, mode=mode, seed=0)[0]
                ),
                repeat=3,
            )
            / SHARDED_BATCH
        )
        out["modes"][mode] = {"us_per_product": round(us, 1)}
    out["validated"] = True
    print(json.dumps(out))


def _sharded_report() -> dict:
    """Run the sharded scenario in a forced-multi-device subprocess."""
    return run_sharded_child("benchmarks.protocol_batch", SHARDED_DEVICES)


def _phase_times(plan, a, b) -> dict:
    """Wall time (us) of each reference-path phase for one product."""
    rng = np.random.default_rng(7)
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    h = proto.worker_multiply(plan, fa, fb)
    i_evals = proto.degree_reduce(plan, h, rng)
    rng2 = np.random.default_rng(7)
    return {
        "share": round(
            timeit(lambda: np.asarray(proto.share_a(plan, a, rng2)), repeat=3), 1
        ),
        "multiply": round(
            timeit(lambda: np.asarray(proto.worker_multiply(plan, fa, fb)), repeat=3), 1
        ),
        "reduce": round(
            timeit(
                lambda: np.asarray(proto.degree_reduce(plan, h, np.random.default_rng(7))),
                repeat=3,
            ),
            1,
        ),
        "decode": round(timeit(lambda: proto.reconstruct(plan, i_evals), repeat=3), 1),
    }


def _padding_report(plan) -> list:
    """Padding-waste ratios of the protocol's hot matmul shapes under
    the legacy fixed tiling vs the adaptive one."""
    sh = plan.shapes
    t = plan.scheme.t
    na = len(plan.scheme.fa_powers)
    bra, bca = sh.blk_a
    brb, bcb = sh.blk_b
    blk_flat = (sh.ma // t) * (sh.mb // t)
    sites = [
        ("phase1_polyeval_a", plan.n_total, na, bra * bca),
        ("phase2_worker_multiply", bra, bca, bcb),
        ("phase2_mix", plan.n_total, plan.n_workers, blk_flat),
        ("phase3_decode", plan.decode_threshold, plan.decode_threshold, blk_flat),
    ]
    out = []
    for name, m, k, n in sites:
        adaptive = pick_tiles(m, k, n)
        out.append(
            {
                "site": name,
                "shape_mkn": [m, k, n],
                "tiles_adaptive": list(adaptive),
                "waste_fixed": round(padding_waste(m, k, n, FIXED_TILES), 4),
                "waste_adaptive": round(padding_waste(m, k, n, adaptive), 4),
            }
        )
    return out


# Deep-K sweep for the int32 tier: [DEEPK_BATCH, 128, K] @ [DEEPK_BATCH,
# K, 128] products, K straddling the single-chunk boundary (256) and
# going deep enough that per-chunk f32 reductions dominate.
DEEPK_BATCH = 4
DEEPK_SWEEP = (256, 512, 1024, 2048, 4096)


def _int_backends_report(plan, field, rng) -> dict:
    """Timings + validation for the native-integer tier (CPU)."""
    import jax.numpy as jnp

    from repro.core.gf import P_DEFAULT
    from repro.kernels.modmatmul.ops import mod_matmul

    kernel_rows = []
    for k in DEEPK_SWEEP:
        a = jnp.asarray(field.random(rng, (DEEPK_BATCH, 128, k)), jnp.int32)
        b = jnp.asarray(field.random(rng, (DEEPK_BATCH, k, 128)), jnp.int32)
        y_f = np.asarray(mod_matmul(a, b, p=P_DEFAULT, backend="f32limb"))
        y_i = np.asarray(mod_matmul(a, b, p=P_DEFAULT, backend="int32"))
        if not np.array_equal(y_f, y_i):
            raise AssertionError(f"int32 disagrees with f32limb at K={k}")
        f32_us = timeit(
            lambda: np.asarray(mod_matmul(a, b, p=P_DEFAULT, backend="f32limb")),
            repeat=5,
        )
        i32_us = timeit(
            lambda: np.asarray(mod_matmul(a, b, p=P_DEFAULT, backend="int32")),
            repeat=5,
        )
        kernel_rows.append(
            {
                "k": k,
                "batch": DEEPK_BATCH,
                "f32limb_us": round(f32_us, 1),
                "int32_us": round(i32_us, 1),
                "speedup": round(f32_us / i32_us, 2),
                "validated": True,
            }
        )

    # dual-prime CRT protocol route vs one single-prime pass
    m = plan.shapes.ma
    batch = 8
    a = field.random(rng, (batch, m, m))
    b = field.random(rng, (batch, m, m))
    single_us = (
        timeit(lambda: np.asarray(proto.run_batched(plan, a, b, seed=0)[0]), repeat=3)
        / batch
    )
    crt_plans = [
        get_plan(plan.scheme, plan.shapes, field=Field(q), seed=17 * i)
        for i, q in enumerate((65521, 65519))
    ]
    want = np.einsum("bki,bkj->bij", a, b) % (65521 * 65519)
    y_crt, _ = proto.run_batched_crt(crt_plans, a, b, seed=0)
    if not np.array_equal(y_crt, want):
        raise AssertionError("CRT protocol route disagrees with the oracle")
    crt_us = (
        timeit(
            lambda: np.asarray(proto.run_batched_crt(crt_plans, a, b, seed=0)[0]),
            repeat=3,
        )
        / batch
    )

    # fused in-kernel blinding vs materialized masks (bit-identical Y)
    y0, _ = proto.run_batched(plan, a, b, seed=0, fused_masks=False)
    y1, _ = proto.run_batched(plan, a, b, seed=0, fused_masks=True)
    if not np.array_equal(y0, y1):
        raise AssertionError("fused-mask run_batched disagrees with unfused")
    fused_us = (
        timeit(
            lambda: np.asarray(
                proto.run_batched(plan, a, b, seed=0, fused_masks=True)[0]
            ),
            repeat=3,
        )
        / batch
    )

    deep = [r for r in kernel_rows if r["k"] >= 256]
    return {
        "deep_k_matmul": kernel_rows,
        "int32_beats_f32limb_deep_k": any(r["speedup"] > 1.0 for r in deep),
        "crt": {
            "primes": [65521, 65519],
            "batch": batch,
            "single_prime_us_per_product": round(single_us, 1),
            "crt_us_per_product": round(crt_us, 1),
            "validated": True,
        },
        "fused_masks": {
            "batch": batch,
            "unfused_us_per_product": round(single_us, 1),
            "fused_us_per_product": round(fused_us, 1),
            "bit_identical": True,
        },
    }


def run():
    field = Field()
    rng = np.random.default_rng(0)
    m, s, t, z = 64, 2, 2, 2
    sch = C.build_scheme("age", s, t, z)
    shapes = BlockShapes(k=m, ma=m, mb=m, s=s, t=t)
    plan = get_plan(sch, shapes)

    rows = []
    best = None
    for batch in BATCHES:
        a = field.random(rng, (batch, m, m))
        b = field.random(rng, (batch, m, m))

        def loop():
            for i in range(batch):
                proto.run(plan, a[i], b[i], seed=i)

        def batched():
            y, _ = proto.run_batched(plan, a, b, seed=0)
            np.asarray(y)

        loop_us = timeit(loop, repeat=3) / batch
        # the batched call is cheap enough to repeat more: the median
        # over 7 keeps one-off scheduler hiccups out of the committed
        # BENCH_protocol.json trajectory
        batched_us = timeit(batched, repeat=7, warmup=2) / batch
        speedup = loop_us / batched_us
        base = PR1_BASELINE_US.get(batch)
        rows.append(
            {
                "batch": batch,
                "m": m,
                "n_workers": plan.n_workers,
                "loop_us_per_product": round(loop_us, 1),
                "batched_us_per_product": round(batched_us, 1),
                "speedup": round(speedup, 2),
                "pr1_baseline_us": base,
                "speedup_vs_pr1": round(base / batched_us, 2) if base else None,
            }
        )
        best = rows[-1]
    path = write_csv("protocol_batch", rows)

    a1 = field.random(rng, (m, m))
    b1 = field.random(rng, (m, m))
    report = {
        "bench": "protocol_batch",
        "config": {
            "m": m,
            "method": "age",
            "s": s,
            "t": t,
            "z": z,
            "n_workers": plan.n_workers,
            "n_total": plan.n_total,
        },
        "batches": rows,
        "phases_us": _phase_times(plan, a1, b1),
        "padding_waste": _padding_report(plan),
        "sharded_batched": _sharded_report(),
        "int_backends": _int_backends_report(plan, field, rng),
    }
    json_path = os.path.join(repo_root(), JSON_NAME)
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    return [
        {
            "name": "protocol_batch",
            "us_per_call": best["batched_us_per_product"],
            "derived": f"csv={path} json={json_path} batch={best['batch']} "
            f"speedup_vs_loop={best['speedup']}x "
            f"speedup_vs_pr1={best['speedup_vs_pr1']}x",
        }
    ]


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        _sharded_child()
    else:
        for row in run():
            print(f"{row['name']},{row['us_per_call']},{row['derived']}")
