"""Protocol wall-time scaling: worker hot loop (the paper's compute
bottleneck) across matrix sizes and partition choices, exercising the
GF(p) kernel path end-to-end."""
from __future__ import annotations

import numpy as np

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan

from .common import timeit, write_csv


def run():
    field = Field()
    rng = np.random.default_rng(0)
    rows = []
    for m, s, t, z in [(64, 2, 2, 2), (128, 2, 2, 2), (128, 4, 2, 3), (256, 4, 4, 4)]:
        sch = C.age_cmpc(s, t, z)
        shapes = BlockShapes(k=m, ma=m, mb=m, s=s, t=t)
        plan = make_plan(sch, shapes)
        a = field.random(rng, (m, m))
        b = field.random(rng, (m, m))
        fa = proto.share_a(plan, a, rng)
        fb = proto.share_b(plan, b, rng)
        us = timeit(lambda: np.asarray(proto.worker_multiply(plan, fa, fb)), repeat=3)
        rows.append(
            {
                "m": m, "s": s, "t": t, "z": z,
                "n_workers": plan.n_workers,
                "worker_multiply_us": round(us, 1),
                "field_muls": plan.n_workers * (m // t) * (m // s) * (m // t),
            }
        )
    path = write_csv("protocol_scaling", rows)
    total = sum(r["worker_multiply_us"] for r in rows)
    return [
        {
            "name": "protocol_scaling",
            "us_per_call": round(total / len(rows), 1),
            "derived": f"csv={path} max_m=256",
        }
    ]
