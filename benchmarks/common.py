"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import csv
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


def repo_root() -> str:
    """Repo root (where the committed BENCH_*.json snapshots live)."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timeit(fn: Callable, repeat: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path
