"""Shared benchmark utilities: timing, CSV emission, and the
forced-multi-device subprocess harness for sharded scenarios."""
from __future__ import annotations

import csv
import json
import os
import subprocess
import sys
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


def repo_root() -> str:
    """Repo root (where the committed BENCH_*.json snapshots live)."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timeit(fn: Callable, repeat: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def run_sharded_child(module: str, devices: int, timeout: int = 900) -> Dict:
    """Run ``python -m <module> --sharded-child`` on a forced
    multi-device host and parse its one-line JSON report.

    A subprocess on purpose: ``--xla_force_host_platform_device_count``
    must be set before JAX initializes, and forcing a device split in
    the parent would perturb its single-device benchmark numbers.
    """
    env = dict(os.environ)
    # append (not overwrite): any operator-supplied XLA_FLAGS must apply
    # to the child too, or its numbers aren't comparable to the parent's
    flags = f"--xla_force_host_platform_device_count={devices}"
    env["XLA_FLAGS"] = (
        env["XLA_FLAGS"] + " " + flags if env.get("XLA_FLAGS") else flags
    )
    env["PYTHONPATH"] = "src" + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    res = subprocess.run(
        [sys.executable, "-m", module, "--sharded-child"],
        capture_output=True,
        text=True,
        env=env,
        cwd=repo_root(),
        timeout=timeout,
    )
    if res.returncode != 0:
        raise RuntimeError(
            f"{module} sharded child failed:\n{res.stdout}\n{res.stderr}"
        )
    return json.loads(res.stdout.strip().splitlines()[-1])


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path
