"""Roofline analysis from the multi-pod dry-run artifacts.

Per (arch x shape) on the single-pod mesh (16 x 16 = 256 chips of
TPU v5e):

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = wire_bytes_per_chip / link_bw   (all-reduce ring
                    counted twice; others once)

FLOPs/bytes come from the loop-aware HLO walker (XLA's cost_analysis
counts while-loop bodies once; see launch/hlo_cost.py) applied to the
per-device SPMD module.  MODEL_FLOPS uses 6*N*D (train) or 2*N*D
(prefill/decode) with N = active parameters.

The roofline fraction reported is
  (MODEL_FLOPS/chips/peak) / max(compute, memory, collective)
i.e. the fraction of the step's lower-bound time spent on *useful*
model FLOPs under perfect overlap.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .common import write_csv

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

COLL_MULT = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def model_flops(rec: dict) -> float:
    n = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * rec["global_batch"]


def model_bytes(rec: dict) -> float:
    """Minimum HBM traffic for the step (global): every step must touch
    the parameters; the optimizer reads/writes p, mu, nu (all fp32);
    decode/prefill additionally stream the cache."""
    p_bytes = rec["params"] * 4.0
    if rec["kind"] == "train":
        return 7.0 * p_bytes  # p read+write, mu/nu read+write, grads
    cache = float(rec.get("cache_bytes", 0))
    return p_bytes + cache


def analyze_record(rec: dict) -> Dict:
    chips = rec["n_devices"]
    walker = rec["walker"]
    flops_chip = walker["flops"]  # per-device SPMD module
    bytes_chip = walker["bytes"]
    wire = sum(
        v * COLL_MULT.get(k, 1.0) for k, v in walker["collective_bytes"].items()
    )
    compute_s = flops_chip / PEAK_FLOPS
    memory_s = bytes_chip / HBM_BW
    collective_s = wire / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    mb = model_bytes(rec)
    # useful time: the larger of the flops-roofline and bytes-roofline
    # floors (a memory-bound decode step is "at roofline" when it
    # streams params+cache at full HBM bandwidth).
    useful_s = max(mf / chips / PEAK_FLOPS, mb / chips / HBM_BW)
    bound = max(terms.values())
    frac = useful_s / bound if bound > 0 else 0.0
    hlo_total = flops_chip * chips
    advice = {
        "compute": "reduce recompute (remat policy) / masked-block waste in attention",
        "memory": "increase arithmetic intensity: larger microbatch, fuse, quantize cache",
        "collective": "reshard to cut all-gathers (FSDP<->TP balance), overlap or compress collectives",
    }[bottleneck]
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": f"{compute_s:.4e}",
        "memory_s": f"{memory_s:.4e}",
        "collective_s": f"{collective_s:.4e}",
        "bottleneck": bottleneck,
        "model_flops": f"{mf:.3e}",
        "hlo_flops_total": f"{hlo_total:.3e}",
        "useful_ratio": round(mf / hlo_total, 3) if hlo_total else 0.0,
        "roofline_fraction": round(frac, 4),
        "hbm_gb_per_chip": round(
            (rec["memory"].get("argument_size_in_bytes", 0)
             + rec["memory"].get("temp_size_in_bytes", 0)) / 1e9, 2),
        "what_moves_it": advice,
    }


def run(dryrun_dir: str = "results/dryrun"):
    rows: List[Dict] = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*__single.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        rows.append(analyze_record(rec))
    path = write_csv("roofline", rows)
    if not rows:
        return [{"name": "roofline", "us_per_call": 0,
                 "derived": "no dry-run artifacts yet — run repro.launch.dryrun"}]
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    by_bottleneck = {}
    for r in rows:
        by_bottleneck[r["bottleneck"]] = by_bottleneck.get(r["bottleneck"], 0) + 1
    return [
        {
            "name": "roofline",
            "us_per_call": 0,
            "derived": (
                f"csv={path} cells={len(rows)} bottlenecks={by_bottleneck} "
                f"worst={worst['arch']}x{worst['shape']}@{worst['roofline_fraction']}"
            ),
        }
    ]
