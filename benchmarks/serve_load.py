"""Serving-engine load benchmark: continuous vs boundary batching under
open-loop Poisson arrivals.

The serving tier's claim is that admitting requests into *in-flight*
pipeline replays (``mode="continuous"``: a launch fires as soon as
fewer than ``pipe_depth`` replays remain in flight, so its Phase-1
upload rides the tail replay's Phase-2/Phase-3 window) bounds tail
latency against the classic batch-boundary server (``mode="boundary"``:
every launch waits for the pipeline to drain).  This harness offers the
SAME seeded request stream over the SAME worker-pool traces to both
modes for each construction, so the comparison isolates the batching
discipline:

* ``load``      — open-loop Poisson arrivals per construction (AGE and
                  PolyDot): sustained throughput and p50/p95/p99 sim
                  latency per mode, every decode validated against the
                  field oracle.  The emitted report asserts the win:
                  continuous p95 < boundary p95 at equal-or-better
                  throughput.
* ``admission`` — the PoolEstimate-driven controller under pressure:
                  a burst against a tight SLO (hopeless deadlines shed
                  before launch), and an elastic pool shrinking below
                  the construction's worker count (the remaining queue
                  shed with reason ``"pool"``); exact shed/served/miss
                  census on deterministic traces.

Every latency in the report is simulated protocol time, so all leaves
are deterministic per seed and ``tools/bench_diff.py`` diffs them
exactly.  Emits ``BENCH_serve.json`` at the repo root
(``make bench-serve``) plus a CSV under results/bench/.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.constructions import PlanConfig
from repro.core.gf import Field
from repro.runtime.pool import ShiftedExponential, sample_trace
from repro.serve import SHED, ServingEngine

from .common import repo_root, write_csv

JSON_NAME = "BENCH_serve.json"

CONSTRUCTIONS = {
    "age": PlanConfig("age", 2, 2, 2),
    "polydot": PlanConfig("polydot", 2, 2, 2),
}

# Open-loop stream: request shape, offered rate, and the (generous) SLO
# for the load section — latency is the measurement there, not shedding.
N_REQUESTS = 40
ROWS, K_DIM, OUT = 4, 16, 8
RATE = 0.6  # offered requests per simulated second
SLO = 30.0
PIPE_DEPTH = 2
MAX_BATCH = 8
N_TRACES = 64

# Service-dominant pool: compute stretches past the network share, so
# there is real Phase-2/3 window for continuous mode's uploads to hide in.
LATENCY = ShiftedExponential(shift=0.1, scale=0.5)
NET_SCALE = 0.3


def _traces(pool: int, seed0: int):
    return [
        sample_trace(pool, LATENCY, seed=seed0 + i, net_scale=NET_SCALE)
        for i in range(N_TRACES)
    ]


def _run_mode(w, traces, cfg, mode, xs, arrivals, field) -> dict:
    eng = ServingEngine(
        w, traces, cfg, field=field, mode=mode, pipe_depth=PIPE_DEPTH,
        max_batch=MAX_BATCH, slo=SLO, validate=True, seed=0,
    )
    for x, t in zip(xs, arrivals):
        eng.submit(x, float(t))
    s = eng.run().summary()
    s["oracle_validated"] = True
    return s


def _load_report(field) -> tuple:
    out = {
        "requests": N_REQUESTS,
        "rows": ROWS, "k": K_DIM, "out": OUT,
        "rate": RATE,
        "pipe_depth": PIPE_DEPTH,
        "max_batch": MAX_BATCH,
    }
    rows = []
    for name, cfg in CONSTRUCTIONS.items():
        # per-construction stream seed, identical across the two modes
        rng = np.random.default_rng([13, sorted(CONSTRUCTIONS).index(name)])
        w = rng.normal(size=(K_DIM, OUT)) * 0.5
        xs = rng.normal(size=(N_REQUESTS, ROWS, K_DIM))
        arrivals = np.cumsum(rng.exponential(1.0 / RATE, N_REQUESTS))
        pool = cfg.n_workers + 4
        traces = _traces(pool, seed0=9000)
        per_mode = {
            mode: _run_mode(w, traces, cfg, mode, xs, arrivals, field)
            for mode in ("continuous", "boundary")
        }
        cont, bound = per_mode["continuous"], per_mode["boundary"]
        if not cont["p95_latency"] < bound["p95_latency"]:
            raise AssertionError(
                f"{name}: continuous p95 {cont['p95_latency']} not below "
                f"boundary {bound['p95_latency']}"
            )
        if cont["throughput"] < 0.99 * bound["throughput"]:
            raise AssertionError(
                f"{name}: continuous throughput {cont['throughput']} fell "
                f"below boundary {bound['throughput']}"
            )
        per_mode["pool_size"] = pool
        per_mode["p95_improvement"] = round(
            bound["p95_latency"] / cont["p95_latency"], 4
        )
        per_mode["throughput_ratio"] = round(
            cont["throughput"] / bound["throughput"], 4
        )
        out[name] = per_mode
        for mode in ("continuous", "boundary"):
            s = per_mode[mode]
            rows.append(
                {
                    "construction": name,
                    "mode": mode,
                    "throughput": s["throughput"],
                    "p50_latency": s["p50_latency"],
                    "p95_latency": s["p95_latency"],
                    "p99_latency": s["p99_latency"],
                    "replays": s["replays"],
                }
            )
    return out, rows


def _shed_census(requests) -> dict:
    reasons = {}
    for r in requests:
        if r.state == SHED:
            reasons[r.shed_reason] = reasons.get(r.shed_reason, 0) + 1
    return reasons


def _admission_report(field) -> dict:
    """The admission controller under pressure, exact census per path."""
    cfg = PlanConfig("age", 2, 2, 1)
    rng = np.random.default_rng(29)
    w = rng.normal(size=(K_DIM, OUT)) * 0.5
    xs = rng.normal(size=(24, ROWS, K_DIM))

    # -- hopeless deadlines: a burst against a tight SLO ----------------
    pool = cfg.n_workers + 2
    eng = ServingEngine(
        w, _traces(pool, seed0=11000), cfg, field=field, slo=2.5,
        validate=True, seed=0,
    )
    for i, x in enumerate(xs):
        eng.submit(x, 0.05 * i)  # burst: far above the pool's service rate
    rep = eng.run()
    s = rep.summary()
    burst = {
        "slo": 2.5,
        "submitted": s["requests"],
        "served": s["served"],
        "shed": _shed_census(rep.requests),
        "deadline_misses": s["deadline_misses"],
        "replays": s["replays"],
        "oracle_validated": True,
    }

    # -- pool shrinks below the construction --------------------------
    big = sample_trace(pool, LATENCY, seed=12000, net_scale=NET_SCALE)
    small = big.take(cfg.n_workers - 2)  # cannot seat age(2,2,1)
    eng = ServingEngine(
        w, [big, big] + [small] * 60, cfg, field=field, slo=None,
        validate=True, seed=0,
    )
    for i, x in enumerate(xs):
        eng.submit(x, 2.0 * i)  # slow drip: the shrink lands mid-stream
    rep = eng.run()
    s = rep.summary()
    shrink = {
        "pool_sizes": [pool, cfg.n_workers - 2],
        "submitted": s["requests"],
        "served": s["served"],
        "shed": _shed_census(rep.requests),
        "replays": s["replays"],
        "oracle_validated": True,
    }
    if not shrink["shed"].get("pool"):
        raise AssertionError("elastic shrink shed nothing with reason 'pool'")
    return {"burst": burst, "elastic_shrink": shrink}


def run():
    field = Field()
    load, rows = _load_report(field)
    admission = _admission_report(field)
    report = {
        "bench": "serve_load",
        "config": {
            "constructions": {
                name: cfg.label() for name, cfg in CONSTRUCTIONS.items()
            },
            "latency_model": "ShiftedExponential(0.1, 0.5)",
            "net_scale": NET_SCALE,
        },
        "load": load,
        "admission": admission,
    }
    csv_path = write_csv("serve_load", rows)
    json_path = os.path.join(repo_root(), JSON_NAME)
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    age = load["age"]
    return [
        {
            "name": "serve_load",
            "us_per_call": 0.0,
            "derived": f"csv={csv_path} json={json_path} "
            f"age_p95_improvement={age['p95_improvement']} "
            f"age_throughput_ratio={age['throughput_ratio']} "
            f"polydot_p95_improvement={load['polydot']['p95_improvement']} "
            f"all_validated=True",
        }
    ]


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
