"""Beyond-paper optimization: Phase-2 exchange collective choice.

The paper's Phase 2 has every worker send G_n(alpha_{n'}) to every
other worker — zeta = N(N-1) m^2/t^2 scalars on the wire (Corollary
12).  Because I(x) = sum_n G_n(x) is *linear*, the exchange can be a
reduce-scatter: the sum is computed inside the collective, so the wire
volume drops to O(N m^2/t^2).

This benchmark compiles the shard_map Phase-2 program in all three
modes on an 8-device worker mesh and counts wire bytes from the HLO.
Run in a subprocess so the parent keeps 1 device:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.cmpc_comm
"""
from __future__ import annotations

import json
import subprocess
import sys

_CHILD = """
import numpy as np, jax, json
from jax.sharding import Mesh
from repro.core import constructions as C, protocol as proto
from repro.core.planner import BlockShapes, make_plan
from repro.core.distributed import run_phase2_sharded
from repro.core.gf import Field
from repro.launch.hlo_cost import analyze

f = Field(); rng = np.random.default_rng(7)
mesh = Mesh(np.array(jax.devices()), ("workers",))
sch = C.build_scheme("age", 2, 2, 4)
m = 256
shapes = BlockShapes(k=m, ma=m, mb=m, s=2, t=2)
plan = make_plan(sch, shapes, n_spare=7, seed=1)
A = f.random(rng, (m, m)); B = f.random(rng, (m, m))
fa = proto.share_a(plan, A, rng); fb = proto.share_b(plan, B, rng)
noise = f.random(rng, (plan.n_workers, plan.scheme.z, m//2, m//2))
want = f.matmul(A.T, B)

out = {"n_workers": plan.n_workers, "n_total": plan.n_total,
       "paper_zeta_scalars": plan.n_workers*(plan.n_workers-1)*(m//2)*(m//2)}
for mode in ("all_to_all", "psum", "psum_scatter"):
    compiled = run_phase2_sharded(plan, fa, fb, noise, mesh, mode=mode,
                                  return_compiled=True)
    cost = analyze(compiled.as_text())
    i_evals = run_phase2_sharded(plan, fa, fb, noise, mesh, mode=mode)
    ok = bool(np.array_equal(proto.reconstruct(plan, i_evals), want))
    out[mode] = {"collective_bytes_per_device": cost.collectives,
                 "correct": ok}
print(json.dumps(out))
"""


def run():
    res = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True, text=True, timeout=580,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
    )
    if res.returncode != 0:
        raise RuntimeError(res.stdout + res.stderr)
    data = json.loads(res.stdout.strip().splitlines()[-1])

    def total(mode):
        return sum(data[mode]["collective_bytes_per_device"].values())

    a2a, ps, rs = total("all_to_all"), total("psum"), total("psum_scatter")
    from .common import write_csv

    rows = [
        {"mode": m, "wire_bytes_per_device": total(m), "correct": data[m]["correct"]}
        for m in ("all_to_all", "psum", "psum_scatter")
    ]
    path = write_csv("cmpc_comm_modes", rows)
    return [
        {
            "name": "cmpc_phase2_collectives",
            "us_per_call": 0,
            "derived": (
                f"csv={path} N={data['n_workers']} all_to_all={a2a} psum={ps} "
                f"reduce_scatter={rs} saving={a2a / max(rs, 1):.1f}x all_correct="
                f"{all(data[m]['correct'] for m in ('all_to_all','psum','psum_scatter'))}"
            ),
        }
    ]
