"""Fig. 3: required workers vs s/t at st = 36, z = 42."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import closed_form as cf
from repro.core import constructions as C

from .common import write_csv

PAIRS = [(1, 36), (2, 18), (3, 12), (4, 9), (6, 6), (9, 4), (12, 3), (18, 2), (36, 1)]
Z = 42


def run() -> List[Dict]:
    t0 = time.perf_counter()
    rows = []
    for s, t in PAIRS:
        n_age, lam = cf.n_age_exact(s, t, Z)
        rows.append(
            {
                "s": s,
                "t": t,
                "s_over_t": round(s / t, 4),
                "age": n_age,
                "age_lambda_star": lam,
                "polydot": C.polydot_cmpc(s, t, Z).n_workers,
                "entangled": cf.n_entangled(s, t, Z),
                "ssmm": cf.n_ssmm(s, t, Z),
                "gcsa_na": cf.n_gcsa_na(s, t, Z),
            }
        )
    elapsed = time.perf_counter() - t0
    path = write_csv("fig3_workers_vs_st", rows)

    assert all(r["age"] <= min(r["polydot"], r["entangled"], r["ssmm"], r["gcsa_na"]) for r in rows)
    pd_wins = [
        (r["s"], r["t"])
        for r in rows
        if r["polydot"] < min(r["entangled"], r["ssmm"], r["gcsa_na"])
    ]
    ok = all(c in pd_wins for c in [(2, 18), (3, 12), (4, 9)])
    return [
        {
            "name": "fig3_workers_vs_st",
            "us_per_call": round(elapsed * 1e6 / len(PAIRS), 1),
            "derived": f"csv={path} polydot_wins={pd_wins} paper_cells_confirmed={ok}",
        }
    ]
