"""Section V-B Example 1 as an executable benchmark: the s=t=z=2
instance end-to-end, timing each protocol phase."""
from __future__ import annotations

import numpy as np

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan

from .common import timeit, write_csv


def run():
    field = Field()
    sch = C.age_cmpc(2, 2, 2)
    assert sch.n_workers == 17 and sch.lam == 2  # the paper's numbers
    m = 64
    shapes = BlockShapes(k=m, ma=m, mb=m, s=2, t=2)
    plan = make_plan(sch, shapes)
    rng = np.random.default_rng(0)
    a = field.random(rng, (m, m))
    b = field.random(rng, (m, m))

    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    h = proto.worker_multiply(plan, fa, fb)
    i_evals = proto.degree_reduce(plan, h, rng)

    rows = [
        {"phase": "phase1_share", "us": timeit(lambda: np.asarray(proto.share_a(plan, a, rng)))},
        {"phase": "phase2_multiply", "us": timeit(lambda: np.asarray(proto.worker_multiply(plan, fa, fb)))},
        {"phase": "phase2_exchange", "us": timeit(lambda: np.asarray(proto.degree_reduce(plan, h, rng)))},
        {"phase": "phase3_decode", "us": timeit(lambda: proto.reconstruct(plan, i_evals))},
    ]
    path = write_csv("example1_phases", rows)
    y = proto.reconstruct(plan, i_evals)
    correct = bool(np.array_equal(y, field.matmul(a.T, b)))
    total = sum(r["us"] for r in rows)
    return [
        {
            "name": "example1_protocol",
            "us_per_call": round(total, 1),
            "derived": f"csv={path} n_workers=17 lambda_star=2 exact={correct} m={m}",
        }
    ]
