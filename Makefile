# Convenience targets; `pythonpath` in pyproject.toml makes the bare
# checkout importable, so no PYTHONPATH=src hack is needed.

PYTHON ?= python

.PHONY: test test-fast bench bench-json bench-edge bench-serve quickstart \
	docs-check shim-check bench-diff trace-check fuzz-kernels

test:
	$(PYTHON) -m pytest -q

test-fast:
	$(PYTHON) -m pytest -x -q

bench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.run

# Machine-readable perf snapshot: refreshes BENCH_protocol.json at the
# repo root so later PRs can track regressions.
bench-json:
	PYTHONPATH=src $(PYTHON) -m benchmarks.protocol_batch

# PolyDot vs AGE over identical edge worker-pool traces; refreshes
# BENCH_edge.json at the repo root.  TRACE=1 additionally writes a
# Perfetto-loadable BENCH_edge.trace.json sidecar (report unchanged).
bench-edge:
	PYTHONPATH=src TRACE=$(TRACE) $(PYTHON) -m benchmarks.edge_runtime

# Serving-engine load benchmark: continuous vs boundary batching under
# open-loop Poisson arrivals; refreshes BENCH_serve.json at the repo root.
bench-serve:
	PYTHONPATH=src $(PYTHON) -m benchmarks.serve_load

quickstart:
	PYTHONPATH=src $(PYTHON) examples/quickstart.py

# Verify every relative link in README.md and docs/*.md resolves.
docs-check:
	$(PYTHON) tools/check_doc_links.py

# Verify version-drifting JAX spellings (shard_map / AxisType /
# CompilerParams) stay inside their shim modules.
shim-check:
	$(PYTHON) tools/check_api_shims.py

# Compare freshly regenerated BENCH_*.json against the committed
# snapshots (deterministic leaves exact, wall-clock within a band).
bench-diff:
	$(PYTHON) tools/bench_diff.py

# Differential fuzz of the GF(p) matmul backends (f32limb / int32 /
# both Pallas kernels in interpret mode / CRT) against the
# arbitrary-precision host oracle.  Fixed seed = reproducible CI gate;
# raise FUZZ_EXAMPLES locally for a longer hunt.
FUZZ_EXAMPLES ?= 24
FUZZ_SEED ?= 0
fuzz-kernels:
	$(PYTHON) tools/fuzz_kernels.py --examples $(FUZZ_EXAMPLES) --seed $(FUZZ_SEED) -q

# Generate a small trace end-to-end (replay + adaptive decision) and
# verify the Chrome/Perfetto export: schema-valid, all three protocol
# phases, per-worker scheduler events, >= 1 AutoPlanner decision.
trace-check:
	PYTHONPATH=src $(PYTHON) tools/trace_check.py
