# Convenience targets; `pythonpath` in pyproject.toml makes the bare
# checkout importable, so no PYTHONPATH=src hack is needed.

PYTHON ?= python

.PHONY: test test-fast bench quickstart

test:
	$(PYTHON) -m pytest -q

test-fast:
	$(PYTHON) -m pytest -x -q

bench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.run

quickstart:
	PYTHONPATH=src $(PYTHON) examples/quickstart.py
