"""Per-link network model and the pipelined batched-replay runtime.

Link traces: deterministic timeline assertions against crafted
``(sender, receiver)`` delay matrices, prefix-replay contracts with
links enabled, and the dropped-link vs dropped-worker fault interplay.
Pipeline: K replays through one pool equal K sequential replays on
non-overlapping traces (timeline and subsets), every decode validated
against the host oracle, and the straggler-cancellation rule.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import constructions as C
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan
from repro.runtime import (
    AsymmetricLinks,
    ClusteredEdge,
    DecodeFailure,
    Deterministic,
    ShiftedExponential,
    UniformLinks,
    run_batch_over_pool,
    run_over_pool,
    run_pipeline_over_pool,
    sample_trace,
)


@pytest.fixture(scope="module")
def setup():
    field = Field()
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, n_spare=3, seed=1)
    rng = np.random.default_rng(0)
    a = field.random(rng, (8, 8))
    b = field.random(rng, (8, 4))
    return plan, a, b, field.matmul(a.T, b)


# ----------------------------------------------------------------------
# per-link traces
# ----------------------------------------------------------------------
def test_scalar_equivalent_link_matrix(setup):
    """with_links() (receiver-constant columns) replays identically to
    the scalar trace — the trace-compatibility guarantee."""
    plan, a, b, want = setup
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=2)
    scalar = run_over_pool(plan, a, b, trace, seed=3)
    linked = run_over_pool(plan, a, b, trace.with_links(), seed=3)
    assert np.array_equal(linked.y, want)
    assert linked.metrics.completion_time == scalar.metrics.completion_time
    assert np.array_equal(linked.metrics.phase2_ids, scalar.metrics.phase2_ids)
    assert np.array_equal(
        linked.metrics.responder_ids, scalar.metrics.responder_ids
    )


def test_link_matrix_deterministic_timeline(setup):
    """Phase-2 completion is the max over a receiver's incoming links:
    one slow incoming link delays exactly that receiver's response."""
    plan, a, b, want = setup
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=4).with_links()
    link = trace.link_delay.copy()  # all incoming links cost 0.1
    slow_recv = 0
    link[3, slow_recv] = 5.0  # one slow link into receiver 0
    trace = trace.with_link_matrix(link)
    run = run_over_pool(plan, a, b, trace, seed=5)
    assert np.array_equal(run.y, want)
    m = run.metrics
    # timeline: share 0.1 + compute 1.0 fixes the set at 1.1; fast
    # receivers respond at 1.1 + 0.1 + 0.1, the decode accepts there
    assert m.phase2_set_time == pytest.approx(1.1)
    assert m.completion_time == pytest.approx(1.3)
    # worker 3 is in the Phase-2 set, so receiver 0's exchange leg is
    # max over incoming = 5.0 -> it cannot be among the fastest
    # decode_threshold responders
    assert 3 in m.phase2_ids
    assert slow_recv not in m.responder_ids


def test_link_trace_prefix_replay():
    """take(n) slices the link matrix [:n, :n] — prefix pools keep the
    sub-fabric among their own workers (identical-links contract)."""
    net = UniformLinks(ShiftedExponential(1.0, 1.0), scale=0.1)
    full = sample_trace(25, ShiftedExponential(1.0, 1.0), seed=6, network=net)
    assert full.link_delay.shape == (25, 25)
    assert np.all(np.diag(full.link_delay) == 0.0)
    part = full.take(20)
    assert part.link_delay.shape == (20, 20)
    assert np.array_equal(part.link_delay, full.link_delay[:20, :20])
    assert np.array_equal(part.share_delay, full.share_delay[:20])
    # with_faults keeps the matrix intact
    faulted = part.with_faults(dropout_ids=[1])
    assert np.array_equal(faulted.link_delay, part.link_delay)


def test_network_models_decode_exactly(setup):
    plan, a, b, want = setup
    nets = [
        UniformLinks(ShiftedExponential(1.0, 1.0)),
        AsymmetricLinks(ShiftedExponential(1.0, 1.0), up_scale=0.5),
        ClusteredEdge(ShiftedExponential(1.0, 1.0), n_clusters=3),
    ]
    for i, net in enumerate(nets):
        trace = sample_trace(
            plan.n_total, ShiftedExponential(1.0, 1.0), seed=10 + i, network=net
        )
        run = run_over_pool(plan, a, b, trace, seed=20 + i)
        assert np.array_equal(run.y, want), type(net).__name__


def test_asymmetric_uplink_dominates_completion(setup):
    """With a 50x uplink, the response leg dominates the timeline."""
    plan, a, b, want = setup
    net = AsymmetricLinks(
        Deterministic(1.0), down_scale=0.1, d2d_scale=0.1, up_scale=5.0
    )
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=7, network=net)
    run = run_over_pool(plan, a, b, trace, seed=8)
    assert np.array_equal(run.y, want)
    # share 0.1 + compute 1.0 + d2d 0.1 + uplink 5.0
    assert run.metrics.completion_time == pytest.approx(6.2)


def test_dropped_link_vs_dropped_worker(setup):
    """A dead incoming link silences the receiver in Phase 3 but keeps
    it serving Phase 2 — strictly weaker than dropping the worker."""
    plan, a, b, want = setup
    base = sample_trace(plan.n_total, Deterministic(1.0), seed=9)
    victim = 2

    # sender 4 -> receiver `victim` link dies
    linkdrop = base.with_dropped_links([(4, victim)])
    run = run_over_pool(plan, a, b, linkdrop, seed=10)
    assert np.array_equal(run.y, want)
    # starvation requires the dead link's sender IN the Phase-2 set —
    # a dead link from a non-sender is harmless by protocol (receivers
    # only sum the senders' contributions)
    assert 4 in run.metrics.phase2_ids
    assert victim in run.metrics.phase2_ids  # still a Phase-2 sender
    assert victim not in run.metrics.responder_ids  # but never responds
    assert run.metrics.n_dropped == 0

    # the harmless case, pinned: a dead link from a spare that stays
    # outside the sender set has no effect — the receiver responds
    # normally (deterministic trace: responses arrive in id order, so
    # the low-id victim lands in the decode subset)
    spare = plan.n_total - 1
    harmless = base.with_dropped_links([(spare, victim)])
    run_h = run_over_pool(plan, a, b, harmless, seed=10)
    assert np.array_equal(run_h.y, want)
    assert spare not in run_h.metrics.phase2_ids
    assert victim in run_h.metrics.responder_ids

    # whole worker drops: excluded from Phase 2 as well
    workerdrop = base.with_faults(dropout_ids=[victim])
    run2 = run_over_pool(plan, a, b, workerdrop, seed=10)
    assert np.array_equal(run2.y, want)
    assert victim not in run2.metrics.phase2_ids
    assert run2.metrics.n_dropped == 1


def test_dropped_links_starve_decode(setup):
    """Killing one incoming link of every worker leaves no responders:
    the failure is loud and names the link starvation."""
    plan, a, b, _ = setup
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=11)
    dead = [(0, r) for r in range(1, plan.n_total)] + [(1, 0)]
    trace = trace.with_dropped_links(dead)
    with pytest.raises(DecodeFailure, match="link_starved"):
        run_over_pool(plan, a, b, trace, seed=12)


def test_dropped_link_validation():
    trace = sample_trace(10, Deterministic(1.0), seed=13)
    with pytest.raises(ValueError, match="out of range"):
        trace.with_dropped_links([(0, 10)])
    with pytest.raises(ValueError, match="self-loop"):
        trace.with_dropped_links([(3, 3)])
    with pytest.raises(ValueError, match="matrix"):
        dataclasses.replace(trace, link_delay=np.zeros((3, 3)))


# ----------------------------------------------------------------------
# pipelined batched replays
# ----------------------------------------------------------------------
def _pipeline_operands(plan, depth, batch, seed=0):
    field = Field()
    rng = np.random.default_rng(seed)
    sh = plan.shapes
    a = field.random(rng, (depth, batch, sh.k, sh.ma))
    b = field.random(rng, (depth, batch, sh.k, sh.mb))
    want = np.stack(
        [
            np.stack([field.matmul(a[k, i].T, b[k, i]) for i in range(batch)])
            for k in range(depth)
        ]
    )
    return a, b, want


def test_pipeline_equals_sequential_on_nonoverlapping_traces(setup):
    """When compute fits inside the share-upload gap (compute <= share),
    workers are always free when the next share arrives, so each
    replay's relative timeline and subsets equal the standalone
    replay's — the pipeline only shifts replay k by k upload slots."""
    plan, _, _, _ = setup
    K, batch = 3, 2
    a, b, want = _pipeline_operands(plan, K, batch, seed=14)
    # net_scale=2.0: share 2.0 > compute 1.0 -> no compute queueing
    traces = [
        sample_trace(plan.n_total, Deterministic(1.0), seed=15, net_scale=2.0)
        for _ in range(K)
    ]
    res = run_pipeline_over_pool(plan, a, b, traces, seed=16)
    assert np.array_equal(res.y, want)
    assert res.metrics.depth == K and res.metrics.batch == batch
    seq = 0.0
    for k in range(K):
        single = run_batch_over_pool(plan, a[k], b[k], traces[k], seed=16)
        sm, pm = single.metrics, res.replay_metrics[k]
        seq += sm.completion_time
        # shifted by k upload slots (share_delay = 2.0), else identical
        assert pm.completion_time == pytest.approx(
            sm.completion_time + 2.0 * k
        )
        assert np.array_equal(pm.phase2_ids, sm.phase2_ids)
        assert np.array_equal(pm.responder_ids, sm.responder_ids)
        assert pm.trace.total == sm.trace.total
    # aggregate accounting: phase-wise sum over replays
    assert res.metrics.trace.total == sum(
        m.trace.total for m in res.replay_metrics
    )
    assert res.metrics.products == K * batch
    # overlap beats the back-to-back sequential sum
    assert res.metrics.makespan < seq
    assert res.metrics.occupancy > 1.0


def test_pipeline_phase1_overlaps_phase2_compute(setup):
    """In the edge regime (share << compute), replay k+1's whole
    Phase-1 upload lands while replay k is still in flight."""
    plan, _, _, _ = setup
    K = 3
    a, b, want = _pipeline_operands(plan, K, 1, seed=17)
    traces = [
        sample_trace(plan.n_total, Deterministic(1.0), seed=18)
        for _ in range(K)
    ]
    res = run_pipeline_over_pool(plan, a, b, traces, seed=19)
    assert np.array_equal(res.y, want)
    # share 0.1, compute 1.0: replay k+1's upload (0.1 long, starting
    # at 0.1 * (k+1)) is fully inside replay k's span -> each of the
    # K-1 later uploads is fully overlapped
    assert res.metrics.phase1_overlap == pytest.approx(0.1 * (K - 1))
    # compute serializes: completion_k = 1.3 + k * 1.0
    assert np.allclose(
        res.metrics.completions, [1.3 + 1.0 * k for k in range(K)]
    )


def test_pipeline_straggler_cancellation(setup):
    """A straggler excluded from replay 0's Phase-2 set abandons its
    stale compute at the announcement, so replay 1 is not gated by the
    10x-slow multiply."""
    plan, _, _, _ = setup
    K = 2
    a, b, want = _pipeline_operands(plan, K, 1, seed=20)
    slow = sample_trace(plan.n_total, Deterministic(1.0), seed=21).with_faults(
        straggler_ids=[0], straggler_slowdown=100.0
    )
    traces = [slow, sample_trace(plan.n_total, Deterministic(1.0), seed=22)]
    res = run_pipeline_over_pool(plan, a, b, traces, seed=23)
    assert np.array_equal(res.y, want)
    assert 0 not in res.replay_metrics[0].phase2_ids
    # replay 0: set at 1.1, accepted 1.3.  Worker 0 abandons at 1.1;
    # its replay-1 share arrived at 0.2, compute restarts at 1.1 and
    # (no straggling in trace 1) finishes at 2.1 — same as everyone
    # else (queued behind their replay-0 multiply), so replay 1's set
    # fixes at 2.1 and completes at 2.3, straggler-free.
    assert res.replay_metrics[1].completion_time == pytest.approx(2.3)
    # without cancellation worker 0 would be busy until 100+; the
    # completion assertion above is the loud check that it is not


def test_pipeline_fault_interplay(setup):
    """Per-replay faults stay per-replay: a corrupt responder in
    replay 0 is detected there and clean in replay 1; a dropped worker
    in replay 1 is skipped there only.  Decode failures stay loud."""
    plan, _, _, _ = setup
    K = 2
    a, b, want = _pipeline_operands(plan, K, 2, seed=24)
    t0 = sample_trace(
        plan.n_total, ShiftedExponential(1.0, 0.2), seed=25
    ).with_faults(corrupt_ids=[2])
    t1 = sample_trace(
        plan.n_total, ShiftedExponential(1.0, 0.2), seed=26
    ).with_faults(dropout_ids=[5])
    res = run_pipeline_over_pool(plan, a, b, [t0, t1], seed=27)
    assert np.array_equal(res.y, want)
    assert 2 not in res.replay_metrics[0].responder_ids
    assert res.replay_metrics[0].confirmed_by.size >= 1
    assert res.replay_metrics[1].n_dropped == 1
    assert 5 not in res.replay_metrics[1].phase2_ids
    # too many dropouts in ANY in-flight replay fails loudly
    bad = sample_trace(plan.n_total, Deterministic(1.0), seed=28).with_faults(
        dropout_ids=list(range(plan.n_spare + 1))
    )
    with pytest.raises(DecodeFailure, match="dropouts"):
        run_pipeline_over_pool(plan, a, b, [t0, bad], seed=29)


def test_pipeline_with_link_traces(setup):
    """Link-resolved traces compose with pipelining: per-replay link
    matrices, exact decode throughout."""
    plan, _, _, _ = setup
    K = 2
    a, b, want = _pipeline_operands(plan, K, 2, seed=30)
    net = ClusteredEdge(ShiftedExponential(1.0, 0.5), n_clusters=2)
    traces = [
        sample_trace(
            plan.n_total, ShiftedExponential(1.0, 0.5), seed=31 + k, network=net
        )
        for k in range(K)
    ]
    res = run_pipeline_over_pool(plan, a, b, traces, seed=33)
    assert np.array_equal(res.y, want)
    assert res.metrics.makespan >= res.metrics.completions[0]


def test_pipeline_validation(setup):
    plan, _, _, _ = setup
    a, b, _ = _pipeline_operands(plan, 2, 1, seed=34)
    with pytest.raises(ValueError, match="at least one"):
        run_pipeline_over_pool(plan, a, b, [], seed=35)
    short = sample_trace(plan.n_total - 1, Deterministic(1.0), seed=36)
    with pytest.raises(ValueError, match="provisions"):
        run_pipeline_over_pool(
            plan, a, b, [short, short], seed=37
        )
    one = sample_trace(plan.n_total, Deterministic(1.0), seed=38)
    with pytest.raises(ValueError, match="depth"):
        run_pipeline_over_pool(plan, a, b, [one], seed=39)
