"""The observability layer: tracer semantics, the metrics registry,
the Chrome/Perfetto exporter, and the empty-run guard regressions.

The tracer tests pin the contracts the instrumentation relies on:
disabled tracing allocates nothing on the hot path, sim-clock traces
of byte-identical replays are byte-identical, wall and sim records
live on separable tracks, and the exported JSON is schema-valid.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import obs
from repro.core.constructions import PlanConfig
from repro.core.planner import (
    BlockShapes,
    decode_check_cache_clear,
    decode_check_cache_info,
    get_plan_for,
)
from repro.core.protocol import Trace
from repro.obs import MetricsRegistry, Tracer
from repro.obs.export import SIM_PID, WALL_PID, to_chrome, to_jsonl, validate_chrome
from repro.obs.tracer import _DISABLED_SPAN
from repro.runtime import AutoPlanner, run_adaptive_over_pool, run_over_pool
from repro.runtime.metrics import PipelineMetrics, summarize
from repro.runtime.pool import sample_trace


@pytest.fixture
def tracer():
    t = Tracer()
    t.enable()
    yield t
    t.disable()


@pytest.fixture
def global_tracing():
    """Enable the module-level TRACER for runtime-integration tests and
    always restore the disabled default."""
    obs.TRACER.clear()
    obs.enable()
    yield obs.TRACER
    obs.disable()
    obs.TRACER.clear()


def _small_setup():
    cfg = PlanConfig("age", 2, 2, 2).resolved()
    m = 4
    plan = get_plan_for(cfg, BlockShapes(k=m, ma=m, mb=m, s=2, t=2), seed=0)
    rng = np.random.default_rng(0)
    a = rng.integers(0, plan.field.p, (m, m))
    b = rng.integers(0, plan.field.p, (m, m))
    return plan, a, b


# ----------------------------------------------------------------------
# tracer semantics
# ----------------------------------------------------------------------
def test_disabled_tracer_allocates_nothing():
    t = Tracer()  # disabled by default
    assert t.span("a") is t.span("b") is _DISABLED_SPAN
    assert t.event("x", k=1) == 0
    assert t.sim_span("y", 0.0, 1.0) == 0
    assert t.sim_event("z", 0.5) == 0
    assert t.events == []
    # the no-op span is a working context manager with the Span surface
    with t.span("a") as sp:
        assert sp.set(extra=1) is sp
        assert sp.id == 0


def test_nested_spans_record_parent_ids(tracer):
    with tracer.span("outer") as outer:
        with tracer.span("inner"):
            tracer.event("tick")
    ev = {e["name"]: e for e in tracer.events}
    assert ev["outer"]["parent"] == 0
    assert ev["inner"]["parent"] == outer.id
    assert ev["tick"]["parent"] == ev["inner"]["id"]
    # completion order: inner closes before outer
    assert [e["name"] for e in tracer.events] == ["tick", "inner", "outer"]


def test_span_set_adds_attributes_midflight(tracer):
    with tracer.span("s", fixed=1) as sp:
        sp.set(late=2)
    (rec,) = tracer.events
    assert rec["attrs"] == {"fixed": 1, "late": 2}


def test_sim_and_wall_tracks_are_separable(tracer):
    with tracer.span("wall_work"):
        pass
    tracer.sim_span("replay", 0.0, 2.5, track=("replay", 0))
    tracer.sim_event("barrier", 1.0, track=("worker", 3))
    sims = tracer.sim_events()
    assert {e["name"] for e in sims} == {"replay", "barrier"}
    assert all(e["clock"] == "sim" for e in sims)
    assert {tuple(e["track"]) for e in sims} == {("replay", 0), ("worker", 3)}
    walls = [e for e in tracer.events if e["clock"] == "wall"]
    assert [e["name"] for e in walls] == ["wall_work"]
    assert isinstance(walls[0]["track"], int)  # thread id, not a lane


def test_event_cap_counts_drops():
    t = Tracer(max_events=2).enable()
    for i in range(5):
        t.sim_event("e", float(i))
    assert len(t.events) == 2
    assert t.dropped == 3
    t.clear()
    assert t.dropped == 0 and t.events == []


def test_identical_replays_trace_identically(global_tracing):
    plan, a, b = _small_setup()
    trace = sample_trace(plan.n_total, seed=7)
    sims = []
    for _ in range(2):
        obs.TRACER.clear()
        run_over_pool(plan, a, b, trace, seed=0)
        # ids are allocation order, not content — compare everything else
        sims.append(
            [
                {k: v for k, v in e.items() if k not in ("id", "parent")}
                for e in obs.TRACER.sim_events()
            ]
        )
    assert sims[0] == sims[1]
    assert len(sims[0]) > 0


def test_tracing_does_not_change_results(global_tracing):
    plan, a, b = _small_setup()
    trace = sample_trace(plan.n_total, seed=7)
    res_on = run_over_pool(plan, a, b, trace, seed=0)
    obs.disable()
    res_off = run_over_pool(plan, a, b, trace, seed=0)
    assert np.array_equal(res_on.y, res_off.y)
    assert res_on.metrics.completion_time == res_off.metrics.completion_time


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------
def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.gauge("g").set(1.5)
    for v in (1.0, 2.0, 3.0):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == 1.5
    assert snap["histograms"]["h"]["count"] == 3
    assert snap["histograms"]["h"]["p50"] == 2.0
    reg.reset()
    assert reg.snapshot()["counters"] == {}


def test_empty_histogram_summary_is_defined():
    reg = MetricsRegistry()
    assert reg.histogram("h").summary() == {
        "count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0,
    }


def test_broken_probe_reports_instead_of_raising():
    reg = MetricsRegistry()

    def boom():
        raise RuntimeError("nope")

    reg.register_probe("bad", boom)
    info = reg.snapshot()["probes"]["bad"]
    assert "error" in info and "nope" in info["error"]


def test_cache_probes_delegate_to_planner():
    """The three legacy cache spellings surface through one snapshot."""
    decode_check_cache_clear()
    plan, a, b = _small_setup()
    run_over_pool(plan, a, b, sample_trace(plan.n_total, seed=1), seed=0)
    snap = obs.snapshot()
    for probe in ("plan_cache", "subset_cache", "decode_check_cache"):
        assert "hits" in snap["probes"][probe], probe
        assert "misses" in snap["probes"][probe], probe
    # the decode-check memo is actually counted now
    info = decode_check_cache_info()
    assert info["hits"] + info["misses"] >= 1
    assert snap["probes"]["decode_check_cache"] == info


def test_runtime_counters_increment(global_tracing):
    plan, a, b = _small_setup()
    before = obs.REGISTRY.counter("runtime.replays").value
    run_over_pool(plan, a, b, sample_trace(plan.n_total, seed=1), seed=0)
    assert obs.REGISTRY.counter("runtime.replays").value == before + 1
    assert json.dumps(obs.snapshot())  # snapshot is JSON-serializable


# ----------------------------------------------------------------------
# Chrome/Perfetto export
# ----------------------------------------------------------------------
def test_chrome_export_is_schema_valid(tracer):
    with tracer.span("wall", k=1):
        pass
    tracer.sim_span("replay", 0.0, 1.0, track=("replay", 0))
    tracer.sim_event("barrier", 0.5, track=("replay", 0))
    chrome = to_chrome(tracer, metrics={"counters": {"c": 1}})
    assert validate_chrome(chrome) == []
    assert chrome["repro_metrics"] == {"counters": {"c": 1}}
    json.dumps(chrome)  # round-trippable


def test_chrome_pids_separate_the_clocks(tracer):
    with tracer.span("wall"):
        pass
    tracer.sim_span("sim", 0.0, 1.0, track=("worker", 2))
    chrome = to_chrome(tracer)
    x = [e for e in chrome["traceEvents"] if e["ph"] == "X"]
    pids = {e["name"]: e["pid"] for e in x}
    assert pids == {"wall": WALL_PID, "sim": SIM_PID}
    # sim timestamps are seconds * 1e6 on the exported microsecond axis
    sim = next(e for e in x if e["name"] == "sim")
    assert sim["dur"] == pytest.approx(1e6)
    # lane metadata names the worker thread
    names = {
        (m["pid"], m["tid"]): m["args"]["name"]
        for m in chrome["traceEvents"]
        if m["ph"] == "M" and m["name"] == "thread_name"
    }
    assert names[(SIM_PID, sim["tid"])] == "worker 2"


def test_chrome_wall_track_rebased_to_zero(tracer):
    with tracer.span("first"):
        pass
    with tracer.span("second"):
        pass
    chrome = to_chrome(tracer)
    ts = [e["ts"] for e in chrome["traceEvents"] if e["ph"] == "X"]
    assert min(ts) == 0.0


def test_validate_chrome_flags_malformed():
    assert validate_chrome({"nope": 1})
    assert validate_chrome({"traceEvents": [{"ph": "Q", "pid": 1, "tid": 1}]})
    bad_dur = {
        "traceEvents": [
            {"ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": -5, "name": "x"}
        ]
    }
    assert validate_chrome(bad_dur)


def test_jsonl_export_round_trips(tracer):
    tracer.sim_span("replay", 0.0, 1.0, track=("replay", 1), note="hi")
    lines = to_jsonl(tracer).strip().splitlines()
    assert len(lines) == 1
    rec = json.loads(lines[0])
    assert rec["name"] == "replay" and rec["track"] == ["replay", 1]


# ----------------------------------------------------------------------
# decision -> replay linkage
# ----------------------------------------------------------------------
def test_adaptive_decisions_link_to_replay_spans(global_tracing):
    cfg = PlanConfig("age", 2, 2, 2)
    m, K, batch = 4, 3, 2
    rng = np.random.default_rng(0)
    a = rng.integers(0, 7, (K, batch, m, m))
    b = rng.integers(0, 7, (K, batch, m, m))
    traces = [sample_trace(cfg.n_total + 2, seed=10 + k) for k in range(K)]
    planner = AutoPlanner([cfg], cost_m=m)
    run = run_adaptive_over_pool(planner, a, b, traces, seed=0)
    assert all(d.obs_id > 0 for d in run.decisions)
    ev = obs.TRACER.events
    decide_ids = {e["id"] for e in ev if e["name"] == "autoplan.decide"}
    replays = [e for e in ev if e["name"] == "replay"]
    assert len(replays) == K
    for rec in replays:
        assert rec["attrs"]["decision_id"] in decide_ids
        assert "config" in rec["attrs"]
        assert rec["attrs"]["wire_bytes_total"] > 0


# ----------------------------------------------------------------------
# empty-run guard regressions
# ----------------------------------------------------------------------
def test_summarize_empty_is_defined():
    assert summarize([]) == {"runs": 0}


def _pm(**kw):
    base = dict(
        depth=2, batch=1, products=2, makespan=4.0,
        completions=np.array([2.0, 4.0]), starts=np.array([0.0, 1.0]),
        occupancy=1.25, phase1_overlap=0.5, trace=Trace(),
    )
    base.update(kw)
    return PipelineMetrics(**base)


def test_pipeline_metrics_guards():
    with pytest.raises(ValueError, match="depth"):
        _pm(depth=0)
    with pytest.raises(ValueError, match="batch"):
        _pm(batch=0)
    with pytest.raises(ValueError, match="makespan"):
        _pm(makespan=float("nan"))
    with pytest.raises(ValueError, match="makespan"):
        _pm(makespan=-1.0)


def test_pipeline_overlap_ratio_zero_makespan():
    pm = _pm(
        makespan=0.0, completions=np.zeros(2), starts=np.zeros(2),
        occupancy=0.0, phase1_overlap=0.0,
    )
    assert pm.overlap_ratio == 0.0
    assert _pm().overlap_ratio == pytest.approx(0.125)
