"""Shared environment for tests that spawn python subprocesses.

The subprocess env is minimal on purpose (reproducible drivers), but
``JAX_PLATFORMS`` must pass through: without it the child re-probes for
accelerators, which stalls for minutes on hosts whose TPU/GPU runtime
is absent.
"""
import os


def subprocess_env(**overrides) -> dict:
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    env.update(overrides)
    return env
