"""End-to-end CMPC protocol: exact Y = A^T B over GF(p), straggler
tolerance, coded-only decode, quantised real-valued layers, CRT mode."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.gf import Field
from repro.core.layers import PrivateLinear, secure_matmul, secure_matmul_crt
from repro.core.planner import BlockShapes, make_plan


@pytest.fixture(scope="module")
def field():
    return Field()


CASES = [
    ("age", 2, 2, 2),
    ("age", 3, 2, 4),
    ("age", 1, 3, 2),
    ("age", 2, 1, 3),
    ("polydot", 2, 3, 3),
    ("polydot", 4, 2, 5),
    ("entangled-greedy", 2, 2, 2),
]


@pytest.mark.parametrize("method,s,t,z", CASES)
def test_end_to_end(method, s, t, z, field):
    rng = np.random.default_rng(42)
    sch = C.build_scheme(method, s, t, z)
    shapes = BlockShapes(k=s * 4, ma=t * 6, mb=t * 2, s=s, t=t)
    plan = make_plan(sch, shapes, seed=1)
    a = field.random(rng, (shapes.k, shapes.ma))
    b = field.random(rng, (shapes.k, shapes.mb))
    y, trace = proto.run(plan, a, b, seed=3)
    assert np.array_equal(y, field.matmul(a.T, b))
    # Corollary 12 accounting: each of the n_workers senders reaches the
    # other n_total - 1 provisioned workers (== n_workers - 1 here since
    # these plans carry no spares; the spare-inclusive case is covered
    # in test_runtime's trace-match test).
    n = plan.n_workers
    assert plan.n_total == n
    assert trace.phase2_worker_to_worker == n * (plan.n_total - 1) * (
        shapes.ma // t
    ) * (shapes.mb // t)


def test_coded_only_decode(field):
    rng = np.random.default_rng(7)
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes)
    a = field.random(rng, (8, 8))
    b = field.random(rng, (8, 4))
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    h = proto.worker_multiply(plan, fa, fb)
    y = proto.reconstruct_coded_only(plan, h)
    assert np.array_equal(y, field.matmul(a.T, b))


def test_straggler_tolerance(field):
    """Spare workers serve Phase 2; Phase 3 decodes from any t^2+z."""
    rng = np.random.default_rng(8)
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, n_spare=4)
    a = field.random(rng, (8, 8))
    b = field.random(rng, (8, 4))
    want = field.matmul(a.T, b)
    # drop workers 0 and 2 from phase 2; decode from a shifted subset
    ids2 = np.array([i for i in range(plan.n_total) if i not in (0, 2)])[: plan.n_workers]
    ids3 = np.arange(3, 3 + plan.decode_threshold)
    y, _ = proto.run(plan, a, b, seed=4, phase2_ids=ids2, phase3_ids=ids3)
    assert np.array_equal(y, want)


def test_phase3_needs_threshold(field):
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=4, ma=4, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes)
    with pytest.raises(ValueError):
        plan.decode_matrix(np.arange(plan.decode_threshold - 1))


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(1, 3), t=st.integers(1, 3), z=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_protocol_property(s, t, z, seed):
    if s == 1 and t == 1:
        return
    field = Field()
    rng = np.random.default_rng(seed)
    sch = C.build_scheme("age", s, t, z)
    shapes = BlockShapes(k=s * 2, ma=t * 2, mb=t * 2, s=s, t=t)
    plan = make_plan(sch, shapes, seed=seed)
    a = field.random(rng, (shapes.k, shapes.ma))
    b = field.random(rng, (shapes.k, shapes.mb))
    y, _ = proto.run(plan, a, b, seed=seed + 1)
    assert np.array_equal(y, field.matmul(a.T, b))


def test_secure_matmul_real():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(16, 12))
    b = rng.normal(size=(16, 8))
    res = secure_matmul(a, b, s=2, t=2, z=2)
    # fixed-point error bound: k * (a_max + b_max) / (2*scale)
    assert np.abs(res.y - a.T @ b).max() < 1.0


def test_secure_matmul_crt_precision():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(16, 12))
    b = rng.normal(size=(16, 8))
    res = secure_matmul_crt(a, b, s=2, t=2, z=2)
    assert np.abs(res.y - a.T @ b).max() < 0.02


def test_private_linear():
    rng = np.random.default_rng(1)
    lin = PrivateLinear(rng.normal(size=(32, 8)), s=2, t=2, z=1, blocks=2)
    x = rng.normal(size=(6, 32))
    assert np.abs(lin(x) - x @ lin.w).max() < 1.0
