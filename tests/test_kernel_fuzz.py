"""Differential fuzz + property tests for the native-integer kernel tier.

Three layers of assurance on top of the fixed-shape grid of
``test_backend_equiv.py``:

* **differential fuzz** — random (B, M, K, N) shapes, primes, and
  adversarial operand distributions (dense-high-limb, near-p, maximal,
  sparse) through every backend — portable f32limb/int32, both Pallas
  kernels in interpret mode, and the dual-prime CRT route — each
  checked bit-for-bit against an arbitrary-precision host matmul
  (``repro.kernels.modmatmul.fuzz``),
* **reduction-bound properties** — the int32 paths must raise loudly,
  never wrap silently, when a contraction exceeds the uint32/int32
  accumulator budgets (mirroring the ``npad * p < 2**31`` regression
  style of test_kernels.py), and stay exact AT the bound,
* **PRNG stream identity** — the threefry2x32 implementation matches
  the Random123 known-answer vectors (and JAX's own implementation when
  importable), and the fused in-kernel mask stream is bit-identical to
  the materialized ``field_mask`` reference under a fixed key.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.gf import (
    CHUNK_K,
    INT32_ACC_K,
    P_DEFAULT,
    crt_combine,
    field_mask,
    mod_matmul_int32,
    threefry2x32,
)
from repro.kernels.modmatmul import fuzz as kfuzz
from repro.kernels.modmatmul.kernel import (
    INT32_KERNEL_MAX_BK,
    modmatmul_masked_pallas,
    modmatmul_pallas,
)
from repro.kernels.modmatmul.ops import (
    _resolve_auto,
    mod_matmul,
    mod_matmul_masked,
)


# ----------------------------------------------------------------------
# differential fuzz across all backends
# ----------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzz_all_backends_match_oracle(seed):
    rng = np.random.default_rng(seed)
    case = kfuzz.sample_case(rng)
    mismatches = kfuzz.check_case(case)
    assert not mismatches, "\n".join(m.describe() for m in mismatches)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzz_deep_k_int32_tier(seed):
    """Deep-K cases (K > 256) exercise the int32 tier's chunked uint32
    accumulator and the deep-bk Pallas int32 kernel."""
    rng = np.random.default_rng(seed)
    case = kfuzz.sample_case(rng, deep_k=True)
    assert case.k > CHUNK_K
    mismatches = kfuzz.check_case(case)
    assert not mismatches, "\n".join(m.describe() for m in mismatches)


def test_run_fuzz_entry_point_clean():
    """The CLI/CI entry point itself: a short fixed-seed run is clean."""
    assert kfuzz.run_fuzz(examples=4, seed=123) == []


def test_fuzz_harness_detects_a_planted_bug():
    """The harness must actually be able to fail: a corrupted engine is
    reported as a mismatch (guards against a vacuous oracle)."""
    case = kfuzz.Case(
        batch=1, m=3, k=5, n=2, p=251, mode="uniform", layout="2d", seed=7
    )
    broken = dict(kfuzz.ENGINES)
    broken["evil"] = lambda a, b, p: kfuzz.ENGINES["f32limb"](a, b, p) + 1
    orig = kfuzz.ENGINES
    kfuzz.ENGINES = broken
    try:
        bad = kfuzz.check_case(case, engines=["evil"])
    finally:
        kfuzz.ENGINES = orig
    assert len(bad) == 1 and bad[0].engine == "evil"


# ----------------------------------------------------------------------
# reduction-bound properties: loud failure, never silent wrap
# ----------------------------------------------------------------------
def test_int32_portable_overflow_raises_loudly():
    a = jnp.zeros((2, INT32_ACC_K + 1), jnp.int32)
    b = jnp.zeros((INT32_ACC_K + 1, 2), jnp.int32)
    with pytest.raises(ValueError, match="wrap silently"):
        mod_matmul_int32(a, b, P_DEFAULT)


def test_int32_portable_exact_at_the_bound():
    """Maximal operands at the exact accumulator limit: the summed
    cross-limb dot reaches its uint32 ceiling and must not wrap."""
    p = P_DEFAULT
    a = jnp.full((1, INT32_ACC_K), p - 1, jnp.int32)
    b = jnp.full((INT32_ACC_K, 1), p - 1, jnp.int32)
    got = int(np.asarray(mod_matmul_int32(a, b, p))[0, 0])
    assert got == (INT32_ACC_K * (p - 1) * (p - 1)) % p


def test_int32_kernel_bk_bound_raises_loudly():
    k = INT32_KERNEL_MAX_BK + 127  # next 128-multiple past the bound
    k -= k % 128
    a = jnp.zeros((8, k), jnp.int32)
    b = jnp.zeros((k, 128), jnp.int32)
    with pytest.raises(ValueError, match="wrap silently"):
        modmatmul_pallas(
            a, b, p=P_DEFAULT, bm=8, bn=128, bk=k, interpret=True,
            variant="int32",
        )


def test_big_prime_rejected_everywhere():
    a = jnp.zeros((8, 128), jnp.int32)
    b = jnp.zeros((128, 128), jnp.int32)
    with pytest.raises(ValueError):
        modmatmul_pallas(a, b, p=65537, bm=8, bn=128, bk=128, interpret=True)
    with pytest.raises(ValueError):
        mod_matmul_int32(a, b, 65537)


def test_auto_dispatch_respects_the_accumulator_bound():
    """``auto`` on CPU: f32limb for shallow K, int32 once deeper than a
    single 256 chunk, and back to f32limb past the uint32 budget —
    never a silently-wrapping int32 pick."""
    assert _resolve_auto(CHUNK_K) == "f32limb"
    assert _resolve_auto(CHUNK_K + 1) == "int32"
    assert _resolve_auto(INT32_ACC_K) == "int32"
    assert _resolve_auto(INT32_ACC_K + 1) == "f32limb"


def test_mask_counter_space_exhaustion_raises():
    with pytest.raises(ValueError, match="counter space"):
        field_mask(jnp.zeros(2, jnp.uint32), (1 << 16, 1 << 16), P_DEFAULT)
    with pytest.raises(ValueError, match="counter space"):
        modmatmul_masked_pallas(
            jnp.zeros((8, 128), jnp.int32),
            jnp.zeros((128, 128), jnp.int32),
            jnp.zeros((8, 2), jnp.int32),
            jnp.zeros(2, jnp.uint32),
            p=P_DEFAULT, ncols=1 << 31, bm=8, bn=128, bk=128, interpret=True,
        )


def test_crt_combine_guards():
    with pytest.raises(ValueError, match="2\\*\\*62"):
        crt_combine(
            [np.zeros(1, np.int64)] * 4, [65521, 65519, 65497, 65479]
        )
    with pytest.raises(ValueError):  # non-coprime moduli
        crt_combine([np.zeros(1, np.int64)] * 2, [12, 8])


# ----------------------------------------------------------------------
# PRNG stream identity
# ----------------------------------------------------------------------
def test_threefry_known_answer_vectors():
    """Random123 KATs for threefry2x32 (20 rounds)."""
    kats = [
        ((0, 0), (0, 0), (0x6B200159, 0x99BA4EFE)),
        (
            (0xFFFFFFFF, 0xFFFFFFFF),
            (0xFFFFFFFF, 0xFFFFFFFF),
            (0x1CB996FC, 0xBB002BE7),
        ),
        (
            (0x13198A2E, 0x03707344),
            (0x243F6A88, 0x85A308D3),
            (0xC4923A9C, 0x483DF7A0),
        ),
    ]
    for (k0, k1), (c0, c1), (e0, e1) in kats:
        x0, x1 = threefry2x32(
            jnp.uint32(k0), jnp.uint32(k1),
            jnp.uint32(c0)[None], jnp.uint32(c1)[None],
        )
        assert (int(x0[0]), int(x1[0])) == (e0, e1)


def test_threefry_matches_jax_internal():
    jax_prng = pytest.importorskip("jax._src.prng")
    key = jnp.asarray([12345, 67890], jnp.uint32)
    ctr = jnp.arange(64, dtype=jnp.uint32)
    ours = threefry2x32(key[0], key[1], ctr, jnp.zeros_like(ctr))
    theirs = jax_prng.threefry_2x32(key, jnp.stack([ctr, jnp.zeros_like(ctr)]))
    np.testing.assert_array_equal(np.asarray(ours[0]), np.asarray(theirs[0]))
    np.testing.assert_array_equal(np.asarray(ours[1]), np.asarray(theirs[1]))


def test_field_mask_deterministic_and_roughly_uniform():
    key = jnp.asarray([5, 6], jnp.uint32)
    m1 = np.asarray(field_mask(key, (64, 64), P_DEFAULT))
    m2 = np.asarray(field_mask(key, (64, 64), P_DEFAULT))
    np.testing.assert_array_equal(m1, m2)
    assert m1.min() >= 0 and m1.max() < P_DEFAULT
    # a different key gives a different stream
    m3 = np.asarray(field_mask(jnp.asarray([5, 7], jnp.uint32), (64, 64), P_DEFAULT))
    assert (m1 != m3).mean() > 0.99
    # coarse uniformity: each quartile of [0, p) gets ~25% of draws
    hist, _ = np.histogram(m1, bins=4, range=(0, P_DEFAULT))
    assert np.abs(hist / m1.size - 0.25).max() < 0.05
    # prefix consistency: a smaller shape is a prefix of the same stream
    m4 = np.asarray(field_mask(key, (16,), P_DEFAULT))
    np.testing.assert_array_equal(m4, m1.reshape(-1)[:16])


# ----------------------------------------------------------------------
# fused in-kernel masks == materialized masks, bit for bit
# ----------------------------------------------------------------------
@pytest.mark.parametrize("variant", ["f32", "int32"])
@pytest.mark.parametrize("batched", [False, True])
def test_fused_mask_bit_identical_to_materialized(variant, batched):
    rng = np.random.default_rng(11)
    p = P_DEFAULT
    z, ncols = 3, 100
    sa = (2, 16, 256) if batched else (16, 256)
    sb = (2, 256, 128) if batched else (256, 128)
    a = jnp.asarray(rng.integers(0, p, sa), jnp.int32)
    b = jnp.asarray(rng.integers(0, p, sb), jnp.int32)
    v = jnp.asarray(rng.integers(0, p, (16, z)), jnp.int32)
    key = jnp.asarray([99, 100], jnp.uint32)
    fused = modmatmul_masked_pallas(
        a, b, v, key, p=p, ncols=ncols, bm=8, bn=128, bk=128,
        interpret=True, variant=variant,
    )
    batch = (2,) if batched else ()
    mask = field_mask(key, batch + (z, ncols), p)
    want = (
        np.asarray(mod_matmul(a, b, p=p, backend="f32limb"), np.int64)[..., :ncols]
        + np.asarray(mod_matmul(v, mask, p=p, backend="f32limb"), np.int64)
    ) % p
    np.testing.assert_array_equal(np.asarray(fused, np.int64)[..., :ncols], want)


@pytest.mark.parametrize("backend", ["f32limb", "int32", "pallas", "pallas_int32"])
def test_mod_matmul_masked_backends_bit_identical(backend):
    """The ops-level fused entry point: every backend produces the same
    bits for the same key (unaligned logical shapes, padding sliced)."""
    rng = np.random.default_rng(12)
    p = P_DEFAULT
    a = jnp.asarray(rng.integers(0, p, (3, 9, 300)), jnp.int32)
    b = jnp.asarray(rng.integers(0, p, (3, 300, 40)), jnp.int32)
    v = jnp.asarray(rng.integers(0, p, (9, 2)), jnp.int32)
    key = jnp.asarray([4, 8], jnp.uint32)
    got = np.asarray(mod_matmul_masked(a, b, v, key, p=p, backend=backend), np.int64)
    mask = field_mask(key, (3, 2, 40), p)
    want = (
        np.asarray(mod_matmul(a, b, p=p, backend="f32limb"), np.int64)
        + np.asarray(mod_matmul(v, mask, p=p, backend="f32limb"), np.int64)
    ) % p
    np.testing.assert_array_equal(got, want)
