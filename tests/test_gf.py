"""Field arithmetic: host oracle + device limb paths."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.gf import Field, P_DEFAULT, mod_matmul_f32

PRIMES = [251, 4093, 7919, 40961, 65519, 65521]


@pytest.fixture(scope="module")
def f():
    return Field()


def test_inverse(f):
    rng = np.random.default_rng(0)
    for _ in range(50):
        a = int(rng.integers(1, f.p))
        assert (a * f.inv(a)) % f.p == 1


def test_solve_roundtrip(f):
    rng = np.random.default_rng(1)
    a = f.random(rng, (8, 8))
    x = f.random(rng, (8, 3))
    b = f.matmul(a, x)
    got = f.solve(a, b)
    assert np.array_equal(got, x)


def test_inv_matrix(f):
    rng = np.random.default_rng(2)
    a = f.random(rng, (10, 10))
    inv = f.inv_matrix(a)
    assert np.array_equal(f.matmul(a, inv), np.eye(10, dtype=np.int64))


def test_vandermonde_invertible(f):
    rng = np.random.default_rng(3)
    pts = rng.choice(f.p - 1, size=12, replace=False) + 1
    v = f.vandermonde(pts, range(12))
    f.inv_matrix(v)  # must not raise


@pytest.mark.parametrize("p", PRIMES)
def test_limb_matmul_all_primes(p):
    rng = np.random.default_rng(p)
    f = Field(p)
    a = rng.integers(0, p, (37, 300)).astype(np.int32)
    b = rng.integers(0, p, (300, 23)).astype(np.int32)
    want = f.matmul(a, b)
    got = np.asarray(mod_matmul_f32(a, b, p))
    assert np.array_equal(want, got)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 600),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_limb_matmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    f = Field()
    a = rng.integers(0, f.p, (m, k)).astype(np.int32)
    b = rng.integers(0, f.p, (k, n)).astype(np.int32)
    assert np.array_equal(f.matmul(a, b), np.asarray(mod_matmul_f32(a, b, f.p)))


def test_encode_decode_roundtrip(f):
    rng = np.random.default_rng(5)
    x = rng.normal(size=(16, 16))
    q = f.encode(x, 256)
    back = f.decode(q, 256)
    assert np.abs(back - x).max() <= 1.0 / 256


def test_encode_overflow_raises(f):
    with pytest.raises(OverflowError):
        f.encode(np.array([1e6]), 256)
