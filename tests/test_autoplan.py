"""Adaptive auto-planner loop: PlanConfig surface, closed-form cost
prior, plan-cache replan fast path, order-statistic pool estimators,
drift scenarios (time-varying links, elastic pools), and the
AutoPlanner's decide/observe feedback — sequential and mid-pipeline."""
import json

import numpy as np
import pytest

from repro.core import closed_form as cf
from repro.core import constructions as C
from repro.core.constructions import PlanConfig
from repro.core.gf import Field
from repro.core.planner import (
    BlockShapes,
    get_plan_for,
    plan_cache_clear,
    plan_cache_info,
)
from repro.runtime import (
    AutoPlanner,
    Deterministic,
    ElasticPool,
    FaultSpec,
    ShiftedExponential,
    TimeVaryingLinks,
    UniformLinks,
    estimate_pool,
    fit_order_stats,
    observed_run,
    order_stat_mean,
    run_adaptive_over_pool,
    run_batch_over_pool,
    run_over_pool,
    run_pipeline_over_pool,
    sample_trace,
)
from repro.runtime.autoplan import _replay_seed
from repro.runtime.metrics import ObservedRun


FIELD = Field()


# ----------------------------------------------------------------------
# PlanConfig + construction registry
# ----------------------------------------------------------------------
def test_plan_config_matches_scheme():
    cfg = PlanConfig("age", 2, 2, 3)
    sch = cfg.scheme()
    assert cfg.n_workers == sch.n_workers == 20
    assert cfg.decode_threshold == sch.decode_threshold == 7
    assert cfg.n_total == cfg.n_workers  # no spares by default


def test_plan_config_fit_to_pool_and_label():
    cfg = PlanConfig("age", 2, 2, 3)
    fitted = cfg.fit_to_pool(25)
    assert fitted.n_spare == 5 and fitted.n_total == 25
    # the label names the construction, not the provisioning
    assert fitted.resolved().label() == cfg.resolved().label()
    with pytest.raises(ValueError):
        cfg.fit_to_pool(cfg.n_workers - 1)


def test_plan_config_resolved_pins_lambda():
    cfg = PlanConfig("age", 2, 2, 2)
    res = cfg.resolved()
    assert res.lam == 2  # Example 1's lambda*
    assert res.resolved() == res  # idempotent
    assert "lam=2" in res.label() and "lam" not in cfg.label()


def test_plan_config_rejects_unknown_method():
    with pytest.raises(KeyError):
        PlanConfig("nonsense", 2, 2, 2)


def test_registry_capabilities():
    assert set(C.known_methods()) >= {"age", "polydot", "entangled-greedy"}
    age = C.get_construction("age")
    assert age.supports_lam and age.adaptive_gap
    poly = C.get_construction("polydot-cmpc")  # alias resolves
    assert poly.name == "polydot" and not poly.supports_lam
    # the registry's cheap oracle agrees with the built scheme
    for method in ("age", "polydot", "entangled-greedy"):
        ctor = C.get_construction(method)
        assert ctor.n_workers(2, 2, 3, None) == ctor.build(2, 2, 3, None).n_workers


def test_age_exact_search_equals_exhaustive_grid():
    """The n_age_exact fast path picks the same-optimal gap as building
    every lambda in [0, z] — over the validation grid."""
    for s in range(1, 5):
        for t in range(1, 4):
            if s == 1 and t == 1:
                continue
            for z in range(1, 5):
                fast = C.age_cmpc(s, t, z, exact_search=True)
                exhaustive = min(
                    (C.age_cmpc_fixed(s, t, z, lam).n_workers
                     for lam in range(0, z + 1)),
                )
                assert fast.n_workers == exhaustive, (s, t, z)


# ----------------------------------------------------------------------
# closed-form cost prior
# ----------------------------------------------------------------------
def test_predict_matches_corollaries():
    cfg = PlanConfig("age", 2, 2, 3)
    pred = cf.predict(cfg, 32)
    n = cfg.n_workers
    m, s, t = 32, 2, 2
    assert pred.n_workers == n
    assert pred.decode_threshold == 7
    assert pred.compute == cf.computation_overhead(m, s, t, 3, n)
    assert pred.comm == cf.communication_overhead(m, t, n)
    assert pred.compute_factor(pred) == 1.0


def test_work_factor_tension():
    """age(4,1,3) fields far fewer workers but each does more work —
    the trade-off the planner arbitrates is real in the cost model."""
    light = cf.predict(PlanConfig("age", 2, 2, 3), 32)
    heavy = cf.predict(PlanConfig("age", 4, 1, 3), 32)
    assert heavy.n_workers < light.n_workers  # 13 < 20
    assert heavy.decode_threshold < light.decode_threshold  # 4 < 7
    assert heavy.compute_factor(light) > 1.2  # but heavier per worker


# ----------------------------------------------------------------------
# plan cache: spares-only replan fast path
# ----------------------------------------------------------------------
def test_replan_fast_path_counts_and_prefix():
    plan_cache_clear()
    m = 8
    cfg = PlanConfig("age", 2, 2, 2, n_spare=2)
    shapes = BlockShapes(k=m, ma=m, mb=m, s=2, t=2)
    p2 = get_plan_for(cfg, shapes)
    assert plan_cache_info()["replans"] == 0
    p4 = get_plan_for(cfg.replace(n_spare=4), shapes)
    assert plan_cache_info()["replans"] == 1
    # prefix-consistent evaluation points: the smaller plan's alphas are
    # a prefix of the larger one's, so decode rows / sender matrices
    # transfer between sibling plans
    assert np.array_equal(p4.alphas[: p2.n_total], p2.alphas)
    # both decode correctly
    from repro.core import protocol as proto

    rng = np.random.default_rng(0)
    a = FIELD.random(rng, (m, m))
    b = FIELD.random(rng, (m, m))
    for plan in (p2, p4):
        y, _ = proto.run(plan, a, b)
        assert np.array_equal(y, FIELD.matmul(a.T, b))


def test_get_plan_for_caches_exact_config():
    plan_cache_clear()
    shapes = BlockShapes(k=8, ma=8, mb=8, s=2, t=2)
    cfg = PlanConfig("age", 2, 2, 2, n_spare=1)
    p1 = get_plan_for(cfg, shapes)
    p2 = get_plan_for(cfg, shapes)
    assert p1 is p2
    assert plan_cache_info()["hits"] >= 1


def test_get_plan_for_rejects_mismatched_shapes():
    with pytest.raises(ValueError):
        get_plan_for(
            PlanConfig("age", 2, 2, 2),
            BlockShapes(k=8, ma=8, mb=8, s=4, t=1),
        )


# ----------------------------------------------------------------------
# order-statistic estimators
# ----------------------------------------------------------------------
def test_order_stat_mean_edges():
    assert order_stat_mean(0, 10, 1.0, 1.0) == 0.0
    assert order_stat_mean(11, 10, 1.0, 1.0) == float("inf")
    means = [order_stat_mean(k, 10, 1.0, 0.5) for k in range(1, 11)]
    assert all(b > a for a, b in zip(means, means[1:]))  # deeper = later


def test_fit_order_stats_recovers_parameters():
    shift, scale = 1.3, 0.4
    samples = [
        (order_stat_mean(k, n, shift, scale), k, n)
        for n in (10, 20, 35)
        for k in (3, n // 2, n - 1)
    ]
    fs, fsc = fit_order_stats(samples)
    assert abs(fs - shift) < 1e-9
    assert abs(fsc - scale) < 1e-9


def test_fit_order_stats_underdetermined_falls_back():
    # one harmonic gap -> proportional fit through the origin
    shift, scale = fit_order_stats([(2.0, 5, 10), (2.0, 5, 10)])
    assert shift == 0.0 and scale > 0.0


def test_estimate_pool_rates_and_prediction():
    runs = [
        ObservedRun(
            n_pool=20, n_workers=10, n_ready_pool=18, thr_arrived=7,
            n_receivers=17, set_time=2.0, response_delta=1.0,
            completion=3.0, n_dropped=2, n_rejected=1,
        )
        for _ in range(4)
    ]
    est = estimate_pool(runs)
    assert est.dropout_rate == pytest.approx(2 / 20)
    assert est.crash_rate == pytest.approx(1 / 18)
    assert est.corrupt_rate == pytest.approx(1 / 17)
    # infeasible requests predict inf
    assert est.predict_completion(50, 7, 20) == float("inf")
    assert np.isfinite(est.predict_completion(10, 7, 20))


def test_observed_run_projection():
    m = 8
    cfg = PlanConfig("age", 2, 2, 2, n_spare=2)
    plan = get_plan_for(cfg, BlockShapes(k=m, ma=m, mb=m, s=2, t=2))
    trace = sample_trace(plan.n_total, ShiftedExponential(1.0, 0.5), seed=3)
    rng = np.random.default_rng(1)
    a = FIELD.random(rng, (m, m))
    b = FIELD.random(rng, (m, m))
    res = run_over_pool(plan, a, b, trace, seed=0)
    rec = observed_run(res.metrics)
    assert rec.n_workers == plan.n_workers
    assert rec.completion == pytest.approx(res.metrics.completion_time)
    assert rec.set_time + rec.response_delta == pytest.approx(rec.completion)
    assert rec.thr_arrived >= plan.decode_threshold


# ----------------------------------------------------------------------
# scenario layer: time-varying links
# ----------------------------------------------------------------------
def _linked_trace(n, seed=11):
    return sample_trace(
        n,
        ShiftedExponential(1.0, 0.5),
        seed=seed,
        network=UniformLinks(ShiftedExponential(0.2, 0.2), scale=0.3),
    )


def test_time_varying_links_schedule_resolution():
    trace = _linked_trace(12)
    tv = TimeVaryingLinks(((0.5, 2.0), (1.5, 4.0))).apply(trace)
    assert np.array_equal(tv.link_at(0.0), trace.link_delay)
    assert np.allclose(tv.link_at(0.7), trace.link_delay * 2.0)
    assert np.allclose(tv.link_at(99.0), trace.link_delay * 4.0)
    # boundary: entry takes effect exactly at its start time
    assert np.allclose(tv.link_at(0.5), trace.link_delay * 2.0)


def test_time_varying_links_future_onset_is_byte_identical():
    """A degradation scheduled after the replay finishes changes
    nothing — the scheduler resolves the matrix at set-announcement."""
    m = 8
    cfg = PlanConfig("age", 2, 2, 2, n_spare=2)
    plan = get_plan_for(cfg, BlockShapes(k=m, ma=m, mb=m, s=2, t=2))
    trace = _linked_trace(plan.n_total)
    rng = np.random.default_rng(2)
    a = FIELD.random(rng, (m, m))
    b = FIELD.random(rng, (m, m))
    base = run_over_pool(plan, a, b, trace, seed=5)
    late = run_over_pool(
        plan, a, b, TimeVaryingLinks(((1e9, 8.0),)).apply(trace), seed=5
    )
    assert base.metrics.completion_time == late.metrics.completion_time
    assert np.array_equal(base.metrics.responder_ids, late.metrics.responder_ids)
    # ... while an immediate degradation slows the run down
    now = run_over_pool(
        plan, a, b, TimeVaryingLinks(((0.0, 8.0),)).apply(trace), seed=5
    )
    assert now.metrics.completion_time > base.metrics.completion_time
    assert np.array_equal(now.y, base.y)  # numerics unaffected


def test_time_varying_links_slice_with_pool():
    trace = _linked_trace(12)
    tv = TimeVaryingLinks(((1.0, 3.0),)).apply(trace)
    sub = tv.take(8)
    assert sub.link_schedule is not None
    for (t_full, m_full), (t_sub, m_sub) in zip(
        tv.link_schedule, sub.link_schedule
    ):
        assert t_full == t_sub
        assert np.array_equal(m_full[:8, :8], m_sub)


# ----------------------------------------------------------------------
# scenario layer: elastic pools
# ----------------------------------------------------------------------
def test_select_prefix_equals_take():
    trace = _linked_trace(14)
    sel = trace.select(np.arange(10))
    tk = trace.take(10)
    assert np.array_equal(sel.compute_delay, tk.compute_delay)
    assert np.array_equal(sel.link_delay, tk.link_delay)


def test_elastic_pool_members_are_byte_identical():
    master = _linked_trace(16)
    pool = ElasticPool(master, ((0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11),
                               (0, 2, 4, 6, 8, 10, 12, 14, 1, 3, 5, 7)))
    assert len(pool) == 2 and pool.sizes() == (12, 12)
    t0, t1 = pool.trace_for(0), pool.trace_for(1)
    # worker 4 appears in both replays: same delays, same link row/col
    i0 = 4          # position of id 4 in membership 0
    i1 = 2          # position of id 4 in membership 1
    assert t0.compute_delay[i0] == t1.compute_delay[i1]
    assert t0.share_delay[i0] == t1.share_delay[i1]
    # link between ids 4 and 8 is the same physical link in both
    j0, j1 = 8, 4   # positions of id 8
    assert t0.link_delay[i0, j0] == t1.link_delay[i1, j1]


def test_elastic_pool_replay_equals_static_subset_run():
    """A replay over ElasticPool membership == the plain run over the
    equivalent selected trace — membership changes nothing but the
    roster."""
    m = 8
    cfg = PlanConfig("age", 2, 2, 2, n_spare=1)
    master = _linked_trace(24)
    ids = tuple(range(cfg.n_workers + 1))
    pool = ElasticPool(master, (ids,))
    rng = np.random.default_rng(3)
    a = FIELD.random(rng, (m, m))
    b = FIELD.random(rng, (m, m))
    plan = get_plan_for(cfg, BlockShapes(k=m, ma=m, mb=m, s=2, t=2))
    via_pool = run_over_pool(plan, a, b, pool.trace_for(0), seed=7)
    via_select = run_over_pool(plan, a, b, master.select(ids), seed=7)
    assert via_pool.metrics.completion_time == via_select.metrics.completion_time
    assert np.array_equal(via_pool.y, via_select.y)


# ----------------------------------------------------------------------
# the planner loop
# ----------------------------------------------------------------------
CANDS = [PlanConfig("age", 2, 2, 2), PlanConfig("age", 4, 1, 2)]


def test_autoplanner_dedupes_and_scores():
    planner = AutoPlanner(CANDS + [PlanConfig("age", 2, 2, 2, n_spare=9)])
    assert len(planner.candidates) == 2  # spares don't distinguish candidates
    d = planner.decide(30)
    assert d.reason == "explore" and d.config.n_total == 30


def test_autoplanner_infeasible_pool_raises():
    planner = AutoPlanner(CANDS)
    with pytest.raises(ValueError):
        planner.decide(min(c.n_workers for c in CANDS) - 1)


def test_adaptive_run_decodes_and_records():
    m = 8
    K = 4
    traces = [
        sample_trace(20, ShiftedExponential(1.0, 0.5), seed=100 + k)
        for k in range(K)
    ]
    rng = np.random.default_rng(5)
    a = FIELD.random(rng, (K, m, m))  # [K, k, m] promotes to batch 1
    b = FIELD.random(rng, (K, m, m))
    planner = AutoPlanner(CANDS, window=4)
    run = run_adaptive_over_pool(planner, a, b, traces, seed=9)
    for k in range(K):
        assert np.array_equal(
            run.y[k, 0], FIELD.matmul(a[k].T, b[k])
        ), f"replay {k} decode != oracle"
    assert len(run.decisions) == K
    assert run.decisions[0].reason == "explore"
    # summary is JSON-ready for the benchmark report
    json.dumps(planner.summary())
    assert planner.estimate().n_runs == K


def test_autoplanner_settles_on_faster_candidate():
    """On a pool where age(2,2,2) [N=17 of 20] finishes earlier than
    age(4,1,2) [N=11 of 20, but x harmonic-deeper uplink...] — whatever
    wins, after exploration the planner repeats one choice."""
    m = 8
    K = 8
    traces = [
        sample_trace(20, ShiftedExponential(1.0, 0.5), seed=200 + k)
        for k in range(K)
    ]
    rng = np.random.default_rng(6)
    a = FIELD.random(rng, (K, m, m))
    b = FIELD.random(rng, (K, m, m))
    planner = AutoPlanner(CANDS, window=6)
    run = run_adaptive_over_pool(planner, a, b, traces, seed=4)
    tail = [d.config.resolved().label() for d in run.decisions[-3:]]
    assert len(set(tail)) == 1  # settled
    assert run.decisions[-1].reason in ("observed", "prior")


def test_autoplanner_forced_switch_on_pool_shrink():
    m = 8
    big, small = 20, 12  # 12 < N=17 of age(2,2,2); age(4,1,2) N=11 fits
    master = sample_trace(big, ShiftedExponential(1.0, 0.5), seed=42)
    pool = ElasticPool(
        master, (tuple(range(big)),) * 3 + (tuple(range(small)),)
    )
    rng = np.random.default_rng(7)
    K = len(pool)
    a = FIELD.random(rng, (K, m, m))
    b = FIELD.random(rng, (K, m, m))
    planner = AutoPlanner(CANDS, window=6)
    run = run_adaptive_over_pool(planner, a, b, pool, seed=2)
    last = run.decisions[-1]
    assert last.pool_size == small
    assert last.config.resolved().label() == PlanConfig("age", 4, 1, 2).resolved().label()
    if run.decisions[-2].config.n_workers > small:
        assert last.reason == "forced" and last.switched
    for k in range(K):
        assert np.array_equal(run.y[k, 0], FIELD.matmul(a[k].T, b[k]))


def test_observations_are_pool_keyed():
    """Medians measured on one pool size must not steer another: after
    observing at pool 20, deciding at pool 30 re-explores."""
    m = 8
    trace = sample_trace(20, ShiftedExponential(1.0, 0.5), seed=77)
    rng = np.random.default_rng(8)
    a = FIELD.random(rng, (2, m, m))
    b = FIELD.random(rng, (2, m, m))
    planner = AutoPlanner([CANDS[0]])
    run_adaptive_over_pool(planner, a, b, [trace, trace], seed=1)
    assert planner.decisions[-1].reason == "observed"
    d = planner.decide(30)
    assert d.reason == "explore"  # no observations at this pool size yet


def test_work_factor_scaling_and_normalized_observe():
    planner = AutoPlanner(CANDS, cost_m=32)
    assert planner.work_factor(CANDS[0]) == 1.0
    wf = planner.work_factor(CANDS[1])
    assert wf > 1.0  # age(4,1,2) does more per-worker work
    # un-costed planner treats everything as unit work
    assert AutoPlanner(CANDS).work_factor(CANDS[1]) == 1.0


def test_pipeline_planner_mode():
    m = 8
    K = 4
    traces = [
        sample_trace(20, ShiftedExponential(1.0, 0.5), seed=300 + k)
        for k in range(K)
    ]
    rng = np.random.default_rng(9)
    a = FIELD.random(rng, (K, 2, m, m))
    b = FIELD.random(rng, (K, 2, m, m))
    planner = AutoPlanner(CANDS, window=4)
    res = run_pipeline_over_pool(None, a, b, traces, seed=3, planner=planner)
    for k in range(K):
        for i in range(2):
            assert np.array_equal(
                res.y[k, i], FIELD.matmul(a[k, i].T, b[k, i])
            )
    assert len(planner.decisions) == K
    # pipeline serialization: replays start in order
    assert np.all(np.diff(res.metrics.starts) >= 0)


def test_pipeline_requires_plan_or_planner():
    m = 8
    trace = sample_trace(20, Deterministic(1.0), seed=0)
    rng = np.random.default_rng(10)
    a = FIELD.random(rng, (1, m, m))
    b = FIELD.random(rng, (1, m, m))
    with pytest.raises(ValueError):
        run_pipeline_over_pool(None, a, b, [trace])


def test_pipeline_planner_rejects_elastic_sizes():
    m = 8
    t1 = sample_trace(20, Deterministic(1.0), seed=0)
    t2 = sample_trace(18, Deterministic(1.0), seed=0)
    rng = np.random.default_rng(11)
    a = FIELD.random(rng, (2, m, m))
    b = FIELD.random(rng, (2, m, m))
    with pytest.raises(ValueError):
        run_pipeline_over_pool(
            None, a, b, [t1, t2], planner=AutoPlanner(CANDS)
        )


def test_replay_seed_deterministic_and_decorrelated():
    assert _replay_seed(17, 3) == _replay_seed(17, 3)
    assert _replay_seed(17, 3) != _replay_seed(17, 4)
    assert _replay_seed(18, 3) != _replay_seed(17, 3)


# ----------------------------------------------------------------------
# corruption tuning: the planner prices detect vs correct
# ----------------------------------------------------------------------
def _corruption_obs(n_rejected=0, n_corrected=0):
    return ObservedRun(
        n_pool=20, n_workers=17, n_ready_pool=20, thr_arrived=8,
        n_receivers=20, set_time=2.0, response_delta=1.0, completion=3.0,
        n_dropped=0, n_rejected=n_rejected, n_corrected=n_corrected,
    )


def test_planner_corruption_tuning_prices_decode_modes():
    planner = AutoPlanner(CANDS, decode_mode="auto")
    # clean history: no witnesses demanded, no error budget provisioned
    planner._runs.append(_corruption_obs())
    assert planner.verify_extras_for() == 0
    assert planner.error_budget(CANDS[0], 20) == 0
    # corrections observed: one witness, budget follows the fitted rate
    planner._runs.append(_corruption_obs(n_corrected=4))
    est = planner.estimate()
    assert est.corrupt_rate == pytest.approx(4 / 40)
    assert planner.verify_extras_for(est) == 1
    e = planner.error_budget(CANDS[0], 20, est)
    thr = CANDS[0].decode_threshold
    assert 1 <= e <= (20 - thr) // 2
    # decode-wait pricing mirrors the runtime's resolution rules
    for mode, want in (
        ("detect", thr + 1),
        ("correct", thr + 2 * e),
        ("auto", min(thr + 1, thr + 2 * e)),
    ):
        p = AutoPlanner(CANDS, decode_mode=mode)
        p._runs.extend(planner._runs)
        assert p._threshold(CANDS[0], p.estimate(), 20) == want
    assert planner.summary()["decode_mode"] == "auto"
    with pytest.raises(ValueError, match="decode_mode"):
        AutoPlanner(CANDS, decode_mode="majority")


def test_adaptive_correct_mode_end_to_end():
    """The adaptive loop rides the BW decode: corrupt traces, every
    replay oracle-validated, corrections fed back into the estimate."""
    m = 8
    K = 3
    traces = [
        sample_trace(
            20,
            ShiftedExponential(1.0, 0.5),
            faults=FaultSpec(corrupt_frac=0.1),
            seed=300 + k,
        )
        for k in range(K)
    ]
    rng = np.random.default_rng(7)
    a = FIELD.random(rng, (K, m, m))
    b = FIELD.random(rng, (K, m, m))
    planner = AutoPlanner(CANDS, window=4, decode_mode="correct")
    run = run_adaptive_over_pool(
        planner, a, b, traces, seed=9, decode_mode="correct"
    )
    for k in range(K):
        assert np.array_equal(run.y[k, 0], FIELD.matmul(a[k].T, b[k]))
    assert planner.summary()["decode_mode"] == "correct"
    n_corrupt = sum(int(t.corrupt.sum()) for t in traces)
    corrected = sum(r.n_corrected for r in planner._runs)
    assert corrected >= 0 and (n_corrupt == 0 or corrected <= n_corrupt * K)
