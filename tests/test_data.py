"""Data pipeline: determinism, shard disjointness, label alignment."""
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticLM


def test_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=7)
    a = SyntheticLM(cfg).batch(5)
    b = SyntheticLM(cfg).batch(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])


def test_steps_differ():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8)
    p = SyntheticLM(cfg)
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_host_shards_disjoint():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8)
    s0 = SyntheticLM(cfg, process_index=0, process_count=2).batch(3)
    s1 = SyntheticLM(cfg, process_index=1, process_count=2).batch(3)
    assert s0["tokens"].shape == (4, 64)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_labels_shifted():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    assert np.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_markov_structure_learnable():
    """order_bias makes next-token partially predictable: mutual
    information with the permutation map is visible."""
    cfg = DataConfig(vocab_size=50, seq_len=256, global_batch=4, order_bias=0.9)
    p = SyntheticLM(cfg)
    b = p.batch(0)
    hits = 0
    total = 0
    for row in b["tokens"]:
        for i in range(len(row) - 1):
            total += 1
            if row[i + 1] == p._perm[row[i]]:
                hits += 1
    assert hits / total > 0.5


def test_iterate_resume():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    p = SyntheticLM(cfg)
    it = p.iterate(start_step=4)
    assert np.array_equal(next(it)["tokens"], p.batch(4)["tokens"])
