"""CLI driver integration tests: the production train/serve entrypoints
run end-to-end at reduced scale in subprocesses."""
import subprocess
import sys

from _subproc import subprocess_env


def _run(args, timeout=560):
    res = subprocess.run(
        [sys.executable, "-m"] + args,
        capture_output=True, text=True, timeout=timeout,
        env=subprocess_env(),
        cwd=".",
    )
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


def test_train_driver_with_resume(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out = _run([
        "repro.launch.train", "--arch", "minicpm-2b", "--reduced",
        "--steps", "6", "--seq-len", "32", "--global-batch", "2",
        "--mesh", "1x1", "--ckpt-dir", ckpt, "--ckpt-every", "3",
        "--microbatch-seqs", "2",
    ])
    assert "loss" in out and "done" in out
    # second invocation resumes from the checkpoint
    out2 = _run([
        "repro.launch.train", "--arch", "minicpm-2b", "--reduced",
        "--steps", "8", "--seq-len", "32", "--global-batch", "2",
        "--mesh", "1x1", "--ckpt-dir", ckpt, "--ckpt-every", "3",
    ])
    assert "auto-resumed from step 6" in out2


def test_serve_driver():
    out = _run([
        "repro.launch.serve", "--arch", "yi-34b", "--reduced",
        "--mesh", "1x1", "--batch", "2", "--prompt-len", "8",
        "--gen-len", "4",
    ])
    assert "decode" in out and "ms/step" in out


def test_dryrun_cli_single_cell(tmp_path):
    """The dry-run harness end-to-end on the smallest arch/shape cell
    (skipped cell — exercises the CLI + skip bookkeeping quickly)."""
    out = _run([
        "repro.launch.dryrun", "--arch", "minicpm-2b", "--shape",
        "long_500k", "--mesh", "single", "--out", str(tmp_path),
    ])
    assert "skipped" in out
