"""Pallas modmatmul kernel vs the numpy oracle (interpret mode executes
the kernel body on CPU), swept over shapes, primes and block sizes."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.gf import Field
from repro.kernels.modmatmul import mod_matmul, modmatmul_jnp_ref, modmatmul_ref
from repro.kernels.modmatmul.ops import polyeval

SHAPES = [(1, 1, 1), (4, 7, 5), (128, 256, 128), (130, 300, 70), (200, 513, 33),
          (256, 256, 256), (17, 1024, 9)]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_pallas_vs_oracle(m, k, n):
    rng = np.random.default_rng(m * 1000 + k + n)
    p = 65521
    a = rng.integers(0, p, (m, k)).astype(np.int32)
    b = rng.integers(0, p, (k, n)).astype(np.int32)
    want = modmatmul_ref(a, b, p)
    got = np.asarray(mod_matmul(a, b, p=p, backend="pallas", interpret=True))
    assert np.array_equal(want, got)


@pytest.mark.parametrize("p", [251, 4093, 7919, 40961, 65519, 65521])
def test_pallas_primes(p):
    rng = np.random.default_rng(p)
    a = rng.integers(0, p, (64, 300)).astype(np.int32)
    b = rng.integers(0, p, (300, 32)).astype(np.int32)
    want = modmatmul_ref(a, b, p)
    got = np.asarray(mod_matmul(a, b, p=p, backend="pallas", interpret=True))
    assert np.array_equal(want, got)


@pytest.mark.parametrize("blocks", [(128, 128, 256), (128, 128, 128), (256, 128, 64)])
def test_pallas_block_shapes(blocks):
    bm, bn, bk = blocks
    rng = np.random.default_rng(bm + bn + bk)
    p = 65521
    a = rng.integers(0, p, (100, 200)).astype(np.int32)
    b = rng.integers(0, p, (200, 50)).astype(np.int32)
    got = np.asarray(
        mod_matmul(a, b, p=p, backend="pallas", interpret=True, bm=bm, bn=bn, bk=bk)
    )
    assert np.array_equal(modmatmul_ref(a, b, p), got)


def test_batched():
    rng = np.random.default_rng(5)
    p = 65521
    a = rng.integers(0, p, (3, 32, 64)).astype(np.int32)
    b = rng.integers(0, p, (3, 64, 16)).astype(np.int32)
    want = np.stack([modmatmul_ref(a[i], b[i], p) for i in range(3)])
    got = np.asarray(mod_matmul(a, b, p=p, backend="pallas", interpret=True))
    assert np.array_equal(want, got)
    got_f = np.asarray(mod_matmul(a, b, p=p, backend="f32limb"))
    assert np.array_equal(want, got_f)


def test_jnp_ref_matches_oracle():
    rng = np.random.default_rng(6)
    p = 65521
    a = rng.integers(0, p, (37, 290)).astype(np.int32)
    b = rng.integers(0, p, (290, 21)).astype(np.int32)
    assert np.array_equal(modmatmul_ref(a, b, p), np.asarray(modmatmul_jnp_ref(a, b, p)))


def test_polyeval():
    rng = np.random.default_rng(7)
    f = Field()
    coeffs = f.random(rng, (5, 4, 3))
    alphas = rng.choice(f.p - 1, size=6, replace=False) + 1
    powers = [0, 2, 3, 7, 11]
    v = f.vandermonde(alphas, powers)
    got = np.asarray(polyeval(v.astype(np.int32), coeffs.astype(np.int32), p=f.p))
    want = np.zeros((6, 4, 3), np.int64)
    for n in range(6):
        for j, u in enumerate(powers):
            want[n] = (want[n] + coeffs[j] * f.pow(alphas[n], u)) % f.p
    assert np.array_equal(want, got)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64), k=st.integers(1, 300), n=st.integers(1, 48),
    seed=st.integers(0, 10_000),
)
def test_pallas_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    p = 65521
    a = rng.integers(0, p, (m, k)).astype(np.int32)
    b = rng.integers(0, p, (k, n)).astype(np.int32)
    got = np.asarray(mod_matmul(a, b, p=p, backend="pallas", interpret=True))
    assert np.array_equal(modmatmul_ref(a, b, p), got)
