"""Training substrate: loss actually falls on structured synthetic data,
schedules, gradient compression with error feedback, checkpoint resume
equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.train import grad_compress as gc
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    get_schedule,
    wsd_schedule,
)


def _tiny_model():
    rc = dataclasses.replace(
        reduced(get_config("minicpm-2b")), num_layers=2, vocab_size=64, d_model=32,
        num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64,
    )
    return rc, build_model(rc)


def test_loss_decreases():
    rc, model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=cosine_schedule(3e-3, 5, 200), weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    data = SyntheticLM(DataConfig(vocab_size=rc.vocab_size, seq_len=32, global_batch=8))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        p2, o2, _ = adamw_update(grads, opt, params, opt_cfg)
        return p2, o2, loss

    losses = []
    for i in range(60):
        params, opt, loss = step(params, opt, data.batch(i))
        losses.append(float(loss))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.25, (first, last)


def test_wsd_schedule_shape():
    lr = wsd_schedule(1.0, warmup=10, stable=80, decay=10, floor=0.01)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(lr(jnp.int32(50))) - 1.0) < 1e-6  # stable plateau
    assert float(lr(jnp.int32(95))) < 0.5  # decaying
    assert abs(float(lr(jnp.int32(100))) - 0.01) < 1e-3


def test_cosine_schedule_shape():
    lr = get_schedule("cosine", 1.0, total=100, warmup=10)
    assert float(lr(jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    cfg = AdamWConfig(lr=lambda s: 1e-2, clip_norm=1.0, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(grads, opt, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_decay_mask():
    from repro.train.optimizer import _decay_mask

    params = {"layers": {"ln_attn": jnp.ones(3), "attn": {"wq": jnp.ones((3, 3))}}}
    mask = _decay_mask(params, ("norm", "ln_"))
    assert mask["layers"]["ln_attn"] is False
    assert mask["layers"]["attn"]["wq"] is True


# ----------------------------------------------------------------------
# gradient compression
# ----------------------------------------------------------------------
def test_compress_roundtrip_bounded():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))}
    comp = gc.compress(g)
    back = gc.decompress(comp, g)
    err = np.abs(np.asarray(back["a"] - g["a"]))
    scale = np.abs(np.asarray(g["a"])).max() / 127
    assert err.max() <= scale * 1.01


def test_error_feedback_telescopes():
    """Sum of transported gradients converges to the true sum (the
    residual stays bounded instead of accumulating bias)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(512, np.float32)
    sent_sum = np.zeros(512, np.float32)
    err = gc.init_error({"g": jnp.zeros(512)})
    for i in range(30):
        g = {"g": jnp.asarray(rng.normal(size=512).astype(np.float32))}
        comp, err = gc.compress_with_feedback(g, err)
        sent = gc.decompress(comp, g)
        true_sum += np.asarray(g["g"])
        sent_sum += np.asarray(sent["g"])
    resid = np.abs(np.asarray(err["g"]))
    assert np.abs(true_sum - sent_sum).max() == pytest.approx(resid.max(), rel=1e-5)
    assert resid.max() < 0.2  # residual bounded, not growing


def test_microbatched_train_step_matches_plain():
    """Grad accumulation is exact: n_micro microbatches == full batch."""
    rc, model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=rc.vocab_size, seq_len=16, global_batch=8))
    batch = data.batch(0)

    (loss_full, _), g_full = jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    micro = jax.tree.map(lambda x: x.reshape((4, 2) + x.shape[1:]), batch)
    g_acc = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    losses = []
    for i in range(4):
        mb = jax.tree.map(lambda x: x[i], micro)
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
        losses.append(float(l))
    g_acc = jax.tree.map(lambda g: g / 4, g_acc)
    flat_f = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_full)])
    flat_a = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_acc)])
    assert float(jnp.abs(flat_f - flat_a).max()) < 2e-3
    assert np.mean(losses) == pytest.approx(float(loss_full), abs=1e-2)
