"""Pipeline parallelism: exact equivalence with sequential execution."""
import subprocess
import sys
import textwrap

from _subproc import subprocess_env


def test_pipeline_matches_sequential():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.distributed.pipeline import pipeline_forward

    S, M, B, D = 4, 6, 2, 8
    mesh = make_mesh((S,), ("stage",))
    rng = jax.random.PRNGKey(0)
    w = jax.random.normal(rng, (S, D, D)) * 0.3

    def stage_fn(params, x):
        return jnp.tanh(x @ params["w"])

    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
    got = pipeline_forward(stage_fn, {"w": w}, x, mesh, axis="stage")

    want = x
    for s in range(S):
        want = jnp.tanh(want @ w[s])
    err = float(jnp.abs(got - want).max())
    assert err < 1e-5, err
    print("OK", err)
    """
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(XLA_FLAGS="--xla_force_host_platform_device_count=4"),
        cwd=".",
    )
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
