"""Batched device-resident protocol engine + kernel broadcasting
regressions: run_batched vs per-sample run, plan-cache behavior, and the
mod_matmul one-sided-batch bugs (2D @ batched, batched @ 2D) on both
backends."""
import numpy as np
import pytest

from repro.core import constructions as C
from repro.core import planner
from repro.core import protocol as proto
from repro.core.gf import CHUNK_K, Field, mod_matmul_f32
from repro.core.layers import secure_matmul_batched
from repro.core.planner import BlockShapes, get_plan, make_plan
from repro.kernels.modmatmul import mod_matmul, modmatmul_ref

P = 65521

BACKENDS = [
    ("f32limb", {}),
    ("pallas", {"interpret": True}),
]


# ----------------------------------------------------------------------
# mod_matmul one-sided batch broadcasting (regression: vmap axis error)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend,kw", BACKENDS)
def test_2d_lhs_batched_rhs(backend, kw):
    rng = np.random.default_rng(0)
    a = rng.integers(0, P, (9, 33)).astype(np.int32)
    b = rng.integers(0, P, (4, 33, 11)).astype(np.int32)
    want = np.stack([modmatmul_ref(a, b[i], P) for i in range(4)])
    got = np.asarray(mod_matmul(a, b, p=P, backend=backend, **kw))
    assert np.array_equal(want, got)


@pytest.mark.parametrize("backend,kw", BACKENDS)
def test_batched_lhs_2d_rhs(backend, kw):
    rng = np.random.default_rng(1)
    a = rng.integers(0, P, (4, 9, 33)).astype(np.int32)
    b = rng.integers(0, P, (33, 11)).astype(np.int32)
    want = np.stack([modmatmul_ref(a[i], b, P) for i in range(4)])
    got = np.asarray(mod_matmul(a, b, p=P, backend=backend, **kw))
    assert np.array_equal(want, got)


@pytest.mark.parametrize("backend,kw", BACKENDS)
def test_broadcastable_batch_dims(backend, kw):
    rng = np.random.default_rng(2)
    a = rng.integers(0, P, (1, 5, 17)).astype(np.int32)
    b = rng.integers(0, P, (3, 17, 7)).astype(np.int32)
    want = np.stack([modmatmul_ref(a[0], b[i], P) for i in range(3)])
    got = np.asarray(mod_matmul(a, b, p=P, backend=backend, **kw))
    assert np.array_equal(want, got)


def test_limb_fast_path_boundary():
    """k <= CHUNK_K takes the no-padding path; both sides of the
    boundary must agree with the oracle."""
    rng = np.random.default_rng(3)
    for k in (1, 31, CHUNK_K, CHUNK_K + 1, 2 * CHUNK_K + 5):
        a = rng.integers(0, P, (7, k)).astype(np.int32)
        b = rng.integers(0, P, (k, 5)).astype(np.int32)
        got = np.asarray(mod_matmul_f32(a, b, P))
        assert np.array_equal(modmatmul_ref(a, b, P), got), k


@pytest.mark.parametrize("backend,kw", BACKENDS)
def test_limb_cross_term_exactness(backend, kw):
    """Regression: values with dense high limbs (>= P-241, hi limb 255)
    drive the raw cross-term sum a_hi@b_lo + a_lo@b_hi past 2**24 at
    full 256-deep accumulation; the two cross dots must be reduced
    separately or the result silently loses the low bit."""
    rng = np.random.default_rng(99)
    for trial in range(8):
        a = rng.integers(P - 241, P, (8, CHUNK_K)).astype(np.int32)
        b = rng.integers(P - 241, P, (CHUNK_K, 8)).astype(np.int32)
        got = np.asarray(mod_matmul(a, b, p=P, backend=backend, **kw))
        assert np.array_equal(modmatmul_ref(a, b, P), got), (backend, trial)


# ----------------------------------------------------------------------
# batched protocol engine
# ----------------------------------------------------------------------
CASES = [("age", 2, 2, 2), ("polydot", 2, 3, 3), ("age", 2, 1, 3)]


@pytest.mark.parametrize("method,s,t,z", CASES)
def test_run_batched_equals_per_sample(method, s, t, z):
    field = Field()
    rng = np.random.default_rng(10)
    sch = C.build_scheme(method, s, t, z)
    shapes = BlockShapes(k=s * 4, ma=t * 4, mb=t * 2, s=s, t=t)
    plan = make_plan(sch, shapes, seed=1)
    batch = 5
    a = field.random(rng, (batch, shapes.k, shapes.ma))
    b = field.random(rng, (batch, shapes.k, shapes.mb))
    y, trace = proto.run_batched(plan, a, b, seed=2)
    for i in range(batch):
        yi, ti = proto.run(plan, a[i], b[i], seed=3 + i)
        assert np.array_equal(y[i], yi)
        assert np.array_equal(y[i], field.matmul(a[i].T, b[i]))
    # trace accounts the whole batch
    _, t1 = proto.run(plan, a[0], b[0], seed=0)
    assert trace.total == batch * t1.total


def test_run_batched_2d_promotion():
    field = Field()
    rng = np.random.default_rng(11)
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes)
    a = field.random(rng, (8, 8))
    b = field.random(rng, (8, 4))
    y, _ = proto.run_batched(plan, a, b)
    assert y.shape == (1, 8, 4)
    assert np.array_equal(y[0], field.matmul(a.T, b))


def test_run_batched_stragglers():
    field = Field()
    rng = np.random.default_rng(12)
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, n_spare=4)
    a = field.random(rng, (3, 8, 8))
    b = field.random(rng, (3, 8, 4))
    ids2 = np.array([i for i in range(plan.n_total) if i not in (0, 2)])
    ids2 = ids2[: plan.n_workers]
    ids3 = np.arange(3, 3 + plan.decode_threshold)
    y, _ = proto.run_batched(plan, a, b, seed=4, phase2_ids=ids2, phase3_ids=ids3)
    for i in range(3):
        assert np.array_equal(y[i], field.matmul(a[i].T, b[i]))


def test_run_batched_shape_validation():
    sch = C.build_scheme("age", 2, 2, 1)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes)
    with pytest.raises(ValueError):
        proto.run_batched(plan, np.zeros((2, 8, 6)), np.zeros((2, 8, 4)))
    with pytest.raises(ValueError):
        proto.run_batched(plan, np.zeros((2, 8, 8)), np.zeros((3, 8, 4)))


def test_device_plan_cached_on_plan():
    sch = C.build_scheme("age", 2, 2, 1)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes)
    assert proto.device_plan(plan) is proto.device_plan(plan)


# ----------------------------------------------------------------------
# plan cache
# ----------------------------------------------------------------------
def test_plan_cache_hits():
    planner.plan_cache_clear()
    sch = C.build_scheme("age", 2, 2, 1)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    p1 = get_plan(sch, shapes)
    info = planner.plan_cache_info()
    assert info["misses"] == 1 and info["hits"] == 0
    p2 = get_plan(sch, shapes)
    assert p2 is p1  # identical signature -> same plan object
    info = planner.plan_cache_info()
    assert info["hits"] == 1 and info["size"] == 1
    # a different shape is a different plan
    p3 = get_plan(sch, BlockShapes(k=8, ma=8, mb=8, s=2, t=2))
    assert p3 is not p1
    assert planner.plan_cache_info()["size"] == 2
    planner.plan_cache_clear()
    assert planner.plan_cache_info() == {
        "hits": 0, "misses": 0, "replans": 0, "size": 0,
    }


def test_secure_matmul_batched_shared_weight():
    rng = np.random.default_rng(13)
    batch = 4
    xs = rng.normal(size=(batch, 16, 12))
    w = rng.normal(size=(16, 8))
    res = secure_matmul_batched(xs, w, s=2, t=2, z=2)
    assert res.y.shape == (batch, 12, 8)
    for i in range(batch):
        assert np.abs(res.y[i] - xs[i].T @ w).max() < 1.0
