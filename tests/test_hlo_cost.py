"""The loop-aware HLO cost walker: exact on loop-free programs, correct
trip-count multiplication for scans, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _xla_cost(c) -> dict:
    # Compiled.cost_analysis() returns a per-device list of dicts on
    # older JAX and a plain dict on newer releases.
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_loop_free_matches_xla():
    def plain(x, w):
        return jnp.tanh(x @ w) @ w

    c = _compile(
        plain,
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 512), jnp.float32),
    )
    got = analyze(c.as_text())
    assert got.flops == pytest.approx(_xla_cost(c)["flops"], rel=1e-6)


def test_scan_multiplied_by_trip_count():
    def scanned(x, w):
        def body(cst, _):
            return jnp.tanh(cst @ w), None

        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    c = _compile(
        scanned,
        jax.ShapeDtypeStruct((512, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 512), jnp.float32),
    )
    got = analyze(c.as_text())
    assert got.flops == pytest.approx(10 * 2 * 512**3, rel=1e-6)
    # XLA itself undercounts (body once) — that's why the walker exists
    assert _xla_cost(c)["flops"] == pytest.approx(2 * 512**3, rel=1e-6)


def test_nested_scan():
    def nested(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    c = _compile(
        nested,
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
    )
    got = analyze(c.as_text())
    assert got.flops == pytest.approx(12 * 2 * 128**3, rel=1e-6)


def test_bytes_positive_and_dominated_by_big_ops():
    def f(x):
        return (x @ x).sum()

    c = _compile(f, jax.ShapeDtypeStruct((512, 512), jnp.float32))
    got = analyze(c.as_text())
    assert got.bytes >= 3 * 512 * 512 * 4  # two reads + one write at least


def test_parser_on_real_model():
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.models import build_model

    rc = dataclasses.replace(reduced(get_config("minicpm-2b")), num_layers=3)
    model = build_model(rc)
    params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
    )
    toks = jax.ShapeDtypeStruct((2, 16), jnp.int32)
    c = jax.jit(lambda p, t: model.loss(p, {"tokens": t, "labels": t})).lower(params, toks).compile()
    got = analyze(c.as_text())
    # 3 layers x (attn + mlp) forward: at least 6*N*D-ish flops present
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert got.flops > 2 * n_params * 2 * 16  # > fwd matmul floor
    assert got.bytes > 0
