"""Distributed semantics, run in subprocesses with 8 forced host
devices (the main test process must keep seeing 1 device)."""
import subprocess
import sys
import textwrap

import pytest

from _subproc import subprocess_env


def _run(code: str, devices: int = 8):
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=subprocess_env(
            XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}"
        ),
        cwd=".",
        timeout=600,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


def test_cmpc_shard_map_all_modes():
    out = _run(
        """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import constructions as C, protocol as proto
        from repro.core.planner import BlockShapes, make_plan
        from repro.core.distributed import run_phase2_sharded
        from repro.core.gf import Field

        f = Field(); rng = np.random.default_rng(7)
        mesh = Mesh(np.array(jax.devices()), ("workers",))
        sch = C.build_scheme("age", 2, 2, 2)
        shapes = BlockShapes(k=8, ma=12, mb=4, s=2, t=2)
        plan = make_plan(sch, shapes, n_spare=3, seed=1)
        A = f.random(rng, (8, 12)); B = f.random(rng, (8, 4))
        want = f.matmul(A.T, B)
        fa = proto.share_a(plan, A, rng); fb = proto.share_b(plan, B, rng)
        noise = f.random(rng, (plan.n_workers, plan.scheme.z, 6, 2))
        for mode in ("all_to_all", "psum", "psum_scatter"):
            i_evals = run_phase2_sharded(plan, fa, fb, noise, mesh, mode=mode)
            y = proto.reconstruct(plan, i_evals)
            assert np.array_equal(y, want), mode
        print("OK")
        """
    )
    assert "OK" in out


def test_batched_sharded_equivalence_all_modes():
    """run_batched_sharded == run_batched == host oracle on a REAL
    multi-device mesh, for every exchange mode and a non-trivial
    Phase-2 sender subset (n_total = 23 over 8 devices also exercises
    the pad-worker path: npad = 24, one receive-only pad worker)."""
    out = _run(
        """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import constructions as C, protocol as proto
        from repro.core.planner import BlockShapes, make_plan
        from repro.core.gf import Field

        f = Field(); rng = np.random.default_rng(7)
        mesh = Mesh(np.array(jax.devices()), ("workers",))
        sch = C.build_scheme("age", 2, 2, 2)
        shapes = BlockShapes(k=8, ma=12, mb=4, s=2, t=2)
        plan = make_plan(sch, shapes, n_spare=3, seed=1)
        batch = 3
        A = f.random(rng, (batch, 8, 12)); B = f.random(rng, (batch, 8, 4))
        want = np.stack([f.matmul(A[i].T, B[i]) for i in range(batch)])
        y_ref, tr_ref = proto.run_batched(plan, A, B, seed=2)
        assert np.array_equal(y_ref, want)
        ids2 = np.array([i for i in range(plan.n_total) if i not in (0, 2)])
        ids2 = ids2[: plan.n_workers]
        ids3 = np.arange(2, 2 + plan.decode_threshold)
        for mode in ("all_to_all", "psum", "psum_scatter"):
            y, tr = proto.run_batched_sharded(plan, A, B, mesh, mode=mode, seed=2)
            assert np.array_equal(y, y_ref), mode
            assert tr.total == tr_ref.total, mode
            ys, _ = proto.run_batched_sharded(
                plan, A, B, mesh, mode=mode, seed=4,
                phase2_ids=ids2, phase3_ids=ids3)
            assert np.array_equal(ys, want), ("subset", mode)
        print("OK")
        """
    )
    assert "OK" in out


def test_batch_over_pool_drives_sharded_phase2():
    """The edge scheduler's fastest-subset selection must drive the
    shard_map exchange end to end on a multi-device mesh, with the
    whole batch riding one collective."""
    out = _run(
        """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import constructions as C
        from repro.core.gf import Field
        from repro.core.planner import BlockShapes, make_plan
        from repro.runtime import Deterministic, run_batch_over_pool, sample_trace

        f = Field(); rng = np.random.default_rng(0)
        sch = C.build_scheme("age", 2, 2, 2)
        shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
        plan = make_plan(sch, shapes, n_spare=3, seed=1)
        batch = 4
        A = f.random(rng, (batch, 8, 8)); B = f.random(rng, (batch, 8, 4))
        want = np.stack([f.matmul(A[i].T, B[i]) for i in range(batch)])
        mesh = Mesh(np.array(jax.devices()), ("workers",))
        # stragglers force a non-prefix Phase-2 subset through the mesh
        trace = sample_trace(plan.n_total, Deterministic(1.0), seed=2).with_faults(
            straggler_ids=[0, 5], straggler_slowdown=100.0)
        for mode in ("all_to_all", "psum_scatter"):
            res = run_batch_over_pool(plan, A, B, trace, seed=3, mesh=mesh, mode=mode)
            assert np.array_equal(res.y, want), mode
            assert not {0, 5} & set(res.metrics.phase2_ids.tolist()), mode
            assert res.metrics.batch == batch
        print("OK")
        """
    )
    assert "OK" in out


def test_data_parallel_grads_match_single_device():
    out = _run(
        """
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.models import build_model
        from repro.launch.mesh import make_mesh
        from repro.distributed.sharding import param_shardings, batch_shardings
        from jax.sharding import NamedSharding, PartitionSpec as P

        rc = dataclasses.replace(reduced(get_config("minicpm-2b")), num_layers=2)
        model = build_model(rc)
        params = model.init(jax.random.PRNGKey(0))
        toks = np.random.default_rng(0).integers(0, rc.vocab_size, (8, 16)).astype(np.int32)
        batch = {"tokens": toks, "labels": toks.copy()}

        gfun = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
        g_single = gfun(params, batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        p_sh = param_shardings(model.abstract_params(), mesh, fsdp=True)
        with mesh:
            params_d = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_sh)
            b_sh = jax.tree.map(lambda x: jax.device_put(x, NamedSharding(mesh, P("data", None))), batch)
            g_dist = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params_d, b_sh)
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(g_single), jax.tree.leaves(g_dist)))
        assert diff < 1e-4, diff
        print("OK", diff)
        """
    )
    assert "OK" in out


def test_train_step_bundle_runs_sharded():
    out = _run(
        """
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced, SHAPES
        from repro.models import build_model
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_train_step, abstract_opt_state
        from repro.train.optimizer import adamw_init, AdamWConfig, cosine_schedule

        rc = dataclasses.replace(reduced(get_config("qwen2-72b")), num_layers=2)
        model = build_model(rc)
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=8)
        mesh = make_mesh((4, 2), ("data", "model"))
        bundle = build_train_step(model, mesh, shape, microbatch_seqs=1)
        with mesh:
            compiled = bundle.lower().compile()
            params = model.init(jax.random.PRNGKey(0))
            opt = adamw_init(params, AdamWConfig(lr=cosine_schedule(1e-3, 2, 10)))
            toks = np.random.default_rng(0).integers(0, rc.vocab_size, (8, 32)).astype(np.int32)
            p2, o2, metrics = compiled(params, opt, {"tokens": toks, "labels": toks.copy()})
        assert np.isfinite(float(metrics["loss"]))
        print("OK", float(metrics["loss"]))
        """
    )
    assert "OK" in out


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint on a (4,2) mesh, restore onto (2,4) — elastic scaling."""
    out = _run(
        f"""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.models import build_model
        from repro.launch.mesh import make_mesh
        from repro.distributed.sharding import param_shardings
        from repro.checkpoint.manager import CheckpointManager

        rc = dataclasses.replace(reduced(get_config("yi-34b")), num_layers=2)
        model = build_model(rc)
        params = model.init(jax.random.PRNGKey(0))
        mgr = CheckpointManager({str(tmp_path)!r})

        mesh_a = make_mesh((4, 2), ("data", "model"))
        sh_a = param_shardings(model.abstract_params(), mesh_a)
        params_a = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh_a)
        mgr.save(1, {{"params": params_a}})

        mesh_b = make_mesh((2, 4), ("data", "model"))
        sh_b = param_shardings(model.abstract_params(), mesh_b)
        _, restored = mgr.restore({{"params": params}}, shardings={{"params": sh_b}})
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])))
        assert diff == 0.0, diff
        print("OK")
        """
    )
    assert "OK" in out


def test_production_mesh_shapes():
    out = _run(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("OK")
        """,
        devices=512,
    )
    assert "OK" in out
