"""Sharding rule unit tests (no multi-device needed: specs only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh():
    # spec-level tests only need mesh axis names/sizes
    import subprocess, sys  # noqa: F401
    from repro.launch.mesh import make_mesh

    # 1 device: (1, 1) mesh with the production axis names
    return make_mesh((1, 1), ("data", "model"))


def test_param_pspecs_basic(mesh):
    from repro.distributed.sharding import param_pspecs

    model = build_model(get_config("qwen2-72b"))
    specs = param_pspecs(model.abstract_params(), mesh)
    assert specs["embed"] == P("model", "data")
    assert specs["final_norm"] == P()  # vectors replicated
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", "data")


def test_param_pspecs_indivisible_replicates():
    from repro.distributed.sharding import param_pspecs
    from repro.launch.mesh import make_mesh
    from repro.models.common import ParamInfo

    mesh = make_mesh((1, 1), ("data", "model"))
    # vocab 122753 is not divisible by 16 -> but mesh is (1,1) so ok;
    # simulate a 3-way axis via a fake info with indivisible dim
    tree = {"w": ParamInfo((7, 64), ("vocab", "embed"))}
    specs = param_pspecs(tree, mesh)
    assert specs["w"] == P("model", "data") or specs["w"] == P(None, "data")


def test_moe_experts_sharded(mesh):
    import dataclasses

    from repro.distributed.sharding import param_pspecs

    cfg = get_config("dbrx-132b")
    # optimized default: expert-TP (FFN hidden over model, experts local)
    specs = param_pspecs(build_model(cfg).abstract_params(), mesh)
    assert specs["layers"]["moe"]["w_gate"] == P(None, None, "data", "model")

    # classic expert-parallel layout still available
    ep = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, expert_tp=False, dispatch_groups=1)
    )
    specs_ep = param_pspecs(build_model(ep).abstract_params(), mesh)
    assert specs_ep["layers"]["moe"]["w_gate"] == P(None, "model", "data", None)


def test_cache_pspecs_decode_vs_long(mesh):
    from repro.distributed.sharding import cache_pspecs

    cfg = get_config("yi-34b")
    model = build_model(cfg)
    cache = model.cache_abstract(4, 64)
    spec = cache_pspecs(cfg, cache, mesh, long_context=False)
    assert spec["layers"]["k"] == P(None, "data", None, "model", None)
    spec_long = cache_pspecs(cfg, cache, mesh, long_context=True)
    assert spec_long["layers"]["k"] == P(None, None, "data", "model", None)
    assert spec_long["layers"]["idx"] == P()


def test_cache_pspecs_mla(mesh):
    from repro.distributed.sharding import cache_pspecs

    cfg = get_config("deepseek-v2-lite-16b")
    model = build_model(cfg)
    cache = model.cache_abstract(4, 64)
    spec = cache_pspecs(cfg, cache, mesh)
    assert spec["layers"]["c"] == P(None, "data", "model", None)


def test_constrain_noop_without_rules():
    from repro.distributed.sharding import constrain

    x = jnp.ones((4, 4))
    assert constrain(x, ("batch", None)) is x


def test_batch_spec_shapes():
    from repro.configs import SHAPES

    model = build_model(get_config("qwen2-72b"))
    spec = model.batch_spec(SHAPES["train_4k"])
    assert spec["tokens"].shape == (256, 4096)
    assert spec["labels"].shape == (256, 4096)
    dec = model.batch_spec(SHAPES["decode_32k"])
    assert dec["tokens"].shape == (128, 1)

    vlm = build_model(get_config("internvl2-26b"))
    spec = vlm.batch_spec(SHAPES["train_4k"])
    assert spec["patches"].shape[1] + spec["tokens"].shape[1] == 4096

    enc = build_model(get_config("seamless-m4t-large-v2"))
    spec = enc.batch_spec(SHAPES["prefill_32k"])
    assert spec["frames"].shape == (32, 32768, 1024)
