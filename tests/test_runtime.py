"""Edge worker-pool runtime: event-driven scheduling, fastest-subset
decode, fault injection, and metrics/trace accounting.

Fast tier-1 coverage: small scheme, deterministic or crafted latency,
fixed seeds — every run validated against the host oracle."""
import numpy as np
import pytest

from repro.core import constructions as C
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan
from repro.runtime import (
    DecodeFailure,
    Deterministic,
    FaultSpec,
    HeavyTail,
    ShiftedExponential,
    run_batch_over_pool,
    run_over_pool,
    sample_trace,
    summarize,
)


@pytest.fixture(scope="module")
def setup():
    field = Field()
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, n_spare=3, seed=1)
    rng = np.random.default_rng(0)
    a = field.random(rng, (8, 8))
    b = field.random(rng, (8, 4))
    return plan, a, b, field.matmul(a.T, b)


def test_all_fast_smoke(setup):
    """Deterministic pool: correct decode, fully known timeline."""
    plan, a, b, want = setup
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=2)
    run = run_over_pool(plan, a, b, trace, seed=3)
    assert np.array_equal(run.y, want)
    m = run.metrics
    # share (0.1) + compute (1.0) + d2d (0.1) + uplink (0.1), all equal
    assert m.completion_time == pytest.approx(1.3)
    assert m.phase2_set_time == pytest.approx(1.1)
    assert m.responder_ids.size == plan.decode_threshold
    assert m.phase2_ids.size == plan.n_workers
    assert m.n_dropped == 0 and m.rejected_ids.size == 0
    # bytes view consistent with the element counts
    assert m.trace.total_bytes == m.trace.total * plan.field.elem_bytes
    # phase 1 provisions every worker, spares included
    sh = plan.shapes
    per_worker = sh.blk_a[0] * sh.blk_a[1] + sh.blk_b[0] * sh.blk_b[1]
    assert m.trace.phase1_source_to_worker == plan.n_total * per_worker


def test_stragglers_excluded_from_phase2(setup):
    """Slowed workers must not gate the Phase-2 barrier."""
    plan, a, b, want = setup
    slow = [0, 5]
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=4).with_faults(
        straggler_ids=slow, straggler_slowdown=100.0
    )
    run = run_over_pool(plan, a, b, trace, seed=5)
    assert np.array_equal(run.y, want)
    assert not set(slow) & set(run.metrics.phase2_ids.tolist())
    # barrier time unaffected by the stragglers
    assert run.metrics.phase2_set_time == pytest.approx(1.1)


def test_dropouts_up_to_spares(setup):
    plan, a, b, want = setup
    drop = list(range(plan.n_spare))
    trace = sample_trace(
        plan.n_total, ShiftedExponential(1.0, 0.3), seed=6
    ).with_faults(dropout_ids=drop)
    run = run_over_pool(plan, a, b, trace, seed=7)
    assert np.array_equal(run.y, want)
    assert run.metrics.n_dropped == plan.n_spare
    used = set(run.metrics.phase2_ids.tolist()) | set(
        run.metrics.responder_ids.tolist()
    )
    assert not set(drop) & used


def test_too_many_dropouts_fail_loudly(setup):
    plan, a, b, _ = setup
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=8).with_faults(
        dropout_ids=list(range(plan.n_spare + 1))
    )
    with pytest.raises(DecodeFailure, match="dropouts"):
        run_over_pool(plan, a, b, trace, seed=9)


def test_crash_after_phase2(setup):
    """Crashers serve the exchange but never respond to the master."""
    plan, a, b, want = setup
    crash = [1, 3]
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=10).with_faults(
        crash_ids=crash
    )
    run = run_over_pool(plan, a, b, trace, seed=11)
    assert np.array_equal(run.y, want)
    assert run.metrics.n_crashed == 2
    assert not set(crash) & set(run.metrics.responder_ids.tolist())


def test_corrupt_response_detected(setup):
    """A corrupted fast responder must be kept out of the accepted
    subset via decode-consistency confirmation."""
    plan, a, b, want = setup
    trace = sample_trace(
        plan.n_total, ShiftedExponential(1.0, 0.2), seed=12
    ).with_faults(corrupt_ids=[2])
    run = run_over_pool(plan, a, b, trace, seed=13)  # verify_extras="auto"
    assert np.array_equal(run.y, want)
    assert 2 not in run.metrics.responder_ids
    assert run.metrics.confirmed_by.size >= 1


def test_many_corrupt_fast_responders(setup):
    """Several corrupted workers among the very fastest responders must
    not starve the subset search (colex front + randomized tail)."""
    plan, a, b, want = setup
    # deterministic latency -> workers respond in id order; corrupt the
    # three earliest so every fastest-prefix subset is poisoned
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=40).with_faults(
        corrupt_ids=[0, 1, 2]
    )
    run = run_over_pool(plan, a, b, trace, seed=41)
    assert np.array_equal(run.y, want)
    assert not {0, 1, 2} & set(run.metrics.responder_ids.tolist())


def test_heavy_tail_model_runs(setup):
    plan, a, b, want = setup
    trace = sample_trace(plan.n_total, HeavyTail(1.0, 0.5, 1.5), seed=14)
    run = run_over_pool(plan, a, b, trace, seed=15)
    assert np.array_equal(run.y, want)


def test_trace_prefix_replay():
    """take(n) replays the same per-worker behaviour across pool sizes
    (the identical-traces contract of the scheme comparison)."""
    full = sample_trace(25, ShiftedExponential(1.0, 1.0),
                        FaultSpec(dropout_frac=0.1), seed=16)
    part = full.take(20)
    assert np.array_equal(part.compute_delay, full.compute_delay[:20])
    assert np.array_equal(part.dropout, full.dropout[:20])
    with pytest.raises(ValueError):
        full.take(26)


def test_trace_mismatch_rejected(setup):
    plan, a, b, _ = setup
    trace = sample_trace(plan.n_total - 1, Deterministic(1.0), seed=17)
    with pytest.raises(ValueError, match="provisions"):
        run_over_pool(plan, a, b, trace, seed=18)


def test_fault_flags_disjoint():
    trace = sample_trace(
        200,
        Deterministic(1.0),
        FaultSpec(dropout_frac=0.3, crash_after_phase2_frac=0.3,
                  corrupt_frac=0.3),
        seed=19,
    )
    assert not (trace.dropout & trace.crash_after_phase2).any()
    assert not (trace.dropout & trace.corrupt).any()
    assert not (trace.crash_after_phase2 & trace.corrupt).any()


def test_sharded_phase2_worker_subset(setup):
    """run_phase2_sharded serves an arbitrary sender subset (the hook
    the runtime needs to drive the real shard_map exchange)."""
    import jax
    from jax.sharding import Mesh

    from repro.core import protocol as proto
    from repro.core.distributed import run_phase2_sharded

    plan, a, b, want = setup
    field = Field()
    rng = np.random.default_rng(30)
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    ids = np.array([i for i in range(plan.n_total) if i not in (0, 2)])
    ids = ids[: plan.n_workers]
    blk = plan.shapes.blk_y
    noise = field.random(rng, (plan.n_workers, plan.scheme.z) + blk)
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    i_evals = run_phase2_sharded(plan, fa, fb, noise, mesh, worker_ids=ids)
    y = proto.reconstruct(
        plan, i_evals, worker_ids=np.arange(2, 2 + plan.decode_threshold)
    )
    assert np.array_equal(y, want)


def test_run_trace_matches_pool_trace_with_spares(setup):
    """Corollary-12 accounting: on a no-fault deterministic trace with
    n_spare > 0, ``protocol.run``'s Trace must equal the scheduler's —
    spares receive Phase-2 I(alpha_n) too (Phase 3 may decode from any
    provisioned worker), so both count n_workers * (n_total - 1)
    receivers, not n_workers * (n_workers - 1)."""
    plan, a, b, _ = setup
    assert plan.n_spare > 0
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=33)
    pool_tr = run_over_pool(plan, a, b, trace, seed=34).metrics.trace
    from repro.core import protocol as proto

    _, run_tr = proto.run(plan, a, b, seed=0)
    assert run_tr.phase1_source_to_worker == pool_tr.phase1_source_to_worker
    assert run_tr.phase2_worker_to_worker == pool_tr.phase2_worker_to_worker
    assert run_tr.phase3_worker_to_master == pool_tr.phase3_worker_to_master
    assert run_tr.total_bytes == pool_tr.total_bytes
    # and the explicit formula, so a regression is loud
    sh = plan.shapes
    blk_y = (sh.ma // plan.scheme.t) * (sh.mb // plan.scheme.t)
    assert (
        run_tr.phase2_worker_to_worker
        == plan.n_workers * (plan.n_total - 1) * blk_y
    )


# ----------------------------------------------------------------------
# batched replay (run_batch_over_pool)
# ----------------------------------------------------------------------
def _batch_operands(plan, batch, seed=0):
    field = Field()
    rng = np.random.default_rng(seed)
    sh = plan.shapes
    a = field.random(rng, (batch, sh.k, sh.ma))
    b = field.random(rng, (batch, sh.k, sh.mb))
    want = np.stack([field.matmul(a[i].T, b[i]) for i in range(batch)])
    return a, b, want


def test_batch_over_pool_matches_oracle_and_timeline(setup):
    """One replay serves the whole batch: every product decodes to the
    oracle, the timeline equals the per-product run's, and the
    aggregate comm trace is batch x the per-product trace."""
    plan, a1, b1, _ = setup
    batch = 4
    a, b, want = _batch_operands(plan, batch, seed=21)
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=22)
    res = run_batch_over_pool(plan, a, b, trace, seed=23)
    assert np.array_equal(res.y, want)
    assert res.metrics.batch == batch
    assert len(res.per_product) == batch
    single = run_over_pool(plan, a1, b1, trace, seed=23)
    assert res.metrics.completion_time == pytest.approx(
        single.metrics.completion_time
    )
    assert np.array_equal(res.metrics.phase2_ids, single.metrics.phase2_ids)
    assert res.metrics.trace.total == batch * res.per_product[0].trace.total
    assert res.per_product[0].trace.total == single.metrics.trace.total


def test_batch_over_pool_faults(setup):
    """Stragglers, dropouts, and a corrupt responder behave identically
    under the batched replay (faults are per-worker, not per-product)."""
    plan, _, _, _ = setup
    a, b, want = _batch_operands(plan, 3, seed=24)
    drop = list(range(plan.n_spare))
    trace = sample_trace(
        plan.n_total, ShiftedExponential(1.0, 0.3), seed=25
    ).with_faults(dropout_ids=drop, corrupt_ids=[plan.n_spare])
    res = run_batch_over_pool(plan, a, b, trace, seed=26)
    assert np.array_equal(res.y, want)
    assert res.metrics.n_dropped == plan.n_spare
    assert plan.n_spare not in res.metrics.responder_ids
    used = set(res.metrics.phase2_ids.tolist()) | set(
        res.metrics.responder_ids.tolist()
    )
    assert not set(drop) & used
    # loud failure past the provisioned tolerance, same as the scalar path
    bad = sample_trace(plan.n_total, Deterministic(1.0), seed=27).with_faults(
        dropout_ids=list(range(plan.n_spare + 1))
    )
    with pytest.raises(DecodeFailure, match="dropouts"):
        run_batch_over_pool(plan, a, b, bad, seed=28)


def test_batch_over_pool_sharded_mesh(setup):
    """mesh= routes the batched replay's Phase 2 through the shard_map
    exchange, driven by the scheduler's fastest subset."""
    import jax
    from jax.sharding import Mesh

    plan, _, _, _ = setup
    a, b, want = _batch_operands(plan, 3, seed=29)
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=30).with_faults(
        straggler_ids=[1], straggler_slowdown=50.0
    )
    for mode in ("all_to_all", "psum", "psum_scatter"):
        res = run_batch_over_pool(plan, a, b, trace, seed=31, mesh=mesh, mode=mode)
        assert np.array_equal(res.y, want), mode
        assert 1 not in res.metrics.phase2_ids


def test_batch_over_pool_2d_promotion(setup):
    plan, a, b, want = setup
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=32)
    res = run_batch_over_pool(plan, a, b, trace, seed=33)
    assert res.y.shape == (1,) + want.shape
    assert np.array_equal(res.y[0], want)
    assert res.metrics.batch == 1


# ----------------------------------------------------------------------
# with_faults id validation
# ----------------------------------------------------------------------
def test_with_faults_empty_lists_noop():
    trace = sample_trace(10, Deterministic(1.0), seed=35)
    same = trace.with_faults()
    assert not same.dropout.any() and not same.corrupt.any()
    assert np.array_equal(same.compute_delay, trace.compute_delay)


@pytest.mark.parametrize(
    "kwargs",
    [
        {"dropout_ids": [-1]},
        {"crash_ids": [10]},
        {"corrupt_ids": [3, 3]},
        {"straggler_ids": [0, -2]},
    ],
)
def test_with_faults_rejects_bad_ids(kwargs):
    """Out-of-range / duplicate ids must fail loudly — numpy fancy
    indexing would silently wrap the negatives onto real workers."""
    trace = sample_trace(10, Deterministic(1.0), seed=36)
    with pytest.raises(ValueError, match="indices|duplicate"):
        trace.with_faults(**kwargs)


def test_summarize(setup):
    plan, a, b, _ = setup
    runs = []
    for seed in range(3):
        trace = sample_trace(plan.n_total, ShiftedExponential(1.0, 0.5),
                             seed=20 + seed)
        runs.append(run_over_pool(plan, a, b, trace, seed=seed).metrics)
    agg = summarize(runs)
    assert agg["runs"] == 3
    assert agg["completion_p50"] <= agg["completion_p95"] <= agg["completion_max"]
    assert 1 <= agg["decode_subsets_distinct"] <= 3
    assert agg["n_provisioned"] == plan.n_total
    assert summarize([]) == {"runs": 0}
