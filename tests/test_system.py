"""End-to-end behaviour of the paper's system: the full CMPC pipeline
as a user would run it, plus the dry-run harness surface."""
import numpy as np
import pytest

from repro.core import closed_form as cf
from repro.core import constructions as C
from repro.core.gf import Field
from repro.core.layers import secure_matmul
from repro.core.planner import BlockShapes, make_plan
from repro.core import protocol as proto


def test_paper_headline_claim():
    """The headline: AGE-CMPC always needs the fewest workers, and the
    full pipeline built on it computes A^T B exactly and privately."""
    s, t, z = 3, 3, 4
    n_age, lam = cf.n_age_exact(s, t, z)
    assert n_age <= min(
        C.polydot_cmpc(s, t, z).n_workers,
        cf.n_entangled(s, t, z),
        cf.n_ssmm(s, t, z),
        cf.n_gcsa_na(s, t, z),
    )

    field = Field()
    rng = np.random.default_rng(0)
    sch = C.age_cmpc(s, t, z)
    assert sch.n_workers == n_age
    shapes = BlockShapes(k=s * 4, ma=t * 4, mb=t * 4, s=s, t=t)
    plan = make_plan(sch, shapes)
    a = field.random(rng, (shapes.k, shapes.ma))
    b = field.random(rng, (shapes.k, shapes.mb))
    y, trace = proto.run(plan, a, b)
    assert np.array_equal(y, field.matmul(a.T, b))
    assert trace.total > 0


def test_workers_scale_with_collusion():
    ns = [C.age_cmpc(2, 2, z).n_workers for z in (1, 2, 4, 8)]
    assert ns == sorted(ns)
    assert ns[-1] > ns[0]


def test_real_valued_pipeline():
    rng = np.random.default_rng(3)
    a = rng.normal(size=(32, 8))
    b = rng.normal(size=(32, 4))
    res = secure_matmul(a, b, method="age", s=2, t=2, z=2)
    rel = np.abs(res.y - a.T @ b).max() / np.abs(a.T @ b).max()
    assert rel < 0.2
    assert res.plan.n_workers == 17  # Example 1 protocol size


def test_dryrun_surface():
    """Harness pieces callable without compiling the big configs."""
    from repro.launch.dryrun import cells, collective_bytes

    cs = list(cells("all", "all", "both"))
    assert len(cs) == 10 * 4 * 2
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[32]{0} all-reduce(%y), to_apply=%add
  %done = f32[32]{0} all-reduce-done(%ar)
"""
    totals, counts = collective_bytes(hlo)
    assert totals["all-gather"] == 16 * 128 * 2
    assert counts["all-reduce"] == 1


def test_shape_skip_matrix():
    """The 40-cell applicability matrix: long_500k only for the two
    sub-quadratic archs, everything else runs everywhere."""
    from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable

    runnable = sum(
        shape_applicable(get_config(a), s) for a in ARCH_NAMES for s in SHAPES.values()
    )
    assert runnable == 10 * 4 - 8  # 8 long_500k skips
