"""Minimal offline stand-in for the ``hypothesis`` property-testing API.

This environment has no network and no ``hypothesis`` wheel, but 7 test
modules are written as property tests.  This shim provides the small
surface they use — ``given``, ``settings``, ``strategies`` (``integers``,
``sampled_from``, ``booleans``, ``floats``, ``data``) and ``assume`` —
and sweeps a *deterministic* example grid instead of random shrinking:

* example 0 pins every strategy to its lower bound / first element,
* example 1 pins every strategy to its upper bound / last element,
* examples 2..max_examples-1 draw from a ``numpy`` generator seeded by
  ``crc32(test_name) + index``, so failures reproduce run-to-run.

On failure the falsifying example is printed and the original exception
re-raised, mirroring hypothesis's report.  Test modules import this via

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

so the real hypothesis is used whenever it is installed.
"""
from __future__ import annotations

import functools
import sys
import zlib

import numpy as np

_SETTINGS_ATTR = "_hc_max_examples"
_DEFAULT_MAX_EXAMPLES = 100


class _Assumption(Exception):
    """Raised by assume(False): skip this example, not a failure."""


def assume(condition) -> bool:
    if not condition:
        raise _Assumption()
    return True


class HealthCheck:
    """Placeholder enum — accepted and ignored."""

    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator recording max_examples; works above or below @given."""

    def deco(func):
        setattr(func, _SETTINGS_ATTR, int(max_examples))
        return func

    return deco


class _Strategy:
    """A deterministic-sweepable value source."""

    def draw(self, rng: np.random.Generator, mode: str):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def draw(self, rng, mode):
        if mode == "lo":
            return self.lo
        if mode == "hi":
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def draw(self, rng, mode):
        if mode == "lo":
            return self.elements[0]
        if mode == "hi":
            return self.elements[-1]
        return self.elements[int(rng.integers(0, len(self.elements)))]


class _Booleans(_Strategy):
    def draw(self, rng, mode):
        if mode == "lo":
            return False
        if mode == "hi":
            return True
        return bool(rng.integers(0, 2))


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def draw(self, rng, mode):
        if mode == "lo":
            return self.lo
        if mode == "hi":
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _DataObject:
    """Interactive draws inside the test body (st.data())."""

    def __init__(self, rng: np.random.Generator, mode: str):
        self._rng = rng
        self._mode = mode

    def draw(self, strategy: _Strategy, label=None):
        return strategy.draw(self._rng, self._mode)


class _Data(_Strategy):
    def draw(self, rng, mode):
        return _DataObject(rng, mode)


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        return _SampledFrom(elements)

    @staticmethod
    def booleans() -> _Strategy:
        return _Booleans()

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def data() -> _Strategy:
        return _Data()


def _stable_seed(name: str, index: int) -> int:
    return (zlib.crc32(name.encode()) + index) & 0x7FFFFFFF


def given(**strats):
    """Sweep the deterministic grid over the keyword strategies."""

    for k, s in strats.items():
        if not isinstance(s, _Strategy):
            raise TypeError(f"@given argument {k!r} is not a strategy: {s!r}")

    def deco(func):
        @functools.wraps(func)
        def wrapper(*args, **fixture_kwargs):
            n = getattr(
                wrapper,
                _SETTINGS_ATTR,
                getattr(func, _SETTINGS_ATTR, _DEFAULT_MAX_EXAMPLES),
            )
            for i in range(n):
                mode = "lo" if i == 0 else ("hi" if i == 1 else "rand")
                rng = np.random.default_rng(_stable_seed(func.__qualname__, i))
                kwargs = {k: s.draw(rng, mode) for k, s in strats.items()}
                try:
                    func(*args, **kwargs, **fixture_kwargs)
                except _Assumption:
                    continue
                except Exception:
                    shown = {
                        k: v for k, v in kwargs.items()
                        if not isinstance(v, _DataObject)
                    }
                    print(
                        f"Falsifying example (#{i}/{n}): "
                        f"{func.__qualname__}({shown!r})",
                        file=sys.stderr,
                    )
                    raise

        # functools.wraps sets __wrapped__, which makes pytest resolve
        # the *original* signature and demand fixtures named after the
        # strategies; the wrapper takes no test parameters.
        del wrapper.__wrapped__
        return wrapper

    return deco


st = strategies
