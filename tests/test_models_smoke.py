"""Per-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import build_model


def _batch(rc, b=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, rc.vocab_size, (b, t)).astype(np.int32)
    batch = {"tokens": toks, "labels": toks.copy()}
    if rc.family == "vlm":
        batch["patches"] = rng.normal(size=(b, 8, rc.d_model)).astype(np.float32)
    if rc.family == "encdec":
        batch = {
            "frames": rng.normal(size=(b, t, rc.d_model)).astype(np.float32),
            "tokens": toks,
            "labels": toks.copy(),
        }
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_loss(arch):
    rc = reduced(get_config(arch))
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(rc)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    assert float(loss) > 0

    logits = jax.jit(model.forward)(params, {k: v for k, v in batch.items() if k != "labels"})
    b = batch["tokens"].shape[0]
    assert logits.shape[0] == b
    assert logits.shape[-1] == rc.padded_vocab
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    """Full grad + AdamW update on the reduced config: params change,
    loss finite, no NaN gradients."""
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule

    rc = reduced(get_config(arch))
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=cosine_schedule(1e-3, 2, 100))
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        new_params, new_opt, m = adamw_update(grads, opt, params, opt_cfg)
        return new_params, new_opt, loss, m["grad_norm"]

    new_params, _, loss, gnorm = step(params, opt, _batch(rc))
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params
    )
    assert any(jax.tree.leaves(changed)), f"{arch}: no parameter moved"


def test_param_counts_sane():
    """Full-config analytic parameter counts are in the advertised
    ballpark (names carry the size)."""
    expect = {
        "minicpm-2b": (2, 4), "yi-34b": (30, 40), "mistral-nemo-12b": (10, 14),
        "qwen2-72b": (65, 80), "dbrx-132b": (120, 140),
        "deepseek-v2-lite-16b": (14, 18), "xlstm-1.3b": (1, 2),
        "zamba2-2.7b": (1.5, 3.5), "internvl2-26b": (17, 26),
        "seamless-m4t-large-v2": (1, 3),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, (arch, n)
