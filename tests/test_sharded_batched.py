"""Sharded batched engine, single-device semantics.

``run_batched_sharded`` must decode bit-identically to ``run_batched``
and the host oracle for every exchange mode, arbitrary Phase-2 sender
subsets, and batched worker-leading operands — here on a 1-device mesh
(the collective degenerates but the shard_map path, padding, subset mix
matrices, and batch folding are all exercised); the multi-device
versions run in subprocesses in ``test_distributed.py``.

Also the int32 safety-bound regression: ``run_phase2_sharded`` used to
assert ``n_total * n_workers < 2**31 // p``, which spuriously rejects
pools past ~180 workers at p = 65521 even though the ``_mod_sum``
accumulation only needs ``npad * p < 2**31`` (padded pool size).
"""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.distributed import run_phase2_sharded
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan

MODES = ("all_to_all", "psum", "psum_scatter")


@pytest.fixture(scope="module")
def setup():
    field = Field()
    rng = np.random.default_rng(0)
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=12, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, n_spare=3, seed=1)
    batch = 3
    a = field.random(rng, (batch, 8, 12))
    b = field.random(rng, (batch, 8, 4))
    want = np.stack([field.matmul(a[i].T, b[i]) for i in range(batch)])
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    return plan, a, b, want, mesh


@pytest.mark.parametrize("mode", MODES)
def test_run_batched_sharded_equals_run_batched(setup, mode):
    plan, a, b, want, mesh = setup
    y_ref, tr_ref = proto.run_batched(plan, a, b, seed=2)
    y, tr = proto.run_batched_sharded(plan, a, b, mesh, mode=mode, seed=2)
    assert np.array_equal(y, y_ref)
    assert np.array_equal(y, want)
    # identical Corollary-12 accounting for identical batch sizes
    assert tr.total == tr_ref.total
    assert tr.phase2_worker_to_worker == tr_ref.phase2_worker_to_worker


@pytest.mark.parametrize("mode", MODES)
def test_run_batched_sharded_worker_subset(setup, mode):
    """A non-trivial Phase-2 sender subset plus a shifted Phase-3
    responder subset must still decode exactly (the scheduler's
    straggler path through the shard_map exchange)."""
    plan, a, b, want, mesh = setup
    ids2 = np.array([i for i in range(plan.n_total) if i not in (0, 2)])
    ids2 = ids2[: plan.n_workers]
    ids3 = np.arange(2, 2 + plan.decode_threshold)
    y, _ = proto.run_batched_sharded(
        plan, a, b, mesh, mode=mode, seed=4, phase2_ids=ids2, phase3_ids=ids3
    )
    assert np.array_equal(y, want)


def test_phase2_sharded_batched_matches_unbatched(setup):
    """The batch fold must reproduce per-product unbatched exchanges
    when fed identical shares and noise."""
    plan, a, b, want, mesh = setup
    field = Field()
    rng = np.random.default_rng(9)
    batch = a.shape[0]
    blk = plan.shapes.blk_y
    fa = np.stack([np.asarray(proto.share_a(plan, a[i], rng)) for i in range(batch)])
    fb = np.stack([np.asarray(proto.share_b(plan, b[i], rng)) for i in range(batch)])
    noise = field.random(rng, (batch, plan.n_workers, plan.scheme.z) + blk)
    i_batched = run_phase2_sharded(plan, fa, fb, noise, mesh)
    assert i_batched.shape == (batch, plan.n_total) + blk
    for i in range(batch):
        i_one = run_phase2_sharded(plan, fa[i], fb[i], noise[i], mesh)
        assert np.array_equal(i_batched[i], i_one), i
        assert np.array_equal(proto.reconstruct(plan, i_one), want[i])


def test_large_pool_passes_int32_bound():
    """Regression: a ~230-worker PolyDot pool is int32-safe (npad * p ~
    1.5e7 << 2**31) but the old ``n_total * n_workers < 2**31 // p``
    formula rejected it (230 * 228 = 52440 > 32775)."""
    field = Field()
    rng = np.random.default_rng(3)
    sch = C.build_scheme("polydot", 5, 5, 3)
    assert sch.n_workers >= 180  # the regime the old assert blocked
    shapes = BlockShapes(k=5, ma=5, mb=5, s=5, t=5)
    plan = make_plan(sch, shapes, n_spare=2, seed=0)
    # the old formula must reject this pool, the real bound must not
    assert plan.n_total * plan.n_workers >= (1 << 31) // field.p
    assert plan.n_total * field.p < (1 << 31)

    a = field.random(rng, (5, 5))
    b = field.random(rng, (5, 5))
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    noise = field.random(
        rng, (plan.n_workers, plan.scheme.z) + plan.shapes.blk_y
    )
    mesh = Mesh(np.array(jax.devices()), ("workers",))
    i_evals = run_phase2_sharded(plan, fa, fb, noise, mesh)
    assert np.array_equal(proto.reconstruct(plan, i_evals), field.matmul(a.T, b))
