"""Repo tooling: the JAX-shim lint (`tools/check_api_shims.py`) and the
benchmark drift diff (`tools/bench_diff.py`)."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "tools"))

import bench_diff  # noqa: E402
import check_api_shims  # noqa: E402


# ----------------------------------------------------------------------
# shim lint
# ----------------------------------------------------------------------
def test_repo_is_shim_clean():
    assert check_api_shims.violations(ROOT) == []


def _write(root, rel, text):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        fh.write(text)


def test_lint_flags_attribute_import_and_getattr(tmp_path):
    root = str(tmp_path)
    _write(root, "src/bad_attr.py", "import jax\njax.shard_map(f)\n")
    _write(root, "src/bad_from.py", "from jax import shard_map\n")
    _write(root, "src/bad_getattr.py", 'x = getattr(pl, "CompilerParams")\n')
    _write(root, "src/fine.py", "# shard_map only in this comment\nx = 1\n")
    found = check_api_shims.violations(root)
    flagged = {v[0] for v in found}
    assert flagged == {
        os.path.join("src", "bad_attr.py"),
        os.path.join("src", "bad_from.py"),
        os.path.join("src", "bad_getattr.py"),
    }


def test_lint_skips_the_sanctioned_shims(tmp_path):
    root = str(tmp_path)
    shim = os.path.join("src", "repro", "compat.py")
    assert shim in check_api_shims.ALLOWED
    _write(root, shim, "from jax import shard_map\n")
    _write(root, "src/elsewhere.py", "from jax import shard_map\n")
    flagged = {v[0] for v in check_api_shims.violations(root)}
    assert flagged == {os.path.join("src", "elsewhere.py")}


def test_lint_reports_unparsable_files(tmp_path):
    root = str(tmp_path)
    _write(root, "src/broken.py", "def broken(:\n")
    found = check_api_shims.violations(root)
    assert len(found) == 1 and "syntax" in found[0][2]


def test_lint_cli_on_repo():
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_api_shims.py"),
         ROOT],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stderr


# ----------------------------------------------------------------------
# bench diff
# ----------------------------------------------------------------------
def test_flatten_paths():
    flat = bench_diff.flatten({"a": {"b": [1.0, {"c_us": 2.0}]}, "d": "x"})
    assert flat == {"a.b[0]": 1.0, "a.b[1].c_us": 2.0, "d": "x"}


def test_leaf_classification():
    assert bench_diff.is_wallclock("kernel.total_us")
    assert bench_diff.is_wallclock("batched.us_per_product[3]")
    # the marker may sit on a parent key: phases_us.* are timings
    assert bench_diff.is_wallclock("phases_us.reduce")
    assert bench_diff.is_ratio("pipelined.age.speedup")
    assert not bench_diff.is_wallclock("scheme.n_workers")
    assert not bench_diff.is_ratio("scheme.n_workers")


def _git_repo_with_baseline(tmp_path, baseline):
    root = str(tmp_path)
    env = dict(os.environ, GIT_AUTHOR_NAME="t", GIT_AUTHOR_EMAIL="t@t",
               GIT_COMMITTER_NAME="t", GIT_COMMITTER_EMAIL="t@t")
    for cmd in (["git", "init", "-q"],
                ["git", "add", "-A"],
                ["git", "commit", "-q", "-m", "baseline"]):
        if cmd[1] == "add":
            _write(root, "BENCH.json", json.dumps(baseline))
        subprocess.run(cmd, cwd=root, env=env, check=True,
                       capture_output=True)
    return root


BASELINE = {
    "deterministic": {"n_workers": 17, "speedup": 2.8},
    "timing": {"total_us": 100.0, "decode_us": 40.0, "share_us": 10.0},
}


def test_bench_diff_passes_uniform_machine_speed_shift(tmp_path):
    root = _git_repo_with_baseline(tmp_path, BASELINE)
    fresh = json.loads(json.dumps(BASELINE))
    for k in fresh["timing"]:
        fresh["timing"][k] *= 2.0  # a uniformly slower machine
    _write(root, "BENCH.json", json.dumps(fresh))
    assert bench_diff.diff_file(root, "BENCH.json", "HEAD", band=2.5) == []


def test_bench_diff_catches_deterministic_change(tmp_path):
    root = _git_repo_with_baseline(tmp_path, BASELINE)
    fresh = json.loads(json.dumps(BASELINE))
    fresh["deterministic"]["n_workers"] = 18
    _write(root, "BENCH.json", json.dumps(fresh))
    problems = bench_diff.diff_file(root, "BENCH.json", "HEAD", band=2.5)
    assert any("n_workers" in p for p in problems)


def test_bench_diff_catches_wallclock_outlier(tmp_path):
    root = _git_repo_with_baseline(tmp_path, BASELINE)
    fresh = json.loads(json.dumps(BASELINE))
    fresh["timing"]["decode_us"] *= 50.0  # one leaf regresses alone
    _write(root, "BENCH.json", json.dumps(fresh))
    problems = bench_diff.diff_file(root, "BENCH.json", "HEAD", band=2.5)
    assert any("decode_us" in p for p in problems)


def test_bench_diff_catches_ratio_drift(tmp_path):
    root = _git_repo_with_baseline(tmp_path, BASELINE)
    fresh = json.loads(json.dumps(BASELINE))
    fresh["deterministic"]["speedup"] = 0.5  # 5.6x off, outside the band
    _write(root, "BENCH.json", json.dumps(fresh))
    problems = bench_diff.diff_file(root, "BENCH.json", "HEAD", band=2.5)
    assert any("speedup" in p for p in problems)


def test_bench_diff_catches_shape_change(tmp_path):
    root = _git_repo_with_baseline(tmp_path, BASELINE)
    fresh = json.loads(json.dumps(BASELINE))
    del fresh["timing"]["share_us"]
    fresh["new_section"] = {"x": 1}
    _write(root, "BENCH.json", json.dumps(fresh))
    problems = bench_diff.diff_file(root, "BENCH.json", "HEAD", band=2.5)
    assert any("share_us" in p for p in problems)
    assert any("new_section" in p for p in problems)


def test_bench_diff_skips_missing_baseline(tmp_path):
    root = _git_repo_with_baseline(tmp_path, BASELINE)
    _write(root, "OTHER.json", json.dumps({"a": 1}))
    assert bench_diff.diff_file(root, "OTHER.json", "HEAD", band=2.5) == []


def test_bench_diff_committed_snapshots_self_consistent():
    """Both committed snapshots must diff clean against themselves via
    the real git plumbing (guards the `git show` path)."""
    for name in bench_diff.DEFAULT_FILES:
        if bench_diff.committed_json(ROOT, name, "HEAD") is None:
            continue  # snapshot not committed yet at this ref
        with open(os.path.join(ROOT, name)) as fh:
            fresh = json.load(fh)
        committed = bench_diff.committed_json(ROOT, name, "HEAD")
        if json.dumps(fresh, sort_keys=True) == json.dumps(
            committed, sort_keys=True
        ):
            assert bench_diff.diff_file(ROOT, name, "HEAD", band=2.5) == []


# ----------------------------------------------------------------------
# trace tooling
# ----------------------------------------------------------------------
def test_bench_diff_cli_skips_trace_sidecars(tmp_path):
    """A *.trace.json sidecar is never diffed — not even when named
    explicitly, and not even when it doesn't exist."""
    root = _git_repo_with_baseline(tmp_path, BASELINE)
    res = subprocess.run(
        [
            sys.executable, os.path.join(ROOT, "tools", "bench_diff.py"),
            "--root", root,
            "--files", "BENCH.json", "BENCH.trace.json",
        ],
        capture_output=True, text=True,
    )
    assert res.returncode == 0, res.stderr
    assert "BENCH.trace.json: trace sidecar, skipped" in res.stdout
    assert "checked 1 files" in res.stdout


def test_trace_check_passes_on_repo():
    """tools/trace_check.py builds a small traced run end to end and
    validates the Perfetto export (the `make trace-check` gate)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    res = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "trace_check.py")],
        capture_output=True, text=True, env=env, cwd=ROOT,
    )
    assert res.returncode == 0, f"{res.stdout}\n{res.stderr}"
    assert "0 problems" in res.stdout


def test_trace_report_summarizes_a_trace(tmp_path):
    """trace_report renders per-phase stats, straggler lanes, and the
    embedded metrics from a written trace file."""
    import numpy as np

    from repro import obs
    from repro.core.constructions import PlanConfig
    from repro.core.planner import BlockShapes, get_plan_for
    from repro.runtime import run_over_pool
    from repro.runtime.pool import sample_trace

    obs.TRACER.clear()
    obs.enable()
    try:
        cfg = PlanConfig("age", 2, 2, 2).resolved()
        plan = get_plan_for(cfg, BlockShapes(k=4, ma=4, mb=4, s=2, t=2))
        rng = np.random.default_rng(0)
        a = rng.integers(0, 7, (4, 4))
        b = rng.integers(0, 7, (4, 4))
        run_over_pool(plan, a, b, sample_trace(plan.n_total, seed=1), seed=0)
        path = str(tmp_path / "trace.json")
        obs.write_chrome(path, obs.TRACER, metrics=obs.snapshot())
    finally:
        obs.disable()
        obs.TRACER.clear()
    res = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "trace_report.py"), path],
        capture_output=True, text=True,
    )
    assert res.returncode == 0, res.stderr
    assert "phase2.compute" in res.stdout
    assert "straggler attribution" in res.stdout
    assert "subset_cache" in res.stdout
    assert "wire bytes" in res.stdout


def test_trace_report_missing_file_fails_loudly(tmp_path):
    res = subprocess.run(
        [
            sys.executable, os.path.join(ROOT, "tools", "trace_report.py"),
            str(tmp_path / "absent.trace.json"),
        ],
        capture_output=True, text=True,
    )
    assert res.returncode == 1
    assert "not found" in res.stderr
