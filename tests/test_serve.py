"""Serving tier: request lifecycle, continuous batching, SLO/admission
semantics, and the async submission API under it.

The engine is a pure function of (requests, traces, seed): every test
below runs on deterministic traces and asserts exact censuses — decode
values against the field oracle, deadline misses by count, shed reasons
by name, replay folding by replay count.  The session/pipeline
regression pins the refactor: ``PipelineSession`` appends must replay
byte-identically to the historical ``run_pipeline_over_pool``.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.constructions import PlanConfig
from repro.core.gf import Field
from repro.core.layers import (
    InlineExecutor,
    PrivateLinear,
    choose_scales,
    secure_matmul,
    secure_matmul_submit,
)
from repro.core.planner import BlockShapes, get_plan_for
from repro.obs import TRACER
from repro.runtime import (
    Deterministic,
    PipelineSession,
    ShiftedExponential,
    run_pipeline_over_pool,
    sample_trace,
)
from repro.serve import DONE, SHED, ServingEngine

FIELD = Field()
CFG = PlanConfig("age", 2, 2, 1)
POOL = CFG.n_workers + 2
K_DIM, OUT, ROWS = 16, 8, 4


def _traces(n, pool=POOL, seed0=100, latency=None, net_scale=0.3):
    latency = latency or ShiftedExponential(shift=0.1, scale=0.5)
    return [
        sample_trace(pool, latency, seed=seed0 + i, net_scale=net_scale)
        for i in range(n)
    ]


def _engine(traces=None, **kw):
    rng = np.random.default_rng(0)
    w = rng.normal(size=(K_DIM, OUT))
    eng = ServingEngine(
        w,
        traces if traces is not None else _traces(16),
        kw.pop("config", CFG),
        field=FIELD,
        seed=0,
        validate=True,
        **kw,
    )
    return eng, w, rng


def _exact_y(x, w):
    """The engine's fixed-point answer, from first principles."""
    s = choose_scales(
        K_DIM, float(np.abs(x).max() + 1e-9), float(np.abs(w).max() + 1e-9),
        FIELD.p,
    )
    yq = FIELD.matmul(FIELD.encode(x.T, s).T, FIELD.encode(w, s))
    return FIELD.decode(yq, s * s)


# ----------------------------------------------------------------------
# request lifecycle and decode exactness
# ----------------------------------------------------------------------
def test_served_requests_decode_exactly():
    """Every served request's y equals the fixed-point oracle computed
    outside the engine — per-request scales survive the batch fold."""
    eng, w, rng = _engine()
    xs = [rng.normal(size=(ROWS, K_DIM)) * mag for mag in (0.1, 1.0, 30.0)]
    reqs = [eng.submit(x, 0.2 * i) for i, x in enumerate(xs)]
    rep = eng.run()
    assert all(r.state == DONE for r in reqs)
    for x, r in zip(xs, reqs):
        assert np.array_equal(r.y, _exact_y(x, w))
        assert r.completion > r.launch >= r.arrival
    s = rep.summary()
    assert s["served"] == 3 and s["shed"] == 0
    assert s["p99_latency"] >= s["p95_latency"] >= s["p50_latency"] > 0


def test_submit_validation():
    eng, w, rng = _engine()
    with pytest.raises(ValueError, match="rows"):
        eng.submit(rng.normal(size=(3, K_DIM)), 0.0)  # t=2 does not divide 3
    eng.submit(rng.normal(size=(ROWS, K_DIM)), 0.0)
    with pytest.raises(ValueError, match="rows"):
        eng.submit(rng.normal(size=(ROWS + 2, K_DIM)), 0.0)  # != first
    with pytest.raises(ValueError, match="k="):
        eng.submit(rng.normal(size=(ROWS, K_DIM + 1)), 0.0)
    with pytest.raises(ValueError, match="mode"):
        ServingEngine(w, _traces(1), CFG, mode="batchy")
    with pytest.raises(ValueError, match="pipe_depth"):
        ServingEngine(w, _traces(1), CFG, pipe_depth=1)


# ----------------------------------------------------------------------
# SLO accounting: exact deadline-miss census on deterministic traces
# ----------------------------------------------------------------------
def test_exact_deadline_census_on_deterministic_trace():
    """Two identical engines: the first learns the (deterministic)
    completion time, the second gets deadlines straddling it — the miss
    census must split exactly there, with no shedding (no estimator
    history on the first launch: admission is optimistic)."""
    det = _traces(4, latency=Deterministic(1.0), net_scale=0.1)
    probe, _, rng = _engine(traces=det)
    x = rng.normal(size=(ROWS, K_DIM))
    c = probe.submit(x, 0.0)
    probe.run()
    completion = c.completion
    assert completion > 0

    eng, _, _ = _engine(traces=det)
    hit = eng.submit(x, 0.0, deadline=completion + 0.5)
    miss = eng.submit(x, 0.0, deadline=completion - 0.5)
    exact = eng.submit(x, 0.0, deadline=completion)  # boundary: met
    rep = eng.run()
    # all three rode the same replay, same deterministic completion
    assert {r.completion for r in (hit, miss, exact)} == {completion}
    assert hit.met_deadline and exact.met_deadline
    assert not miss.met_deadline
    assert rep.summary()["deadline_misses"] == 1
    assert rep.summary()["served"] == 3


def test_admission_sheds_hopeless_deadlines():
    """A burst against a tight SLO: once the estimator has one
    observation, requests whose deadline the prediction rules out are
    shed with reason 'deadline' before any launch is wasted on them."""
    eng, _, rng = _engine(slo=2.0)
    reqs = [eng.submit(rng.normal(size=(ROWS, K_DIM)), 0.05 * i)
            for i in range(12)]
    rep = eng.run()
    shed = [r for r in reqs if r.state == SHED]
    assert shed and all(r.shed_reason == "deadline" for r in shed)
    assert all(r.y is None and math.isnan(r.completion) for r in shed)
    served = [r for r in reqs if r.state == DONE]
    assert served  # the first wave launches before any prediction exists
    assert rep.summary()["shed"] == len(shed)


def test_drained_queue_leaves_no_orphans():
    """After run(), every submitted request is terminal (done or shed)
    and the internal queue is empty — nothing in flight, nothing lost."""
    eng, _, rng = _engine(slo=2.5)
    reqs = [eng.submit(rng.normal(size=(ROWS, K_DIM)), 0.1 * i)
            for i in range(10)]
    rep = eng.run()
    assert eng._queue == []
    assert all(r.state in (DONE, SHED) for r in reqs)
    s = rep.summary()
    assert s["served"] + s["shed"] == s["requests"] == 10


def test_pool_shrink_sheds_remaining_queue():
    """When the trace source shrinks below the construction's worker
    count, nothing the engine launches can complete: the remaining
    queue is shed with reason 'pool', earlier requests stay served."""
    big = sample_trace(POOL, ShiftedExponential(0.1, 0.5), seed=7,
                       net_scale=0.3)
    small = big.take(CFG.n_workers - 2)
    eng, _, rng = _engine(traces=[big, big] + [small] * 20)
    reqs = [eng.submit(rng.normal(size=(ROWS, K_DIM)), 3.0 * i)
            for i in range(8)]
    eng.run()
    served = [r for r in reqs if r.state == DONE]
    shed = [r for r in reqs if r.state == SHED]
    assert served and shed
    assert all(r.shed_reason == "pool" for r in shed)
    # served requests all predate the shrink
    assert max(r.arrival for r in served) < min(r.arrival for r in shed)


def test_degraded_estimates_halve_admission_cap(monkeypatch):
    """When pool-health estimates disagree (degraded), the admission
    cap halves: the same 4-request wave folds into one replay normally
    but two replays under degradation (deferred, not shed)."""
    det = _traces(8, latency=Deterministic(1.0), net_scale=0.1)
    base, _, rng = _engine(traces=det, max_batch=4)
    xs = [rng.normal(size=(ROWS, K_DIM)) for _ in range(4)]
    for x in xs:
        base.submit(x, 0.0)
    assert base.run().summary()["replays"] == 1

    eng, _, _ = _engine(traces=det, max_batch=4)
    monkeypatch.setattr(eng, "_predicted_service", lambda: (0.5, True))
    reqs = [eng.submit(x, 0.0) for x in xs]
    rep = eng.run()
    assert all(r.state == DONE for r in reqs)  # deferred != shed
    assert rep.summary()["replays"] == 2


# ----------------------------------------------------------------------
# continuous vs boundary batching
# ----------------------------------------------------------------------
def test_continuous_beats_boundary_p95_on_identical_stream():
    """Same requests, same traces, same seed: admitting into in-flight
    replays must cut tail latency without losing a single request."""
    rng = np.random.default_rng(3)
    xs = rng.normal(size=(24, ROWS, K_DIM))
    arrivals = np.cumsum(rng.exponential(1.4, 24))
    stats = {}
    for mode in ("continuous", "boundary"):
        eng, _, _ = _engine(traces=_traces(32), mode=mode)
        for x, t in zip(xs, arrivals):
            eng.submit(x, float(t))
        stats[mode] = eng.run().summary()
    assert stats["continuous"]["served"] == stats["boundary"]["served"] == 24
    assert (
        stats["continuous"]["p95_latency"]
        < stats["boundary"]["p95_latency"]
    )
    assert (
        stats["continuous"]["throughput"]
        >= 0.99 * stats["boundary"]["throughput"]
    )


def test_ready_at_boundary_vs_continuous():
    """ready_at(1) waits for the pipeline to drain; ready_at(2) only
    needs the master uplink free — strictly earlier while a replay is
    still in its Phase-2/3 window."""
    plan = get_plan_for(
        PlanConfig("age", 2, 2, 1, n_spare=2),
        BlockShapes(k=8, ma=4, mb=4, s=2, t=2),
        field=FIELD,
    )
    sess = PipelineSession(plan, seed=0, base_time=1.5)
    assert sess.ready_at(1) == sess.ready_at(2) == 1.5
    rng = np.random.default_rng(0)
    a = FIELD.random(rng, (1, 8, 4))
    b = FIELD.random(rng, (1, 8, 4))
    trace = _traces(1, pool=plan.n_total)[0]
    rep = sess.append(a, b, trace, not_before=2.0)
    assert rep.start >= 2.0
    assert sess.ready_at(1) == rep.completion
    assert sess.ready_at(2) < rep.completion  # uplink frees mid-flight
    with pytest.raises(ValueError, match="pipe_depth"):
        sess.ready_at(0)


def test_session_matches_run_pipeline_over_pool():
    """Refactor regression: K appends on a fresh session replay
    byte-identically to the one-shot pipeline entry point."""
    plan = get_plan_for(
        PlanConfig("age", 2, 2, 1, n_spare=2),
        BlockShapes(k=8, ma=4, mb=4, s=2, t=2),
        field=FIELD,
    )
    K, batch = 3, 2
    rng = np.random.default_rng(5)
    a = FIELD.random(rng, (K, batch, 8, 4))
    b = FIELD.random(rng, (K, batch, 8, 4))
    traces = _traces(K, pool=plan.n_total, seed0=50)
    ref = run_pipeline_over_pool(plan, a, b, traces, seed=9)
    sess = PipelineSession(plan, seed=9)
    reps = [sess.append(a[k], b[k], traces[k]) for k in range(K)]
    run = sess.result()
    assert np.array_equal(run.y, ref.y)
    assert run.metrics.makespan == ref.metrics.makespan
    assert run.metrics.occupancy == ref.metrics.occupancy
    for rm, rm_ref in zip(run.replay_metrics, ref.replay_metrics):
        assert rm.completion_time == rm_ref.completion_time
    assert [r.completion for r in reps] == [
        m.completion_time for m in ref.replay_metrics
    ]


# ----------------------------------------------------------------------
# hybrid Byzantine posture through the engine
# ----------------------------------------------------------------------
def test_engine_hybrid_escalates_and_corrects():
    """A persistently corrupt fastest worker: the first replay rejects
    it on the detect path, later replays run Berlekamp-Welch — and
    validate=True proves every decode against the oracle either way."""
    cfg = PlanConfig("age", 2, 2, 2)
    pool = cfg.n_workers + 6
    trace = sample_trace(pool, Deterministic(1.0), seed=2)
    trace = dataclasses.replace(
        trace, uplink_delay=0.1 + 0.01 * np.arange(pool)
    )
    trace = trace.with_faults(corrupt_ids=[0])
    eng, w, rng = _engine(
        traces=[trace], config=cfg, decode_mode="hybrid", verify_extras=2
    )
    reqs = [eng.submit(rng.normal(size=(ROWS, K_DIM)), 8.0 * i)
            for i in range(3)]
    rep = eng.run()
    assert all(r.state == DONE for r in reqs)
    assert rep.summary()["replays"] >= 2
    state = eng._session.hybrid_state
    assert state is not None and state.escalated
    # first replay runs the detect path (rejects, corrects nothing);
    # post-escalation replays BW-correct the corrupt worker instead.
    assert eng._obs[0].n_corrected == 0
    assert any(o.n_corrected for o in eng._obs[1:])
    for r in reqs:
        assert np.array_equal(r.y, _exact_y(r.x, w))


# ----------------------------------------------------------------------
# observability: request lanes in the trace
# ----------------------------------------------------------------------
def test_serve_spans_link_queue_to_replay():
    """Each served request contributes a serve.queue and a serve.service
    sim span on its own ("request", rid) lane, service bounds matching
    the replay it rode; shed requests contribute a serve.shed instant."""
    TRACER.clear()
    TRACER.enable()
    try:
        eng, _, rng = _engine(slo=2.0)
        reqs = [eng.submit(rng.normal(size=(ROWS, K_DIM)), 0.05 * i)
                for i in range(8)]
        eng.run()
    finally:
        TRACER.disable()
    sim = TRACER.sim_events()
    TRACER.clear()
    by_name = {}
    for e in sim:
        by_name.setdefault(e["name"], []).append(e)
    served = [r for r in reqs if r.state == DONE]
    shed = [r for r in reqs if r.state == SHED]
    assert len(by_name.get("serve.service", [])) == len(served)
    assert len(by_name.get("serve.queue", [])) == len(served)
    assert len(by_name.get("serve.shed", [])) == len(shed)
    replays = {e["attrs"]["replay"] for e in by_name.get("replay", [])} or None
    for r in served:
        svc = next(
            e for e in by_name["serve.service"]
            if e["track"] == ("request", r.rid)
        )
        assert svc["t0"] == r.launch and svc["t1"] == r.completion
        q = next(
            e for e in by_name["serve.queue"]
            if e["track"] == ("request", r.rid)
        )
        assert q["t0"] == r.arrival and q["t1"] == r.launch
        assert svc["attrs"]["replay"] == r.replay


# ----------------------------------------------------------------------
# the async submission API under the engine
# ----------------------------------------------------------------------
def test_submit_handle_matches_sync_secure_matmul():
    """handle.result() is exactly secure_matmul's answer: the field
    computation is scale-deterministic, so the async path cannot drift."""
    rng = np.random.default_rng(11)
    a = rng.normal(size=(8, 6))
    b = rng.normal(size=(8, 4))
    h = secure_matmul_submit(a, b, s=2, t=2, z=1)
    assert not h.done()
    res = h.result()  # implicit flush
    assert h.done()
    want = secure_matmul(a, b, s=2, t=2, z=1)
    assert np.array_equal(res.y, want.y)


def test_executor_folds_submissions_into_one_flush():
    """Same-signature submissions share one batched protocol run; the
    per-request scales still decode each product exactly."""
    ex = InlineExecutor(field=FIELD, seed=3)
    rng = np.random.default_rng(12)
    pairs = [
        (rng.normal(size=(8, 6)) * mag, rng.normal(size=(8, 4)))
        for mag in (0.1, 10.0)
    ]
    handles = [secure_matmul_submit(a, b, executor=ex) for a, b in pairs]
    assert ex.pending() == 2 and ex.flushes == 0
    ex.flush()
    assert ex.flushes == 1 and ex.pending() == 0
    for (a, b), h in zip(pairs, handles):
        assert h.done()
        assert np.array_equal(h.result().y, secure_matmul(a, b).y)
    with pytest.raises(ValueError, match="field"):
        secure_matmul_submit(
            pairs[0][0], pairs[0][1], executor=ex,
            field=Field(p=2**31 - 1),
        )


def test_private_linear_submit_path_matches_call():
    """PrivateLinear with an executor: submit + flush + result is
    bit-identical to the historical per-block protocol.run path."""
    rng = np.random.default_rng(13)
    w = rng.normal(size=(16, 6))
    x = rng.normal(size=(4, 16))
    plain = PrivateLinear(w, blocks=2, field=FIELD)(x)
    ex = InlineExecutor(field=FIELD)
    layer = PrivateLinear(w, blocks=2, field=FIELD, executor=ex)
    h = layer.submit(x)
    assert not h.done()
    ex.flush()
    assert h.done()
    assert np.array_equal(h.result(), plain)
    # the sync facade drives the same path
    assert np.array_equal(layer(x), plain)
