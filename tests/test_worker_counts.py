"""Worker counts: paper's published numbers, closed forms vs exact
constructions, and the dominance claims (Lemmas 3/9, Fig. 2)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import closed_form as cf
from repro.core import constructions as C


# ----------------------------------------------------------------------
# paper anchor points
# ----------------------------------------------------------------------
def test_example1_age():
    """Section V-B Example 1: s = t = z = 2 -> lambda* = 2, N = 17."""
    sch = C.age_cmpc(2, 2, 2)
    assert sch.n_workers == 17
    assert sch.lam == 2
    n, lam = cf.n_age_exact(2, 2, 2)
    assert (n, lam) == (17, 2)
    assert cf.n_age(2, 2, 2) == 17


def test_example1_entangled():
    assert cf.n_entangled(2, 2, 2) == 19


def test_example1_share_polynomials():
    """F_A = C_A + S_A with the exact powers of Example 1."""
    sch = C.age_cmpc_fixed(2, 2, 2, 2)
    assert sch.fa_powers == [0, 1, 2, 3, 4, 5]
    assert sch.fb_powers == [0, 1, 6, 7, 10, 11]
    assert len(sch.h_powers) == 17  # x^0..x^16, all present


def test_fig2_crossovers():
    """Fig. 2 (s=4, t=15): SSMM second-best through z=48; PolyDot-CMPC
    best baseline for 49 <= z <= 180; Entangled/GCSA from 181."""
    s, t = 4, 15

    def best_baseline(z):
        vals = {
            "polydot": C.polydot_cmpc(s, t, z).n_workers,
            "ssmm": cf.n_ssmm(s, t, z),
            "entangled": cf.n_entangled(s, t, z),
            "gcsa": cf.n_gcsa_na(s, t, z),
        }
        return min(vals, key=vals.get), vals

    for z in (10, 48):
        name, vals = best_baseline(z)
        assert name == "ssmm", (z, vals)
    for z in (49, 100, 180):
        name, vals = best_baseline(z)
        assert name == "polydot", (z, vals)
    for z in (181, 300):
        name, vals = best_baseline(z)
        assert name in ("entangled", "gcsa"), (z, vals)


def test_fig2_age_always_best():
    s, t = 4, 15
    for z in range(1, 301, 7):
        n, _ = cf.n_age_exact(s, t, z)
        assert n <= C.polydot_cmpc(s, t, z).n_workers
        assert n <= cf.n_ssmm(s, t, z)
        assert n <= cf.n_entangled(s, t, z)
        assert n <= cf.n_gcsa_na(s, t, z)


def test_fig3_polydot_wins_cells():
    """Fig. 3 (st=36, z=42): PolyDot-CMPC beats the other baselines at
    (s,t) in {(2,18), (3,12), (4,9)}."""
    z = 42
    for s, t in [(2, 18), (3, 12), (4, 9)]:
        n_pd = C.polydot_cmpc(s, t, z).n_workers
        others = min(cf.n_entangled(s, t, z), cf.n_ssmm(s, t, z), cf.n_gcsa_na(s, t, z))
        assert n_pd < others, (s, t, n_pd, others)


# ----------------------------------------------------------------------
# closed forms vs exact constructions
# ----------------------------------------------------------------------
@settings(max_examples=80, deadline=None)
@given(s=st.integers(1, 6), t=st.integers(1, 6), z=st.integers(1, 16))
def test_polydot_closed_form_upper_bounds_exact(s, t, z):
    """Theorem 2 matches the exact |P(H)| except for gapped s=1 small-z
    supports where the formula overcounts (exact is authoritative by
    eq. (23)); the formula is never below the construction."""
    if s == 1 and t == 1:
        return
    exact = C.polydot_cmpc(s, t, z).n_workers
    formula = cf.n_polydot(s, t, z)
    assert formula >= exact
    if s != 1:
        assert formula == exact, (s, t, z)


@settings(max_examples=80, deadline=None)
@given(s=st.integers(1, 6), t=st.integers(2, 6), z=st.integers(1, 12), data=st.data())
def test_age_supports_fastpath_equals_greedy(s, t, z, data):
    lam = data.draw(st.integers(0, z))
    sch = C.age_cmpc_fixed(s, t, z, lam)
    fa, fb = cf.age_supports(s, t, z, lam)
    assert sorted(sch.fa_powers) == fa
    assert sorted(sch.fb_powers) == fb
    assert cf.n_age_exact_fixed(s, t, z, lam) == sch.n_workers


@settings(max_examples=60, deadline=None)
@given(s=st.integers(1, 5), t=st.integers(2, 5), z=st.integers(1, 12), data=st.data())
def test_age_gamma_transcription_upper_bounds_exact(s, t, z, data):
    """Appendix F Gamma(lambda): validated == exact in most regions;
    a few (Upsilon_5/7/9) transcribed cells overcount by O(1) — exact
    set cardinality is authoritative, the formula never undercounts."""
    lam = data.draw(st.integers(1, z))
    exact = cf.n_age_exact_fixed(s, t, z, lam)
    gamma = cf.age_gamma(s, t, z, lam)
    assert gamma >= exact


@settings(max_examples=60, deadline=None)
@given(s=st.integers(1, 6), t=st.integers(1, 6), z=st.integers(1, 14))
def test_lemma9_age_dominates(s, t, z):
    """Lemma 9: N_AGE <= every baseline (exact construction)."""
    n, _ = cf.n_age_exact(s, t, z)
    assert n <= cf.n_entangled(s, t, z)
    assert n <= cf.n_ssmm(s, t, z)
    assert n <= cf.n_gcsa_na(s, t, z)
    if not (s == 1 and t == 1):
        assert n <= C.polydot_cmpc(s, t, z).n_workers


def test_overhead_formulas():
    """Corollaries 10-12 at the Fig. 4 operating point."""
    m, s, t, z = 36_000, 4, 9, 42
    n = cf.n_age(s, t, z)
    comp = cf.computation_overhead(m, s, t, z, n)
    stor = cf.storage_overhead(m, s, t, z, n)
    comm = cf.communication_overhead(m, t, n)
    assert comp == m**3 // (s * t * t) + m * m + n * (t * t + z - 1) * (m * m // (t * t))
    assert stor == (2 * n + z + 1) * (m * m // (t * t)) + 2 * m * m // (s * t) + t * t
    assert comm == n * (n - 1) * (m * m // (t * t))
    # larger N strictly increases every overhead
    assert cf.computation_overhead(m, s, t, z, n + 10) > comp
    assert cf.storage_overhead(m, s, t, z, n + 10) > stor
    assert cf.communication_overhead(m, t, n + 10) > comm
