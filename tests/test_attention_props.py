"""Property tests for the flash-chunked attention primitive."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.models.attention import _sdpa_chunked, _sdpa_naive


def _ref(q, k, v, scale, q_positions=None, kv_valid=None):
    tq, s = q.shape[1], k.shape[1]
    mask = np.ones((1, tq, s), bool)
    if q_positions is not None:
        mask = mask & (np.arange(s)[None, :] <= np.asarray(q_positions)[:, None])[None]
    if kv_valid is not None:
        kvm = np.asarray(kv_valid)
        kvm = kvm[:, None, :] if kvm.ndim == 2 else kvm[None, None, :]
        mask = mask & kvm
    return np.asarray(
        _sdpa_naive(q, k, v, jnp.asarray(mask), scale), np.float32
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    tq=st.sampled_from([1, 3, 8, 17]),
    s=st.sampled_from([4, 16, 33]),
    kv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    hd=st.sampled_from([4, 8]),
    causal=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_chunked_matches_naive(b, tq, s, kv, g, hd, causal, seed):
    if causal and tq > s:
        return
    rng = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(rng, 3)
    h = kv * g
    q = jax.random.normal(k1, (b, tq, h, hd), jnp.float32)
    k = jax.random.normal(k2, (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, s, kv, hd), jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    qpos = jnp.arange(s - tq, s) if causal else None
    got = np.asarray(
        _sdpa_chunked(q, k, v, scale, q_positions=qpos, q_chunk=4, k_chunk=8),
        np.float32,
    )
    want = _ref(q, k, v, scale, q_positions=qpos)
    assert np.abs(got - want).max() < 1e-4


def test_kv_valid_mask():
    rng = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (2, 4, 2, 8), jnp.float32)
    k = jax.random.normal(k2, (2, 16, 2, 8), jnp.float32)
    v = jax.random.normal(k3, (2, 16, 2, 8), jnp.float32)
    valid = jnp.arange(16)[None, :] < 9
    got = np.asarray(
        _sdpa_chunked(q, k, v, 0.35, kv_valid=valid, k_chunk=4), np.float32
    )
    want = _ref(q, k, v, 0.35, kv_valid=valid)
    assert np.abs(got - want).max() < 1e-4


def test_different_value_dim():
    """MLA path: value head dim != key head dim."""
    rng = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (1, 6, 4, 8), jnp.float32)
    k = jax.random.normal(k2, (1, 12, 1, 8), jnp.float32)
    v = jax.random.normal(k3, (1, 12, 1, 16), jnp.float32)
    got = _sdpa_chunked(q, k, v, 0.3, q_positions=jnp.arange(6, 12))
    assert got.shape == (1, 6, 4 * 16)
    want = _ref(q, k, v, 0.3, q_positions=np.arange(6, 12))
    assert np.abs(np.asarray(got, np.float32) - want).max() < 1e-4


def test_grad_flows():
    """Checkpointed kv-step still differentiates correctly."""
    rng = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (1, 8, 2, 4), jnp.float32)
    k = jax.random.normal(k2, (1, 8, 2, 4), jnp.float32)
    v = jax.random.normal(k3, (1, 8, 2, 4), jnp.float32)

    def loss_chunked(q):
        return jnp.sum(_sdpa_chunked(q, k, v, 0.5, q_positions=jnp.arange(8), q_chunk=4, k_chunk=4) ** 2)

    def loss_naive(q):
        mask = (jnp.arange(8)[None, :] <= jnp.arange(8)[:, None])[None]
        return jnp.sum(_sdpa_naive(q, k, v, mask, 0.5) ** 2)

    g1 = jax.grad(loss_chunked)(q)
    g2 = jax.grad(loss_naive)(q)
    assert np.abs(np.asarray(g1 - g2)).max() < 1e-3
