"""Byzantine-tolerant runtime decode: correct vs detect over one pool.

The acceptance bar: ``decode_mode="correct"`` recovers the
oracle-validated product from ``thr + 2e`` responses with ``e``
injected corruptions for ``e`` up to ``n_spare // 2``, on byte-identical
traces where ``"detect"`` raises :class:`DecodeFailure` or needs
strictly more responders.  Plus the two satellite regressions: the
``verify_extras="auto"`` oracle-knowledge fix and the
``max_subset_tries`` knob."""
import dataclasses

import numpy as np
import pytest

from repro.core import constructions as C
from repro.core.bw_decode import bw_system_size
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan
from repro.runtime import (
    DecodeFailure,
    Deterministic,
    FaultSpec,
    HybridState,
    run_batch_over_pool,
    run_over_pool,
    sample_trace,
)
from repro.runtime.metrics import observed_run
from repro.runtime.scheduler import (
    DEFAULT_SUBSET_TRIES,
    _resolve_decode_mode,
    _resolve_error_budget,
    _resolve_hybrid,
    _resolve_verify_extras,
)


@pytest.fixture(scope="module")
def setup():
    field = Field()
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, n_spare=6, seed=1)
    rng = np.random.default_rng(0)
    a = field.random(rng, (8, 8))
    b = field.random(rng, (8, 4))
    return plan, a, b, field.matmul(a.T, b)


def _staircase_trace(plan, corrupt_ids=(), crash_tail=0, seed=2):
    """Deterministic trace with strictly increasing uplink delays, so
    Phase-3 responses arrive exactly in worker-id order; optionally the
    ``crash_tail`` highest ids crash after Phase 2 (shrinking the
    responder pool to a known prefix)."""
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=seed)
    trace = dataclasses.replace(trace, uplink_delay=0.1 + 0.01 * np.arange(plan.n_total))
    kwargs = {"corrupt_ids": list(corrupt_ids)}
    if crash_tail:
        kwargs["crash_ids"] = list(range(plan.n_total - crash_tail, plan.n_total))
    return trace.with_faults(**kwargs)


# ----------------------------------------------------------------------
# tentpole acceptance: correct from thr + 2e where detect cannot
# ----------------------------------------------------------------------
def test_correct_recovers_up_to_half_spares(setup):
    """e = 1 .. n_spare // 2 corruptions among the fastest responders:
    BW decodes from exactly thr + 2e responses, names the corrupt, and
    the same byte-identical trace starves detect (verify_extras = e + 1,
    the witness margin that tolerates e corrupt witnesses) of
    confirmable responses entirely."""
    plan, a, b, want = setup
    thr = plan.decode_threshold
    for e in range(1, plan.n_spare // 2 + 1):
        need = bw_system_size(thr, e)
        # crash everyone beyond the thr + 2e fastest: the responder pool
        # is exactly the BW window
        trace = _staircase_trace(
            plan,
            corrupt_ids=range(e),
            crash_tail=plan.n_total - need,
            seed=10 + e,
        )
        run = run_over_pool(
            plan, a, b, trace, seed=3, decode_mode="correct", error_budget=e
        )
        assert np.array_equal(run.y, want)
        assert np.array_equal(
            run.metrics.corrected_workers, np.arange(e)
        )
        assert observed_run(run.metrics).thr_arrived == need
        # byte-identical trace, detect: thr + e clean responders exist
        # but thr + (e + 1) are demanded -> no acceptable decode
        with pytest.raises(DecodeFailure):
            run_over_pool(
                plan, a, b, trace, seed=3,
                decode_mode="detect", verify_extras=e + 1,
            )


def test_correct_widens_past_budget(setup):
    """More corrupt responders than the budget: each extra arrival
    widens the window ((k - thr) // 2) until the decode lands."""
    plan, a, b, want = setup
    trace = _staircase_trace(plan, corrupt_ids=[0, 1, 2], seed=5)
    run = run_over_pool(
        plan, a, b, trace, seed=3, decode_mode="correct", error_budget=1
    )
    assert np.array_equal(run.y, want)
    assert np.array_equal(run.metrics.corrected_workers, np.array([0, 1, 2]))


def test_correct_exhaustion_census(setup):
    """Too many corrupt for the pool: the failure names the BW budget
    and attempt count, not the detect-mode confirmation census."""
    plan, a, b, _ = setup
    thr = plan.decode_threshold
    n_corrupt = plan.n_total - thr + 1  # < thr clean responders remain
    trace = _staircase_trace(plan, corrupt_ids=range(n_corrupt), seed=6)
    with pytest.raises(DecodeFailure, match="Berlekamp-Welch.*BW attempts"):
        run_over_pool(
            plan, a, b, trace, seed=3, decode_mode="correct", error_budget=2
        )


def test_auto_mode_resolves_from_fault_model(setup):
    """decode_mode="auto" turns correction on exactly when the
    configured fault model prices a positive error budget."""
    plan, a, b, want = setup
    corrupt = _staircase_trace(plan, corrupt_ids=[0, 1], seed=7)
    run = run_over_pool(plan, a, b, corrupt, seed=3, decode_mode="auto")
    assert np.array_equal(run.y, want)
    assert run.metrics.corrected_workers.size == 2
    clean = _staircase_trace(plan, seed=8)
    run2 = run_over_pool(plan, a, b, clean, seed=3, decode_mode="auto")
    assert np.array_equal(run2.y, want)
    assert run2.metrics.corrected_workers.size == 0
    assert run2.metrics.responder_ids.size == plan.decode_threshold


def test_batched_correct_mode(setup):
    """The whole batch rides one BW decode; per-product results match
    the oracle and the aggregate names the corrupt workers."""
    plan, _, _, _ = setup
    field = plan.field
    rng = np.random.default_rng(9)
    a = field.random(rng, (3, 8, 8))
    b = field.random(rng, (3, 8, 4))
    want = np.stack([field.matmul(x.T, y) for x, y in zip(a, b)])
    trace = _staircase_trace(plan, corrupt_ids=[1, 3], seed=10)
    run = run_batch_over_pool(
        plan, a, b, trace, seed=3, decode_mode="correct", error_budget=2
    )
    assert np.array_equal(run.y, want)
    assert np.array_equal(run.metrics.corrected_workers, np.array([1, 3]))
    assert all(
        np.array_equal(m.corrected_workers, np.array([1, 3]))
        for m in run.per_product
    )


# ----------------------------------------------------------------------
# satellite: verify_extras="auto" must not peek at sampled ground truth
# ----------------------------------------------------------------------
def test_auto_extras_resolves_from_configuration_not_oracle(setup):
    plan, _, _, _ = setup
    # hand-built corrupt flags, no fault model: the master knows nothing
    bare = sample_trace(plan.n_total, Deterministic(1.0), seed=11)
    bare = dataclasses.replace(bare, corrupt=np.isin(np.arange(plan.n_total), [2]),
                        fault_model=None)
    assert _resolve_verify_extras("auto", bare) == 0
    # configured model with corruption, zero sampled corrupt: protected
    spec = FaultSpec(corrupt_frac=0.2)
    configured = sample_trace(
        plan.n_total, Deterministic(1.0), faults=spec, seed=12
    )
    configured = dataclasses.replace(configured, 
        corrupt=np.zeros(plan.n_total, bool), fault_model=spec
    )
    assert _resolve_verify_extras("auto", configured) == 1
    assert _resolve_error_budget("auto", configured, plan) >= 1
    assert _resolve_error_budget("auto", bare, plan) == 0
    assert _resolve_decode_mode("auto", 0) == "detect"
    assert _resolve_decode_mode("auto", 2) == "correct"
    with pytest.raises(ValueError, match="decode_mode"):
        _resolve_decode_mode("majority", 0)


def test_unprotected_corrupt_trace_is_wrong_or_fails(setup):
    """Regression for the oracle-knowledge bug: a corrupt trace with
    verify_extras=0 (or a hand-built trace resolving to 0) must produce
    a wrong-or-failed decode — protection cannot come from flags the
    master is not supposed to see."""
    plan, a, b, want = setup
    trace = _staircase_trace(plan, corrupt_ids=[0], seed=13)
    trace = dataclasses.replace(trace, fault_model=None)  # hand-built: no configuration
    try:
        run = run_over_pool(plan, a, b, trace, seed=3, verify_extras="auto")
        assert not np.array_equal(run.y, want)
    except DecodeFailure:
        pass


def test_with_faults_updates_fault_model(setup):
    """Explicit placement is a configuration act: the resulting trace
    advertises at least the placed fraction per fault class."""
    plan, _, _, _ = setup
    trace = sample_trace(plan.n_total, Deterministic(1.0), seed=14)
    assert trace.fault_model is not None
    assert trace.fault_model.corrupt_frac == 0.0
    faulted = trace.with_faults(corrupt_ids=[0, 1], crash_ids=[5])
    assert faulted.fault_model.corrupt_frac == pytest.approx(2 / plan.n_total)
    assert faulted.fault_model.crash_after_phase2_frac == pytest.approx(
        1 / plan.n_total
    )
    # selection keeps the pool-level configuration
    assert faulted.take(plan.n_total - 1).fault_model == faulted.fault_model


# ----------------------------------------------------------------------
# satellite: max_subset_tries is a real knob
# ----------------------------------------------------------------------
def test_max_subset_tries_bounds_detect_search(setup):
    """A tiny search budget starves detect on a corrupt-heavy prefix
    (or forces strictly more responders); the default budget succeeds
    on the byte-identical trace."""
    plan, a, b, want = setup
    thr = plan.decode_threshold
    trace = _staircase_trace(plan, corrupt_ids=range(4), seed=15)
    ok = run_over_pool(
        plan, a, b, trace, seed=3, verify_extras=1,
        max_subset_tries=DEFAULT_SUBSET_TRIES,
    )
    assert np.array_equal(ok.y, want)
    arrived_ok = observed_run(ok.metrics).thr_arrived
    try:
        starved = run_over_pool(
            plan, a, b, trace, seed=3, verify_extras=1, max_subset_tries=2
        )
        # with only 2 colex candidates per arrival the clean subset is
        # found later (if at all): strictly more responders consumed
        assert observed_run(starved.metrics).thr_arrived > arrived_ok
        assert np.array_equal(starved.y, want)
    except DecodeFailure:
        pass
    with pytest.raises(DecodeFailure):
        # zero budget: no candidate subsets at all
        run_over_pool(
            plan, a, b, trace, seed=3, verify_extras=1, max_subset_tries=0
        )


# ----------------------------------------------------------------------
# hybrid mode: detect until the first rejection, then escalate to BW
# ----------------------------------------------------------------------
def test_hybrid_resolution_unit(setup):
    """The per-replay resolution: non-hybrid modes pass through, a fresh
    hybrid state starts in detect, an escalated one runs correct with
    the budget floored at 1 and capped by the pool's BW capacity."""
    plan, _, _, _ = setup
    assert _resolve_hybrid("detect", None, 2, plan) == ("detect", 2, None)
    mode, budget, state = _resolve_hybrid("hybrid", None, 2, plan)
    assert mode == "detect" and isinstance(state, HybridState)
    assert not state.escalated
    state.escalated = True
    mode, budget, _ = _resolve_hybrid("hybrid", state, 2, plan)
    assert mode == "correct" and budget == 2
    # zero configured budget still corrects once escalated (floor 1)
    assert _resolve_hybrid("hybrid", state, 0, plan)[:2] == ("correct", 1)
    # and never beyond what the responder pool can seat
    cap = (plan.n_total - plan.decode_threshold) // 2
    assert _resolve_hybrid("hybrid", state, 99, plan)[1] == cap
    state.reset()
    assert not state.escalated and state.rejections_seen == 0


def test_hybrid_escalates_after_first_rejection(setup):
    """Clean replays stay on the cheap detect path; the first rejected
    responder flips the shared state, and the next replay on the same
    pool runs Berlekamp-Welch and names the corrupt worker."""
    plan, a, b, want = setup
    state = HybridState()
    clean = _staircase_trace(plan, seed=20)
    r1 = run_over_pool(
        plan, a, b, clean, seed=3, decode_mode="hybrid", hybrid_state=state
    )
    assert np.array_equal(r1.y, want)
    assert not state.escalated
    assert r1.metrics.responder_ids.size == plan.decode_threshold
    assert r1.metrics.corrected_workers.size == 0

    corrupt = _staircase_trace(plan, corrupt_ids=[0], seed=21)
    r2 = run_over_pool(
        plan, a, b, corrupt, seed=3, decode_mode="hybrid",
        hybrid_state=state, verify_extras=2,
    )
    assert np.array_equal(r2.y, want)
    # this replay still ran detect (witnessed, rejected, retried) ...
    assert r2.metrics.corrected_workers.size == 0
    assert r2.metrics.rejected_ids.size > 0
    # ... and the rejection armed the escalation
    assert state.escalated and state.rejections_seen > 0

    r3 = run_over_pool(
        plan, a, b, corrupt, seed=3, decode_mode="hybrid",
        hybrid_state=state, verify_extras=2,
    )
    assert np.array_equal(r3.y, want)
    assert np.array_equal(r3.metrics.corrected_workers, np.array([0]))


def test_hybrid_default_state_and_validation(setup):
    """decode_mode="hybrid" without an explicit state still runs (a
    throwaway state per call), and the mode name is accepted by the
    resolver chain."""
    plan, a, b, want = setup
    clean = _staircase_trace(plan, seed=24)
    run = run_over_pool(plan, a, b, clean, seed=3, decode_mode="hybrid")
    assert np.array_equal(run.y, want)
    with pytest.raises(ValueError, match="decode_mode"):
        run_over_pool(plan, a, b, clean, seed=3, decode_mode="bogus")


def test_hybrid_batched_threads_state(setup):
    """The batched replay feeds the same shared state: a rejection in
    one batch escalates the next batch to correction."""
    plan, _, _, _ = setup
    field = plan.field
    rng = np.random.default_rng(22)
    a = field.random(rng, (2, 8, 8))
    b = field.random(rng, (2, 8, 4))
    want = np.stack([field.matmul(x.T, y) for x, y in zip(a, b)])
    state = HybridState()
    corrupt = _staircase_trace(plan, corrupt_ids=[0], seed=23)
    r1 = run_batch_over_pool(
        plan, a, b, corrupt, seed=3, decode_mode="hybrid",
        hybrid_state=state, verify_extras=2,
    )
    assert np.array_equal(r1.y, want)
    assert state.escalated
    assert r1.metrics.corrected_workers.size == 0
    r2 = run_batch_over_pool(
        plan, a, b, corrupt, seed=3, decode_mode="hybrid",
        hybrid_state=state, verify_extras=2,
    )
    assert np.array_equal(r2.y, want)
    assert np.array_equal(r2.metrics.corrected_workers, np.array([0]))
