"""Documentation layer stays healthy: required docs exist and every
relative link in README.md / docs/*.md resolves (the same checker the
CI docs smoke step runs)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_required_docs_exist():
    for rel in (
        "README.md",
        "docs/protocol_engine.md",
        "docs/edge_runtime.md",
        "docs/kernel_design.md",
        "docs/autoplanner.md",
        "docs/observability.md",
    ):
        assert os.path.exists(os.path.join(ROOT, rel)), f"{rel} missing"


def test_doc_links_resolve():
    res = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_doc_links.py"), ROOT],
        capture_output=True,
        text=True,
    )
    assert res.returncode == 0, f"broken doc links:\n{res.stderr}"


def test_readme_names_the_entry_points():
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for needle in (
        "run_batched",
        "run_pipeline_over_pool",
        "make bench-edge",
        "docs/protocol_engine.md",
        "docs/edge_runtime.md",
    ):
        assert needle in readme, f"README.md no longer mentions {needle}"
