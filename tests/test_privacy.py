"""Privacy structure (Theorem 13 / Lemma 14 mechanics).

Information-theoretic privacy against z colluders reduces to: the z
secret coefficients act as a one-time pad on any z workers' shares,
i.e. the z x z Vandermonde submatrix on the secret powers is invertible
mod p.  We verify that algebraic condition for many worker subsets, and
run a distribution smoke test (share histograms are uniform).

The property tests at the bottom extend the subset sweep to the
*adversarial* setting: the colluding set may consist entirely of
corrupt-flagged workers — including the ones a Berlekamp-Welch decode
identifies and corrects — and their joint view stays independent of
the secrets.  Misbehaving in Phase 3 reveals nothing extra: a worker's
view is fixed by the shares it *receives*, not by what it sends back."""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.bw_decode import bw_decode_evals, bw_system_size
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan


@pytest.mark.parametrize("method,s,t,z", [("age", 2, 2, 2), ("polydot", 2, 2, 3), ("age", 3, 2, 4)])
def test_secret_vandermonde_invertible(method, s, t, z):
    field = Field()
    sch = C.build_scheme(method, s, t, z)
    shapes = BlockShapes(k=s * 2, ma=t * 2, mb=t * 2, s=s, t=t)
    plan = make_plan(sch, shapes, seed=3)
    rng = np.random.default_rng(0)
    for sa_or_sb in (sch.sa, sch.sb):
        for _ in range(10):
            subset = rng.choice(plan.n_total, size=z, replace=False)
            v = field.vandermonde(plan.alphas[subset], sa_or_sb)
            field.inv_matrix(v)  # raises if singular -> privacy broken


def test_share_uniformity_smoke():
    """Shares of two very different inputs should look identically
    distributed to any single worker (chi-square-free coarse check)."""
    field = Field()
    sch = C.build_scheme("age", 2, 2, 1)
    shapes = BlockShapes(k=64, ma=64, mb=64, s=2, t=2)
    plan = make_plan(sch, shapes, seed=5)
    rng = np.random.default_rng(1)
    a0 = np.zeros((64, 64), np.int64)
    a1 = field.random(rng, (64, 64))
    buckets = 16
    hists = []
    for a in (a0, a1):
        h = np.zeros(buckets)
        for seed in range(8):
            fa = np.asarray(proto.share_a(plan, a, np.random.default_rng(seed)))
            h += np.histogram(fa[0].ravel(), bins=buckets, range=(0, field.p))[0]
        hists.append(h / h.sum())
    # both near-uniform and near each other
    assert np.abs(hists[0] - 1 / buckets).max() < 0.01
    assert np.abs(hists[0] - hists[1]).max() < 0.01


# ----------------------------------------------------------------------
# adversarial collusion properties (Byzantine workers learn nothing)
# ----------------------------------------------------------------------
_FIELD = Field()


def _adversarial_plan(method, s, t, z, seed):
    sch = C.build_scheme(method, s, t, z)
    shapes = BlockShapes(k=s * 2, ma=t * 2, mb=t * 2, s=s, t=t)
    return sch, make_plan(sch, shapes, n_spare=4, seed=seed)


@settings(max_examples=12, deadline=None)
@given(
    method_stz=st.sampled_from(
        [("age", 2, 2, 2), ("polydot", 2, 2, 3), ("age", 3, 2, 4)]
    ),
    e=st.integers(1, 2),
    seed=st.integers(0, 10_000),
)
def test_bw_identified_workers_views_stay_padded(method_stz, e, seed):
    """Run the protocol with e corrupt workers, let Berlekamp-Welch name
    them, then check the privacy condition for a colluding set built
    AROUND the identified workers: the z x z secret-power Vandermonde of
    any subset containing them stays invertible, so their joint view is
    one-time-padded regardless of having been caught misbehaving."""
    method, s, t, z = method_stz
    sch, plan = _adversarial_plan(method, s, t, z, seed % 7)
    rng = np.random.default_rng(seed)
    a = _FIELD.random(rng, (plan.shapes.k, plan.shapes.ma))
    b = _FIELD.random(rng, (plan.shapes.k, plan.shapes.mb))
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    i_all = np.array(proto.degree_reduce(
        plan, proto.worker_multiply(plan, fa, fb), rng
    )).reshape(plan.n_total, -1)
    ids = rng.permutation(plan.n_total)[: bw_system_size(plan.decode_threshold, e)]
    bad = ids[:e]
    for w in bad:
        i_all[w] = _FIELD.random(rng, i_all[w].shape)
    coeffs, corrected = bw_decode_evals(plan, i_all, ids, e, rng=rng)
    assert np.array_equal(
        proto.assemble_y(plan, coeffs), _FIELD.matmul(a.T, b)
    )
    assert np.array_equal(corrected, np.sort(bad))
    # colluders: every identified-corrupt worker, padded to z with other
    # (corrupt-flagged or honest) workers
    rest = np.setdiff1d(np.arange(plan.n_total), corrected)
    colluders = np.concatenate(
        [corrected, rng.permutation(rest)]
    )[:z].astype(np.int64)
    for powers in (sch.sa, sch.sb):
        v = _FIELD.vandermonde(plan.alphas[colluders], powers)
        _FIELD.inv_matrix(v)  # raises if singular -> privacy broken


@settings(max_examples=12, deadline=None)
@given(
    method_stz=st.sampled_from([("age", 2, 2, 2), ("polydot", 2, 2, 3)]),
    seed=st.integers(0, 10_000),
)
def test_equalizing_noise_exists_for_any_colluding_view(method_stz, seed):
    """The one-time-pad property, executed: for ANY two inputs a0 != a1
    and any z colluding workers (corrupt-flagged ones included), there
    is a noise draw under which the colluders' shares of a1 are
    byte-identical to their shares of a0 — so the view determines
    nothing about the input.  Built from linearity: sharing a0 and a1
    under the SAME noise leaves a noise-free difference, and the secret
    Vandermonde maps a noise delta onto exactly that difference."""
    method, s, t, z = method_stz
    sch, plan = _adversarial_plan(method, s, t, z, seed % 5)
    rng = np.random.default_rng(seed)
    a0 = _FIELD.random(rng, (plan.shapes.k, plan.shapes.ma))
    a1 = _FIELD.random(rng, (plan.shapes.k, plan.shapes.ma))
    if np.array_equal(a0, a1):  # astronomically unlikely; keep the claim honest
        a1 = (a1 + 1) % _FIELD.p
    share_seed = int(rng.integers(2**31 - 1))
    f0 = np.asarray(proto.share_a(plan, a0, np.random.default_rng(share_seed)))
    f1 = np.asarray(proto.share_a(plan, a1, np.random.default_rng(share_seed)))
    colluders = rng.permutation(plan.n_total)[:z].astype(np.int64)
    # identical noise cancels: the colluders' view difference is purely
    # data-driven, and the z x z secret Vandermonde absorbs it
    diff = (f0[colluders] - f1[colluders]) % _FIELD.p
    v = _FIELD.vandermonde(plan.alphas[colluders], sch.sa)
    delta = _FIELD.solve(v, diff.reshape(z, -1))  # the equalizing noise delta
    patched = (f1[colluders].reshape(z, -1) + _FIELD.matmul(v, delta)) % _FIELD.p
    assert np.array_equal(patched, f0[colluders].reshape(z, -1))
    # the pad is real: the inputs differ, and so did the raw views
    assert not np.array_equal(a0, a1)
    assert not np.array_equal(f0[colluders], f1[colluders])


def test_no_secret_leak_without_noise():
    """Sanity counterexample: with z random terms removed, a worker's
    share is a deterministic function of the data (i.e. the random
    terms are what provides privacy)."""
    field = Field()
    sch = C.build_scheme("age", 2, 2, 1)
    shapes = BlockShapes(k=4, ma=4, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, seed=6)
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(2)
    a = field.random(np.random.default_rng(0), (4, 4))
    fa1 = np.asarray(proto.share_a(plan, a, rng1))
    fa2 = np.asarray(proto.share_a(plan, a, rng2))
    # different blinding -> different shares (randomness is live)
    assert not np.array_equal(fa1, fa2)
