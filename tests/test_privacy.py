"""Privacy structure (Theorem 13 / Lemma 14 mechanics).

Information-theoretic privacy against z colluders reduces to: the z
secret coefficients act as a one-time pad on any z workers' shares,
i.e. the z x z Vandermonde submatrix on the secret powers is invertible
mod p.  We verify that algebraic condition for many worker subsets, and
run a distribution smoke test (share histograms are uniform)."""
import itertools

import numpy as np
import pytest

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan


@pytest.mark.parametrize("method,s,t,z", [("age", 2, 2, 2), ("polydot", 2, 2, 3), ("age", 3, 2, 4)])
def test_secret_vandermonde_invertible(method, s, t, z):
    field = Field()
    sch = C.build_scheme(method, s, t, z)
    shapes = BlockShapes(k=s * 2, ma=t * 2, mb=t * 2, s=s, t=t)
    plan = make_plan(sch, shapes, seed=3)
    rng = np.random.default_rng(0)
    for sa_or_sb in (sch.sa, sch.sb):
        for _ in range(10):
            subset = rng.choice(plan.n_total, size=z, replace=False)
            v = field.vandermonde(plan.alphas[subset], sa_or_sb)
            field.inv_matrix(v)  # raises if singular -> privacy broken


def test_share_uniformity_smoke():
    """Shares of two very different inputs should look identically
    distributed to any single worker (chi-square-free coarse check)."""
    field = Field()
    sch = C.build_scheme("age", 2, 2, 1)
    shapes = BlockShapes(k=64, ma=64, mb=64, s=2, t=2)
    plan = make_plan(sch, shapes, seed=5)
    rng = np.random.default_rng(1)
    a0 = np.zeros((64, 64), np.int64)
    a1 = field.random(rng, (64, 64))
    buckets = 16
    hists = []
    for a in (a0, a1):
        h = np.zeros(buckets)
        for seed in range(8):
            fa = np.asarray(proto.share_a(plan, a, np.random.default_rng(seed)))
            h += np.histogram(fa[0].ravel(), bins=buckets, range=(0, field.p))[0]
        hists.append(h / h.sum())
    # both near-uniform and near each other
    assert np.abs(hists[0] - 1 / buckets).max() < 0.01
    assert np.abs(hists[0] - hists[1]).max() < 0.01


def test_no_secret_leak_without_noise():
    """Sanity counterexample: with z random terms removed, a worker's
    share is a deterministic function of the data (i.e. the random
    terms are what provides privacy)."""
    field = Field()
    sch = C.build_scheme("age", 2, 2, 1)
    shapes = BlockShapes(k=4, ma=4, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, seed=6)
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(2)
    a = field.random(np.random.default_rng(0), (4, 4))
    fa1 = np.asarray(proto.share_a(plan, a, rng1))
    fa2 = np.asarray(proto.share_a(plan, a, rng2))
    # different blinding -> different shares (randomness is live)
    assert not np.array_equal(fa1, fa2)
