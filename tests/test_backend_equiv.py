"""Cross-backend equivalence for the GF(p) matmul layer.

Five implementations must agree bit-exactly: both Pallas kernels
(f32-limb and native-int32, interpret mode on CPU), the portable
f32limb and int32 paths, and the host ``Field.matmul`` oracle — swept
over non-tile-multiple shapes, batched/broadcast operand layouts, and
adversarial dense-high-limb inputs that sit on the lazy-reduction
bounds.  Also pins the single-launch contract: batched ``mod_matmul``
lowers to ONE ``pallas_call`` whose grid carries the batch axis.

(The randomized extension of this fixed grid — random shapes, primes,
and distributions — lives in ``test_kernel_fuzz.py``.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gf import CHUNK_K, LAZY_K, Field, mod_matmul_f32
from repro.kernels.modmatmul import mod_matmul, modmatmul_ref
from repro.kernels.modmatmul.ops import padded_shape, padding_waste, pick_tiles

P = 65521


def _oracle(a, b, p=P):
    """Broadcasting host oracle built on Field.matmul."""
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    batch = np.broadcast_shapes(a.shape[:-2], b.shape[:-2])
    a = np.broadcast_to(a, batch + a.shape[-2:])
    b = np.broadcast_to(b, batch + b.shape[-2:])
    af = a.reshape((-1,) + a.shape[-2:])
    bf = b.reshape((-1,) + b.shape[-2:])
    out = np.stack([modmatmul_ref(af[i], bf[i], p) for i in range(af.shape[0])])
    return out.reshape(batch + out.shape[-2:])


BACKENDS = ("f32limb", "int32", "pallas", "pallas_int32")


def _all_backends(a, b, **kw):
    """{backend: result} over every backend (Pallas in interpret mode)."""
    out = {}
    for backend in BACKENDS:
        if backend.startswith("pallas"):
            kw.setdefault("interpret", True)
        out[backend] = np.asarray(mod_matmul(a, b, backend=backend, **kw))
    return out


def _assert_all_equal(want, got_by_backend, ctx=None):
    for backend, got in got_by_backend.items():
        assert np.array_equal(want, got), (backend, ctx)


# non-tile-multiple shapes: every dim off the 8/128/256 alignment grid
SHAPES = [(1, 1, 1), (3, 5, 2), (9, 33, 11), (130, 257, 70), (17, 129, 200)]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_2d_all_backends(m, k, n):
    rng = np.random.default_rng(m * 7919 + k * 31 + n)
    a = rng.integers(0, P, (m, k)).astype(np.int32)
    b = rng.integers(0, P, (k, n)).astype(np.int32)
    want = modmatmul_ref(a, b, P)
    _assert_all_equal(want, _all_backends(a, b, p=P), (m, k, n))


BATCH_CASES = [
    ((4, 9, 33), (4, 33, 11)),       # both batched
    ((9, 33), (4, 33, 11)),          # 2D constant LHS, batched RHS
    ((4, 9, 33), (33, 11)),          # batched LHS, 2D constant RHS
    ((1, 5, 17), (3, 17, 7)),        # unit-batch broadcast
    ((2, 1, 5, 17), (1, 3, 17, 7)),  # multi-dim batch broadcast
    ((3, 9, 300), (3, 300, 11)),     # deep-K batched (scan path on f32limb)
    ((9, 300), (3, 300, 11)),        # deep-K constant LHS
]


@pytest.mark.parametrize("sa,sb", BATCH_CASES)
def test_batched_layouts_all_backends(sa, sb):
    rng = np.random.default_rng(sum(sa) * 131 + sum(sb))
    a = rng.integers(0, P, sa).astype(np.int32)
    b = rng.integers(0, P, sb).astype(np.int32)
    want = _oracle(a, b)
    _assert_all_equal(want, _all_backends(a, b, p=P), (sa, sb))


@pytest.mark.parametrize("p", [251, 4093, 40961, 65519, 65521])
def test_batched_primes(p):
    rng = np.random.default_rng(p)
    a = rng.integers(0, p, (3, 12, 37)).astype(np.int32)
    b = rng.integers(0, p, (3, 37, 9)).astype(np.int32)
    want = _oracle(a, b, p)
    _assert_all_equal(want, _all_backends(a, b, p=p), p)


# ----------------------------------------------------------------------
# lazy-reduction bound regression: dense high limbs at boundary depths
# ----------------------------------------------------------------------
# Values >= P-241 have hi limb 255; depths 127/128/129 bracket the
# LAZY_K cutoff just under the raw-cross-dot-sum exactness limit
# (2*d*255**2 < 2**24 holds through d = 129, fails at 130), and
# 255/256/257 straddle the raw-low-limb fold bound
# 3*(p-1) + d*255**2 < 2**24 and the CHUNK_K chunking boundary.
ADVERSARIAL_K = [LAZY_K - 1, LAZY_K, LAZY_K + 1, 255, CHUNK_K, CHUNK_K + 1]


@pytest.mark.parametrize("k", ADVERSARIAL_K)
def test_dense_high_limb_bounds(k):
    rng = np.random.default_rng(k)
    a = rng.integers(P - 241, P, (2, 8, k)).astype(np.int32)
    b = rng.integers(P - 241, P, (2, k, 8)).astype(np.int32)
    want = _oracle(a, b)
    _assert_all_equal(want, _all_backends(a, b, p=P), k)


def test_all_maximal_elements():
    """Every element p-1: worst case for every accumulation bound."""
    for k in (LAZY_K, 255, CHUNK_K, CHUNK_K + 1):
        a = np.full((2, 4, k), P - 1, np.int32)
        b = np.full((2, k, 4), P - 1, np.int32)
        want = _oracle(a, b)
        _assert_all_equal(want, _all_backends(a, b, p=P), k)


# ----------------------------------------------------------------------
# single-launch + tile-adaptivity contracts
# ----------------------------------------------------------------------
def _collect_eqns(jaxpr, name, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            out.append(eqn)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", v)
            if hasattr(sub, "eqns"):
                _collect_eqns(sub, name, out)
    return out


def _grid_of(eqn):
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", None)
    if grid is None:
        grid = eqn.params.get("grid")
    return tuple(grid)


def test_batched_single_pallas_launch():
    """[B, M, K] @ [B, K, N] lowers to ONE pallas_call with the batch on
    the leading grid axis (no vmap-of-2D launches)."""
    a = jnp.zeros((4, 16, 32), jnp.int32)
    b = jnp.zeros((4, 32, 8), jnp.int32)

    def f(x, y):
        return mod_matmul(x, y, p=P, backend="pallas", interpret=True)

    jaxpr = jax.make_jaxpr(f)(a, b)
    calls = _collect_eqns(jaxpr.jaxpr, "pallas_call", [])
    assert len(calls) == 1, f"expected one pallas_call, got {len(calls)}"
    grid = _grid_of(calls[0])
    assert len(grid) == 4, grid  # (batch, m, n, k)
    assert grid[0] == 4, grid
    # interpret-mode output stays bit-exact against the host oracle
    rng = np.random.default_rng(0)
    av = rng.integers(0, P, a.shape).astype(np.int32)
    bv = rng.integers(0, P, b.shape).astype(np.int32)
    assert np.array_equal(np.asarray(f(av, bv)), _oracle(av, bv))


def test_constant_lhs_not_broadcast_in_launch():
    """A 2D constant LHS against a batched RHS stays 2D inside the one
    pallas_call: its block index map is batch-invariant, so no [B, ...]
    copy of the constant is materialized."""
    a = jnp.zeros((8, 32), jnp.int32)
    b = jnp.zeros((5, 32, 8), jnp.int32)

    def f(x, y):
        return mod_matmul(x, y, p=P, backend="pallas", interpret=True)

    jaxpr = jax.make_jaxpr(f)(a, b)
    calls = _collect_eqns(jaxpr.jaxpr, "pallas_call", [])
    assert len(calls) == 1
    assert len(_grid_of(calls[0])) == 4
    # the kernel's first operand keeps rank 2 (shared across the batch axis)
    a_inval = calls[0].invars[0].aval
    assert a_inval.ndim == 2, a_inval


def test_pick_tiles_alignment_and_adaptivity():
    for m, k, n in [(1, 1, 1), (10, 6, 1024), (32, 32, 32), (300, 700, 513)]:
        bm, bn, bk = pick_tiles(m, k, n)
        assert bm % 8 == 0 and bn % 128 == 0 and bk in (128, 256)
        # adaptive tiles never waste more than the fixed 128/128/256 tiling
        assert padding_waste(m, k, n, (bm, bn, bk)) <= padding_waste(
            m, k, n, (128, 128, 256)
        ) + 1e-12
    # the protocol's small blocks: the lane dim keeps a 128 floor, but
    # adaptive tiles still cut the total padded MAC count by >4x vs the
    # fixed 128/128/256 tiling
    def macs(m, k, n, tiles):
        mp, kp, np_ = padded_shape(m, k, n, tiles)
        return mp * kp * np_

    assert macs(17, 6, 1024, pick_tiles(17, 6, 1024)) * 4 < macs(
        17, 6, 1024, (128, 128, 256)
    )


def test_explicit_tiles_still_win():
    rng = np.random.default_rng(7)
    a = rng.integers(0, P, (3, 20, 40)).astype(np.int32)
    b = rng.integers(0, P, (3, 40, 10)).astype(np.int32)
    want = _oracle(a, b)
    got = np.asarray(
        mod_matmul(a, b, p=P, backend="pallas", interpret=True, bm=8, bn=128, bk=128)
    )
    assert np.array_equal(want, got)


def test_f32limb_matches_field_matmul_oracle_large():
    f = Field(P)
    rng = np.random.default_rng(11)
    a = rng.integers(0, P, (65, 517)).astype(np.int32)
    b = rng.integers(0, P, (517, 43)).astype(np.int32)
    assert np.array_equal(f.matmul(a, b), np.asarray(mod_matmul_f32(a, b, P)))
