"""Serving correctness: KV-cache decode equals full recompute, and
prefill -> decode continuation matches the teacher-forced forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import build_model
from repro.models import lm

REL_TOL = {"xlstm-1.3b": 0.05, "zamba2-2.7b": 0.08}  # bf16 chunked-vs-step recurrences


def _run_decode(model, rc, params, toks, cache, start, end):
    step = jax.jit(model.decode_step)
    outs = []
    b = toks.shape[0]
    for i in range(start, end):
        logits, cache = step(params, toks[:, i : i + 1], cache, np.full((b, 1), i, np.int32))
        outs.append(np.asarray(logits, np.float32)[:, 0])
    return np.stack(outs, 1), cache


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    rc = reduced(get_config(arch))
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    b, t = 2, 8
    toks = np.random.default_rng(0).integers(0, rc.vocab_size, (b, t)).astype(np.int32)
    cache = model.init_cache(b, 16)
    if rc.family == "encdec":
        frames = np.random.default_rng(1).normal(size=(b, 12, rc.d_model)).astype(np.float32)
        enc_out = jax.jit(lambda p, f: lm.encode(rc, p, f))(params, frames)
        full = np.asarray(
            jax.jit(lambda p, tk: lm.decode_stack(rc, p, tk, enc_out)[0])(params, toks),
            np.float32,
        )
        cache["enc_out"] = jnp.pad(enc_out, ((0, 0), (0, 4), (0, 0))).astype(jnp.bfloat16)
        cache["enc_len"] = jnp.int32(12)
    else:
        full = np.asarray(jax.jit(model.forward)(params, {"tokens": toks}), np.float32)
    dec, _ = _run_decode(model, rc, params, toks, cache, 0, t)
    rel = np.abs(dec - full).max() / (np.abs(full).max() + 1e-9)
    assert rel <= REL_TOL.get(arch, 1e-3), (arch, rel)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode(arch):
    rc = reduced(get_config(arch))
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    b, t, extra = 2, 8, 3
    rng = np.random.default_rng(2)
    toks = rng.integers(0, rc.vocab_size, (b, t + extra)).astype(np.int32)
    cache = model.init_cache(b, 16)
    if rc.family == "encdec":
        frames = rng.normal(size=(b, t, rc.d_model)).astype(np.float32)
        pre_batch = {"frames": frames, "tokens": toks[:, :t]}
        full = np.asarray(
            jax.jit(
                lambda p, tk: lm.decode_stack(rc, p, tk, lm.encode(rc, p, frames))[0]
            )(params, toks),
            np.float32,
        )
    else:
        pre_batch = {"tokens": toks[:, :t]}
        full = np.asarray(jax.jit(model.forward)(params, {"tokens": toks}), np.float32)
    last, cache = jax.jit(model.prefill)(params, pre_batch, cache)
    errs = [np.abs(np.asarray(last, np.float32)[:, 0] - full[:, t - 1]).max()]
    dec, _ = _run_decode(model, rc, params, toks, cache, t, t + extra)
    errs.append(np.abs(dec - full[:, t : t + extra]).max())
    rel = max(errs) / (np.abs(full).max() + 1e-9)
    assert rel <= REL_TOL.get(arch, 1e-3), (arch, rel)


def test_chunked_attention_matches_naive():
    """The flash-chunked primitive agrees with the naive softmax."""
    import dataclasses

    from repro.models.attention import gqa_attention, gqa_params
    from repro.models.common import materialize

    rc = dataclasses.replace(reduced(get_config("yi-34b")), compute_dtype="float32")
    p = materialize(gqa_params(rc), jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, rc.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    for causal in (True, False):
        a = gqa_attention(p, x, pos, rc, causal=causal, impl="chunked")[0]
        b = gqa_attention(p, x, pos, rc, causal=causal, impl="naive")[0]
        assert np.abs(np.asarray(a - b)).max() < 2e-4


def test_long_context_flag():
    from repro.configs import SHAPES, shape_applicable

    long = SHAPES["long_500k"]
    runs = [a for a in ARCH_NAMES if shape_applicable(get_config(a), long)]
    assert sorted(runs) == ["xlstm-1.3b", "zamba2-2.7b"]
