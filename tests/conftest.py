import os
import sys

# Tests must see exactly ONE device (the dry-run manages its own device
# count in a separate process); guard against leaked XLA_FLAGS.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Make the offline hypothesis fallback (tests/_hypothesis_compat.py)
# importable regardless of how pytest computed rootdir.
sys.path.insert(0, os.path.dirname(__file__))
