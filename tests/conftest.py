import os
import sys

# Tests must see exactly ONE device (the dry-run manages its own device
# count in a separate process); guard against leaked XLA_FLAGS.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
