"""Power-set combinatorics: coded supports, conditions C1-C6,
decodability invariants of Theorem 6 (property-tested)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import constructions as C
from repro.core.powers import (
    age_coded,
    coded_garbage_disjoint,
    diffset,
    entangled_coded,
    greedy_powers,
    h_support,
    important_powers_distinct,
    polydot_coded,
    secret_conditions_hold,
    sumset,
)


def test_sumset_basic():
    assert list(sumset([0, 1], [0, 2])) == [0, 1, 2, 3]
    assert list(diffset([5, 7], [1, 10])) == [4, 6]


def test_greedy_powers():
    assert greedy_powers(3, np.array([0, 1, 3])) == [2, 4, 5]


def test_polydot_supports_match_paper():
    c = polydot_coded(2, 2)
    # eq. (7): {0..ts-1}; eq. (8) with theta' = t(2s-1) = 6
    assert sorted(c.pa) == [0, 1, 2, 3]
    assert sorted(c.pb) == [0, 2, 6, 8]


def test_age_example1_supports():
    c = age_coded(2, 2, 2)
    assert sorted(c.pa) == [0, 1, 2, 3]
    assert sorted(c.pb) == [0, 1, 6, 7]
    assert sorted(c.imp) == [1, 3, 7, 9]


@settings(max_examples=60, deadline=None)
@given(s=st.integers(1, 6), t=st.integers(1, 6), lam=st.integers(0, 8))
def test_age_decodable(s, t, lam):
    """Theorem 6: important powers distinct and garbage-free."""
    c = age_coded(s, t, lam)
    assert important_powers_distinct(c)
    assert coded_garbage_disjoint(c)


@settings(max_examples=40, deadline=None)
@given(s=st.integers(1, 6), t=st.integers(1, 6))
def test_polydot_decodable(s, t):
    c = polydot_coded(s, t)
    assert important_powers_distinct(c)
    assert coded_garbage_disjoint(c)


@settings(max_examples=60, deadline=None)
@given(s=st.integers(1, 5), t=st.integers(1, 5), z=st.integers(1, 10))
def test_polydot_cmpc_conditions(s, t, z):
    """Algorithm 1 output satisfies C1-C3 (eq. 9)."""
    if s == 1 and t == 1:
        return
    sch = C.polydot_cmpc(s, t, z)
    assert secret_conditions_hold(sch.coded, list(sch.sa), list(sch.sb))
    assert len(sch.sa) == z and len(sch.sb) == z


@settings(max_examples=60, deadline=None)
@given(
    s=st.integers(1, 5), t=st.integers(1, 5), z=st.integers(1, 10),
    data=st.data(),
)
def test_age_cmpc_conditions(s, t, z, data):
    """Algorithm 2 output satisfies C4-C6 (eq. 27) for every lambda."""
    lam = data.draw(st.integers(0, z))
    sch = C.age_cmpc_fixed(s, t, z, lam)
    assert secret_conditions_hold(sch.coded, list(sch.sa), list(sch.sb))


def test_entangled_is_age_lambda0():
    assert entangled_coded(3, 4).pa == age_coded(3, 4, 0).pa
    assert entangled_coded(3, 4).pb == age_coded(3, 4, 0).pb


def test_h_support_is_n_workers():
    sch = C.age_cmpc(2, 2, 2)
    assert len(h_support(sch.coded, list(sch.sa), list(sch.sb))) == sch.n_workers
