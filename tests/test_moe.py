"""MoE dispatch invariants (property-based) and reference equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import MoEConfig, ModelConfig
from repro.models.common import materialize
from repro.models.ffn import moe_ffn, moe_params


def _cfg(e, k, cf, d=32, ffe=16):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=4,
        num_kv_heads=4, d_ff=ffe, vocab_size=64,
        moe=MoEConfig(num_experts=e, num_experts_per_tok=k, d_ff_expert=ffe,
                      capacity_factor=cf),
        compute_dtype="float32",
    )


def _dense_reference(p, x, cfg):
    """Dropless reference: every token runs through its top-k experts."""
    m = cfg.moe
    b, t, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, m.num_experts_per_tok)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for e in range(m.num_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        w = jnp.where(eidx == e, gates, 0.0).sum(-1)
        out = out + ye * w[:, None]
    return out.reshape(b, t, d)


def test_dropless_matches_dense_reference():
    cfg = _cfg(e=4, k=2, cf=16.0)  # capacity high enough: no drops
    p = materialize(moe_params(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    got, aux = moe_ffn(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    assert np.abs(np.asarray(got - want)).max() < 1e-4
    assert float(aux) >= 0


@settings(max_examples=20, deadline=None)
@given(
    e=st.sampled_from([2, 4, 8]), k=st.integers(1, 3),
    cf=st.floats(0.5, 4.0), seed=st.integers(0, 100),
)
def test_dispatch_invariants(e, k, cf, seed):
    k = min(k, e)
    cfg = _cfg(e=e, k=k, cf=cf)
    p = materialize(moe_params(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))
    assert np.isfinite(float(aux))


def test_capacity_drops_reduce_output():
    """With capacity 0-ish, nearly all tokens are dropped -> output ~ 0
    (plus shared experts if any)."""
    cfg = _cfg(e=8, k=2, cf=0.01)
    p = materialize(moe_params(cfg), jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model), jnp.float32)
    out, _ = moe_ffn(p, x, cfg)
    dense = _dense_reference(p, x, cfg)
    assert float(jnp.abs(out).mean()) < float(jnp.abs(dense).mean())


def test_deepseek_shared_experts_present():
    rc = reduced(get_config("deepseek-v2-lite-16b"))
    p = moe_params(rc)
    assert "shared" in p
    assert rc.moe.dense_layers == (0,)
